"""Mixture-of-Experts layer on the diffusive message substrate.

Token dispatch here is literally the paper's operon pattern (DESIGN.md §3):
a token is a message whose destination is an expert; the router predicate
decides whether work is generated; tokens are *coalesced per destination*
(sort by expert id) and the grouped GEMM (``jax.lax.ragged_dot`` —
MegaBlocks-style, dropless) does per-destination compute.

Distribution: the layer is wrapped in shard_map by the dist layer — tokens
stay resident on their data shard (sort is local), expert weights are
tensor-sharded on d_ff over the model axis, and a single psum after the
down-projection completes the layer.  No [T, E, C] one-hot dispatch tensor
is ever materialized (that costs more MXU FLOPs than the experts
themselves — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import ACTIVATIONS, dense_init

__all__ = ["MoEConfig", "init_moe", "moe_ffn", "router_aux_loss"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2
    act: str = "silu"
    capacity_factor: float = 1.25
    impl: str = "sliced"     # 'sliced' (capacity grouped-GEMM) | 'ragged'


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    e, f = cfg.n_experts, cfg.d_ff
    return {
        "router": dense_init(ks[0], (d_model, e), 0, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d_model, f), 1, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d_model, f), 1, dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d_model), 1, dtype=dtype),
    }


def moe_ffn(params, x, cfg: MoEConfig):
    """x [T, d] -> (y [T, d] partial-sum over d_ff shards, aux dict).

    Caller psums y over the tensor axis when w_* are d_ff-sharded.
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    act = ACTIVATIONS[cfg.act]

    logits = (x.astype(jnp.float32) @ params["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # --- operon coalescing: sort the T*k (token, expert) messages by dest
    flat_expert = expert_idx.reshape(-1)                         # [T*k]
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)
    tok_s = flat_token[order]
    gate_s = flat_gate[order]
    group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)
    xs = x[tok_s]                                                # [T*k, d]

    if cfg.impl == "ragged":
        # MegaBlocks-style grouped GEMM. NOTE: XLA currently lowers
        # ragged_dot densely (E x M x F) off-TPU — see EXPERIMENTS.md §Perf.
        h = act(jax.lax.ragged_dot(xs, params["w_gate"], group_sizes))
        h = h * jax.lax.ragged_dot(xs, params["w_up"], group_sizes)
        y = jax.lax.ragged_dot(
            h.astype(x.dtype), params["w_down"], group_sizes
        )                                                        # [T*k, d]
        y = y * gate_s[:, None].astype(y.dtype)
        out = jax.ops.segment_sum(y, tok_s, num_segments=t)
    else:
        # capacity-sliced grouped GEMM: per expert, one dense [C, d] x
        # [d, f] MXU matmul on a dynamic slice of the sorted token stream.
        # FLOPs = capacity_factor x ideal; no [T, E, C] one-hot tensor.
        cap = int(cfg.capacity_factor * t * k / e)
        cap = max(128, -(-cap // 128) * 128)                     # MXU align
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes)[:-1]]
        )
        xs_pad = jnp.pad(xs, ((0, cap), (0, 0)))
        gate_pad = jnp.pad(gate_s, (0, cap)).astype(x.dtype)
        tok_pad = jnp.pad(tok_s, (0, cap), constant_values=t)
        d = x.shape[-1]
        rows = jnp.arange(cap)
        ys, row_tok = [], []
        for ei in range(e):
            start = offsets[ei]
            xe = jax.lax.dynamic_slice(xs_pad, (start, 0), (cap, d))
            ge = jax.lax.dynamic_slice(gate_pad, (start,), (cap,))
            te = jax.lax.dynamic_slice(tok_pad, (start,), (cap,))
            keep = rows < group_sizes[ei]
            he = act(xe @ params["w_gate"][ei]) * (xe @ params["w_up"][ei])
            ye = (he @ params["w_down"][ei]) * (ge * keep)[:, None]
            ys.append(ye.astype(x.dtype))
            row_tok.append(jnp.where(keep, te, t))   # t => dropped row
        # one scatter for all experts — no read-modify-write chain, so the
        # transpose is a single gather (vs E chained add_any cotangents)
        stack = jnp.concatenate(ys, axis=0)               # [E*cap, d]
        idx = jnp.concatenate(row_tok, axis=0)
        out = jax.ops.segment_sum(stack, idx, num_segments=t + 1)[:t]

    aux = {
        "router_probs_mean": probs.mean(0),                      # [E]
        "router_frac": jnp.zeros((e,), jnp.float32).at[flat_expert].add(
            1.0 / (t * k)
        ),
        "router_z": jnp.square(
            jax.scipy.special.logsumexp(logits, axis=-1)
        ).mean(),
    }
    return out.astype(x.dtype), aux


def router_aux_loss(aux, cfg: MoEConfig):
    """GShard load-balance loss + router z-loss from accumulated stats."""
    lb = cfg.n_experts * jnp.sum(
        aux["router_probs_mean"] * aux["router_frac"]
    )
    return cfg.load_balance_coef * lb + cfg.router_z_coef * aux["router_z"]
