"""MeshGraphNet (arXiv:2010.03409): encode-process-decode with residual
edge/node MLP message passing (15 steps, d=128, 2-layer MLPs + LayerNorm).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import GraphBatch, mlp_init, mlp_apply

__all__ = ["MeshGraphNetConfig", "init_params", "apply", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class MeshGraphNetConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 8
    d_edge_in: int = 4       # e.g. relative pos + norm
    d_out: int = 3           # e.g. predicted acceleration
    dtype: object = jnp.float32


def _mlp_dims(cfg, d_in, d_out=None):
    return (d_in,) + (cfg.d_hidden,) * cfg.mlp_layers + (
        d_out or cfg.d_hidden,
    )


def init_params(key, cfg: MeshGraphNetConfig):
    ks = jax.random.split(key, cfg.n_layers + 3)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[i], 2)
        layers.append({
            "edge_mlp": mlp_init(lk[0], _mlp_dims(cfg, 3 * d), dtype=cfg.dtype),
            "node_mlp": mlp_init(lk[1], _mlp_dims(cfg, 2 * d), dtype=cfg.dtype),
        })
    layers = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "node_enc": mlp_init(ks[-3], _mlp_dims(cfg, cfg.d_node_in),
                             dtype=cfg.dtype),
        "edge_enc": mlp_init(ks[-2], _mlp_dims(cfg, cfg.d_edge_in),
                             dtype=cfg.dtype),
        "decoder": mlp_init(ks[-1], _mlp_dims(cfg, d, cfg.d_out),
                            dtype=cfg.dtype),
        "layers": layers,
    }


def apply(params, batch: GraphBatch, cfg: MeshGraphNetConfig):
    n = batch.n_nodes
    snd, rcv = batch.senders, batch.receivers
    emask = batch.edge_mask
    rcv_safe = jnp.where(emask, rcv, n) if emask is not None else rcv

    h = mlp_apply(params["node_enc"], batch.nodes.astype(cfg.dtype),
                  norm_final=True)
    e_in = (
        batch.edges
        if batch.edges is not None
        else jnp.ones((snd.shape[0], cfg.d_edge_in), cfg.dtype)
    )
    e = mlp_apply(params["edge_enc"], e_in.astype(cfg.dtype), norm_final=True)

    def body(carry, p):
        h, e = carry
        msg_in = jnp.concatenate([e, h[snd], h[rcv]], axis=-1)
        e = e + mlp_apply(p["edge_mlp"], msg_in, norm_final=True)
        agg_in = jnp.where(emask[:, None], e, 0) if emask is not None else e
        agg = jax.ops.segment_sum(agg_in, rcv_safe, num_segments=n + 1)[:n]
        h = h + mlp_apply(
            p["node_mlp"], jnp.concatenate([h, agg], axis=-1), norm_final=True
        )
        return (h, e), None

    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    return mlp_apply(params["decoder"], h)


def loss_fn(params, batch: GraphBatch, cfg: MeshGraphNetConfig):
    pred = apply(params, batch, cfg)
    err = jnp.square(pred - batch.labels.astype(pred.dtype)).sum(-1)
    if batch.node_mask is not None:
        err = jnp.where(batch.node_mask, err, 0)
        return err.sum() / jnp.maximum(batch.node_mask.sum(), 1)
    return err.mean()
