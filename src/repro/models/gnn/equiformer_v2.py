"""EquiformerV2 (arXiv:2306.12059): equivariant graph attention with
eSCN-style SO(2) convolutions (l_max=6, m_max=2, 8 heads, 12 blocks).

TPU adaptations (DESIGN.md §2):

* eSCN rotation trick — per-edge Wigner alignment turns the O(L^6) tensor
  product into per-m SO(2) mixes (equivariant.py).
* **Channel-grouped (block-diagonal) mixing** (``channel_groups``): with
  groups == the tensor-axis size, a channel shard never communicates.
* **Edge streaming** (``edge_chunks``): edges flow through the layer in
  chunks with an online-softmax (flash-attention) recurrence, so peak edge
  memory is O(E / chunks).
* **SPMD edge routing** (``spmd_edges``): the aggregation runs under
  shard_map — each device owns an edge shard + a channel shard, scatters
  locally into a full-node partial accumulator, and one
  pmax/psum-combine per layer merges the per-device online-softmax states.
  This is the diffusive-operon pattern: compute moves to where the edges
  live, partial results merge once per round, replacing GSPMD's
  replicate-and-all-reduce fallback (measured in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from ...dist.sharding import current_context, logical_constraint
from ..common import dense_init
from .common import GraphBatch, edge_softmax_agg, mlp_init, mlp_apply
from .equivariant import (
    bessel_basis,
    irrep_slices,
    n_sph,
    poly_cutoff,
    wigner_blocks,
    rotate_irreps,
)

__all__ = ["EquiformerV2Config", "init_params", "apply", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128           # channels per irrep component
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 8
    r_cut: float = 5.0
    n_species: int = 10
    d_out: int = 1
    dtype: object = jnp.float32
    edge_chunks: int = 1          # >1: stream edges, online-softmax agg
    remat: bool = False           # checkpoint each block (big graphs)
    channel_groups: int = 1       # block-diag channel mixing (TPU scaling)
    spmd_edges: bool = False      # shard_map operon-routed aggregation


def _m_layout(l_max, m_max):
    pos = {m: [] for m in range(0, m_max + 1)}
    neg = {m: [] for m in range(1, m_max + 1)}
    for l in range(l_max + 1):
        base = l * l + l
        pos[0].append(base)
        for m in range(1, min(l, m_max) + 1):
            pos[m].append(base + m)
            neg[m].append(base - m)
    return pos, neg


def init_params(key, cfg: EquiformerV2Config):
    c = cfg.d_hidden
    g = cfg.channel_groups
    assert c % g == 0 and c % cfg.n_heads == 0
    cg = c // g
    pos, neg = _m_layout(cfg.l_max, cfg.m_max)
    n0 = len(pos[0])
    ks = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    for t in range(cfg.n_layers):
        lk = jax.random.split(ks[t], 8 + 2 * (cfg.m_max + 1))
        so2 = {
            "w0": dense_init(lk[0], (g, 2 * n0 * cg, n0 * cg), 1,
                             dtype=cfg.dtype)
        }
        for m in range(1, cfg.m_max + 1):
            nm = len(pos[m])
            so2[f"w{m}_r"] = dense_init(
                lk[2 * m], (g, 2 * nm * cg, nm * cg), 1, dtype=cfg.dtype
            )
            so2[f"w{m}_i"] = dense_init(
                lk[2 * m + 1], (g, 2 * nm * cg, nm * cg), 1, dtype=cfg.dtype
            )
        layers.append({
            "so2": so2,
            # radial MLP: final layer emits C channels (channel-shardable)
            "radial": mlp_init(lk[-6], (cfg.n_rbf, 64, c), dtype=cfg.dtype),
            # attention logits: per-group partial contraction + combine
            "alpha_w1": dense_init(lk[-5], (g, (n0 + 1) * cg, 64), 1,
                                   dtype=cfg.dtype),
            "alpha_b1": jnp.zeros((64,), cfg.dtype),
            "alpha_w2": dense_init(lk[-4], (64, cfg.n_heads), 0,
                                   dtype=cfg.dtype),
            "ffn_gate": {
                "w1": dense_init(lk[-3], (g, cg, cg), 1, dtype=cfg.dtype),
                "w2": dense_init(jax.random.fold_in(lk[-3], 1),
                                 (c, cfg.l_max + 1), 0, dtype=cfg.dtype),
            },
            "ffn_scalar": {
                "w1": dense_init(lk[-2], (g, cg, 2 * cg), 1,
                                 dtype=cfg.dtype),
                "w2": dense_init(jax.random.fold_in(lk[-2], 1),
                                 (g, 2 * cg, cg), 1, dtype=cfg.dtype),
            },
            "w_out": dense_init(lk[-1], (g, cg, cg), 1, dtype=cfg.dtype),
        })
    return {
        "embed": dense_init(ks[-2], (cfg.n_species, c), 0, dtype=cfg.dtype)
        * 3.0,
        "head": mlp_init(ks[-1], (c, c, cfg.d_out), dtype=cfg.dtype),
        "layers": layers,
    }


def _grouped(x, g):
    """[E, n, C] -> [E, g, n*Cg]."""
    e, n, c = x.shape
    return x.reshape(e, n, g, c // g).transpose(0, 2, 1, 3).reshape(
        e, g, n * (c // g)
    )


def _ungrouped(y, g, n, c):
    e = y.shape[0]
    return y.reshape(e, g, n, c // g).transpose(0, 2, 1, 3).reshape(e, n, c)


def _so2_conv(p, x_src, x_dst, pos, neg, m_max, g):
    c = x_src.shape[-1]
    out = jnp.zeros_like(x_src)
    idx0 = jnp.asarray(pos[0])
    n0 = len(pos[0])
    f0 = jnp.concatenate(
        [_grouped(x_src[:, idx0, :], g), _grouped(x_dst[:, idx0, :], g)],
        axis=-1,
    )
    y0 = jnp.einsum("egi,gio->ego", f0, p["w0"])
    out = out.at[:, idx0, :].set(_ungrouped(y0, g, n0, c))
    for m in range(1, m_max + 1):
        ip, im = jnp.asarray(pos[m]), jnp.asarray(neg[m])
        nm = len(pos[m])
        xp_ = jnp.concatenate(
            [_grouped(x_src[:, ip, :], g), _grouped(x_dst[:, ip, :], g)],
            axis=-1,
        )
        xm_ = jnp.concatenate(
            [_grouped(x_src[:, im, :], g), _grouped(x_dst[:, im, :], g)],
            axis=-1,
        )
        yp = (jnp.einsum("egi,gio->ego", xp_, p[f"w{m}_r"])
              - jnp.einsum("egi,gio->ego", xm_, p[f"w{m}_i"]))
        ym = (jnp.einsum("egi,gio->ego", xp_, p[f"w{m}_i"])
              + jnp.einsum("egi,gio->ego", xm_, p[f"w{m}_r"]))
        out = out.at[:, ip, :].set(_ungrouped(yp, g, nm, c))
        out = out.at[:, im, :].set(_ungrouped(ym, g, nm, c))
    return out


def _layer_params_local(p, g_local):
    """Slice of per-layer params for a channel shard (g_local groups)."""
    return p  # shard_map in_specs do the slicing; helper kept for clarity


def _edge_messages(p, x, snd_c, rcv_c, vec_c, emask_c, cfg, g, psum_axis=None):
    """Per-edge-chunk messages on (possibly channel-local) features.

    Returns (logits [Ec,H] f32, vals [Ec, nsph, C_local] f32 rotated back,
    geom_ok mask)."""
    pos, neg = _m_layout(cfg.l_max, cfg.m_max)
    r = jnp.linalg.norm(vec_c, axis=-1)
    geom_ok = (r > 1e-6) & emask_c
    rbf = (bessel_basis(r, cfg.n_rbf, cfg.r_cut)
           * poly_cutoff(r, cfg.r_cut)[..., None]).astype(cfg.dtype)
    D = wigner_blocks(cfg.l_max, vec_c)
    x_src = rotate_irreps(x[snd_c], D, cfg.l_max)
    x_dst = rotate_irreps(x[rcv_c], D, cfg.l_max)
    radial = mlp_apply(p["radial"], rbf)                   # [Ec, C_local]
    msg = _so2_conv(p["so2"], x_src, x_dst, pos, neg, cfg.m_max, g)
    msg = msg * radial[:, None, :]
    # attention logits: per-group partial + (optional cross-shard) combine
    idx0 = jnp.asarray(pos[0])
    inv = jnp.concatenate([msg[:, idx0, :], radial[:, None, :]], axis=1)
    inv_g = _grouped(inv, g)                               # [Ec,g,(n0+1)cg]
    part = jnp.einsum("egi,gio->eo", inv_g, p["alpha_w1"])
    if psum_axis is not None:
        part = lax.psum(part, psum_axis)
    hidden = jax.nn.silu(part + p["alpha_b1"])
    logits = (hidden @ p["alpha_w2"]).astype(jnp.float32)
    logits = jnp.where(geom_ok[:, None], logits, -jnp.inf)
    vals = rotate_irreps(msg, D, cfg.l_max, inverse=True).astype(jnp.float32)
    return logits, vals, geom_ok


def _heads_split(vals, h):
    """[E, nsph, C] -> [E, H, nsph*(C/H)]."""
    e, ns, c = vals.shape
    return vals.reshape(e, ns, h, c // h).transpose(0, 2, 1, 3).reshape(
        e, h, ns * (c // h)
    )


def _heads_merge(agg, h, ns, c):
    n = agg.shape[0]
    return agg.reshape(n, h, ns, c // h).transpose(0, 2, 1, 3).reshape(
        n, ns, c
    )


def _chunk_scan(p, x, snd, rcv, vec, emask, cfg, g, n, nch, psum_axis=None):
    """Online-softmax edge streaming; returns per-shard (m, l, acc)."""
    e = snd.shape[0]
    c_local = x.shape[-1]
    assert c_local % cfg.n_heads == 0, (
        "channel shard must keep whole heads (C/shards % n_heads == 0)"
    )
    h_eff = cfg.n_heads
    k_ = n_sph(cfg.l_max) * (c_local // h_eff)
    ec = e // nch
    xs = (snd.reshape(nch, ec), rcv.reshape(nch, ec),
          vec.reshape(nch, ec, 3), emask.reshape(nch, ec))

    def body(carry, inp):
        m, l, acc = carry
        snd_c, rcv_c, vec_c, em_c = inp
        logits, vals, ok = _edge_messages(p, x, snd_c, rcv_c, vec_c, em_c,
                                          cfg, g, psum_axis)
        vals = _heads_split(vals, h_eff)
        rcv_s = jnp.where(ok, rcv_c, n)
        # softmax shift: stability-only, gradient-neutral => stop_gradient
        m_chunk = lax.stop_gradient(
            jax.ops.segment_max(logits, rcv_s, num_segments=n + 1)[:n]
        )
        m_new = jnp.maximum(m, m_chunk)
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        scale = jnp.exp(jnp.where(jnp.isneginf(m), -jnp.inf, m - m_safe))
        w = jnp.exp(logits - m_safe[rcv_s.clip(0, n - 1)])
        w = jnp.where(ok[:, None], w, 0.0)
        l = l * scale + jax.ops.segment_sum(w, rcv_s, num_segments=n + 1)[:n]
        acc = acc * scale[..., None] + jax.ops.segment_sum(
            w[..., None] * vals, rcv_s, num_segments=n + 1
        )[:n]
        return (m_new, l, acc), None

    m0 = jnp.full((n, h_eff), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((n, h_eff), jnp.float32)
    acc0 = jnp.zeros((n, h_eff, k_), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, acc0), xs)
    return m, l, acc, h_eff



def _zero_tan(a):
    import numpy as _np
    return _np.zeros(a.shape, jax.dtypes.float0)


def _heads_split_nodes(a, h):
    """[N, ns, C] -> [N, H, ns*(C/H)] (node-side twin of _heads_split)."""
    n, ns, c = a.shape
    return a.reshape(n, ns, h, c // h).transpose(0, 2, 1, 3).reshape(
        n, h, ns * (c // h)
    )


def _make_spmd_agg(cfg, mesh, data_axes, model_axis, layer_specs, n, nch,
                   g_local):
    """Receiver-partitioned SPMD graph attention (custom VJP at pjit level).

    Contract: the edge arrays are partitioned so device d's shard only
    contains edges whose RECEIVER lies in node block d (the diffusive
    partitioning from core/partition.py, applied at data ingest).  Then:

    * every node's softmax lives on exactly one device — no cross-shard
      softmax combine at all;
    * the scatter is local; accumulators are node-block sized;
    * only the sender table x is replicated (one all-gather per layer,
      transient); residuals saved for backward are all node-SHARDED
      (lse + agg per block), so backward re-gathers x but never stores a
      full-node tensor across layers.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    h = cfg.n_heads
    ns = n_sph(cfg.l_max)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    block = n // n_data
    mspec = model_axis if model_axis else None
    espec = P(data_axes)

    def _offset():
        idx = jnp.zeros((), jnp.int32)
        for a in data_axes:
            idx = idx * mesh.shape[a] + lax.axis_index(a)
        return idx * block

    def fwd_body(pl, x_full, snd, rcv, vec, emask):
        off = _offset()
        rcv_l = rcv - off
        ok0 = (rcv_l >= 0) & (rcv_l < block) & emask
        rcv_l = jnp.clip(rcv_l, 0, block - 1)
        m, l, acc, h_eff = _chunk_scan(
            pl, x_full, snd, rcv_l, vec, ok0, cfg, g_local, block, nch,
            psum_axis=model_axis,
        )
        shift = jnp.where(jnp.isneginf(m), 0.0, m)
        l = jnp.maximum(l, 1e-20)
        agg = acc / l[..., None]                       # [block, H, K]
        lse = shift + jnp.log(l)
        return _heads_merge(agg, h_eff, ns, x_full.shape[-1]), lse

    fwd_sm = shard_map(
        fwd_body, mesh=mesh,
        in_specs=(layer_specs, P(None, None, mspec), espec, espec,
                  P(data_axes, None), espec),
        out_specs=(P(data_axes, None, mspec), P(data_axes, None)),
        check_rep=False,
    )

    def bwd_body(pl, x_full, snd, rcv, vec, emask, lse, agg_l, d_agg_l):
        off = _offset()
        rcv_l0 = rcv - off
        ok0 = (rcv_l0 >= 0) & (rcv_l0 < block) & emask
        rcv_l = jnp.clip(rcv_l0, 0, block - 1)
        e_l = snd.shape[0]
        ec = e_l // nch
        c_local = x_full.shape[-1]
        agg_h = _heads_split_nodes(agg_l.astype(jnp.float32), h)
        d_agg_h = _heads_split_nodes(d_agg_l.astype(jnp.float32), h)
        delta = (agg_h * d_agg_h).sum(-1)              # [block, H]
        xs = (snd.reshape(nch, ec), rcv_l.reshape(nch, ec),
              vec.reshape(nch, ec, 3), ok0.reshape(nch, ec))
        dp0 = jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, jnp.float32), pl)
        dx0 = jnp.zeros(x_full.shape, jnp.float32)

        def body(carry, inp):
            dp, dx = carry
            snd_c, rcv_c, vec_c, ok_c = inp

            def fwd_chunk(p_, x_, vec_):
                lo, va, _ok = _edge_messages(
                    p_, x_, snd_c, rcv_c, vec_, ok_c, cfg, g_local,
                    model_axis,
                )
                return lo, va

            (logits, vals), vjp = jax.vjp(fwd_chunk, pl, x_full, vec_c)
            valid = jnp.isfinite(logits[:, 0])
            w = jnp.exp(logits - lse[rcv_c])
            w = jnp.where(valid[:, None], w, 0.0)
            vals_h = _heads_split(vals, h)
            dyr = d_agg_h[rcv_c]
            d_vals_h = jnp.where(valid[:, None, None],
                                 w[..., None] * dyr, 0.0)
            d_logits = jnp.where(
                valid[:, None],
                w * ((vals_h * dyr).sum(-1) - delta[rcv_c]), 0.0)
            d_vals = d_vals_h.reshape(ec, h, ns, c_local // h).transpose(
                0, 2, 1, 3).reshape(ec, ns, c_local)
            dpc, dxc, dvecc = vjp((d_logits, d_vals))
            dp = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), dp, dpc)
            return (dp, dx + dxc.astype(jnp.float32)), \
                dvecc.astype(jnp.float32)

        (dp, dx), dvecs = lax.scan(body, (dp0, dx0), xs)
        # edge shards each produced partial param/node cotangents
        dp = lax.psum(dp, data_axes)
        dx = lax.psum(dx, data_axes)
        dp = jax.tree_util.tree_map(lambda a, b: a.astype(b.dtype), dp, pl)
        return dp, dx, dvecs.reshape(e_l, 3)

    bwd_sm = shard_map(
        bwd_body, mesh=mesh,
        in_specs=(layer_specs, P(None, None, mspec), espec, espec,
                  P(data_axes, None), espec, P(data_axes, None),
                  P(data_axes, None, mspec), P(data_axes, None, mspec)),
        out_specs=(layer_specs, P(None, None, mspec), P(data_axes, None)),
        check_rep=False,
    )

    @jax.custom_vjp
    def agg_fn(p, x, snd, rcv, vec, emask):
        return fwd_sm(p, x, snd, rcv, vec, emask)[0]

    def fwd(p, x, snd, rcv, vec, emask):
        agg, lse = fwd_sm(p, x, snd, rcv, vec, emask)
        return agg, (p, x, snd, rcv, vec, emask, lse, agg)

    def bwd(res, d_agg):
        p, x, snd, rcv, vec, emask, lse, agg = res
        dp, dx, dvec = bwd_sm(p, x, snd, rcv, vec, emask, lse, agg, d_agg)
        return (dp, dx.astype(x.dtype), _zero_tan(snd), _zero_tan(rcv),
                dvec.astype(vec.dtype), _zero_tan(emask))

    agg_fn.defvjp(fwd, bwd)
    return agg_fn


def _attention_agg(p, x, batch, cfg):
    """Returns agg [N, nsph, C(-local)] (softmax-weighted messages)."""
    n = batch.n_nodes
    snd, rcv = batch.senders, batch.receivers
    e = snd.shape[0]
    emask = (batch.edge_mask if batch.edge_mask is not None
             else jnp.ones((e,), bool))
    vec = batch.positions[rcv] - batch.positions[snd]
    g = cfg.channel_groups
    nch = max(cfg.edge_chunks, 1)
    c = cfg.d_hidden
    ns = n_sph(cfg.l_max)

    ctx = current_context()
    if cfg.spmd_edges and ctx is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = ctx["mesh"]
        rules = ctx["rules"]
        data_axes = rules.get("edges") or ("data",)
        model_axis = rules.get("channels")
        n_data = 1
        for a in data_axes:
            n_data *= mesh.shape[a]
        n_model = mesh.shape[model_axis] if model_axis else 1
        g_local = max(1, g // n_model)

        mspec = model_axis if model_axis else None
        layer_specs = jax.tree_util.tree_map(lambda _: P(), p)
        # channel-sharded leaves: group-dim or channel-dim sharding
        layer_specs = {
            "so2": jax.tree_util.tree_map(lambda _: P(mspec), p["so2"]),
            "radial": [
                {"w": P(None, None), "b": P(None)},
                {"w": P(None, mspec), "b": P(mspec)},
            ],
            "alpha_w1": P(mspec, None, None),
            "alpha_b1": P(None),
            "alpha_w2": P(None, None),
            "ffn_gate": {"w1": P(mspec, None, None), "w2": P(mspec, None)},
            "ffn_scalar": {"w1": P(mspec, None, None),
                           "w2": P(mspec, None, None)},
            "w_out": P(mspec, None, None),
        }
        agg_fn = _make_spmd_agg(cfg, mesh, data_axes, model_axis,
                                layer_specs, n, nch, g_local)
        return agg_fn(p, x, snd, rcv, vec, emask)

    if nch <= 1:
        logits, vals, ok = _edge_messages(p, x, snd, rcv, vec, emask, cfg, g)
        vals = _heads_split(vals, cfg.n_heads)
        agg = edge_softmax_agg(logits, vals, rcv, n, edge_mask=ok)
        return _heads_merge(agg, cfg.n_heads, ns, c)
    # hoist node-table replication out of the chunk scan
    x = logical_constraint(x, None, None, "channels")
    m, l, acc, h_eff = _chunk_scan(p, x, snd, rcv, vec, emask, cfg, g, n,
                                   nch)
    agg = acc / jnp.maximum(l, 1e-20)[..., None]
    return _heads_merge(agg, h_eff, ns, c)


def _eqv_rmsnorm(x, l_max, eps=1e-6):
    outs = []
    for sl in irrep_slices(l_max):
        blk = x[:, sl, :]
        nrm = jnp.sqrt(jnp.mean(jnp.square(blk), axis=(1, 2),
                                keepdims=True) + eps)
        outs.append(blk / nrm)
    return jnp.concatenate(outs, axis=1)


def _block(p, x, batch, cfg):
    n = batch.n_nodes
    c = cfg.d_hidden
    g = cfg.channel_groups
    ns = n_sph(cfg.l_max)
    agg = _attention_agg(p, x, batch, cfg)                  # [N, ns, C]
    aggd = agg.astype(cfg.dtype).reshape(n, ns, g, c // g)
    x = x + jnp.einsum("nagk,gkm->nagm", aggd, p["w_out"]).reshape(n, ns, c)
    x = _eqv_rmsnorm(x, cfg.l_max).astype(cfg.dtype)
    x = logical_constraint(x, "nodes", None, "channels")
    # gated feed-forward (block-diag over channel groups)
    s = x[:, 0, :]
    sg = s.reshape(n, g, c // g)
    gate_h = jax.nn.silu(
        jnp.einsum("ngk,gkm->ngm", sg, p["ffn_gate"]["w1"]).reshape(n, c)
    )
    gate = jax.nn.sigmoid(gate_h @ p["ffn_gate"]["w2"])     # [N, L+1]
    hid = jax.nn.silu(jnp.einsum("ngk,gkm->ngm", sg, p["ffn_scalar"]["w1"]))
    s_out = s + jnp.einsum("ngk,gkm->ngm", hid,
                           p["ffn_scalar"]["w2"]).reshape(n, c)
    outs = [s_out[:, None, :]]
    for l, sl in enumerate(irrep_slices(cfg.l_max)):
        if l == 0:
            continue
        outs.append(x[:, sl, :] * gate[:, l, None, None])
    return jnp.concatenate(outs, axis=1)


def apply(params, batch: GraphBatch, cfg: EquiformerV2Config):
    n = batch.n_nodes
    c = cfg.d_hidden
    x = jnp.zeros((n, n_sph(cfg.l_max), c), cfg.dtype)
    x = x.at[:, 0, :].set(params["embed"][batch.species])
    x = logical_constraint(x, "nodes", None, "channels")

    block = _block
    if cfg.remat:
        block = jax.checkpoint(_block, static_argnums=(3,),
                               prevent_cse=False)
    for p in params["layers"]:
        x = block(p, x, batch, cfg)

    scalars = x[:, 0, :]
    out = mlp_apply(params["head"], scalars)                # [N, d_out]
    if batch.node_mask is not None:
        out = jnp.where(batch.node_mask[:, None], out, 0)
    return out


def loss_fn(params, batch: GraphBatch, cfg: EquiformerV2Config):
    pred = apply(params, batch, cfg)
    if batch.labels.ndim == 1 and cfg.d_out > 1:
        logp = jax.nn.log_softmax(pred.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, batch.labels[:, None], -1)[:, 0]
        if batch.node_mask is not None:
            nll = jnp.where(batch.node_mask, nll, 0)
            return nll.sum() / jnp.maximum(batch.node_mask.sum(), 1)
        return nll.mean()
    gids = batch.graph_ids if batch.graph_ids is not None else jnp.zeros(
        (batch.n_nodes,), jnp.int32
    )
    pooled = jax.ops.segment_sum(
        pred[:, 0].astype(jnp.float32), gids, num_segments=batch.n_graphs
    )
    return jnp.mean(jnp.square(pooled - batch.labels.astype(jnp.float32)))
