"""Equivariant building blocks: real spherical harmonics, Wigner rotations,
and Clebsch-Gordan couplings — all derived *numerically* from the harmonics
themselves, so every tensor is convention-consistent by construction (and
cross-validated by the equivariance tests).

Key pieces:

* :func:`sph_harm` — real spherical harmonics up to l_max (JAX, recurrence).
* :func:`wigner_blocks` — per-edge Wigner-D block matrices for the rotation
  aligning each edge with +z, via the Euler/J-matrix factorization
  ``D(Q) = K · Xz(−θ) · Kᵀ · Xz(−φ)`` where ``K = D(Rx(−π/2))`` is a fixed
  numerical constant per l (the e3nn trick, rederived by least squares).
  This is what makes eSCN's O(L³) SO(2) convolution possible on TPU: the
  only per-edge dense math is block-diagonal (2l+1)-sized matmuls.
* :func:`cg_coupling` — real CG intertwiner for (l1 ⊗ l2 → l3), computed by
  projecting onto the rotation-fixed subspace of D3ᵀ·(D1 ⊗ D2) averaged over
  random rotations (unique up to scale; learnable path weights absorb it).
* Radial bases: Bessel + polynomial cutoff (MACE/NequIP standard).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "n_sph", "sph_harm", "sph_harm_np", "wigner_K", "wigner_blocks",
    "rotate_irreps", "cg_coupling", "bessel_basis", "poly_cutoff",
    "irrep_slices",
]


def n_sph(l_max: int) -> int:
    return (l_max + 1) ** 2


def irrep_slices(l_max: int):
    return [slice(l * l, (l + 1) * (l + 1)) for l in range(l_max + 1)]


# ---------------------------------------------------------------------------
# Real spherical harmonics (orthonormal), index layout m = -l..l at l^2+l+m
# ---------------------------------------------------------------------------

def _sph_impl(l_max: int, xyz, xp):
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    r = xp.sqrt(xp.maximum(x * x + y * y + z * z, 1e-20))
    x, y, z = x / r, y / r, z / r
    rxy = xp.sqrt(xp.maximum(x * x + y * y, 1e-20))
    # cos(m phi), sin(m phi) by recurrence (phase from x, y)
    cphi = x / xp.maximum(rxy, 1e-20)
    sphi = y / xp.maximum(rxy, 1e-20)
    cos_m = [xp.ones_like(x), cphi]
    sin_m = [xp.zeros_like(x), sphi]
    for m in range(2, l_max + 1):
        c_prev, s_prev = cos_m[-1], sin_m[-1]
        cos_m.append(cphi * c_prev - sphi * s_prev)
        sin_m.append(sphi * c_prev + cphi * s_prev)
    # associated Legendre P_l^m(z) with sin^m factor folded in via rxy^m
    P = {}
    P[(0, 0)] = xp.ones_like(z)
    for m in range(1, l_max + 1):
        P[(m, m)] = -(2 * m - 1) * rxy * P[(m - 1, m - 1)]
    for m in range(0, l_max):
        P[(m + 1, m)] = (2 * m + 1) * z * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = ((2 * l - 1) * z * P[(l - 1, m)]
                         - (l + m - 1) * P[(l - 2, m)]) / (l - m)
    out = []
    for l in range(l_max + 1):
        row = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            nrm = math.sqrt(
                (2 * l + 1) / (4 * math.pi)
                * math.factorial(l - m) / math.factorial(l + m)
            )
            if m == 0:
                row[l] = nrm * P[(l, 0)]
            else:
                row[l + m] = math.sqrt(2.0) * nrm * P[(l, m)] * cos_m[m]
                row[l - m] = math.sqrt(2.0) * nrm * P[(l, m)] * sin_m[m]
        out.extend(row)
    return xp.stack(out, axis=-1)


def sph_harm(l_max: int, xyz: jnp.ndarray) -> jnp.ndarray:
    """Real SH of unit(ized) vectors. xyz [..., 3] -> [..., (l_max+1)^2]."""
    return _sph_impl(l_max, xyz, jnp)


def sph_harm_np(l_max: int, xyz: np.ndarray) -> np.ndarray:
    return _sph_impl(l_max, np.asarray(xyz, np.float64), np)


# ---------------------------------------------------------------------------
# Wigner-D machinery (numerical, convention-free)
# ---------------------------------------------------------------------------

def _rot_x(a):
    c, s = math.cos(a), math.sin(a)
    return np.array([[1, 0, 0], [0, c, -s], [0, s, c]])


def _d_of_rotation_np(l: int, R: np.ndarray) -> np.ndarray:
    """D_l(R) with Y(Rv) = D Y(v), by least squares over sampled vectors."""
    rng = np.random.default_rng(12345 + l)
    v = rng.normal(size=(8 * (2 * l + 1), 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Y = sph_harm_np(l, v)[:, l * l:(l + 1) * (l + 1)].T       # [2l+1, n]
    YR = sph_harm_np(l, v @ R.T)[:, l * l:(l + 1) * (l + 1)].T
    D, *_ = np.linalg.lstsq(Y.T, YR.T, rcond=None)
    return D.T                                                 # [2l+1, 2l+1]


@functools.lru_cache(maxsize=64)
def wigner_K(l: int) -> np.ndarray:
    """K_l = D_l(Rx(-pi/2)); D(Ry(b)) = K Xz(b) K^T."""
    return _d_of_rotation_np(l, _rot_x(-math.pi / 2))


@functools.lru_cache(maxsize=64)
def _xz_masks(l: int):
    """Constant masks s.t. Xz(g) = I0 + sum_m cos(mg) Cm + sin(mg) Sm."""
    n = 2 * l + 1
    I0 = np.zeros((n, n))
    I0[l, l] = 1.0
    Cs, Ss = [], []
    for m in range(1, l + 1):
        C = np.zeros((n, n))
        S = np.zeros((n, n))
        C[l + m, l + m] = 1.0
        C[l - m, l - m] = 1.0
        S[l + m, l - m] = -1.0
        S[l - m, l + m] = 1.0
        Cs.append(C)
        Ss.append(S)
    if not Cs:
        return I0, np.zeros((0, n, n)), np.zeros((0, n, n))
    return I0, np.stack(Cs), np.stack(Ss)


def _xz(l: int, gamma: jnp.ndarray) -> jnp.ndarray:
    """D_l(Rz(gamma)) for batched angles gamma [...]: [..., 2l+1, 2l+1]."""
    I0, Cm, Sm = _xz_masks(l)
    ms = jnp.arange(1, l + 1, dtype=jnp.float32)
    cos = jnp.cos(gamma[..., None] * ms)      # [..., l]
    sin = jnp.sin(gamma[..., None] * ms)
    out = jnp.asarray(I0)
    out = out + jnp.einsum("...m,mij->...ij", cos, jnp.asarray(Cm))
    out = out + jnp.einsum("...m,mij->...ij", sin, jnp.asarray(Sm))
    return out


def wigner_blocks(l_max: int, directions: jnp.ndarray):
    """Per-edge D_l(Q) with Q·dir = +z, for l = 0..l_max.

    directions [E, 3] (need not be normalized).
    Returns list of [E, 2l+1, 2l+1] arrays.
    """
    d = directions / jnp.maximum(
        jnp.linalg.norm(directions, axis=-1, keepdims=True), 1e-12
    )
    theta = jnp.arccos(jnp.clip(d[..., 2], -1.0, 1.0))
    phi = jnp.arctan2(d[..., 1], d[..., 0])
    blocks = []
    for l in range(l_max + 1):
        if l == 0:
            blocks.append(jnp.ones(d.shape[:-1] + (1, 1), jnp.float32))
            continue
        K = jnp.asarray(wigner_K(l), jnp.float32)
        Dy = K @ _xz(l, -theta) @ K.T          # [E, n, n]
        blocks.append(jnp.einsum("...ij,...jk->...ik", Dy, _xz(l, -phi)))
    return blocks


def rotate_irreps(feats: jnp.ndarray, blocks, l_max: int,
                  inverse: bool = False) -> jnp.ndarray:
    """feats [E, (L+1)^2, C]; apply block-diag D (or D^T)."""
    outs = []
    for l, sl in enumerate(irrep_slices(l_max)):
        D = blocks[l]
        eq = "...ji,...jc->...ic" if inverse else "...ij,...jc->...ic"
        outs.append(jnp.einsum(eq, D, feats[..., sl, :]))
    return jnp.concatenate(outs, axis=-2)


# ---------------------------------------------------------------------------
# Real Clebsch-Gordan couplings by invariant-subspace projection
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def cg_coupling(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """Real coupling C [2l3+1, 2l1+1, 2l2+1] with
    D3(R) C = C (D1(R) ⊗ D2(R)) for all R; None if not triangle-valid."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    n1, n2, n3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    dim = n3 * n1 * n2
    rng = np.random.default_rng(999 + 17 * l1 + 31 * l2 + 53 * l3)
    # Invariant-tensor condition for orthogonal reps: for all R,
    #   sum_ijk D3[ai] D1[bj] D2[ck] C[ijk] = C[abc].
    # Stack (M(R_k) - I) and take the (1-dim) null space.
    rows = []
    for _ in range(8):
        A = rng.normal(size=(3, 3))
        Q, _ = np.linalg.qr(A)
        if np.linalg.det(Q) < 0:
            Q[:, 0] *= -1
        D1 = _d_of_rotation_np(l1, Q)
        D2 = _d_of_rotation_np(l2, Q)
        D3 = _d_of_rotation_np(l3, Q)
        M = np.einsum("ai,bj,ck->abcijk", D3, D1, D2).reshape(dim, dim)
        rows.append(M - np.eye(dim))
    A = np.vstack(rows)
    _, s, vt = np.linalg.svd(A, full_matrices=False)
    if s[-1] > 1e-6:
        return None
    c = vt[-1].reshape(n3, n1, n2)
    return c / np.linalg.norm(c)


# ---------------------------------------------------------------------------
# Radial bases
# ---------------------------------------------------------------------------

def bessel_basis(r: jnp.ndarray, n_rbf: int, r_cut: float) -> jnp.ndarray:
    """e_n(r) = sqrt(2/c) sin(n pi r / c) / r   (DimeNet/MACE standard)."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rs = jnp.maximum(r[..., None], 1e-9)
    return jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * rs / r_cut) / rs


def poly_cutoff(r: jnp.ndarray, r_cut: float, p: int = 6) -> jnp.ndarray:
    """Smooth polynomial cutoff (NequIP)."""
    x = jnp.clip(r / r_cut, 0.0, 1.0)
    return (
        1.0
        - (p + 1) * (p + 2) / 2 * x ** p
        + p * (p + 2) * x ** (p + 1)
        - p * (p + 1) / 2 * x ** (p + 2)
    )
