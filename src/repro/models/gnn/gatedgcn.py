"""GatedGCN (Bresson & Laurent; benchmarked in arXiv:2003.00982).

Per layer (edge j -> i):
    e'_ij = e_ij + ReLU(Norm(A h_i + B h_j + C e_ij))
    eta_ij = sigmoid(e'_ij)
    h'_i  = h_i + ReLU(Norm(U h_i + (sum_j eta_ij * V h_j) /
                                   (sum_j eta_ij + eps)))

Deviation noted in DESIGN.md: BatchNorm -> LayerNorm (graph-sharding safe;
standard in later GatedGCN implementations).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..common import dense_init
from .common import GraphBatch, layernorm_simple, mlp_init, mlp_apply

__all__ = ["GatedGCNConfig", "init_params", "apply", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 1433
    d_edge_in: int = 1
    n_classes: int = 16
    dtype: object = jnp.float32


def init_params(key, cfg: GatedGCNConfig):
    ks = jax.random.split(key, cfg.n_layers + 3)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[i], 5)
        layers.append({
            "A": dense_init(lk[0], (d, d), 0, dtype=cfg.dtype),
            "B": dense_init(lk[1], (d, d), 0, dtype=cfg.dtype),
            "C": dense_init(lk[2], (d, d), 0, dtype=cfg.dtype),
            "U": dense_init(lk[3], (d, d), 0, dtype=cfg.dtype),
            "V": dense_init(lk[4], (d, d), 0, dtype=cfg.dtype),
        })
    # stack for scan
    layers = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "node_enc": dense_init(ks[-3], (cfg.d_in, d), 0, dtype=cfg.dtype),
        "edge_enc": dense_init(ks[-2], (cfg.d_edge_in, d), 0, dtype=cfg.dtype),
        "head": mlp_init(ks[-1], (d, d, cfg.n_classes), dtype=cfg.dtype),
        "layers": layers,
    }


def apply(params, batch: GraphBatch, cfg: GatedGCNConfig):
    n = batch.n_nodes
    snd, rcv = batch.senders, batch.receivers
    h = batch.nodes.astype(cfg.dtype) @ params["node_enc"]
    e_in = (
        batch.edges
        if batch.edges is not None
        else jnp.ones((snd.shape[0], cfg.d_edge_in), cfg.dtype)
    )
    e = e_in.astype(cfg.dtype) @ params["edge_enc"]
    emask = batch.edge_mask
    rcv_safe = jnp.where(emask, rcv, n) if emask is not None else rcv

    def body(carry, p):
        h, e = carry
        hi, hj = h[rcv], h[snd]
        e_hat = hi @ p["A"] + hj @ p["B"] + e @ p["C"]
        e = e + jax.nn.relu(layernorm_simple(e_hat))
        eta = jax.nn.sigmoid(e)
        vh = hj @ p["V"]
        num = jnp.where(emask[:, None], eta * vh, 0) if emask is not None \
            else eta * vh
        den = jnp.where(emask[:, None], eta, 0) if emask is not None else eta
        s_num = jax.ops.segment_sum(num, rcv_safe, num_segments=n + 1)[:n]
        s_den = jax.ops.segment_sum(den, rcv_safe, num_segments=n + 1)[:n]
        h_hat = h @ p["U"] + s_num / (s_den + 1e-6)
        h = h + jax.nn.relu(layernorm_simple(h_hat))
        return (h, e), None

    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    return mlp_apply(params["head"], h)


def loss_fn(params, batch: GraphBatch, cfg: GatedGCNConfig):
    logits = apply(params, batch, cfg)
    labels = batch.labels
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if batch.node_mask is not None:
        nll = jnp.where(batch.node_mask, nll, 0)
        return nll.sum() / jnp.maximum(batch.node_mask.sum(), 1)
    return nll.mean()
