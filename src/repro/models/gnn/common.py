"""GNN substrate: graph batches + message passing on the sparse substrate.

Message passing is the diffusive pattern (DESIGN.md §3): gather sender
state, per-edge compute, segment-reduce at receivers.  ``jax.ops.segment_*``
over an edge-index IS the system's scatter layer (JAX has no sparse-matrix
message passing) — the Pallas segment kernel accelerates the sorted case on
TPU.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..common import dense_init
from ...dist.sharding import logical_constraint

__all__ = ["GraphBatch", "mlp_init", "mlp_apply", "gather_scatter",
           "edge_softmax_agg", "layernorm_simple"]


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Plain container; any field may be None.  Arrays:
    nodes [N, F] | positions [N, 3] | species [N] | edges [E, Fe] |
    senders/receivers [E] | node_mask [N] | edge_mask [E] |
    graph_ids [N] (for batched small graphs) | labels (task-dependent)
    """
    senders: Any
    receivers: Any
    n_nodes: int
    nodes: Any = None
    positions: Any = None
    species: Any = None
    edges: Any = None
    node_mask: Any = None
    edge_mask: Any = None
    graph_ids: Any = None
    n_graphs: int = 1
    labels: Any = None


jax.tree_util.register_dataclass(
    GraphBatch,
    data_fields=["senders", "receivers", "nodes", "positions", "species",
                 "edges", "node_mask", "edge_mask", "graph_ids", "labels"],
    meta_fields=["n_nodes", "n_graphs"],
)


def mlp_init(key, dims, dtype=jnp.float32, final_bias=True):
    ks = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, k in enumerate(ks):
        layers.append({
            "w": dense_init(k, (dims[i], dims[i + 1]), 0, dtype=dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    return layers


def mlp_apply(layers, x, act=jax.nn.silu, final_act=False,
              norm_final: bool = False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    if norm_final:
        x = layernorm_simple(x)
    return x


def layernorm_simple(x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def gather_scatter(values, senders, receivers, n_nodes, edge_fn=None,
                   edge_mask=None, combine="sum"):
    """The message-passing primitive: m_e = edge_fn(x[senders_e]);
    out_i = combine_e->i m_e."""
    msgs = values[senders]
    if edge_fn is not None:
        msgs = edge_fn(msgs)
    if edge_mask is not None:
        msgs = jnp.where(edge_mask[:, None], msgs, 0)
        receivers = jnp.where(edge_mask, receivers, n_nodes)
    msgs = logical_constraint(msgs, "edges", None)
    if combine == "sum":
        out = jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes + 1)
    elif combine == "mean":
        out = jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes + 1)
        cnt = jax.ops.segment_sum(
            jnp.ones_like(receivers, msgs.dtype), receivers,
            num_segments=n_nodes + 1,
        )
        out = out / jnp.maximum(cnt, 1)[:, None]
    elif combine == "max":
        out = jax.ops.segment_max(msgs, receivers, num_segments=n_nodes + 1)
    else:
        raise ValueError(combine)
    return out[:n_nodes]


def edge_softmax_agg(logits, values, receivers, n_nodes, edge_mask=None):
    """GAT-style: softmax(logits) within each receiver, weighted sum.

    logits [E, H]; values [E, H, C]; returns [N, H, C]."""
    if edge_mask is not None:
        em = edge_mask.reshape(edge_mask.shape + (1,) * (logits.ndim - 1))
        logits = jnp.where(em, logits, -jnp.inf)
        receivers = jnp.where(edge_mask, receivers, n_nodes)
    mx = jax.ops.segment_max(logits, receivers, num_segments=n_nodes + 1)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(logits - mx[receivers])
    if edge_mask is not None:
        em = edge_mask.reshape(edge_mask.shape + (1,) * (logits.ndim - 1))
        ex = jnp.where(em, ex, 0.0)
    den = jax.ops.segment_sum(ex, receivers, num_segments=n_nodes + 1)
    w = ex / jnp.maximum(den[receivers], 1e-16)
    out = jax.ops.segment_sum(
        values * w[..., None], receivers, num_segments=n_nodes + 1
    )
    return out[:n_nodes]
