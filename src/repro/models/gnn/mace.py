"""MACE (arXiv:2206.07697): higher-order equivariant message passing.

Structure per interaction layer (l_max=2, correlation order 3, n_rbf=8):

1. Edge basis: phi_ij = R_path(r_ij) * Y_l2(r_hat_ij), Bessel radial + cutoff.
2. A-basis (one-particle): A_i^{l3} = sum_j sum_paths W CG(h_j^{l1}, phi^{l2})
3. B-basis (higher order, ACE): nu=1: A; nu=2: CG(A, A); nu=3: CG(CG(A,A), A)
   — symmetric contractions with learnable path weights, all l <= l_max.
4. Message m_i = sum_nu W_nu B_i^(nu);  update h' = Linear(m) + Res(h).
5. Site energy readout from invariants (l=0) per layer; total = sum.

Features are uniform-multiplicity irreps: h [N, (l_max+1)^2, C].
CG tensors come from equivariant.cg_coupling (numerically exact).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ...dist.sharding import logical_constraint
from ..common import dense_init
from .common import GraphBatch, mlp_init, mlp_apply
from .equivariant import (
    bessel_basis,
    cg_coupling,
    irrep_slices,
    n_sph,
    poly_cutoff,
    sph_harm,
)

__all__ = ["MACEConfig", "init_params", "apply", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128          # channels per irrep component
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    n_species: int = 10
    dtype: object = jnp.float32
    edge_chunks: int = 1         # >1: stream edges through the A-basis
    remat: bool = False
    channel_groups: int = 1      # block-diag channel mixing (TPU scaling)
    spmd_edges: bool = False     # shard_map operon-routed A-basis


def _paths(l_max):
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if cg_coupling(l1, l2, l3) is not None:
                    out.append((l1, l2, l3))
    return out


def init_params(key, cfg: MACEConfig):
    paths = _paths(cfg.l_max)
    c = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers * 6 + 2)
    layers = []
    for t in range(cfg.n_layers):
        lk = jax.random.split(ks[t], 8)
        layers.append({
            # radial MLP: bessel -> hidden; explicit [64, P, C] head so a
            # channel shard slices the LAST dim cleanly
            "radial": mlp_init(lk[0], (cfg.n_rbf, 64, 64), dtype=cfg.dtype),
            "radial_out": dense_init(lk[7], (64, len(paths), c), 0,
                                     dtype=cfg.dtype),
            "w_A": dense_init(
                lk[1], (len(paths), cfg.channel_groups,
                        c // cfg.channel_groups, c // cfg.channel_groups),
                2, dtype=cfg.dtype,
            ),
            "w_B2": dense_init(lk[2], (len(paths), c), 0, dtype=cfg.dtype)
            * 0.1,
            "w_B3": dense_init(lk[3], (len(paths), c), 0, dtype=cfg.dtype)
            * 0.1,
            "w_msg": dense_init(
                lk[4], (3, n_sph(cfg.l_max), cfg.channel_groups,
                        c // cfg.channel_groups, c // cfg.channel_groups),
                3, dtype=cfg.dtype,
            ),
            "w_res": dense_init(
                lk[5], (cfg.n_species, cfg.channel_groups,
                        c // cfg.channel_groups, c // cfg.channel_groups),
                2, dtype=cfg.dtype,
            ),
            "readout": mlp_init(lk[6], (c, 32, 1), dtype=cfg.dtype),
        })
    return {
        "embed": dense_init(ks[-2], (cfg.n_species, c), 0, dtype=cfg.dtype)
        * 5.0,
        "layers": layers,  # NOT stacked: CG paths differ in no way, but
        # 2 layers only — python loop keeps einsums simple
    }


def _cg_apply(u, v, l1, l2, l3):
    """u [N, 2l1+1, C], v [N, 2l2+1, C] -> [N, 2l3+1, C] channelwise."""
    C = jnp.asarray(cg_coupling(l1, l2, l3), u.dtype)
    return jnp.einsum("abc,nbk,nck->nak", C, u, v)


def _sym_contract(x, y, paths, l_max, weights):
    """All CG paths of x (x) y, weighted per path+channel, summed into
    a fresh irrep stack [N, (l_max+1)^2, C]."""
    sl = irrep_slices(l_max)
    n, _, c = x.shape
    out = jnp.zeros((n, n_sph(l_max), c), x.dtype)
    for pi, (l1, l2, l3) in enumerate(paths):
        term = _cg_apply(x[:, sl[l1], :], y[:, sl[l2], :], l1, l2, l3)
        out = out.at[:, sl[l3], :].add(term * weights[pi][None, None, :])
    return out


def _a_basis_chunk(p, h, snd_c, rcv_c, vec_c, emask_c, cfg, paths, sl):
    """One edge chunk's contribution to the A-basis [N-block scatter]."""
    n = h.shape[0]
    c = h.shape[-1]                  # local channels under a channel shard
    r = jnp.linalg.norm(vec_c, axis=-1)
    ok = (r > 1e-6) & emask_c
    Y = sph_harm(cfg.l_max, vec_c).astype(cfg.dtype)
    rbf = bessel_basis(r, cfg.n_rbf, cfg.r_cut) * poly_cutoff(
        r, cfg.r_cut
    )[..., None]
    hrad = mlp_apply(p["radial"], rbf.astype(cfg.dtype), final_act=True)
    Rw = jnp.einsum("eh,hpc->epc", hrad, p["radial_out"])
    h_src = h[snd_c]
    rcv_safe = jnp.where(ok, rcv_c, n)
    A = jnp.zeros((n, n_sph(cfg.l_max), c), cfg.dtype)
    for pi, (l1, l2, l3) in enumerate(paths):
        Ct = jnp.asarray(cg_coupling(l1, l2, l3), cfg.dtype)
        msg = jnp.einsum(
            "abc,nbk,nc->nak", Ct, h_src[:, sl[l1], :], Y[:, sl[l2]]
        )
        msg = msg * Rw[:, pi, None, :]
        msg = jnp.where(ok[:, None, None], msg, 0)
        agg = jax.ops.segment_sum(msg, rcv_safe, num_segments=n + 1)[:n]
        gg = max(1, cfg.channel_groups // (cfg.d_hidden // c))
        aggd = agg.reshape(agg.shape[0], agg.shape[1], gg, c // gg)
        mixed = jnp.einsum("nagk,gkm->nagm", aggd, p["w_A"][pi])
        A = A.at[:, sl[l3], :].add(
            mixed.reshape(agg.shape[0], agg.shape[1], c)
        )
    return A


def _layer(p, h, batch: GraphBatch, cfg: MACEConfig, paths, sl):
    n = batch.n_nodes
    snd, rcv = batch.senders, batch.receivers
    e = snd.shape[0]
    emask = (batch.edge_mask if batch.edge_mask is not None
             else jnp.ones((e,), bool))
    vec = batch.positions[rcv] - batch.positions[snd]
    nch = cfg.edge_chunks

    from ...dist.sharding import current_context
    ctx = current_context()
    if cfg.spmd_edges and ctx is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh, rules = ctx["mesh"], ctx["rules"]
        data_axes = rules.get("edges") or ("data",)
        mspec = rules.get("channels")

        def _zero_tan(a):
            import numpy as _np
            return _np.zeros(a.shape, jax.dtypes.float0)

        def _scan_A(pl, hl, snd_l, rcv_l, vec_l, em_l):
            e_l = snd_l.shape[0]
            ec = e_l // nch
            xs_l = (snd_l.reshape(nch, ec), rcv_l.reshape(nch, ec),
                    vec_l.reshape(nch, ec, 3), em_l.reshape(nch, ec))

            def body(Acc, inp):
                s_, r_, v_, m_ = inp
                return Acc + _a_basis_chunk(pl, hl, s_, r_, v_, m_, cfg,
                                            paths, sl), None

            A0 = jnp.zeros((n, n_sph(cfg.l_max), hl.shape[-1]), cfg.dtype)
            Al, _ = jax.lax.scan(body, A0, xs_l)
            # merge the per-edge-shard partial A's: one psum per layer
            return jax.lax.psum(Al, data_axes)

        # custom VJP: the A-sum is linear per chunk, so the backward is a
        # second chunk scan pushing the SAME d_A through each chunk's vjp —
        # no O(chunks x N x C) scan-carry checkpoints.
        @jax.custom_vjp
        def per_device(pl, hl, snd_l, rcv_l, vec_l, em_l):
            return _scan_A(pl, hl, snd_l, rcv_l, vec_l, em_l)

        def _fwd(pl, hl, snd_l, rcv_l, vec_l, em_l):
            A = _scan_A(pl, hl, snd_l, rcv_l, vec_l, em_l)
            return A, (pl, hl, snd_l, rcv_l, vec_l, em_l)

        def _bwd(res, dA):
            pl, hl, snd_l, rcv_l, vec_l, em_l = res
            e_l = snd_l.shape[0]
            ec = e_l // nch
            xs_l = (snd_l.reshape(nch, ec), rcv_l.reshape(nch, ec),
                    vec_l.reshape(nch, ec, 3), em_l.reshape(nch, ec))
            dp0 = jax.tree_util.tree_map(
                lambda a: jnp.zeros(a.shape, jnp.float32), pl
            )
            dh0 = jnp.zeros(hl.shape, jnp.float32)

            def body(carry, inp):
                dp, dh = carry
                s_, r_, v_, m_ = inp
                _, vjp = jax.vjp(
                    lambda P_, H_, V_: _a_basis_chunk(
                        P_, H_, s_, r_, V_, m_, cfg, paths, sl
                    ),
                    pl, hl, v_,
                )
                dpc, dhc, dvc = vjp(dA)
                dp = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), dp, dpc
                )
                return (dp, dh + dhc.astype(jnp.float32)), \
                    dvc.astype(jnp.float32)

            (dp, dh), dvecs = jax.lax.scan(body, (dp0, dh0), xs_l)
            dp = jax.tree_util.tree_map(
                lambda a, b: a.astype(b.dtype), dp, pl
            )
            return (dp, dh.astype(hl.dtype), _zero_tan(snd_l),
                    _zero_tan(rcv_l),
                    dvecs.reshape(e_l, 3).astype(vec_l.dtype),
                    _zero_tan(em_l))

        per_device.defvjp(_fwd, _bwd)

        pl_specs = {
            "radial": jax.tree_util.tree_map(lambda _: P(), p["radial"]),
            "radial_out": P(None, None, mspec),
            "w_A": P(None, mspec, None, None),
        }
        espec = P(data_axes)
        pl = {k: p[k] for k in ("radial", "radial_out", "w_A")}
        A = shard_map(
            per_device, mesh=mesh,
            in_specs=(pl_specs, P(None, None, mspec), espec, espec,
                      P(data_axes, None), espec),
            out_specs=P(None, None, mspec),
            check_rep=False,
        )(pl, h, snd, rcv, vec, emask)
    elif nch <= 1:
        A = _a_basis_chunk(p, h, snd, rcv, vec, emask, cfg, paths, sl)
    else:
        assert e % nch == 0, "pad edges to a multiple of edge_chunks"
        ec = e // nch
        # hoist the node-table replication out of the chunk scan (one
        # all-gather per layer, not per chunk)
        h = logical_constraint(h, None, None, "channels")
        xs = (snd.reshape(nch, ec), rcv.reshape(nch, ec),
              vec.reshape(nch, ec, 3), emask.reshape(nch, ec))

        def body(A, inp):
            snd_c, rcv_c, vec_c, em_c = inp
            return A + _a_basis_chunk(p, h, snd_c, rcv_c, vec_c, em_c, cfg,
                                      paths, sl), None

        A0 = jnp.zeros((n, n_sph(cfg.l_max), cfg.d_hidden), cfg.dtype)
        A, _ = jax.lax.scan(body, A0, xs)
    A = logical_constraint(A, "nodes", None, "channels")
    return A


def apply(params, batch: GraphBatch, cfg: MACEConfig):
    """Returns per-graph energies [n_graphs]."""
    n = batch.n_nodes
    paths = _paths(cfg.l_max)
    sl = irrep_slices(cfg.l_max)
    c = cfg.d_hidden

    # initial features: species embedding in l=0
    h = jnp.zeros((n, n_sph(cfg.l_max), c), cfg.dtype)
    h = h.at[:, 0, :].set(params["embed"][batch.species])
    energies = jnp.zeros((n,), jnp.float32)

    layer_fn = _layer
    if cfg.remat:
        layer_fn = jax.checkpoint(_layer, static_argnums=(3, 4, 5),
                                  prevent_cse=False)

    for p in params["layers"]:
        A = layer_fn(p, h, batch, cfg, paths, sl)
        # B-basis: symmetric contractions up to correlation order
        B1 = A
        B2 = _sym_contract(A, A, paths, cfg.l_max, p["w_B2"])
        B3 = _sym_contract(B2, A, paths, cfg.l_max, p["w_B3"])
        gg = cfg.channel_groups
        cg = c // gg
        nsph = n_sph(cfg.l_max)

        def _mix(B, w):                     # w [comps, G, Cg, Cg]
            Bd = B.reshape(n, nsph, gg, cg)
            return jnp.einsum("nagk,agkm->nagm", Bd, w).reshape(n, nsph, c)

        m = (_mix(B1, p["w_msg"][0]) + _mix(B2, p["w_msg"][1])
             + _mix(B3, p["w_msg"][2]))
        hd = h.reshape(n, nsph, gg, cg)
        res = jnp.einsum("nagk,ngkm->nagm", hd,
                         p["w_res"][batch.species]).reshape(n, nsph, c)
        h = m + res
        # per-layer site-energy readout from invariants
        e_site = mlp_apply(p["readout"], h[:, 0, :])[:, 0]
        energies = energies + e_site.astype(jnp.float32)

    if batch.node_mask is not None:
        energies = jnp.where(batch.node_mask, energies, 0.0)
    gids = batch.graph_ids if batch.graph_ids is not None else jnp.zeros(
        (n,), jnp.int32
    )
    return jax.ops.segment_sum(energies, gids,
                               num_segments=batch.n_graphs)


def loss_fn(params, batch: GraphBatch, cfg: MACEConfig):
    e = apply(params, batch, cfg)
    target = batch.labels.astype(jnp.float32)
    return jnp.mean(jnp.square(e - target))
