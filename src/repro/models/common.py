"""Shared model-building blocks (framework-free: params are plain pytrees)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "embed_init",
    "rmsnorm",
    "layernorm",
    "apply_rope",
    "rope_freqs",
    "cross_entropy",
    "ACTIVATIONS",
]


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    if not isinstance(in_axis, int):
        fan_in = 1
        for a in in_axis:
            fan_in *= shape[a]
    std = (1.0 / fan_in) ** 0.5
    return (jax.random.truncated_normal(key, -2, 2, shape) * std).astype(dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias=None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x [..., S, D]; positions [S] or [B, S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    if positions.ndim == 1:        # [S] -> [1,..,1,S,D/2]
        while angles.ndim < x.ndim:
            angles = jnp.expand_dims(angles, 0)
    else:                          # [B,S] -> [B,1,..,1,S,D/2] (head axes)
        while angles.ndim < x.ndim:
            angles = jnp.expand_dims(angles, 1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, z_loss: float = 0.0):
    """Mean token cross entropy; logits [..., V] fp32-safe, labels int."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}
