"""Decoder-only LM family: GQA + RoPE + {RMS,Layer}Norm + {dense,MoE} FFN.

One configurable definition covers all five assigned LM architectures
(command-r-plus-104b, tinyllama-1.1b, qwen2-7b, grok-1-314b,
phi3.5-moe-42b).  Layers are *scanned* (params stacked on a leading L axis)
so the HLO stays O(1) in depth — essential for the 64-layer 512-device
dry-run compiles — with jax.checkpoint (remat) around the layer body for
training-memory feasibility.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from ..dist.sharding import logical_constraint, moe_apply
from ..kernels.flash_attention.ops import attention, decode_attention
from .common import (
    ACTIVATIONS,
    apply_rope,
    cross_entropy,
    dense_init,
    embed_init,
    layernorm,
    rmsnorm,
)
from .moe import MoEConfig, init_moe, moe_ffn, router_aux_loss

__all__ = ["TransformerConfig", "init_params", "forward", "loss_fn",
           "prefill", "decode_step", "init_cache"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: int | None = None
    qkv_bias: bool = False
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    parallel_block: bool = False     # command-r style attn ∥ ffn
    act: str = "silu"
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0        # grok-1 logit capping
    logit_softcap: float = 0.0
    moe: MoEConfig | None = None
    tie_embeddings: bool = True
    emb_scale: float = 1.0
    logit_scale: float = 1.0
    dtype: Any = jnp.float32         # params/activations dtype
    remat: bool = True
    # remat policy: None = full recompute; "dots" = save matmul outputs
    # (less backward recompute, more live memory)
    remat_policy: str | None = None
    # KV cache quantization: decode is KV-bandwidth-bound, so int8 halves
    # the dominant roofline term vs bf16 (per-position-per-head scales)
    kv_quant: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        h, hkv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * h * hd + 2 * d * hkv * hd + h * hd * d
        if self.moe is not None:
            ffn = d * self.moe.n_experts + 3 * self.moe.n_experts * d * self.moe.d_ff
        else:
            ffn = 3 * d * f
        emb = v * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn) + emb

    def active_param_count(self) -> int:
        """6·N_active·D convention for MoE rooflines."""
        if self.moe is None:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        h, hkv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * h * hd + 2 * d * hkv * hd + h * hd * d
        ffn = d * self.moe.n_experts + 3 * self.moe.top_k * d * self.moe.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn) + emb


def _norm(cfg, x, p):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p.get("bias"))


def _init_layer(key, cfg: TransformerConfig):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    dt = cfg.dtype
    attn = {
        "wq": dense_init(ks[0], (d, h, hd), 0, dtype=dt),
        "wk": dense_init(ks[1], (d, hkv, hd), 0, dtype=dt),
        "wv": dense_init(ks[2], (d, hkv, hd), 0, dtype=dt),
        "wo": dense_init(ks[3], (h, hd, d), (0, 1), dtype=dt),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((h, hd), dt)
        attn["bk"] = jnp.zeros((hkv, hd), dt)
        attn["bv"] = jnp.zeros((hkv, hd), dt)
    norm_p = {"scale": jnp.zeros((d,), dt)}
    if cfg.norm == "layernorm":
        norm_p["bias"] = jnp.zeros((d,), dt)
    layer = {"attn": attn, "ln1": jax.tree_util.tree_map(jnp.copy, norm_p)}
    if not cfg.parallel_block:
        layer["ln2"] = jax.tree_util.tree_map(jnp.copy, norm_p)
    if cfg.moe is not None:
        layer["moe"] = init_moe(ks[4], d, cfg.moe, dtype=dt)
    else:
        layer["mlp"] = {
            "w_gate": dense_init(ks[5], (d, cfg.d_ff), 0, dtype=dt),
            "w_up": dense_init(ks[6], (d, cfg.d_ff), 0, dtype=dt),
            "w_down": dense_init(ks[7], (cfg.d_ff, d), 0, dtype=dt),
        }
    return layer


def init_params(key, cfg: TransformerConfig):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dtype=cfg.dtype),
        "layers": layers,
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), cfg.dtype)},
    }
    if cfg.norm == "layernorm":
        params["final_norm"]["bias"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(
            k_out, (cfg.d_model, cfg.vocab), 0, dtype=cfg.dtype
        )
    return params


def _ffn_dense(cfg, p, x):
    act = ACTIVATIONS[cfg.act]
    h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    h = logical_constraint(h, "batch", "seq", "ffn")
    return h @ p["w_down"]


def _attention_block(cfg, p, h, positions, kv_cache=None, cache_len=None):
    """h [B,S,d] (pre-normed) -> (attn_out [B,S,d], new (k,v))."""
    q = jnp.einsum("bsd,dhk->bhsk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", h, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = logical_constraint(q, "batch", "heads", "seq", None)
    k = logical_constraint(k, "batch", "kv_heads", "seq", None)
    v = logical_constraint(v, "batch", "kv_heads", "seq", None)

    if kv_cache is None:
        o = attention(q, k, v, causal=True, softcap=cfg.attn_softcap)
        new_kv = (k, v)
    elif len(kv_cache) == 4:
        # int8-quantized KV cache (per-position-per-head scales)
        ck, cv, cks, cvs = kv_cache
        qk, sk = kv_quantize(k)
        qv, sv = kv_quantize(v)
        at = (0, 0, cache_len, 0)
        ck = lax.dynamic_update_slice(ck, qk, at)
        cv = lax.dynamic_update_slice(cv, qv, at)
        cks = lax.dynamic_update_slice(cks, sk, at)
        cvs = lax.dynamic_update_slice(cvs, sv, at)
        kd = kv_dequantize(ck, cks, h.dtype)
        vd = kv_dequantize(cv, cvs, h.dtype)
        o = decode_attention(q, kd, vd, cache_len + q.shape[2],
                             softcap=cfg.attn_softcap)
        new_kv = (ck, cv, cks, cvs)
    else:
        ck, cv = kv_cache
        ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                      (0, 0, cache_len, 0))
        cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                      (0, 0, cache_len, 0))
        o = decode_attention(q, ck, cv, cache_len + q.shape[2],
                             softcap=cfg.attn_softcap)
        new_kv = (ck, cv)
    out = jnp.einsum("bhsk,hkd->bsd", o.astype(h.dtype), p["wo"])
    return logical_constraint(out, "batch", "seq", "embed"), new_kv


def _layer_apply(cfg, p, x, positions, kv_cache=None, cache_len=None):
    h = _norm(cfg, x, p["ln1"])
    attn_out, new_kv = _attention_block(cfg, p["attn"], h, positions,
                                        kv_cache, cache_len)
    if cfg.parallel_block:
        ff_in = h
    else:
        x = x + attn_out
        ff_in = _norm(cfg, x, p["ln2"])
    b, s, d = ff_in.shape
    if cfg.moe is not None:
        y2d, aux = moe_apply(
            partial(moe_ffn, cfg=cfg.moe), p["moe"], ff_in.reshape(b * s, d)
        )
        ff_out = y2d.reshape(b, s, d)
    else:
        ff_out = _ffn_dense(cfg, p["mlp"], ff_in)
        aux = None
    if cfg.parallel_block:
        x = x + attn_out + ff_out
    else:
        x = x + ff_out
    x = logical_constraint(x, "batch", "seq", "embed")
    return x, new_kv, aux


def _zero_aux(cfg):
    if cfg.moe is None:
        return None
    e = cfg.moe.n_experts
    return {
        "router_probs_mean": jnp.zeros((e,), jnp.float32),
        "router_frac": jnp.zeros((e,), jnp.float32),
        "router_z": jnp.zeros((), jnp.float32),
    }


def forward(params, tokens, cfg: TransformerConfig):
    """Training/prefill forward. tokens [B,S] -> logits [B,S,V], aux."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype) * cfg.emb_scale
    x = logical_constraint(x, "batch", "seq", "embed")
    positions = jnp.arange(s)

    def body(x, p_l):
        x, _, aux = _layer_apply(cfg, p_l, x, positions)
        return x, aux

    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots" else None
        )
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    x, auxs = lax.scan(body, x, params["layers"])
    x = _norm(cfg, x, params["final_norm"])
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(cfg.dtype)
    logits = (x @ unembed) * cfg.logit_scale
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = logical_constraint(logits, "batch", "seq", "vocab")
    aux = (
        jax.tree_util.tree_map(lambda a: a.mean(0), auxs)
        if cfg.moe is not None
        else None
    )
    return logits, aux


def loss_fn(params, tokens, labels, cfg: TransformerConfig):
    logits, aux = forward(params, tokens, cfg)
    loss = cross_entropy(logits, labels, z_loss=1e-4)
    if aux is not None:
        loss = loss + router_aux_loss(aux, cfg.moe)
    return loss


def kv_quantize(x):
    """[..., D] -> (int8 values, per-row scale [..., 1] f32).

    The scale stays f32: a bf16 scale adds ~0.4% relative error on every
    dequantized row — enough to flip near-tied argmax logits — for a
    saving of 2 bytes per D-element row."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    s = jnp.maximum(s, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return q, s


def kv_dequantize(q, s, dtype):
    return (q.astype(jnp.float32) * s.astype(jnp.float32)).astype(dtype)


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.hd)
    if cfg.kv_quant:
        sshape = shape[:-1] + (1,)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, tokens, cfg: TransformerConfig, max_len: int):
    """Prefill pass: returns (last-position logits, filled KV cache)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype) * cfg.emb_scale
    x = logical_constraint(x, "batch", "seq", "embed")
    positions = jnp.arange(s)

    def body(x, p_l):
        h = _norm(cfg, x, p_l["ln1"])
        attn_out, (k, v) = _attention_block(cfg, p_l["attn"], h, positions)
        if cfg.parallel_block:
            ff_in, base = h, x
        else:
            x = x + attn_out
            ff_in, base = _norm(cfg, x, p_l["ln2"]), x
        bb, ss, d = ff_in.shape
        if cfg.moe is not None:
            y2d, _ = moe_apply(
                partial(moe_ffn, cfg=cfg.moe), p_l["moe"],
                ff_in.reshape(bb * ss, d),
            )
            ff_out = y2d.reshape(bb, ss, d)
        else:
            ff_out = _ffn_dense(cfg, p_l["mlp"], ff_in)
        x = base + attn_out + ff_out if cfg.parallel_block else x + ff_out
        pad = max_len - s
        kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x, (kc, vc)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (kcs, vcs) = lax.scan(body, x, params["layers"])
    x = _norm(cfg, x[:, -1:, :], params["final_norm"])
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(cfg.dtype)
    logits = (x @ unembed) * cfg.logit_scale
    return logits, {"k": kcs, "v": vcs}


def decode_step(params, token, cache, cache_len, cfg: TransformerConfig):
    """One-token decode. token [B,1]; cache leaves [L,B,Hkv,M,hd]."""
    x = params["embed"][token].astype(cfg.dtype) * cfg.emb_scale
    positions = jnp.full((token.shape[0], 1), cache_len, jnp.int32)

    if cfg.kv_quant:
        def body(x, inputs):
            p_l, ck, cv, cks, cvs = inputs
            x, nkv, _ = _layer_apply(
                cfg, p_l, x, positions, kv_cache=(ck, cv, cks, cvs),
                cache_len=cache_len,
            )
            return x, nkv

        x, (nks, nvs, nkss, nvss) = lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]),
        )
        new_cache = {"k": nks, "v": nvs, "k_scale": nkss, "v_scale": nvss}
        x = _norm(cfg, x, params["final_norm"])
        unembed = (
            params["embed"].T if cfg.tie_embeddings else params["unembed"]
        ).astype(cfg.dtype)
        logits = (x @ unembed) * cfg.logit_scale
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(
                logits / cfg.logit_softcap
            )
        return logits, new_cache

    def body(x, inputs):
        p_l, ck, cv = inputs
        x, (nk, nv), _ = _layer_apply(
            cfg, p_l, x, positions, kv_cache=(ck, cv), cache_len=cache_len
        )
        return x, (nk, nv)

    x, (nks, nvs) = lax.scan(body, x, (params["layers"], cache["k"],
                                       cache["v"]))
    x = _norm(cfg, x, params["final_norm"])
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(cfg.dtype)
    logits = (x @ unembed) * cfg.logit_scale
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, {"k": nks, "v": nvs}
