"""Two-tower retrieval (Yi et al., RecSys'19): sampled-softmax retrieval.

The embedding LOOKUP is the hot path and JAX has no native EmbeddingBag —
it is built here from ``jnp.take`` + masked reduction (fixed-size bags) /
``jax.ops.segment_sum`` (ragged bags), the same substrate op as graph
aggregation (DESIGN.md §3).  Tables are vocab-sharded over the model axis at
scale (dist layer); lookups are the operons.

Shapes served: train_batch (in-batch sampled softmax + logQ correction),
serve_p99 / serve_bulk (tower forward + dot), retrieval_cand (1 query vs
1M candidate matrix -> top-k, a single MXU matmul, not a loop).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..dist.sharding import logical_constraint
from .gnn.common import mlp_init, mlp_apply

__all__ = ["TwoTowerConfig", "init_params", "embedding_bag",
           "embedding_bag_ragged", "user_tower", "item_tower", "loss_fn",
           "score", "retrieval_topk"]


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: tuple = (1024, 512, 256)
    n_user_fields: int = 8       # multi-hot fields per user
    bag_len: int = 16            # padded multi-hot length per field
    user_vocab: int = 2_000_000
    item_vocab: int = 2_000_000
    n_dense: int = 13
    temperature: float = 0.05
    dtype: object = jnp.float32


def init_params(key, cfg: TwoTowerConfig):
    ks = jax.random.split(key, 5)
    d = cfg.embed_dim
    dims_u = (cfg.n_user_fields * d + cfg.n_dense,) + cfg.tower_mlp
    dims_i = (d + cfg.n_dense,) + cfg.tower_mlp
    return {
        "user_table": (jax.random.normal(ks[0], (cfg.user_vocab, d))
                       * 0.01).astype(cfg.dtype),
        "item_table": (jax.random.normal(ks[1], (cfg.item_vocab, d))
                       * 0.01).astype(cfg.dtype),
        "user_mlp": mlp_init(ks[2], dims_u, dtype=cfg.dtype),
        "item_mlp": mlp_init(ks[3], dims_i, dtype=cfg.dtype),
    }


def embedding_bag(table, ids, combine: str = "sum"):
    """Fixed-size bags: ids [..., L] int32, -1 = padding -> [..., D].

    jnp.take + masked reduce — the JAX-native EmbeddingBag."""
    mask = ids >= 0
    safe = jnp.where(mask, ids, 0)
    rows = jnp.take(table, safe, axis=0)             # [..., L, D]
    rows = jnp.where(mask[..., None], rows, 0)
    if combine == "sum":
        return rows.sum(-2)
    if combine == "mean":
        return rows.sum(-2) / jnp.maximum(
            mask.sum(-1, keepdims=True), 1
        ).astype(rows.dtype)
    if combine == "max":
        rows = jnp.where(mask[..., None], rows, -jnp.inf)
        out = rows.max(-2)
        return jnp.where(jnp.isfinite(out), out, 0)
    raise ValueError(combine)


def embedding_bag_ragged(table, flat_ids, bag_ids, n_bags: int,
                         combine: str = "sum"):
    """Ragged bags: gather + segment reduce (the graph-aggregation twin)."""
    rows = jnp.take(table, jnp.maximum(flat_ids, 0), axis=0)
    rows = jnp.where((flat_ids >= 0)[:, None], rows, 0)
    seg = jnp.where(flat_ids >= 0, bag_ids, n_bags)
    out = jax.ops.segment_sum(rows, seg, num_segments=n_bags + 1)[:n_bags]
    if combine == "mean":
        cnt = jax.ops.segment_sum(
            (flat_ids >= 0).astype(rows.dtype), seg, num_segments=n_bags + 1
        )[:n_bags]
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out


def user_tower(params, user_ids, user_dense, cfg: TwoTowerConfig):
    """user_ids [B, F, L] multi-hot; user_dense [B, n_dense]."""
    b = user_ids.shape[0]
    bags = embedding_bag(params["user_table"], user_ids)     # [B, F, D]
    bags = logical_constraint(bags, "batch", None, None)
    x = jnp.concatenate(
        [bags.reshape(b, -1), user_dense.astype(bags.dtype)], axis=-1
    )
    u = mlp_apply(params["user_mlp"], x)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def item_tower(params, item_ids, item_dense, cfg: TwoTowerConfig):
    """item_ids [B] single-hot; item_dense [B, n_dense]."""
    emb = jnp.take(params["item_table"], item_ids, axis=0)
    x = jnp.concatenate([emb, item_dense.astype(emb.dtype)], axis=-1)
    v = mlp_apply(params["item_mlp"], x)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def loss_fn(params, batch, cfg: TwoTowerConfig):
    """In-batch sampled softmax with logQ correction (Yi et al. '19).

    batch: dict(user_ids, user_dense, item_ids, item_dense, item_logq [B]).
    """
    u = user_tower(params, batch["user_ids"], batch["user_dense"], cfg)
    v = item_tower(params, batch["item_ids"], batch["item_dense"], cfg)
    logits = (u @ v.T).astype(jnp.float32) / cfg.temperature
    logits = logits - batch["item_logq"][None, :]      # logQ correction
    logits = logical_constraint(logits, "batch", None)
    b = logits.shape[0]
    labels = jnp.arange(b)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], -1).mean()


def score(params, batch, cfg: TwoTowerConfig):
    """Online/bulk scoring: returns the dot score per (user, item) row."""
    u = user_tower(params, batch["user_ids"], batch["user_dense"], cfg)
    v = item_tower(params, batch["item_ids"], batch["item_dense"], cfg)
    return (u * v).sum(-1)


def retrieval_topk(params, batch, cfg: TwoTowerConfig, k: int = 100):
    """1 query vs n_candidates: single matmul + top-k (no loop).

    batch: dict(user_ids [1,F,L], user_dense [1,n], cand_emb [Nc, D])."""
    u = user_tower(params, batch["user_ids"], batch["user_dense"], cfg)
    scores = (batch["cand_emb"] @ u[0]).astype(jnp.float32)   # [Nc]
    return jax.lax.top_k(scores, k)
