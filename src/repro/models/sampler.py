"""Uniform-fanout neighbor sampler (GraphSAGE-style) for minibatch GNN
training at reddit/ogbn scale — a real sampler over CSR, host-side numpy
(the data-pipeline boundary), emitting fixed-shape padded blocks so the
train step compiles once.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["CSRGraph", "SampledBlocks", "build_csr", "sample_blocks",
           "block_shapes"]


class CSRGraph(NamedTuple):
    indptr: np.ndarray    # [N+1]
    indices: np.ndarray   # [E]
    n_nodes: int


class SampledBlocks(NamedTuple):
    """K-hop sampled subgraph, fixed shapes (padded).

    nodes   [n_max]   — unique node ids, layer-0 seeds first (-1 pad)
    senders [e_max]   — indices INTO nodes (-1 pad)
    receivers [e_max]
    edge_mask [e_max]
    node_mask [n_max]
    seeds   [n_seeds]
    """
    nodes: np.ndarray
    senders: np.ndarray
    receivers: np.ndarray
    edge_mask: np.ndarray
    node_mask: np.ndarray
    seeds: np.ndarray


def build_csr(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    order = np.argsort(src, kind="stable")
    s, d = src[order], dst[order]
    indptr = np.searchsorted(s, np.arange(n_nodes + 1))
    return CSRGraph(indptr.astype(np.int64), d.astype(np.int32), n_nodes)


def block_shapes(batch_nodes: int, fanouts) -> tuple[int, int]:
    """(n_max, e_max) for given seeds + fanouts (the static shape contract)."""
    n_max = batch_nodes
    e_max = 0
    frontier = batch_nodes
    for f in fanouts:
        e_max += frontier * f
        frontier = frontier * f
        n_max += frontier
    return n_max, e_max


def sample_blocks(g: CSRGraph, seeds: np.ndarray, fanouts,
                  rng: np.random.Generator) -> SampledBlocks:
    seeds = np.asarray(seeds, np.int64)
    n_max, e_max = block_shapes(len(seeds), fanouts)
    id_of = {}
    nodes = []

    def intern(v: int) -> int:
        k = id_of.get(v)
        if k is None:
            k = len(nodes)
            id_of[v] = k
            nodes.append(v)
        return k

    for s in seeds:
        intern(int(s))
    snd, rcv = [], []
    frontier = list(seeds)
    for f in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg) if deg <= f else f
            picks = (
                g.indices[lo:hi]
                if deg <= f
                else g.indices[lo + rng.integers(0, deg, size=f)]
            )
            for u in picks[:take]:
                ui = intern(int(u))
                snd.append(ui)
                rcv.append(id_of[int(v)])
                nxt.append(int(u))
        frontier = nxt

    n, e = len(nodes), len(snd)
    nodes_a = np.full(n_max, -1, np.int64)
    nodes_a[:n] = nodes
    snd_a = np.zeros(e_max, np.int32)
    rcv_a = np.zeros(e_max, np.int32)
    snd_a[:e] = snd
    rcv_a[:e] = rcv
    emask = np.zeros(e_max, bool)
    emask[:e] = True
    nmask = np.zeros(n_max, bool)
    nmask[:n] = True
    return SampledBlocks(nodes_a, snd_a, rcv_a, emask, nmask, seeds)
