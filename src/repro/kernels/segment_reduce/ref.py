"""Pure-jnp oracle for sorted segment reduction."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["segment_sum_ref", "segment_min_ref"]


def segment_sum_ref(values, seg_ids, num_segments: int):
    """values [E, F] (or [E]), seg_ids [E] int32 (−1 = dropped)."""
    ids = jnp.where(seg_ids < 0, num_segments, seg_ids)
    return jax.ops.segment_sum(values, ids, num_segments=num_segments + 1)[
        :num_segments
    ]


def segment_min_ref(values, seg_ids, num_segments: int):
    ids = jnp.where(seg_ids < 0, num_segments, seg_ids)
    return jax.ops.segment_min(values, ids, num_segments=num_segments + 1)[
        :num_segments
    ]
