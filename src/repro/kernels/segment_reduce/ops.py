"""Public segment-reduce ops with kernel dispatch + custom VJP.

``segment_sum(values, seg_ids, num_segments)`` — seg_ids need NOT be sorted;
the wrapper sorts once (XLA sort, fused) and runs the Pallas one-hot-matmul
kernel over the sorted stream.  Gradient of segment_sum is a gather, which
XLA handles natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import segment_sum_sorted
from .ref import segment_sum_ref

__all__ = ["segment_sum", "segment_sum_presorted"]


def _backend_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def segment_sum_presorted(values, seg_ids, num_segments, block_e=128,
                          backend=None):
    """values [E, F], seg_ids [E] sorted ascending (-1 pads) -> [N, F]."""
    backend = backend or _backend_default()
    if backend == "xla":
        return segment_sum_ref(values, seg_ids, num_segments)
    e = values.shape[0]
    pad = (-e) % block_e
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        seg_ids = jnp.pad(seg_ids, (0, pad), constant_values=-1)
    return segment_sum_sorted(
        values, seg_ids, num_segments, block_e=block_e,
        interpret=(backend == "interpret"),
    ).astype(values.dtype)


def _fwd(values, seg_ids, num_segments, block_e, backend):
    out = segment_sum_presorted(values, seg_ids, num_segments, block_e, backend)
    return out, seg_ids


def _bwd(num_segments, block_e, backend, seg_ids, g):
    # d/dvalues of a segment sum is a row gather; -1 ids get zero grad
    safe = jnp.clip(seg_ids, 0, num_segments - 1)
    gv = jnp.where((seg_ids >= 0)[:, None], g[safe], 0)
    return gv, None


segment_sum_presorted.defvjp(_fwd, _bwd)


def segment_sum(values, seg_ids, num_segments, block_e=128, backend=None):
    """Unsorted segment sum: sort by id, then the sorted kernel."""
    order = jnp.argsort(seg_ids)
    return segment_sum_presorted(
        values[order], seg_ids[order], num_segments, block_e, backend
    )
