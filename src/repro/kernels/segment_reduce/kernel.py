"""Pallas TPU sorted-segment-sum — the GNN / embedding-bag / diffusion
scatter hot path, re-thought for the MXU (DESIGN.md hardware adaptation).

GPU scatter-add relies on atomics; the TPU has none, but it has a 128x128
systolic array.  With edge values sorted by destination, each edge block
touches at most ``block_e`` distinct segments, so the in-block scatter is a
dense one-hot matmul::

    partial[w, f] = one_hot(rank(ids))[e, w]^T @ values[e, f]

where ``rank`` is the within-block dense rank of each segment id (a cheap
cumsum over sorted ids).  Phase 2 (XLA) scatter-adds the tiny per-block
partial tables into the [N, F] output — O(blocks * block_e) work instead of
O(E).  All the O(E*F) flow goes through the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import CompilerParams as _CompilerParams

__all__ = ["segment_sum_sorted"]


def _kernel(vals_ref, ids_ref, part_ref, uniq_ref, *, block_e: int):
    vals = vals_ref[...].astype(jnp.float32)          # [Be, F]
    ids = ids_ref[0]                                  # [Be] int32, sorted, -1 pad
    valid = ids >= 0

    prev = jnp.concatenate([jnp.full((1,), -2, jnp.int32), ids[:-1]])
    new_seg = (ids != prev) & valid
    # dense within-block rank of each segment (first valid segment = 0)
    rank = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    rank = jnp.where(valid, rank, -1)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 1)
    onehot = (rank[:, None] == lanes) & valid[:, None]     # [Be, W=Be]
    part = jax.lax.dot_general(
        onehot.astype(jnp.float32), vals,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # [W, F]
    part_ref[0] = part
    # the segment id belonging to each rank lane (-1 where unused)
    uniq = jnp.max(
        jnp.where(onehot, ids[:, None], -1), axis=0
    )                                                      # [W]
    uniq_ref[0] = uniq


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_e", "interpret")
)
def segment_sum_sorted(
    values: jnp.ndarray,       # [E, F] float; E % block_e == 0 (pad with -1 ids)
    seg_ids: jnp.ndarray,      # [E] int32 sorted ascending; -1 = padding
    num_segments: int,
    block_e: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    e, f = values.shape
    assert e % block_e == 0, "pad via ops.segment_sum"
    nblocks = e // block_e

    part, uniq = pl.pallas_call(
        functools.partial(_kernel, block_e=block_e),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_e, f), lambda i: (i, 0)),
            pl.BlockSpec((1, block_e), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_e, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, block_e), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, block_e, f), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, block_e), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(values, seg_ids.reshape(nblocks, block_e))

    # phase 2: tiny cross-block combine (O(blocks*block_e) rows)
    flat_ids = jnp.where(uniq.reshape(-1) < 0, num_segments, uniq.reshape(-1))
    out = jnp.zeros((num_segments + 1, f), jnp.float32)
    out = out.at[flat_ids].add(part.reshape(-1, f))
    return out[:num_segments]
