"""Memory-efficient attention in pure XLA (Rabe–Staats / FlashAttention
recurrence via lax.scan) with a hand-written two-pass backward.

This is the non-Pallas execution path: O(Sq * chunk) live memory in both
passes, so 32k-token prefill and 4k training steps lower + compile without
materializing S x S score tensors.  Used on CPU (dry-run) and as the exact
backward for the Pallas forward.  Supports GQA, causal masking and logit
softcap (grok-1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ...dist.sharding import logical_constraint

__all__ = ["mea_attention"]


def _pin(x, *names):
    """Anchor GSPMD so fwd/bwd agree (prevents replication fallbacks when a
    seq-sharded residual cotangent meets head-sharded attention tensors)."""
    return logical_constraint(x, *names)


def _scores(q, k, scale, softcap):
    # q [B,H,G,Sq,D] ; k [B,H,Ck,D] -> s [B,H,G,Sq,Ck] (f32)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", q.astype(jnp.float32) * scale,
        k.astype(jnp.float32), preferred_element_type=jnp.float32,
    )
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _mask(s, kv0, chunk, sq, skv, causal, kv_len):
    kpos = kv0 + jnp.arange(chunk)
    m = kpos[None, :] < kv_len
    if causal:
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        m = m & (kpos[None, :] <= qpos)
    return jnp.where(m[None, None, None], s, -jnp.inf)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def mea_attention(q, k, v, causal=True, softcap=0.0, chunk=512, kv_len=None):
    out, _ = _mea_fwd(q, k, v, causal, softcap, chunk, kv_len)
    return out


def _mea_fwd(q, k, v, causal, softcap, chunk, kv_len):
    q = _pin(q, "batch", "heads", None, None)
    k = _pin(k, "batch", "kv_heads", None, None)
    v = _pin(v, "batch", "kv_heads", None, None)
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    kv_len = skv if kv_len is None else kv_len
    scale = 1.0 / (d ** 0.5)
    assert skv % chunk == 0, "kv length must divide the chunk size"
    nc = skv // chunk

    qg = q.reshape(b, hkv, g, sq, d)
    kc = k.reshape(b, hkv, nc, chunk, d)
    vc = v.reshape(b, hkv, nc, chunk, d)

    def step(carry, inputs):
        acc, m, l = carry
        kb, vb, idx = inputs
        s = _scores(qg, kb, scale, softcap)
        s = _mask(s, idx * chunk, chunk, sq, skv, causal, kv_len)
        m_new = jnp.maximum(m, s.max(-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        alpha = jnp.exp(jnp.where(jnp.isneginf(m), -jnp.inf, m - m_safe))
        p = jnp.exp(s - m_safe[..., None])
        l = alpha * l + p.sum(-1)
        pv = jnp.einsum("bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (acc, m, l), _ = lax.scan(
        step, (acc0, m0, l0),
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(nc)),
    )
    l_safe = jnp.maximum(l, 1e-20)
    out = (acc / l_safe[..., None]).reshape(b, hq, sq, d).astype(q.dtype)
    lse = jnp.where(jnp.isneginf(m), -jnp.inf, m + jnp.log(l_safe))
    return out, (q, k, v, out, lse)


def _mea_bwd(causal, softcap, chunk, kv_len, res, dout):
    q, k, v, out, lse = res
    dout = _pin(dout, "batch", "heads", None, None)
    out = _pin(out, "batch", "heads", None, None)
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    kv_len_ = skv if kv_len is None else kv_len
    scale = 1.0 / (d ** 0.5)
    nc = skv // chunk

    qg = q.reshape(b, hkv, g, sq, d)
    og = out.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    dog = dout.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    delta = (og * dog).sum(-1)                     # [b,hkv,g,sq]
    kc = k.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)

    def step(dq, inputs):
        kb, vb, idx = inputs
        s_pre = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32) * scale,
            kb.astype(jnp.float32),
        )
        if softcap and softcap > 0:
            s = softcap * jnp.tanh(s_pre / softcap)
            dcap = 1.0 - (s / softcap) ** 2
        else:
            s = s_pre
            dcap = None
        s = _mask(s, idx * chunk, chunk, sq, skv, causal, kv_len_)
        p = jnp.exp(s - lse[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        dv = jnp.einsum("bhgqk,bhgqd->bhkd", p, dog)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", dog, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if dcap is not None:
            ds = ds * dcap
        dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kb.astype(jnp.float32)) * scale
        dk = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg.astype(jnp.float32)) * scale
        return dq, (dk, dv)

    dq0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    dq, (dks, dvs) = lax.scan(step, dq0, (kc, vc, jnp.arange(nc)))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, skv, d)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(b, hkv, skv, d)
    return (
        _pin(dq.reshape(b, hq, sq, d).astype(q.dtype),
             "batch", "heads", None, None),
        _pin(dk.astype(k.dtype), "batch", "kv_heads", None, None),
        _pin(dv.astype(v.dtype), "batch", "kv_heads", None, None),
    )


mea_attention.defvjp(lambda q, k, v, c, sc, ch, kl: _mea_fwd(q, k, v, c, sc, ch, kl),
                     _mea_bwd)
