"""Pure-jnp oracle for GQA flash attention (causal, optional logit softcap)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref", "decode_attention_ref"]


def attention_ref(
    q: jnp.ndarray,       # [B, Hq, Sq, D]
    k: jnp.ndarray,       # [B, Hkv, Skv, D]
    v: jnp.ndarray,       # [B, Hkv, Skv, D]
    causal: bool = True,
    softcap: float = 0.0,
    kv_len: int | None = None,   # true (unpadded) kv length
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    skv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / (d ** 0.5)

    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf * scale, kf)
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        # query i attends to kv <= i + (skv - sq) (decode-style alignment)
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(ki <= qi, s, -jnp.inf)
    if kv_len is not None and kv_len < skv:
        s = jnp.where(jnp.arange(skv)[None, :] < kv_len, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len=None, softcap: float = 0.0):
    """Single-step decode: q [B, Hq, 1, D] vs full KV cache."""
    return attention_ref(q, k, v, causal=True, softcap=softcap, kv_len=kv_len)
