"""Jit'd public wrapper around the flash-attention kernel.

Backend dispatch:

* ``pallas``    — the fused TPU kernel forward; exact two-pass flash
                  backward from xla_flash (custom_vjp).
* ``xla``       — memory-efficient scan attention (O(S*chunk) live memory in
                  both passes).  Default off-TPU; this is what the multi-pod
                  dry-run lowers, so 32k-token cells fit.
* ``interpret`` — the Pallas kernel executed by the interpreter (CPU
                  validation path used by the kernel test sweeps).
* ``naive``     — the quadratic oracle (small shapes only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention as _flash_kernel
from .ref import attention_ref
from .xla_flash import mea_attention

__all__ = ["attention", "decode_attention"]


def _backend_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _pallas_attention(q, k, v, causal, softcap, block_q, block_k, interpret):
    qp, sq = _pad_to(q, 2, block_q)
    kp, skv = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    out = _flash_kernel(
        qp, kp, vp, causal=causal, softcap=softcap, block_q=block_q,
        block_k=block_k, kv_len=skv, interpret=interpret,
    )
    return out[:, :, :sq, :]


def _pallas_fwd(q, k, v, causal, softcap, block_q, block_k, interpret):
    out = _pallas_attention(q, k, v, causal, softcap, block_q, block_k,
                            interpret)
    return out, (q, k, v)


def _pallas_bwd(causal, softcap, block_q, block_k, interpret, res, g):
    q, k, v = res
    chunk = min(512, k.shape[2])
    _, vjp = jax.vjp(
        lambda q_, k_, v_: mea_attention(q_, k_, v_, causal, softcap, chunk,
                                         None),
        q, k, v,
    )
    return vjp(g)


_pallas_attention.defvjp(_pallas_fwd, _pallas_bwd)


def attention(
    q: jnp.ndarray,            # [B, Hq, S, D]
    k: jnp.ndarray,            # [B, Hkv, S, D]
    v: jnp.ndarray,
    causal: bool = True,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    backend: str | None = None,
) -> jnp.ndarray:
    """GQA attention. q [B,Hq,S,D]; k/v [B,Hkv,S,D] -> [B,Hq,S,D]."""
    backend = backend or _backend_default()
    if backend == "naive":
        return attention_ref(q, k, v, causal=causal, softcap=softcap)
    if backend == "xla":
        skv = k.shape[2]
        chunk = min(512, skv) if skv % 512 == 0 or skv < 512 else _gcd_chunk(skv)
        return mea_attention(q, k, v, causal, softcap, chunk, None)
    return _pallas_attention(
        q, k, v, causal, softcap, block_q, block_k, backend == "interpret"
    )


def _gcd_chunk(skv: int, target: int = 512) -> int:
    for c in range(min(target, skv), 0, -1):
        if skv % c == 0:
            return c
    return 1


def decode_attention(
    q: jnp.ndarray,            # [B, Hq, 1, D]
    k_cache: jnp.ndarray,      # [B, Hkv, Smax, D]
    v_cache: jnp.ndarray,
    cache_len,                 # int or scalar array: live cache entries
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Single-token decode against a KV cache (memory-bound matvec; XLA
    emits this optimally on TPU — no kernel needed)."""
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    smax = k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf * scale, k_cache.astype(jnp.float32))
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    live = jnp.arange(smax)[None, None, None, :] < cache_len
    s = jnp.where(live, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, hq, 1, d).astype(q.dtype)
