"""Pallas TPU flash attention (GQA, causal, optional logit softcap).

Online-softmax kernel in the FlashAttention-2 style, adapted to the TPU
memory hierarchy: Q/K/V blocks staged HBM->VMEM via BlockSpec, the score
matmul and the PV matmul hit the MXU with 128-aligned tiles, and the running
(max, sum, acc) state lives in VMEM scratch persisted across the innermost
(KV) grid axis — the TPU analogue of CUDA's SRAM accumulators.

Grid: (batch, q_heads, q_blocks, kv_blocks), kv innermost/sequential.
GQA is free: the K/V index_map folds the query head onto its KV head, so no
head replication is ever materialized.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention"]

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, softcap: float, kv_len: int,
            block_q: int, block_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [Bq, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [Bk, D]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # [Bq, Bk]
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < kv_len                                 # padding mask
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0
        ) + q_offset
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                                 # [Bq]
    l_prev = l_ref[:, 0]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # fully-masked-so-far rows keep m = -inf; make alpha/p well-defined
    m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
    alpha = jnp.exp(jnp.where(m_prev == NEG_INF, NEG_INF, m_prev - m_safe))
    p = jnp.exp(jnp.where(mask, s - m_safe[:, None], NEG_INF))
    l_new = alpha * l_prev + p.sum(axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)                  # [Bk, D]
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-20)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softcap", "block_q", "block_k", "kv_len",
                     "interpret"),
)
def flash_attention(
    q: jnp.ndarray,            # [B, Hq, Sq, D]
    k: jnp.ndarray,            # [B, Hkv, Skv, D]
    v: jnp.ndarray,            # [B, Hkv, Skv, D]
    causal: bool = True,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    kv_len: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, "GQA requires q_heads % kv_heads == 0"
    assert sq % block_q == 0 and skv % block_k == 0, "pad via ops.attention"
    g = hq // hkv
    scale = 1.0 / (d ** 0.5)
    kv_len = skv if kv_len is None else kv_len
    q_offset = skv - sq  # decode-style alignment of the causal diagonal

    grid = (b, hq, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        softcap=softcap,
        kv_len=kv_len,
        block_q=block_q,
        block_k=block_k,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bb, h, iq, ik, g=g: (bb, h // g, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bb, h, iq, ik, g=g: (bb, h // g, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bb, h, iq, ik: (bb, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
