"""edge_relax — the paper's memory-driven execution model as a kernel.

The paper's central claim is that dynamic graph processing should be
*memory-driven*: computation is carried to the memory that owns the data
(the compute cell holding a vertex block), instead of data being hauled to
a central processor.  This package is that claim expressed at the kernel
level, for the engine's hot loop (one relaxation sweep of one cell):

* the cell's **vertex block is the resident operand** — in the Pallas
  kernel it is pinned in VMEM for the entire edge sweep, exactly the
  paper's "computation moves to where the vertex data lives" (and the
  Dalorex/Rhizomes argument that fusing gather→combine→scatter at the data
  is where memory-bound graph workloads win);
* the **edge stream is the moving operand** — it arrives in the graph's
  destination-sorted blocked-CSR layout (``ShardedGraph.with_csr``), so
  each block's messages form contiguous per-destination runs and the
  in-block combine is a dense-rank one-hot reduction (shared with
  ``segment_reduce``; MXU-shaped for the sum monoid);
* the result is the cell's **operon traffic**: a combined per-destination
  message table over the flat ``(dst_shard, dst_local)`` key space — row
  *self* is the local inbox, the other rows are the coalesced cross-cell
  mailbox entries of diffuse.py's round exchange.

Layout: kernel.py (Pallas ``pallas_call``; interpret mode off-TPU),
ref.py (shared per-block math + XLA reference paths), ops.py (backend
dispatch + the shared cross-block phase 2).  Both backends are
bitwise-identical by construction — see ops.py.
"""

from .ops import RELAX_BACKENDS, edge_relax

__all__ = ["edge_relax", "RELAX_BACKENDS"]
