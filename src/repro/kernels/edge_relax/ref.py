"""Shared math + XLA reference paths for the edge_relax kernels.

Two *single-source-of-truth* bodies keep the backends bitwise-identical:

* ``stream_scan`` — the segmented associative scan over the globally
  destination-sorted stream.  The Pallas scan kernel
  (:func:`~.kernel.edge_relax_scan`) and the XLA scan path
  (:func:`edge_relax_stream`) execute exactly this function, and its
  fixed tree order depends only on the stream length — never on lane
  count or block boundaries — which is what lets the engine promise that
  a query lane reproduces the same query run solo bit-for-bit, even for
  the order-sensitive sum monoid.  The canonical sum path and the fast
  path for all multi-query-lane runs.
* ``block_combine`` — the blocked dense-rank segment combine executed
  verbatim by the blocked Pallas kernel
  (:func:`~.kernel.edge_relax_blocks`) and the XLA blocked reference
  (:func:`edge_relax_blocks_ref`).

``edge_relax_flat`` is the fast unblocked path for single-query
order-free monoids (min/max): plain segment ops over the sorted stream.
Min/max over a set is association-free, so flat, blocked, and scan all
agree bitwise by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.msg import identity_for, segment_combine

__all__ = [
    "edge_messages",
    "block_combine",
    "edge_relax_blocks_ref",
    "edge_relax_flat",
    "stream_scan",
    "gather_runs",
    "edge_relax_stream",
]


def edge_messages(prog, vstate, senders, gid, key, src, weight, dst_gid):
    """Gather + emit along the destination-sorted edge stream.

    Elementwise: per edge, gather the source vertex state, run the
    program's ``emit``, and mask non-sending / dead edges to the combine
    identity (the *monoid's* identity — custom monoids may differ from
    their scatter class's, and the scan path folds padding through the
    custom ``op``, where only a true identity is absorbing).  Runs
    identically inside the Pallas kernels (on VMEM-resident vertex
    blocks) and in the XLA paths.

    Returns (cand [E] msg_dtype, send [E] bool, pay [E] int32 | None).
    """
    src_state = jax.tree_util.tree_map(lambda a: a[src], vstate)
    valid = key >= 0
    send = senders[src] & valid
    msg = prog.emit(src_state, weight, gid[src], dst_gid)
    ident = prog.monoid.identity(prog.msg_dtype)
    cand = jnp.where(send, msg, ident).astype(prog.msg_dtype)
    pay = None
    if prog.with_payload:
        pay = prog.payload(src_state, gid[src]).astype(jnp.int32)
        pay = jnp.where(send, pay, -1)
    return cand, send, pay


def block_combine(cand, send, key, pay, combine: str, block_e: int):
    """One block of the dense-rank segment combine (see module docstring).

    ``key`` is sorted within the block with ``-1`` padding trailing, so
    each destination's messages form a contiguous run; ``rank`` densely
    numbers the runs and the combine reduces over a one-hot [E, W] mask
    (the same trick as segment_reduce — on TPU the sum case is MXU food).

    Returns (part [Be], cnt [Be] int32, uniq [Be] int32, pay_part | None).
    """
    valid = key >= 0
    prev = jnp.concatenate([jnp.full((1,), -2, jnp.int32), key[:-1]])
    new_seg = (key != prev) & valid
    rank = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    rank = jnp.where(valid, rank, -1)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 1)
    onehot = (rank[:, None] == lanes) & valid[:, None]        # [Be, W]
    ident = identity_for(combine, cand.dtype)
    if combine == "min":
        part = jnp.min(jnp.where(onehot, cand[:, None], ident), axis=0)
    elif combine == "max":
        part = jnp.max(jnp.where(onehot, cand[:, None], ident), axis=0)
    elif combine == "sum":
        part = jnp.sum(jnp.where(onehot, cand[:, None],
                                 jnp.zeros((), cand.dtype)), axis=0)
    else:  # pragma: no cover
        raise ValueError(f"unknown combine {combine!r}")
    cnt = jnp.sum(jnp.where(onehot, send[:, None].astype(jnp.int32), 0),
                  axis=0)
    uniq = jnp.max(jnp.where(onehot, key[:, None], -1), axis=0)
    pay_part = None
    if pay is not None:
        win = onehot & send[:, None] & (cand[:, None] == part[None, :])
        pay_part = jnp.max(jnp.where(win, pay[:, None], -1), axis=0)
    return part, cnt, uniq, pay_part


def edge_relax_blocks_ref(prog, vstate, senders, gid, key, src, weight,
                          dst_gid, block_e: int):
    """XLA reference: the blocked combine vmapped over edge blocks.

    Bitwise-identical to the Pallas kernel's per-block outputs (shared
    :func:`block_combine` body) — the engine's ``backend="xla"`` sum path.
    """
    cand, send, pay = edge_messages(prog, vstate, senders, gid, key, src,
                                    weight, dst_gid)
    nb = key.shape[0] // block_e
    blk = lambda a: a.reshape(nb, block_e)
    if pay is None:
        part, cnt, uniq, _ = jax.vmap(
            lambda c, s, k: block_combine(c, s, k, None, prog.combine,
                                          block_e)
        )(blk(cand), blk(send), blk(key))
        return part, cnt, uniq, None
    part, cnt, uniq, pay_part = jax.vmap(
        lambda c, s, k, p: block_combine(c, s, k, p, prog.combine, block_e)
    )(blk(cand), blk(send), blk(key), blk(pay))
    return part, cnt, uniq, pay_part


def stream_scan(monoid, cand, send, key, pay):
    """Segmented inclusive scan over the destination-sorted edge stream.

    The whole per-shard stream is globally sorted by destination key
    (``ShardedGraph.build_csr``), so every destination's messages form
    one contiguous run.  A segmented ``lax.associative_scan`` combines
    each run left-to-right in a *fixed tree order* determined only by the
    stream length — never by the lane count — which is what makes a
    lane's sum bitwise-identical to the same query run solo, and lets
    lanes batch as pure elementwise ops (no scatters: a vmapped scatter
    is ~30x slower on CPU).

    Carries (combined value, sending count, winning payload) per element;
    ``scanned[..., e]`` holds the run-prefix combine up to e.  Shared
    verbatim by the XLA path and the Pallas scan kernel (bitwise parity
    by construction).

    ``cand``/``send`` are [..., E] (leading lane axes broadcast), ``key``
    [E], ``pay`` [..., E] int32 or None.
    """
    prev = jnp.concatenate([jnp.full((1,), -2, key.dtype), key[:-1]])
    start = jnp.broadcast_to(key != prev, cand.shape)
    cnt = jnp.broadcast_to(send, cand.shape).astype(jnp.int32)

    if pay is None:
        def comb(a, b):
            va, ca, sa = a
            vb, cb, sb = b
            return (jnp.where(sb, vb, monoid.elem(va, vb)),
                    jnp.where(sb, cb, ca + cb),
                    sa | sb)
        v, c, _ = jax.lax.associative_scan(comb, (cand, cnt, start),
                                           axis=-1)
        return v, c, None

    pay = jnp.broadcast_to(pay, cand.shape)

    def comb(a, b):
        va, ca, pa, sa = a
        vb, cb, pb, sb = b
        v = jnp.where(sb, vb, monoid.elem(va, vb))
        c = jnp.where(sb, cb, ca + cb)
        # winner's payload rides along; ties keep the max payload —
        # the same rule as the flat path's segment-max over winners
        bw = monoid.improves(vb, va)
        aw = monoid.improves(va, vb)
        p = jnp.where(sb, pb,
                      jnp.where(bw, pb,
                                jnp.where(aw, pa, jnp.maximum(pa, pb))))
        return v, c, p, sa | sb

    v, c, p, _ = jax.lax.associative_scan(
        comb, (cand, cnt, pay, start), axis=-1)
    return v, c, p


def gather_runs(scanned, key, n_keys: int, monoid, msg_dtype):
    """Phase 2 of the scan path: read each destination's run total.

    The stream is sorted, so the last element of destination k's run sits
    at ``searchsorted(key, k, 'right') - 1`` — a pure gather (no scatter),
    lane-batched for free.  Shared XLA code for both backends.
    """
    v, c, p = scanned
    key2 = jnp.where(key < 0, n_keys, key).astype(jnp.int32)
    ks = jnp.arange(n_keys, dtype=jnp.int32)
    last = jnp.searchsorted(key2, ks, side="right").astype(jnp.int32) - 1
    li = jnp.clip(last, 0)
    ok = (last >= 0) & (key2[li] == ks)
    ident = monoid.identity(msg_dtype)
    table = jnp.where(ok, jnp.take(v, li, axis=-1), ident)
    cnt = jnp.where(ok, jnp.take(c, li, axis=-1), 0)
    pay = None
    if p is not None:
        pay = jnp.where(ok & (cnt > 0), jnp.take(p, li, axis=-1), -1)
    return table, cnt, pay


def edge_relax_stream(prog, vstate, senders, gid, key, src, weight, dst_gid,
                      n_keys: int):
    """Scan-based relaxation sweep (XLA): gather → emit → segmented scan
    → run-end gather.  Handles single ([Np] leaves) and laned ([L, Np])
    vertex blocks uniformly; the canonical sum path and the fast path for
    every laned program.

    Returns (table [..., n_keys], cnt, pay | None).
    """
    src_state = jax.tree_util.tree_map(lambda a: a[..., src], vstate)
    valid = key >= 0
    send = senders[..., src] & valid
    msg = prog.emit(src_state, weight, gid[src], dst_gid)
    ident = prog.monoid.identity(prog.msg_dtype)
    cand = jnp.where(send, msg, ident).astype(prog.msg_dtype)
    pay = None
    if prog.with_payload:
        pay = prog.payload(src_state, gid[src]).astype(jnp.int32)
        pay = jnp.where(send, pay, -1)
    scanned = stream_scan(prog.monoid, cand, send, key, pay)
    return gather_runs(scanned, key, n_keys, prog.monoid, prog.msg_dtype)


def edge_relax_flat(prog, vstate, senders, gid, key, src, weight, dst_gid,
                    n_keys: int):
    """Unblocked segment-combine over the sorted stream (min/max only).

    Order-free monoids make this bitwise-equal to the blocked paths while
    doing O(E) scatter work — the engine's ``backend="xla"`` fast path.

    Returns (table [n_keys], cnt [n_keys] int32, pay [n_keys] | None).
    """
    cand, send, pay = edge_messages(prog, vstate, senders, gid, key, src,
                                    weight, dst_gid)
    ids = jnp.where(send, key, n_keys)       # non-senders dropped off-range
    table = segment_combine(cand, ids, n_keys + 1, prog.combine,
                            indices_are_sorted=False)
    cnt = segment_combine(send.astype(jnp.int32), ids, n_keys + 1, "sum")
    pay_t = None
    if pay is not None:
        win = send & (cand == table[ids])
        pay_t = segment_combine(jnp.where(win, pay, -1), ids, n_keys + 1,
                                "max")
        pay_t = jnp.where(cnt[:n_keys] > 0, pay_t[:n_keys], -1)
    return table[:n_keys], cnt[:n_keys], pay_t
