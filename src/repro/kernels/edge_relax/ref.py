"""Shared math + XLA reference paths for the edge_relax kernel.

``block_combine`` is the *single source of truth* for the blocked
dense-rank segment combine: the Pallas kernel (kernel.py) and the XLA
blocked reference (:func:`edge_relax_blocks_ref`) both execute exactly this
function, op for op, so their results are bitwise identical on a given
backend — which is what lets the engine promise ``backend="pallas"``
reproduces ``backend="xla"`` fixed points bit-for-bit even for the
order-sensitive sum monoid.

``edge_relax_flat`` is the fast unblocked path for the order-free monoids
(min/max): plain segment ops over the sorted stream.  Min/max over a set
is association-free, so flat and blocked agree bitwise by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.msg import identity_for, segment_combine

__all__ = [
    "edge_messages",
    "block_combine",
    "edge_relax_blocks_ref",
    "edge_relax_flat",
]


def edge_messages(prog, vstate, senders, gid, key, src, weight, dst_gid):
    """Gather + emit along the destination-sorted edge stream.

    Elementwise: per edge, gather the source vertex state, run the
    program's ``emit``, and mask non-sending / dead edges to the combine
    identity.  Runs identically inside the Pallas kernel (on VMEM-resident
    vertex blocks) and in the XLA paths.

    Returns (cand [E] msg_dtype, send [E] bool, pay [E] int32 | None).
    """
    src_state = jax.tree_util.tree_map(lambda a: a[src], vstate)
    valid = key >= 0
    send = senders[src] & valid
    msg = prog.emit(src_state, weight, gid[src], dst_gid)
    ident = identity_for(prog.combine, prog.msg_dtype)
    cand = jnp.where(send, msg, ident).astype(prog.msg_dtype)
    pay = None
    if prog.with_payload:
        pay = prog.payload(src_state, gid[src]).astype(jnp.int32)
        pay = jnp.where(send, pay, -1)
    return cand, send, pay


def block_combine(cand, send, key, pay, combine: str, block_e: int):
    """One block of the dense-rank segment combine (see module docstring).

    ``key`` is sorted within the block with ``-1`` padding trailing, so
    each destination's messages form a contiguous run; ``rank`` densely
    numbers the runs and the combine reduces over a one-hot [E, W] mask
    (the same trick as segment_reduce — on TPU the sum case is MXU food).

    Returns (part [Be], cnt [Be] int32, uniq [Be] int32, pay_part | None).
    """
    valid = key >= 0
    prev = jnp.concatenate([jnp.full((1,), -2, jnp.int32), key[:-1]])
    new_seg = (key != prev) & valid
    rank = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    rank = jnp.where(valid, rank, -1)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 1)
    onehot = (rank[:, None] == lanes) & valid[:, None]        # [Be, W]
    ident = identity_for(combine, cand.dtype)
    if combine == "min":
        part = jnp.min(jnp.where(onehot, cand[:, None], ident), axis=0)
    elif combine == "max":
        part = jnp.max(jnp.where(onehot, cand[:, None], ident), axis=0)
    elif combine == "sum":
        part = jnp.sum(jnp.where(onehot, cand[:, None],
                                 jnp.zeros((), cand.dtype)), axis=0)
    else:  # pragma: no cover
        raise ValueError(f"unknown combine {combine!r}")
    cnt = jnp.sum(jnp.where(onehot, send[:, None].astype(jnp.int32), 0),
                  axis=0)
    uniq = jnp.max(jnp.where(onehot, key[:, None], -1), axis=0)
    pay_part = None
    if pay is not None:
        win = onehot & send[:, None] & (cand[:, None] == part[None, :])
        pay_part = jnp.max(jnp.where(win, pay[:, None], -1), axis=0)
    return part, cnt, uniq, pay_part


def edge_relax_blocks_ref(prog, vstate, senders, gid, key, src, weight,
                          dst_gid, block_e: int):
    """XLA reference: the blocked combine vmapped over edge blocks.

    Bitwise-identical to the Pallas kernel's per-block outputs (shared
    :func:`block_combine` body) — the engine's ``backend="xla"`` sum path.
    """
    cand, send, pay = edge_messages(prog, vstate, senders, gid, key, src,
                                    weight, dst_gid)
    nb = key.shape[0] // block_e
    blk = lambda a: a.reshape(nb, block_e)
    if pay is None:
        part, cnt, uniq, _ = jax.vmap(
            lambda c, s, k: block_combine(c, s, k, None, prog.combine,
                                          block_e)
        )(blk(cand), blk(send), blk(key))
        return part, cnt, uniq, None
    part, cnt, uniq, pay_part = jax.vmap(
        lambda c, s, k, p: block_combine(c, s, k, p, prog.combine, block_e)
    )(blk(cand), blk(send), blk(key), blk(pay))
    return part, cnt, uniq, pay_part


def edge_relax_flat(prog, vstate, senders, gid, key, src, weight, dst_gid,
                    n_keys: int):
    """Unblocked segment-combine over the sorted stream (min/max only).

    Order-free monoids make this bitwise-equal to the blocked paths while
    doing O(E) scatter work — the engine's ``backend="xla"`` fast path.

    Returns (table [n_keys], cnt [n_keys] int32, pay [n_keys] | None).
    """
    cand, send, pay = edge_messages(prog, vstate, senders, gid, key, src,
                                    weight, dst_gid)
    ids = jnp.where(send, key, n_keys)       # non-senders dropped off-range
    table = segment_combine(cand, ids, n_keys + 1, prog.combine,
                            indices_are_sorted=False)
    cnt = segment_combine(send.astype(jnp.int32), ids, n_keys + 1, "sum")
    pay_t = None
    if pay is not None:
        win = send & (cand == table[ids])
        pay_t = segment_combine(jnp.where(win, pay, -1), ids, n_keys + 1,
                                "max")
        pay_t = jnp.where(cnt[:n_keys] > 0, pay_t[:n_keys], -1)
    return table[:n_keys], cnt[:n_keys], pay_t
