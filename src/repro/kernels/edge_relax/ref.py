"""Shared math + XLA reference paths for the edge_relax kernels.

Two *single-source-of-truth* bodies keep the backends bitwise-identical:

* ``stream_scan`` — the segmented associative scan over the globally
  destination-sorted stream.  The Pallas scan kernel
  (:func:`~.kernel.edge_relax_scan`) and the XLA scan path
  (:func:`edge_relax_stream`) execute exactly this function, and its
  fixed tree order depends only on the stream length — never on lane
  count or block boundaries — which is what lets the engine promise that
  a query lane reproduces the same query run solo bit-for-bit, even for
  the order-sensitive sum monoid.  The canonical sum path and the fast
  path for all multi-query-lane runs.
* ``block_combine`` — the blocked dense-rank segment combine executed
  verbatim by the blocked Pallas kernel
  (:func:`~.kernel.edge_relax_blocks`) and the XLA blocked reference
  (:func:`edge_relax_blocks_ref`).

``edge_relax_flat`` is the fast unblocked path for single-query
order-free monoids (min/max): plain segment ops over the sorted stream.
Min/max over a set is association-free, so flat, blocked, and scan all
agree bitwise by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.msg import identity_for, segment_combine

__all__ = [
    "edge_messages",
    "stream_messages",
    "block_combine",
    "flat_combine",
    "edge_relax_blocks_ref",
    "edge_relax_flat",
    "stream_scan",
    "gather_runs",
    "delta_tables",
    "merge_tables",
    "stream_combine",
    "edge_relax_stream",
    "compact_push_blocks",
    "push_gather",
    "edge_relax_push_flat",
    "edge_relax_push_stream",
]


def edge_messages(prog, vstate, senders, gid, key, src, weight, dst_gid):
    """Gather + emit along the destination-sorted edge stream.

    Elementwise: per edge, gather the source vertex state, run the
    program's ``emit``, and mask non-sending / dead edges to the combine
    identity (the *monoid's* identity — custom monoids may differ from
    their scatter class's, and the scan path folds padding through the
    custom ``op``, where only a true identity is absorbing).  Runs
    identically inside the Pallas kernels (on VMEM-resident vertex
    blocks) and in the XLA paths.

    Returns (cand [E] msg_dtype, send [E] bool, pay [E] int32 | None).
    """
    src_state = jax.tree_util.tree_map(lambda a: a[src], vstate)
    valid = key >= 0
    send = senders[src] & valid
    msg = prog.emit(src_state, weight, gid[src], dst_gid)
    ident = prog.monoid.identity(prog.msg_dtype)
    cand = jnp.where(send, msg, ident).astype(prog.msg_dtype)
    pay = None
    if prog.with_payload:
        pay = prog.payload(src_state, gid[src]).astype(jnp.int32)
        pay = jnp.where(send, pay, -1)
    return cand, send, pay


def stream_messages(prog, vstate, senders, gid, key, src, weight, dst_gid):
    """Lane-broadcasting twin of :func:`edge_messages` (``senders`` and
    vstate leaves may carry leading lane axes).  Shared verbatim by the
    dense scan path and the push stream path, so a future emit/mask
    change cannot split them."""
    src_state = jax.tree_util.tree_map(lambda a: a[..., src], vstate)
    valid = key >= 0
    send = senders[..., src] & valid
    msg = prog.emit(src_state, weight, gid[src], dst_gid)
    ident = prog.monoid.identity(prog.msg_dtype)
    cand = jnp.where(send, msg, ident).astype(prog.msg_dtype)
    pay = None
    if prog.with_payload:
        pay = prog.payload(src_state, gid[src]).astype(jnp.int32)
        pay = jnp.where(send, pay, -1)
    return cand, send, pay


def flat_combine(cand, send, pay, ids, n_keys: int, combine: str):
    """Phase 2 of the unsorted segment paths: scatter-combine the
    candidate messages by destination id (``n_keys`` = drop row), count
    senders, and ride the argbest payload with the segment-max-over-
    winners tie-break.  Shared verbatim by the dense flat path and the
    compacted push path — the push == pull bitwise contract for payload
    programs lives here, structurally."""
    table = segment_combine(cand, ids, n_keys + 1, combine,
                            indices_are_sorted=False)
    cnt = segment_combine(send.astype(jnp.int32), ids, n_keys + 1, "sum")
    pay_t = None
    if pay is not None:
        win = send & (cand == table[ids])
        pay_t = segment_combine(jnp.where(win, pay, -1), ids, n_keys + 1,
                                "max")
        pay_t = jnp.where(cnt[:n_keys] > 0, pay_t[:n_keys], -1)
    return table[:n_keys], cnt[:n_keys], pay_t


def block_combine(cand, send, key, pay, combine: str, block_e: int):
    """One block of the dense-rank segment combine (see module docstring).

    ``key`` is sorted within the block with ``-1`` padding trailing, so
    each destination's messages form a contiguous run; ``rank`` densely
    numbers the runs and the combine reduces over a one-hot [E, W] mask
    (the same trick as segment_reduce — on TPU the sum case is MXU food).

    Returns (part [Be], cnt [Be] int32, uniq [Be] int32, pay_part | None).
    """
    valid = key >= 0
    prev = jnp.concatenate([jnp.full((1,), -2, jnp.int32), key[:-1]])
    new_seg = (key != prev) & valid
    rank = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    rank = jnp.where(valid, rank, -1)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 1)
    onehot = (rank[:, None] == lanes) & valid[:, None]        # [Be, W]
    ident = identity_for(combine, cand.dtype)
    if combine == "min":
        part = jnp.min(jnp.where(onehot, cand[:, None], ident), axis=0)
    elif combine == "max":
        part = jnp.max(jnp.where(onehot, cand[:, None], ident), axis=0)
    elif combine == "sum":
        part = jnp.sum(jnp.where(onehot, cand[:, None],
                                 jnp.zeros((), cand.dtype)), axis=0)
    else:  # pragma: no cover
        raise ValueError(f"unknown combine {combine!r}")
    cnt = jnp.sum(jnp.where(onehot, send[:, None].astype(jnp.int32), 0),
                  axis=0)
    uniq = jnp.max(jnp.where(onehot, key[:, None], -1), axis=0)
    pay_part = None
    if pay is not None:
        win = onehot & send[:, None] & (cand[:, None] == part[None, :])
        pay_part = jnp.max(jnp.where(win, pay[:, None], -1), axis=0)
    return part, cnt, uniq, pay_part


def edge_relax_blocks_ref(prog, vstate, senders, gid, key, src, weight,
                          dst_gid, block_e: int):
    """XLA reference: the blocked combine vmapped over edge blocks.

    Bitwise-identical to the Pallas kernel's per-block outputs (shared
    :func:`block_combine` body) — the engine's ``backend="xla"`` sum path.
    """
    cand, send, pay = edge_messages(prog, vstate, senders, gid, key, src,
                                    weight, dst_gid)
    nb = key.shape[0] // block_e
    blk = lambda a: a.reshape(nb, block_e)
    if pay is None:
        part, cnt, uniq, _ = jax.vmap(
            lambda c, s, k: block_combine(c, s, k, None, prog.combine,
                                          block_e)
        )(blk(cand), blk(send), blk(key))
        return part, cnt, uniq, None
    part, cnt, uniq, pay_part = jax.vmap(
        lambda c, s, k, p: block_combine(c, s, k, p, prog.combine, block_e)
    )(blk(cand), blk(send), blk(key), blk(pay))
    return part, cnt, uniq, pay_part


def stream_scan(monoid, cand, send, key, pay):
    """Segmented inclusive scan over the destination-sorted edge stream.

    The whole per-shard stream is globally sorted by destination key
    (``ShardedGraph.build_csr``), so every destination's messages form
    one contiguous run.  A segmented ``lax.associative_scan`` combines
    each run left-to-right in a *fixed tree order* determined only by the
    stream length — never by the lane count — which is what makes a
    lane's sum bitwise-identical to the same query run solo, and lets
    lanes batch as pure elementwise ops (no scatters: a vmapped scatter
    is ~30x slower on CPU).

    Carries (combined value, sending count, winning payload) per element;
    ``scanned[..., e]`` holds the run-prefix combine up to e.  Shared
    verbatim by the XLA path and the Pallas scan kernel (bitwise parity
    by construction).

    ``cand``/``send`` are [..., E] (leading lane axes broadcast), ``key``
    [E], ``pay`` [..., E] int32 or None.
    """
    prev = jnp.concatenate([jnp.full((1,), -2, key.dtype), key[:-1]])
    start = jnp.broadcast_to(key != prev, cand.shape)
    cnt = jnp.broadcast_to(send, cand.shape).astype(jnp.int32)

    if pay is None:
        def comb(a, b):
            va, ca, sa = a
            vb, cb, sb = b
            return (jnp.where(sb, vb, monoid.elem(va, vb)),
                    jnp.where(sb, cb, ca + cb),
                    sa | sb)
        v, c, _ = jax.lax.associative_scan(comb, (cand, cnt, start),
                                           axis=-1)
        return v, c, None

    pay = jnp.broadcast_to(pay, cand.shape)

    def comb(a, b):
        va, ca, pa, sa = a
        vb, cb, pb, sb = b
        v = jnp.where(sb, vb, monoid.elem(va, vb))
        c = jnp.where(sb, cb, ca + cb)
        # winner's payload rides along; ties keep the max payload —
        # the same rule as the flat path's segment-max over winners
        bw = monoid.improves(vb, va)
        aw = monoid.improves(va, vb)
        p = jnp.where(sb, pb,
                      jnp.where(bw, pb,
                                jnp.where(aw, pa, jnp.maximum(pa, pb))))
        return v, c, p, sa | sb

    v, c, p, _ = jax.lax.associative_scan(
        comb, (cand, cnt, pay, start), axis=-1)
    return v, c, p


def gather_runs(scanned, key, n_keys: int, monoid, msg_dtype):
    """Phase 2 of the scan path: read each destination's run total.

    The stream is sorted, so the last element of destination k's run sits
    at ``searchsorted(key, k, 'right') - 1`` — a pure gather (no scatter),
    lane-batched for free.  Shared XLA code for both backends.
    """
    v, c, p = scanned
    key2 = jnp.where(key < 0, n_keys, key).astype(jnp.int32)
    ks = jnp.arange(n_keys, dtype=jnp.int32)
    last = jnp.searchsorted(key2, ks, side="right").astype(jnp.int32) - 1
    li = jnp.clip(last, 0)
    ok = (last >= 0) & (key2[li] == ks)
    ident = monoid.identity(msg_dtype)
    table = jnp.where(ok, jnp.take(v, li, axis=-1), ident)
    cnt = jnp.where(ok, jnp.take(c, li, axis=-1), 0)
    pay = None
    if p is not None:
        pay = jnp.where(ok & (cnt > 0), jnp.take(p, li, axis=-1), -1)
    return table, cnt, pay


def delta_tables(prog, cand, send, pay, key, n_keys: int):
    """Combine a staged **delta segment** (DESIGN.md §2.9) into a flat
    key-space table: the appended delta blocks are unsorted, so they take
    a shared-index scatter by destination instead of the scan — the same
    scatter-class semantics as :func:`flat_combine`, batched over leading
    lane axes for free because the index vector is shared across lanes.
    Order-free (min/max) monoids stay bitwise-equal to a full rebuild
    that would have sorted these edges into their runs; sum reassociates
    (which is why the engines compact before sum-combine programs).

    ``cand``/``send``/``pay`` are [..., D] message streams from
    :func:`stream_messages` over the delta slice, ``key`` [D] its
    destination ids (``-1`` = free/tombstoned, dropped).
    """
    ids = jnp.where(key >= 0, key, n_keys).astype(jnp.int32)
    lane = cand.shape[:-1]
    ident = prog.monoid.identity(prog.msg_dtype)
    table = jnp.full(lane + (n_keys + 1,), ident, prog.msg_dtype)
    if prog.combine == "min":
        table = table.at[..., ids].min(cand)
    elif prog.combine == "max":
        table = table.at[..., ids].max(cand)
    else:
        table = table.at[..., ids].add(cand)     # non-senders hold +0
    sendb = jnp.broadcast_to(send, cand.shape)
    cnt = jnp.zeros(lane + (n_keys + 1,), jnp.int32).at[..., ids].add(
        sendb.astype(jnp.int32))
    pay_t = None
    if pay is not None:
        payb = jnp.broadcast_to(pay, cand.shape)
        win = sendb & (cand == table[..., ids])
        pay_t = jnp.full(lane + (n_keys + 1,), -1, jnp.int32).at[
            ..., ids].max(jnp.where(win, payb, -1))
        pay_t = jnp.where(cnt[..., :n_keys] > 0, pay_t[..., :n_keys], -1)
    return table[..., :n_keys], cnt[..., :n_keys], pay_t


def merge_tables(prog, a, b):
    """Monoid-merge two (table, cnt, pay) triples over the same key space
    — how the sorted region's scan output absorbs the delta segment's
    scatter output.  The payload rule is the shared tie-break (max over
    winners), so argbest programs stay bitwise-equal to the single-pass
    combines."""
    t1, c1, p1 = a
    t2, c2, p2 = b
    table = prog.monoid.elem(t1, t2)
    cnt = c1 + c2
    pay = None
    if p1 is not None:
        pay = jnp.maximum(jnp.where((t1 == table) & (c1 > 0), p1, -1),
                          jnp.where((t2 == table) & (c2 > 0), p2, -1))
    return table, cnt, pay


def stream_combine(prog, cand, send, pay, key, skey, n_keys: int,
                   delta_e: int):
    """The one home of the sorted-region/delta-segment split: segmented
    scan + run-end gather over ``[..., :es]`` against the structural
    ``skey``, with the staged delta segment (``delta_e`` trailing
    positions, unsorted) folded in through :func:`delta_tables` and
    merged by the monoid.  Every full-width message-stream consumer
    (dense scan path, push-sweep reconstruction) calls this, so the
    'incremental == rebuild bitwise' contract cannot drift between
    backends or sweeps.
    """
    es = key.shape[-1] - delta_e
    sl = lambda a: None if a is None else a[..., :es]
    scanned = stream_scan(prog.monoid, cand[..., :es], send[..., :es],
                          skey[:es], sl(pay))
    out = gather_runs(scanned, skey[:es], n_keys, prog.monoid,
                      prog.msg_dtype)
    if delta_e:
        dl = lambda a: None if a is None else a[..., es:]
        out = merge_tables(prog, out, delta_tables(
            prog, cand[..., es:], send[..., es:], dl(pay), key[es:],
            n_keys))
    return out


def edge_relax_stream(prog, vstate, senders, gid, key, src, weight, dst_gid,
                      n_keys: int, skey=None, delta_e: int = 0):
    """Scan-based relaxation sweep (XLA): gather → emit → segmented scan
    over the sorted region → run-end gather, plus the shared-index
    scatter over the staged delta segment (``delta_e`` trailing
    positions) merged in by the monoid (:func:`stream_combine`).
    Handles single ([Np] leaves) and laned ([L, Np]) vertex blocks
    uniformly; the canonical sum path and the fast path for every laned
    program.

    ``key`` is the live-masked destination key (tombstones ``-1``) used
    for send masking; ``skey`` the structural sorted key driving the
    run layout (defaults to ``key`` — identical on delta-free graphs).

    Returns (table [..., n_keys], cnt, pay | None).
    """
    if skey is None:
        skey = key
    cand, send, pay = stream_messages(prog, vstate, senders, gid, key, src,
                                      weight, dst_gid)
    return stream_combine(prog, cand, send, pay, key, skey, n_keys,
                          delta_e)


# --------------------------------------------------------------------------
# push (frontier-compacted) sweep — work proportional to the active
# frontier's out-edge blocks instead of the whole stream (DESIGN.md §2.8)
# --------------------------------------------------------------------------

def compact_push_blocks(senders_any, push_src, block_e: int, cap: int):
    """Compact the frontier's out-edge blocks of one cell to ``cap`` slots.

    The push stream is source-sorted (``ShardedGraph.build_push_csr``), so
    a sender's out-edges are contiguous and a block is *active* iff any of
    its edges' sources is a sender.  Active block indices compact to the
    front in ascending order (stable argsort — deterministic); fill slots
    carry ``nb`` (one past the last block).  ``cap`` must bound the true
    active count — the direction selector (relax.py) guarantees it by
    picking the bucket from the measured count.

    Returns (idx [cap] int32, valid [cap] bool).
    """
    nb = push_src.shape[0] // block_e
    ok = push_src >= 0
    act = senders_any[jnp.clip(push_src, 0)] & ok            # [Eb]
    blk = act.reshape(nb, block_e).any(axis=-1)              # [nb]
    order = jnp.argsort(~blk, stable=True).astype(jnp.int32)
    idx = order[:cap]
    valid = jnp.take(blk, idx)
    return jnp.where(valid, idx, nb), valid


def push_gather(sg_push, idx, block_e: int):
    """Gather the compacted blocks' edge streams ([cap] block indices ->
    [cap * block_e] element streams).  Fill blocks (``idx == nb``) clamp
    to the last block and are neutralized by the returned ``valid`` mask.
    """
    nb = sg_push["push_src"].shape[0] // block_e
    cap = idx.shape[0]
    base = jnp.clip(idx, 0, nb - 1)[:, None] * block_e
    pos = (base + jnp.arange(block_e, dtype=jnp.int32)).reshape(-1)
    g = lambda a: jnp.take(a, pos, axis=-1)
    src = g(sg_push["push_src"])
    blk_ok = jnp.repeat(idx < nb, block_e, total_repeat_length=cap * block_e)
    valid = blk_ok & (src >= 0)
    return {
        "src": src,
        # key carries the validity (-1 on dead positions AND fill-block
        # positions), so the shared message bodies' ``key >= 0`` mask
        # covers compaction fills with no extra plumbing
        "key": jnp.where(valid, g(sg_push["push_key"]), -1),
        "weight": g(sg_push["push_weight"]),
        "dst_gid": g(sg_push["push_dst_gid"]),
        "pos": g(sg_push["push_pos"]),
    }, valid


def edge_relax_push_flat(prog, vstate, senders, gid, sg_push, n_keys: int,
                         block_e: int, cap: int):
    """Frontier-compacted push sweep, single-query min/max (order-free):
    compact -> gather -> emit -> unsorted segment-combine by destination.

    The sending-edge multiset is exactly the dense sweep's (inactive
    blocks hold no senders by construction) and min/max segment scatters
    are association-free, so the table/cnt/payload triple is bitwise-equal
    to :func:`edge_relax_flat` — structurally, via the shared
    :func:`edge_messages` / :func:`flat_combine` bodies — while touching
    O(cap * block_e) edges.
    """
    idx, _ = compact_push_blocks(senders, sg_push["push_src"], block_e, cap)
    g, _ = push_gather(sg_push, idx, block_e)
    cand, send, pay = edge_messages(prog, vstate, senders, gid, g["key"],
                                    g["src"], g["weight"], g["dst_gid"])
    ids = jnp.where(send, g["key"], n_keys)
    return flat_combine(cand, send, pay, ids, n_keys, prog.combine)


def edge_relax_push_stream(prog, vstate, senders, gid, sg_push, csr_key,
                           n_keys: int, block_e: int, cap: int, skey=None,
                           delta_e: int = 0):
    """Frontier-compacted push sweep for sum programs and all laned runs:
    compact -> gather -> emit -> scatter the messages back into the dense
    destination-sorted stream layout (via ``push_pos``) -> the shared
    :func:`stream_scan` + :func:`gather_runs` over the sorted region and
    :func:`delta_tables` over the staged delta segment (a staged edge's
    ``push_pos`` is its own delta position, so its message lands exactly
    where the dense sweep would emit it).

    Reconstructing the dense stream (identity everywhere no gathered edge
    sends — exactly what the dense sweep holds there) keeps the scan's
    fixed tree order, so the order-sensitive sum monoid and every laned
    run stay bitwise-equal to the dense path; only the gather/emit work
    shrinks to the frontier's blocks.  Laned ``senders`` [L, Np] share one
    OR-ed active set (one gather serves every lane).
    """
    if skey is None:
        skey = csr_key
    senders_any = senders if senders.ndim == 1 else senders.any(axis=0)
    idx, _ = compact_push_blocks(senders_any, sg_push["push_src"], block_e,
                                 cap)
    g, valid = push_gather(sg_push, idx, block_e)
    cand, send, pay = stream_messages(prog, vstate, senders, gid, g["key"],
                                      g["src"], g["weight"], g["dst_gid"])
    e = csr_key.shape[0]
    dpos = jnp.where(valid, g["pos"], e)               # fills dropped
    ident = prog.monoid.identity(prog.msg_dtype)
    lane = cand.shape[:-1]
    scat = lambda full, v: full.at[..., dpos].set(v, mode="drop")
    cand_full = scat(jnp.full(lane + (e,), ident, prog.msg_dtype), cand)
    send_full = scat(jnp.zeros(lane + (e,), bool),
                     jnp.broadcast_to(send, cand.shape))
    pay_full = None
    if pay is not None:
        pay_full = scat(jnp.full(lane + (e,), -1, jnp.int32),
                        jnp.broadcast_to(pay, cand.shape))
    return stream_combine(prog, cand_full, send_full, pay_full, csr_key,
                          skey, n_keys, delta_e)


def edge_relax_flat(prog, vstate, senders, gid, key, src, weight, dst_gid,
                    n_keys: int):
    """Unblocked segment-combine over the sorted stream (min/max only).

    Order-free monoids make this bitwise-equal to the blocked paths while
    doing O(E) scatter work — the engine's ``backend="xla"`` fast path.

    Returns (table [n_keys], cnt [n_keys] int32, pay [n_keys] | None).
    """
    cand, send, pay = edge_messages(prog, vstate, senders, gid, key, src,
                                    weight, dst_gid)
    ids = jnp.where(send, key, n_keys)       # non-senders dropped off-range
    return flat_combine(cand, send, pay, ids, n_keys, prog.combine)
