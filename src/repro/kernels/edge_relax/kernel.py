"""Pallas TPU program-parametric edge relaxation — the paper's memory-driven
hot loop as one kernel.

Two kernels share the engine's relaxation step (gather ``vstate[src]`` →
``prog.emit`` → segment-combine by destination), fused into a single
VMEM-resident pipeline, generalized to every registered combine
:class:`~repro.core.monoid.Monoid` and the payload path:

* :func:`edge_relax_blocks` — the blocked dense-rank kernel (min/max
  single-query path; per 128-edge block, grid-parallel);
* :func:`edge_relax_scan` — the segmented-scan kernel (sum programs and
  multi-query lanes; whole stream resident, ``ref.stream_scan`` body
  executed verbatim for bitwise parity with the XLA path).

Blocked-kernel anatomy:

* the **vertex block stays pinned in VMEM** across the whole edge stream —
  the paper's memory-driven execution model: compute (the edge sweep) moves
  to where the vertex data lives, instead of three XLA scatter passes each
  re-streaming the vertex arrays through HBM;
* edges arrive in the graph's **blocked-CSR layout** (sorted by
  ``(dst_shard, dst_local)``, ``-1``-padded to a block multiple — see
  ``ShardedGraph.with_csr``), so each block's combine is the dense-rank
  one-hot reduction shared with ``segment_reduce`` (MXU-shaped for sum);
* ``prog.emit`` / ``prog.payload`` are traced *into* the kernel body, so any
  registered vertex program (SSSP / BFS / CC / PPR / PageRank) runs on this
  path unchanged.

Phase 2 (cross-block combine of the tiny per-block partial tables) is XLA
code shared with the reference — see ops.py.  The per-block math itself
lives in ref.py (:func:`~.ref.block_combine`) and is executed verbatim here,
which is what makes the two backends bitwise-interchangeable.

Interpret-mode caveat: on CPU/GPU (CI) the kernel runs under
``pl.pallas_call(..., interpret=True)`` — same ops, no Mosaic lowering — so
the bitwise backend-equivalence tests run everywhere; compiled TPU execution
additionally wants ``n_per_shard`` padded to the lane width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import CompilerParams as _CompilerParams
from .ref import block_combine, edge_messages, stream_scan

__all__ = ["edge_relax_blocks", "edge_relax_scan", "edge_relax_push_blocks"]


def _kernel(*refs, prog, treedef, n_leaves: int, block_e: int):
    vrefs = refs[:n_leaves]
    senders_ref, gid_ref, key_ref, src_ref, w_ref, dstg_ref = (
        refs[n_leaves:n_leaves + 6]
    )
    outs = refs[n_leaves + 6:]
    vstate = jax.tree_util.tree_unflatten(
        treedef, [r[0] for r in vrefs]      # [Np] leaves, VMEM-resident
    )
    cand, send, pay = edge_messages(
        prog, vstate, senders_ref[0], gid_ref[0], key_ref[0], src_ref[0],
        w_ref[0], dstg_ref[0],
    )
    part, cnt, uniq, pay_part = block_combine(
        cand, send, key_ref[0], pay, prog.combine, block_e
    )
    outs[0][0] = part
    outs[1][0] = cnt
    outs[2][0] = uniq
    if pay_part is not None:
        outs[3][0] = pay_part


def _scan_kernel(*refs, prog, treedef, n_leaves: int):
    vrefs = refs[:n_leaves]
    senders_ref, gid_ref, key_ref, skey_ref, src_ref, w_ref, dstg_ref = (
        refs[n_leaves:n_leaves + 7]
    )
    outs = refs[n_leaves + 7:]
    vstate = jax.tree_util.tree_unflatten(
        treedef, [r[0] for r in vrefs]
    )
    cand, send, pay = edge_messages(
        prog, vstate, senders_ref[0], gid_ref[0], key_ref[0], src_ref[0],
        w_ref[0], dstg_ref[0],
    )
    v, c, p = stream_scan(prog.monoid, cand, send, skey_ref[0], pay)
    outs[0][0] = v
    outs[1][0] = c
    if p is not None:
        outs[2][0] = p


def edge_relax_scan(prog, vstate, senders, gid, key, src, weight, dst_gid,
                    skey=None, interpret: bool = False):
    """Pallas scan kernel: the whole destination-sorted stream resident in
    VMEM, combined by the segmented associative scan (``ref.stream_scan``
    executed verbatim — bitwise parity with the XLA scan path by
    construction).  The canonical ``backend="pallas"`` path for sum
    programs, whose per-destination accumulation must not depend on block
    boundaries or lane count.

    ``key`` is the live-masked destination key (send masking; tombstones
    read ``-1``) and ``skey`` the structural sorted key driving the
    scan's run layout (defaults to ``key``); the caller slices off the
    staged delta segment first — it is combined outside the kernel by
    the shared scatter pass (ops.py).

    Returns the scanned (value, count[, payload]) streams, each [E]; feed
    to ``ref.gather_runs`` for the run-end gather (shared XLA phase 2).
    """
    if skey is None:
        skey = key
    leaves, treedef = jax.tree_util.tree_flatten(vstate)
    np_ = gid.shape[0]
    e = key.shape[0]

    whole = lambda n: pl.BlockSpec((1, n), lambda: (0, 0))
    n_out = 3 if prog.with_payload else 2
    out_dtypes = [prog.msg_dtype, jnp.int32, jnp.int32][:n_out]
    outs = pl.pallas_call(
        functools.partial(_scan_kernel, prog=prog, treedef=treedef,
                          n_leaves=len(leaves)),
        in_specs=(
            [whole(np_) for _ in leaves]
            + [whole(np_), whole(np_)]          # senders, gid
            + [whole(e) for _ in range(5)]      # key, skey, src, w, dst_gid
        ),
        out_specs=[whole(e) for _ in range(n_out)],
        out_shape=[jax.ShapeDtypeStruct((1, e), dt) for dt in out_dtypes],
        interpret=interpret,
    )(
        *[leaf[None] for leaf in leaves],
        senders[None], gid[None],
        key[None], skey[None], src[None], weight[None], dst_gid[None],
    )
    v, c = outs[0][0], outs[1][0]
    p = outs[2][0] if prog.with_payload else None
    return v, c, p


def _push_kernel(idx_ref, *refs, prog, treedef, n_leaves: int, block_e: int):
    # same body as the dense blocked kernel — the grid walks the *active
    # block list* instead of every block (idx_ref is the scalar-prefetched
    # compaction; the BlockSpec index maps consumed it before this body
    # runs, so the refs already hold the gathered block)
    del idx_ref
    _kernel(*refs, prog=prog, treedef=treedef, n_leaves=n_leaves,
            block_e=block_e)


def edge_relax_push_blocks(prog, vstate, senders, gid, key, src, weight,
                           dst_gid, idx, block_e: int,
                           interpret: bool = False):
    """Frontier-compacted Pallas sweep: per-block partial tables for the
    ``cap = len(idx)`` active blocks of the *source-sorted* push stream.

    ``idx`` is the compacted active-block list
    (:func:`~.ref.compact_push_blocks`; fill slots carry ``nb``).  It is
    scalar-prefetched, and the edge-stream BlockSpecs index through it —
    grid step ``i`` DMAs block ``idx[i]`` — so only the frontier's blocks
    ever leave HBM; the vertex block stays pinned in VMEM as in the dense
    kernel.  Fill slots clamp to the last block and must be neutralized
    by the caller (``ops._mask_fill_blocks``) before the cross-block
    combine — a duplicated block is harmless for the idempotent min/max
    values but would double the sending-edge counts.

    Push blocks are not destination-sorted, so a destination may occupy
    several dense ranks within one block; the shared phase-2 scatter
    merges them (order-free min/max), keeping push bitwise-equal to the
    dense paths.  Returns (part, cnt, uniq[, pay]) each [cap, block_e].
    """
    from jax.experimental.pallas import tpu as pltpu

    leaves, treedef = jax.tree_util.tree_flatten(vstate)
    np_ = gid.shape[0]
    e = key.shape[0]
    assert e % block_e == 0, "pad the stream via ShardedGraph.with_csr"
    nb = e // block_e
    cap = idx.shape[0]

    pinned = lambda: pl.BlockSpec((1, np_), lambda i, idx: (0, 0))
    stream = lambda: pl.BlockSpec(
        (1, block_e), lambda i, idx: (0, jnp.minimum(idx[i], nb - 1)))
    out_blk = lambda: pl.BlockSpec((1, block_e), lambda i, idx: (i, 0))

    n_out = 4 if prog.with_payload else 3
    out_dtypes = [prog.msg_dtype, jnp.int32, jnp.int32, jnp.int32][:n_out]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(cap,),
        in_specs=(
            [pinned() for _ in leaves]          # vstate: whole cell, pinned
            + [pinned(), pinned()]              # senders, gid
            + [stream() for _ in range(4)]      # key, src, weight, dst_gid
        ),
        out_specs=[out_blk() for _ in range(n_out)],
    )
    outs = pl.pallas_call(
        functools.partial(_push_kernel, prog=prog, treedef=treedef,
                          n_leaves=len(leaves), block_e=block_e),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((cap, block_e), dt)
                   for dt in out_dtypes],
        interpret=interpret,
    )(
        idx,
        *[leaf[None] for leaf in leaves],
        senders[None], gid[None],
        key[None], src[None], weight[None], dst_gid[None],
    )
    part, cnt, uniq = outs[0], outs[1], outs[2]
    pay = outs[3] if prog.with_payload else None
    return part, cnt, uniq, pay


def edge_relax_blocks(prog, vstate, senders, gid, key, src, weight, dst_gid,
                      block_e: int, interpret: bool = False):
    """Per-block partial tables for one relaxation sweep of one cell.

    Inputs are this cell's vertex block ([Np] vstate leaves, ``senders``,
    ``gid``) and its destination-sorted edge streams ([Eb], Eb a multiple
    of ``block_e``).  Returns (part, cnt, uniq[, pay]) each [nb, block_e] —
    feed to ``ops._combine_blocks`` for the cross-block phase.
    """
    leaves, treedef = jax.tree_util.tree_flatten(vstate)
    np_ = gid.shape[0]
    e = key.shape[0]
    assert e % block_e == 0, "pad the stream via ShardedGraph.with_csr"
    nb = e // block_e

    pinned = lambda: pl.BlockSpec((1, np_), lambda i: (0, 0))
    stream = lambda: pl.BlockSpec((1, block_e), lambda i: (0, i))
    out_blk = lambda: pl.BlockSpec((1, block_e), lambda i: (i, 0))

    n_out = 4 if prog.with_payload else 3
    out_dtypes = [prog.msg_dtype, jnp.int32, jnp.int32, jnp.int32][:n_out]
    outs = pl.pallas_call(
        functools.partial(_kernel, prog=prog, treedef=treedef,
                          n_leaves=len(leaves), block_e=block_e),
        grid=(nb,),
        in_specs=(
            [pinned() for _ in leaves]          # vstate: whole cell, pinned
            + [pinned(), pinned()]              # senders, gid
            + [stream() for _ in range(4)]      # key, src, weight, dst_gid
        ),
        out_specs=[out_blk() for _ in range(n_out)],
        out_shape=[jax.ShapeDtypeStruct((nb, block_e), dt)
                   for dt in out_dtypes],
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(
        *[leaf[None] for leaf in leaves],
        senders[None], gid[None],
        key[None], src[None], weight[None], dst_gid[None],
    )
    part, cnt, uniq = outs[0], outs[1], outs[2]
    pay = outs[3] if prog.with_payload else None
    return part, cnt, uniq, pay
