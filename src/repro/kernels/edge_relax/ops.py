"""Public wrapper for the edge_relax kernel: backend dispatch + the shared
cross-block combine (phase 2).

The contract both backends satisfy: given one cell's vertex block and its
destination-sorted edge streams, return the combined per-destination
message table over the flat key space ``dst_shard * Np + dst_local``:

    table [n_keys] msg_dtype   combined messages (identity where none)
    cnt   [n_keys] int32       number of sending edges per destination
    pay   [n_keys] int32|None  argmin payload (min-combine programs only)

``backend="xla"`` uses the flat segment path for the order-free monoids
(min/max) and the vmapped blocked reference for sum; ``backend="pallas"``
runs the fused kernel (interpret mode off-TPU).  Both share phase 2
verbatim, and the sum paths share the per-block body, so the two backends
are bitwise-identical — asserted program-by-program in tests/test_session.
"""

from __future__ import annotations

import jax.numpy as jnp

from ...core.msg import identity_for
from ...core.relax import RELAX_BACKENDS
from .kernel import edge_relax_blocks
from .ref import edge_relax_blocks_ref, edge_relax_flat

__all__ = ["edge_relax", "RELAX_BACKENDS"]


def _combine_blocks(part, cnt, uniq, pay, n_keys: int, combine: str,
                    msg_dtype):
    """Phase 2: scatter the per-block partial tables into the flat key
    space — O(blocks * block_e) rows, shared by both backends."""
    ident = identity_for(combine, msg_dtype)
    ids = jnp.where(uniq < 0, n_keys, uniq).reshape(-1)
    p = part.reshape(-1)
    table = jnp.full((n_keys + 1,), ident, msg_dtype)
    if combine == "min":
        table = table.at[ids].min(p)
    elif combine == "max":
        table = table.at[ids].max(p)
    else:
        table = table.at[ids].add(p)
    cnt_t = jnp.zeros((n_keys + 1,), jnp.int32).at[ids].add(cnt.reshape(-1))
    pay_t = None
    if pay is not None:
        # winners: block partials equal to the globally combined value
        win = jnp.where(p == table[ids], pay.reshape(-1), -1)
        pay_t = jnp.full((n_keys + 1,), -1, jnp.int32).at[ids].max(win)
        pay_t = pay_t[:n_keys]
    return table[:n_keys], cnt_t[:n_keys], pay_t


def edge_relax(prog, vstate, senders, gid, key, src, weight, dst_gid,
               n_keys: int, block_e: int, backend: str = "xla",
               interpret: bool = False):
    """One relaxation sweep of one cell; see module docstring for the
    returned (table, cnt, pay) contract."""
    if backend not in RELAX_BACKENDS:
        raise ValueError(
            f"backend must be one of {RELAX_BACKENDS}, got {backend!r}")
    if backend == "xla":
        if prog.combine in ("min", "max"):
            return edge_relax_flat(prog, vstate, senders, gid, key, src,
                                   weight, dst_gid, n_keys)
        part, cnt, uniq, pay = edge_relax_blocks_ref(
            prog, vstate, senders, gid, key, src, weight, dst_gid, block_e)
    else:
        part, cnt, uniq, pay = edge_relax_blocks(
            prog, vstate, senders, gid, key, src, weight, dst_gid, block_e,
            interpret=interpret)
    return _combine_blocks(part, cnt, uniq, pay, n_keys, prog.combine,
                           prog.msg_dtype)
