"""Public wrapper for the edge_relax kernels: backend dispatch + the
shared phase-2 combines.

The contract both backends satisfy: given one cell's vertex block and its
destination-sorted edge streams, return the combined per-destination
message table over the flat key space ``dst_shard * Np + dst_local``:

    table [n_keys] msg_dtype   combined messages (identity where none)
    cnt   [n_keys] int32       number of sending edges per destination
    pay   [n_keys] int32|None  argbest payload (selection monoids only)

Lane-stacked inputs (``senders`` [L, Np] — multi-query lanes) broadcast
the sweep over lanes and return [L, n_keys] tables.

Dispatch: sum programs and all laned runs take the segmented-scan path
(``ref.stream_scan`` — fixed tree order, lane- and block-independent, so
lanes are bitwise-equal to solo queries); single-query min/max keeps the
flat segment path on ``xla`` and the fused blocked kernel on ``pallas``
(order-free monoids agree across all paths).  Phase 2 — the run-end
gather (scan) / cross-block scatter (blocked) — is XLA code shared
verbatim by both backends, so the two are bitwise-identical — asserted
program-by-program in tests/test_session and per-lane in
tests/test_lanes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.msg import identity_for
from ...core.relax import RELAX_BACKENDS
from .kernel import edge_relax_blocks, edge_relax_push_blocks, edge_relax_scan
from .ref import (
    compact_push_blocks,
    delta_tables,
    edge_relax_flat,
    edge_relax_push_flat,
    edge_relax_push_stream,
    edge_relax_stream,
    gather_runs,
    merge_tables,
    stream_messages,
)

__all__ = ["edge_relax", "edge_relax_push", "RELAX_BACKENDS"]


def _combine_blocks(part, cnt, uniq, pay, n_keys: int, combine: str,
                    msg_dtype):
    """Phase 2: scatter the per-block partial tables into the flat key
    space — O(blocks * block_e) rows, shared by both backends."""
    ident = identity_for(combine, msg_dtype)
    ids = jnp.where(uniq < 0, n_keys, uniq).reshape(-1)
    p = part.reshape(-1)
    table = jnp.full((n_keys + 1,), ident, msg_dtype)
    if combine == "min":
        table = table.at[ids].min(p)
    elif combine == "max":
        table = table.at[ids].max(p)
    else:
        table = table.at[ids].add(p)
    cnt_t = jnp.zeros((n_keys + 1,), jnp.int32).at[ids].add(cnt.reshape(-1))
    pay_t = None
    if pay is not None:
        # winners: block partials equal to the globally combined value
        win = jnp.where(p == table[ids], pay.reshape(-1), -1)
        pay_t = jnp.full((n_keys + 1,), -1, jnp.int32).at[ids].max(win)
        pay_t = pay_t[:n_keys]
    return table[:n_keys], cnt_t[:n_keys], pay_t


def _mask_fill_blocks(part, cnt, uniq, pay, valid):
    """Neutralize the fill slots of a power-of-two compaction bucket
    (``cap > n_active`` — their grid steps clamped to a real block whose
    contribution must not repeat): route their keys off-range and zero
    their counts so the phase-2 scatter drops them."""
    v = valid[:, None]
    uniq = jnp.where(v, uniq, -1)
    cnt = jnp.where(v, cnt, 0)
    if pay is not None:
        pay = jnp.where(v, pay, -1)
    return part, cnt, uniq, pay


def edge_relax_push(prog, vstate, senders, gid, sg_push, csr_key,
                    n_keys: int, block_e: int, cap: int,
                    backend: str = "xla", interpret: bool = False,
                    skey=None, delta_e: int = 0):
    """Frontier-compacted push sweep of one cell — the sparse twin of
    :func:`edge_relax`, same (table, cnt, pay) contract.

    ``sg_push`` holds the source-sorted streams (``ShardedGraph.
    push_view``); ``cap`` is the static compaction bucket (power-of-two
    ladder, see relax.py) and must bound the cell's true active-block
    count.  Dispatch mirrors the dense sweep: sum programs and all laned
    runs scatter their compacted messages back into the dense stream
    layout and run the shared scan (``ref.edge_relax_push_stream`` —
    bitwise-equal to the dense scan for the order-sensitive monoid, on
    either backend); single-query min/max takes the unsorted segment path
    on ``xla`` and the scalar-prefetch blocked kernel on ``pallas``
    (order-free monoids agree across all paths).  Phase 2 is the same
    shared XLA code as the dense sweep.

    A graph with a staged delta segment (``delta_e`` trailing stream
    positions, DESIGN.md §2.9) needs no special push handling on the
    flat/blocked paths — a delta block is active exactly when one of its
    staged edges' sources sends, so compaction covers it like any sorted
    block; the stream path forwards ``skey``/``delta_e`` so its dense
    reconstruction scans only the sorted region.
    """
    if backend not in RELAX_BACKENDS:
        raise ValueError(
            f"backend must be one of {RELAX_BACKENDS}, got {backend!r}")
    laned = senders.ndim == 2

    if prog.combine == "sum" or laned:
        return edge_relax_push_stream(prog, vstate, senders, gid, sg_push,
                                      csr_key, n_keys, block_e, cap,
                                      skey=skey, delta_e=delta_e)
    if backend == "xla":
        return edge_relax_push_flat(prog, vstate, senders, gid, sg_push,
                                    n_keys, block_e, cap)
    idx, valid = compact_push_blocks(senders, sg_push["push_src"], block_e,
                                     cap)
    part, cnt, uniq, pay = edge_relax_push_blocks(
        prog, vstate, senders, gid, sg_push["push_key"],
        sg_push["push_src"], sg_push["push_weight"],
        sg_push["push_dst_gid"], idx, block_e, interpret=interpret)
    part, cnt, uniq, pay = _mask_fill_blocks(part, cnt, uniq, pay, valid)
    return _combine_blocks(part, cnt, uniq, pay, n_keys, prog.combine,
                           prog.msg_dtype)


def edge_relax(prog, vstate, senders, gid, key, src, weight, dst_gid,
               n_keys: int, block_e: int, backend: str = "xla",
               interpret: bool = False, skey=None, delta_e: int = 0):
    """One relaxation sweep of one cell; see module docstring for the
    returned (table, cnt, pay) contract.

    Multi-query lanes: when ``senders`` is [L, Np] (vstate leaves [L, Np])
    the sweep broadcasts over the lane axis against the *same* edge stream
    — the kernel's gather/emit/combine runs per lane under one batched
    dispatch — and the outputs gain a leading lane axis [L, n_keys].

    Incremental streams (DESIGN.md §2.9): ``key`` is the live-masked
    destination key (tombstones read ``-1`` and never send) and ``skey``
    the structural sorted key; ``delta_e`` trailing positions are the
    staged delta segment.  The flat and blocked paths consume tombstones
    and delta blocks through their ordinary masking/scatter handling; the
    scan paths scan the sorted region against ``skey`` and fold the
    (unsorted) delta segment in through the shared
    :func:`~.ref.delta_tables` scatter."""
    if backend not in RELAX_BACKENDS:
        raise ValueError(
            f"backend must be one of {RELAX_BACKENDS}, got {backend!r}")
    if skey is None:
        skey = key
    laned = senders.ndim == 2      # [L, Np] lane-stacked vertex block

    # Sum programs take the segmented-scan path on *both* backends: its
    # fixed tree order is independent of block boundaries and lane count,
    # which is what makes a lane's sum bitwise-equal to the same query
    # run solo (laned min/max take it on xla for speed — order-free
    # monoids match every other path bitwise anyway).
    if prog.combine == "sum" or (laned and backend == "xla"):
        if backend == "xla":
            return edge_relax_stream(prog, vstate, senders, gid, key, src,
                                     weight, dst_gid, n_keys, skey=skey,
                                     delta_e=delta_e)
        es = key.shape[-1] - delta_e
        scan1 = lambda vs, sd: edge_relax_scan(
            prog, vs, sd, gid, key[:es], src[:es], weight[:es],
            dst_gid[:es], skey=skey[:es], interpret=interpret)
        scanned = (jax.vmap(scan1)(vstate, senders) if laned
                   else scan1(vstate, senders))
        out = gather_runs(scanned, skey[:es], n_keys, prog.monoid,
                          prog.msg_dtype)
        if delta_e:
            # delta tail: shared XLA phase (message bodies + scatter),
            # merged by the monoid — same code the XLA path runs
            cand, send, pay = stream_messages(
                prog, vstate, senders, gid, key[es:], src[es:],
                weight[es:], dst_gid[es:])
            out = merge_tables(prog, out, delta_tables(
                prog, cand, send, pay, key[es:], n_keys))
        return out

    if laned:                      # pallas min/max: lane-batched kernel
        return jax.vmap(
            lambda vs, sd: edge_relax(
                prog, vs, sd, gid, key, src, weight, dst_gid,
                n_keys=n_keys, block_e=block_e, backend=backend,
                interpret=interpret, skey=skey, delta_e=delta_e,
            )
        )(vstate, senders)
    if backend == "xla":
        return edge_relax_flat(prog, vstate, senders, gid, key, src,
                               weight, dst_gid, n_keys)
    part, cnt, uniq, pay = edge_relax_blocks(
        prog, vstate, senders, gid, key, src, weight, dst_gid, block_e,
        interpret=interpret)
    return _combine_blocks(part, cnt, uniq, pay, n_keys, prog.combine,
                           prog.msg_dtype)
