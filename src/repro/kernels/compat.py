"""Pallas API compatibility shims shared by every kernel.

Mirrors launch/mesh.py's role for the mesh API: version drift in the
Pallas surface is absorbed here, once.
"""

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams"]

# pallas renamed TPUCompilerParams -> CompilerParams across JAX versions
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
