"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel package has kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper w/ backend dispatch + custom VJP where trained), and
ref.py (pure-jnp oracle used for interpret-mode validation and as the
CPU/GPU execution path).
"""
from .edge_relax.ops import edge_relax
from .flash_attention.ops import attention, decode_attention
from .segment_reduce.ops import segment_sum, segment_sum_presorted
from .sssp_relax.ops import relax

__all__ = [
    "attention",
    "decode_attention",
    "edge_relax",
    "segment_sum",
    "segment_sum_presorted",
    "relax",
]
