"""Public wrapper for the fused relax kernel (forward-only; the diffusion
engine is not differentiated)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import relax_sorted
from .ref import relax_ref

__all__ = ["relax"]


def _backend_default() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def relax(dist, active, weight, src, dst_sorted, n_nodes, block_e=256,
          backend=None):
    backend = backend or _backend_default()
    if backend == "xla":
        return relax_ref(dist, weight, src, dst_sorted, active, n_nodes)
    e = weight.shape[0]
    pad = (-e) % block_e
    if pad:
        weight = jnp.pad(weight, (0, pad))
        src = jnp.pad(src, (0, pad))
        dst_sorted = jnp.pad(dst_sorted, (0, pad), constant_values=-1)
    return relax_sorted(
        dist, active, weight, src, dst_sorted, n_nodes, block_e=block_e,
        interpret=(backend == "interpret"),
    )
