"""Pallas TPU fused SSSP relaxation — the paper's hot loop as one kernel.

Fuses the three memory passes of a relaxation sweep into one VMEM-resident
pipeline: gather ``dist[src]``, add the edge weight, and segment-min by
destination.  The per-cell distance array stays pinned in VMEM across the
whole edge stream (a vertex block of 512k nodes is 2 MB — the "memory-driven"
layout: compute moves to the distances, not the other way).  The segment-min
uses the same sorted-run dense-rank trick as segment_reduce, with a masked
min instead of a matmul.

Phase 2 (XLA) min-combines the per-block partial tables — O(blocks*block_e).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import CompilerParams as _CompilerParams

__all__ = ["relax_sorted"]

INF = jnp.inf


def _kernel(dist_ref, active_ref, w_ref, src_ref, dst_ref, part_ref, uniq_ref,
            *, block_e: int):
    src = src_ref[0]                                  # [Be]
    dst = dst_ref[0]                                  # [Be] sorted, -1 pad
    valid = dst >= 0

    d_src = dist_ref[0, src]                          # VMEM gather
    act = active_ref[0, src]
    cand = jnp.where(valid & act, d_src + w_ref[0], INF)

    prev = jnp.concatenate([jnp.full((1,), -2, jnp.int32), dst[:-1]])
    new_seg = (dst != prev) & valid
    rank = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    rank = jnp.where(valid, rank, -1)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_e, block_e), 1)
    onehot = rank[:, None] == lanes                   # [Be, W]
    part = jnp.min(
        jnp.where(onehot, cand[:, None], INF), axis=0
    )                                                 # [W]
    uniq = jnp.max(jnp.where(onehot, dst[:, None], -1), axis=0)
    part_ref[0] = part
    uniq_ref[0] = uniq


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "block_e", "interpret")
)
def relax_sorted(
    dist: jnp.ndarray,        # [Np] float32 — cell-resident distances
    active: jnp.ndarray,      # [Np] bool
    weight: jnp.ndarray,      # [E] float32, edges sorted by dst
    src: jnp.ndarray,         # [E] int32 local source index
    dst_sorted: jnp.ndarray,  # [E] int32 sorted ascending, -1 = dead/pad
    n_nodes: int,
    block_e: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    e = weight.shape[0]
    assert e % block_e == 0, "pad via ops.relax"
    nblocks = e // block_e
    np_ = dist.shape[0]

    part, uniq = pl.pallas_call(
        functools.partial(_kernel, block_e=block_e),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, np_), lambda i: (0, 0)),     # dist: whole cell
            pl.BlockSpec((1, np_), lambda i: (0, 0)),     # active
            pl.BlockSpec((1, block_e), lambda i: (0, i)),
            pl.BlockSpec((1, block_e), lambda i: (0, i)),
            pl.BlockSpec((1, block_e), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_e), lambda i: (i, 0)),
            pl.BlockSpec((1, block_e), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, block_e), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, block_e), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(
        dist[None], active[None], weight[None].astype(jnp.float32),
        src[None], dst_sorted[None],
    )

    flat_ids = jnp.where(uniq.reshape(-1) < 0, n_nodes, uniq.reshape(-1))
    out = jnp.full((n_nodes + 1,), INF, jnp.float32)
    out = out.at[flat_ids].min(part.reshape(-1))
    return out[:n_nodes]
