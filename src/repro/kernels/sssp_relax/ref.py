"""Pure-jnp oracle for the fused SSSP relaxation step."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["relax_ref"]


def relax_ref(dist, weight, src, dst_sorted, active, n_nodes: int):
    """One relaxation sweep: candidates dist[src]+w from active sources,
    segment-min by (sorted) destination.  -1 dst = dead edge.

    Returns [n_nodes] candidate array (+inf where no message)."""
    cand = dist[src] + weight
    cand = jnp.where(active[src] & (dst_sorted >= 0), cand, jnp.inf)
    ids = jnp.where(dst_sorted < 0, n_nodes, dst_sorted)
    out = jax.ops.segment_min(cand, ids, num_segments=n_nodes + 1)
    return out[:n_nodes]
