"""Optimizers (optax-free): AdamW, Adafactor, SGD-momentum + schedules,
global-norm clipping, gradient accumulation, and int8 gradient compression
with error feedback (the distributed-optimization trick used by the
compressed-all-reduce data-parallel plan).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer", "adamw", "adafactor", "sgd", "cosine_schedule",
    "linear_warmup", "clip_by_global_norm", "global_norm",
    "compress_int8", "decompress_int8", "GradAccumulator",
]


class Optimizer(NamedTuple):
    init: Callable    # params -> state
    update: Callable  # (grads, state, params, step) -> (updates, state)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    # keep each leaf's dtype (an f32 scale would silently double grad memory)
    return jax.tree_util.tree_map(
        lambda x: (x * scale.astype(x.dtype)), tree
    ), g


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 100,
                    final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return lr


def linear_warmup(base_lr: float, warmup: int = 100):
    return lambda step: base_lr * jnp.minimum(
        1.0, jnp.asarray(step, jnp.float32) / max(warmup, 1)
    )


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0):
    """lr may be a float or a schedule fn(step)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = lr_fn(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        mu_hat = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), mu)
        nu_hat = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), nu)

        def upd(p, m, v):
            u = m / (jnp.sqrt(v) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, params, mu_hat, nu_hat)
        return updates, {"mu": mu, "nu": nu}

    return Optimizer(init, update)


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0):
    """Factored second-moment optimizer (Shazeer & Stern) — O(n+m) state for
    [n, m] matrices; the memory-frugal choice for 100B-param training."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree_util.tree_map(st, params,
                                      is_leaf=lambda x: hasattr(x, "shape"))

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(-1)[..., None, None], eps)
                )
                u = gf * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr_t * u).astype(p.dtype), new_s

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        updates = tdef.unflatten([o[0] for o in out])
        new_state = tdef.unflatten([o[1] for o in out])
        return updates, new_state

    return Optimizer(init, update)


def sgd(lr=1e-2, momentum=0.9, nesterov=False):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        mom = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32), mom, grads)
        else:
            upd = mom
        updates = jax.tree_util.tree_map(
            lambda p, u: (-lr_t * u).astype(p.dtype), params, upd)
        return updates, mom

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------

def compress_int8(g, err):
    """Quantize g+err to int8 with per-tensor scale; returns (q, scale,
    new_err).  Used around the data-parallel all-reduce: 4x less ICI bytes,
    error feedback keeps the optimizer unbiased over time."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass
class GradAccumulator:
    """Micro-batch gradient accumulation driver (host-side loop)."""
    n_micro: int

    def init(self, params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def add(self, acc, grads):
        return jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32) / self.n_micro, acc, grads)
