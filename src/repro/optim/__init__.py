from .optimizers import *  # noqa: F401,F403
