"""Sharded, asynchronous, elastic checkpointing.

* **Sharded save**: every pytree leaf is written as its own .npy plus a
  manifest (step, tree paths, dtypes/shapes, blake2 digests).  Writes go to
  a temp dir + atomic rename, so a preemption mid-save never corrupts the
  latest checkpoint.
* **Async**: device->host transfer happens on the caller thread (cheap),
  file IO on a background thread — training overlaps the write.  A writer
  failure is re-raised on the caller thread at the next ``wait()``/``save()``
  rather than dying silently in the daemon thread.
* **Elastic restore**: restore() takes the *target mesh + shardings*; the
  saved global arrays are device_put with the new layout, so a checkpoint
  taken on a 16x16 mesh restores onto 2x16x16, 8x8, or 1 device unchanged —
  node-failure recovery = restore onto the surviving mesh.
* **Fallback restore**: with ``step=None`` a damaged latest checkpoint
  (truncated/unparsable manifest, missing leaf file, digest mismatch) is
  skipped and the previous retained step is tried, newest-first — recovery
  degrades to an older snapshot instead of raising mid-restore.  An
  explicit ``step=`` never falls back, and when *no* retained step loads
  cleanly the last error propagates.
* Retention: keep the last ``keep`` checkpoints, prune older.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import warnings

import jax
import numpy as np

from ..core import chaos

__all__ = ["CheckpointManager"]


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None
        self._write_error: BaseException | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, wait: bool = False):
        """Snapshot ``tree`` at ``step``; returns immediately (async IO)."""
        self.wait()
        host = {}
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        for path, leaf in leaves:
            host[_path_str(path)] = np.asarray(jax.device_get(leaf))

        def _write():
            try:
                tmp = os.path.join(self.directory, f".tmp_step_{step}")
                final = os.path.join(self.directory, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                manifest = {"step": step, "leaves": {}}
                for name, arr in host.items():
                    fname = name.replace("/", "__") + ".npy"
                    np.save(os.path.join(tmp, fname), arr)
                    chaos.point("checkpoint.leaf-written")
                    manifest["leaves"][name] = {
                        "file": fname,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "digest": hashlib.blake2b(
                            arr.tobytes(), digest_size=16
                        ).hexdigest(),
                    }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                chaos.point("checkpoint.pre-rename")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._prune()
            except BaseException as e:   # surfaced on the caller thread
                self._write_error = e

        self._pending = threading.Thread(target=_write, daemon=True)
        self._pending.start()
        if wait:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._write_error is not None:
            err, self._write_error = self._write_error, None
            raise err

    def _prune(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                if os.path.exists(
                    os.path.join(self.directory, name, "manifest.json")
                ):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- loading -------------------------------------------------------

    def _load_step(self, step: int, verify: bool) -> dict[str, np.ndarray]:
        """Read + digest-check every leaf of one step; raises on any damage
        (unparsable manifest, missing file, digest mismatch)."""
        d = os.path.join(self.directory, f"step_{step}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise IOError(f"checkpoint step {step}: bad manifest ({e})")
        arrays: dict[str, np.ndarray] = {}
        for name, meta in manifest["leaves"].items():
            try:
                arr = np.load(os.path.join(d, meta["file"]))
            except (OSError, ValueError) as e:
                raise IOError(
                    f"checkpoint step {step}: leaf {name} unreadable ({e})")
            if verify:
                digest = hashlib.blake2b(arr.tobytes(),
                                         digest_size=16).hexdigest()
                if digest != meta["digest"]:
                    raise IOError(
                        f"checkpoint step {step}: leaf {name} is corrupt")
            arrays[name] = arr
        return arrays

    def _load_with_fallback(self, step: int | None, verify: bool):
        """-> (arrays, step). step=None walks retained steps newest-first."""
        if step is not None:
            return self._load_step(step, verify), step
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        last_err: Exception | None = None
        for s in reversed(steps):
            try:
                return self._load_step(s, verify), s
            except (IOError, KeyError) as e:
                warnings.warn(
                    f"checkpoint step {s} is damaged ({e}); falling back "
                    f"to the previous retained step")
                last_err = e if isinstance(e, Exception) else IOError(str(e))
        raise last_err

    def restore_flat(self, step: int | None = None, verify: bool = True):
        """Load a checkpoint as a flat {path-string: np.ndarray} dict.

        Manifest-driven — no target structure needed (the session
        save/open path reconstructs its own pytree from these names).
        Returns ``(arrays, step)``; ``step=None`` falls back past damaged
        steps, newest-first."""
        self.wait()
        return self._load_with_fallback(step, verify)

    def restore(self, target_tree, step: int | None = None, shardings=None,
                verify: bool = True):
        """Restore into the structure of ``target_tree``.

        shardings: optional matching pytree of Shardings (the *new* mesh's
        layout — this is the elastic-rescale path)."""
        arrays, step = self._load_with_fallback(step, verify)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for (path, leaf), sh in zip(leaves, shard_leaves):
            arr = arrays[_path_str(path)]
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(
            treedef, out
        ), step
