"""Sharded, asynchronous, elastic checkpointing.

* **Sharded save**: every pytree leaf is written as its own .npy plus a
  manifest (step, tree paths, dtypes/shapes, blake2 digests).  Writes go to
  a temp dir + atomic rename, so a preemption mid-save never corrupts the
  latest checkpoint.
* **Async**: device->host transfer happens on the caller thread (cheap),
  file IO on a background thread — training overlaps the write.
* **Elastic restore**: restore() takes the *target mesh + shardings*; the
  saved global arrays are device_put with the new layout, so a checkpoint
  taken on a 16x16 mesh restores onto 2x16x16, 8x8, or 1 device unchanged —
  node-failure recovery = restore onto the surviving mesh.
* Retention: keep the last ``keep`` checkpoints, prune older.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, wait: bool = False):
        """Snapshot ``tree`` at ``step``; returns immediately (async IO)."""
        self.wait()
        host = {}
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        for path, leaf in leaves:
            host[_path_str(path)] = np.asarray(jax.device_get(leaf))

        def _write():
            tmp = os.path.join(self.directory, f".tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": {}}
            for name, arr in host.items():
                fname = name.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][name] = {
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "digest": hashlib.blake2b(
                        arr.tobytes(), digest_size=16
                    ).hexdigest(),
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._prune()

        self._pending = threading.Thread(target=_write, daemon=True)
        self._pending.start()
        if wait:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _prune(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                if os.path.exists(
                    os.path.join(self.directory, name, "manifest.json")
                ):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, step: int | None = None, shardings=None,
                verify: bool = True):
        """Restore into the structure of ``target_tree``.

        shardings: optional matching pytree of Shardings (the *new* mesh's
        layout — this is the elastic-rescale path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for (path, leaf), sh in zip(leaves, shard_leaves):
            name = _path_str(path)
            meta = manifest["leaves"][name]
            arr = np.load(os.path.join(d, meta["file"]))
            if verify:
                digest = hashlib.blake2b(arr.tobytes(),
                                         digest_size=16).hexdigest()
                if digest != meta["digest"]:
                    raise IOError(f"checkpoint leaf {name} is corrupt")
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(
            treedef, out
        ), step
