"""Per-family logical sharding rules and parameter shardings.

One rules dict per model family maps logical dim names to mesh axes; the
same model code then shards correctly on a (data, model) pod mesh or a
(pod, data, model) two-pod mesh (DESIGN.md §3).
"""

from __future__ import annotations

from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["logical_rules", "param_sharding", "FAMILIES"]

FAMILIES = ("lm", "gnn_geometric", "gnn_scalar", "recsys")


def _data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def logical_rules(mesh, family: str) -> dict:
    """Logical dim name -> mesh axes for ``family`` on ``mesh``."""
    data = _data_axes(mesh)
    if family == "lm":
        return {
            "batch": data,
            "seq": (),
            "embed": (),
            "heads": "model",
            "kv_heads": "model",
            "ffn": "model",
            "vocab": "model",
            "experts": "model",
        }
    if family in ("gnn_geometric", "gnn_scalar"):
        return {
            "nodes": data,
            "edges": data,
            "channels": "model",
        }
    if family == "recsys":
        return {
            "batch": data,
            "embed": "model",
            "candidates": data + ("model",),
        }
    raise ValueError(f"unknown rules family {family!r}")


def param_sharding(params_struct, mesh, family: str):
    """NamedSharding pytree for a parameter struct: shard the largest dim
    of every big leaf over the model axis (tensor parallelism); replicate
    small leaves.  Memory-driven rather than name-driven — the layout the
    dry-runs use to prove the big configs fit."""
    import jax

    model = mesh.shape.get("model", 1)

    def pick(leaf):
        shape = leaf.shape
        if model <= 1 or len(shape) == 0 or max(shape) < 1024:
            return NamedSharding(mesh, P())
        dim = max(range(len(shape)), key=lambda i: shape[i])
        if shape[dim] % model != 0:
            return NamedSharding(mesh, P())
        entries = [None] * len(shape)
        entries[dim] = "model"
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map(pick, params_struct)
