"""GPipe pipeline parallelism over a mesh axis (DESIGN.md §3).

One stage per device along ``axis``; micro-batches stream through the
stages with a ``ppermute`` shift per tick.  The schedule runs
``n_micro + n_stages - 1`` ticks; the classic bubble fraction is
``(S - 1) / (M + S - 1)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["make_pipeline_fn", "bubble_fraction"]


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Idle fraction of the GPipe schedule (fill + drain)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def make_pipeline_fn(mesh, stage_fn, n_stages: int, n_micro: int,
                     axis: str = "pod"):
    """Build ``(ws [S, ...], xs [M, ...]) -> ys [M, ...]`` running
    ``stage_fn(w_s, x)`` for stages s = 0..S-1 in sequence over every
    micro-batch.

    ``ws`` is stage-sharded over ``axis``; ``xs`` is replicated (stage 0
    injects micro-batches, the last stage collects outputs, merged with a
    psum so the result is replicated).
    """
    S, M = n_stages, n_micro
    fwd = [(i, (i + 1) % S) for i in range(S)]

    def per_device(ws, xs):
        w = ws[0]                       # my stage's weights
        stage = lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])     # activation arriving from stage-1
        ys = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, ys = carry
            mb_in = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(stage == 0, xs[mb_in], buf)
            out = stage_fn(w, x_in)
            mb_out = t - (S - 1)
            write = (stage == S - 1) & (mb_out >= 0)
            slot = jnp.clip(mb_out, 0, M - 1)
            ys = ys.at[slot].set(jnp.where(write, out, ys[slot]))
            buf = lax.ppermute(out, axis, fwd)
            return (buf, ys), None

        (_, ys), _ = lax.scan(tick, (buf, ys), jnp.arange(M + S - 1))
        # only the last stage wrote outputs; psum replicates them
        return lax.psum(ys, axis)

    return shard_map(
        per_device,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
