"""Distribution machinery shared by every cell: logical sharding rules
(GSPMD annotations by *name*, not by mesh axis), pipeline parallelism,
compressed data-parallel all-reduce, and the MoE expert-parallel plan.

See DESIGN.md §3 for how these compose with the diffusive engine's
operon routing.
"""

from . import rules  # noqa: F401
from .sharding import (  # noqa: F401
    current_context,
    logical_constraint,
    moe_apply,
    sharding_context,
)
