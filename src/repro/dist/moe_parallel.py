"""MoE expert-parallel plan (DESIGN.md §3).

The plan is a plain dict consumed by :func:`repro.dist.sharding.moe_apply`:
it names the mesh, the token (data) axes, the tensor (model) axis carrying
the d_ff shards, and the FSDP axis for parameter storage.  Keeping it a
dict keeps the contract between the cell builders and the sharding layer
serializable and inspectable.
"""

from __future__ import annotations

__all__ = ["make_moe_plan"]


def make_moe_plan(mesh, data_axes=("data",), model_axis: str = "model",
                  fsdp_axis: str = "data") -> dict:
    """Build the expert-parallel plan for ``mesh``.

    data_axes: mesh axes tokens are sharded over (("pod", "data") on the
    two-pod mesh).  model_axis: the d_ff / expert tensor axis.  fsdp_axis:
    where expert parameters are stored when sharded at rest.
    """
    data_axes = tuple(a for a in data_axes if a in mesh.shape)
    n_tensor = mesh.shape.get(model_axis, 1)
    return {
        "mesh": mesh,
        "data_axes": data_axes,
        "model_axis": model_axis,
        "fsdp_axis": fsdp_axis,
        "n_tensor_shards": n_tensor,
    }
