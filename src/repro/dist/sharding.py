"""Logical sharding: name-based GSPMD constraints (DESIGN.md §3).

Model code annotates tensors with *logical* dimension names ("batch",
"heads", "nodes", "channels", ...).  A :func:`sharding_context` binds those
names to mesh axes through a rules dict; :func:`logical_constraint` turns
the names into ``with_sharding_constraint`` calls, silently dropping axes
that do not apply (indivisible dims, axes already claimed by an earlier
dim, axes missing from the mesh).  Outside a context — or outside a trace —
it is the identity, so the same model code runs single-device eagerly and
sharded under jit without edits.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from math import prod

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "sharding_context",
    "current_context",
    "logical_constraint",
    "moe_apply",
]

_STATE = threading.local()


def current_context() -> dict | None:
    """The innermost active sharding context, or None.

    The context is a dict with keys ``mesh``, ``rules`` (logical name ->
    tuple of mesh axis names) and ``plan`` (MoE expert-parallel plan or
    None).  Model code may read it to build explicit shard_map paths.
    """
    stack = getattr(_STATE, "stack", None)
    return stack[-1] if stack else None


def _as_axes(axes) -> tuple:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


@contextmanager
def sharding_context(mesh, rules: dict, plan: dict | None = None):
    """Bind logical dimension names to mesh axes for the enclosed scope.

    ``rules`` values may be a mesh axis name, a tuple of axis names, or
    None; they are stored verbatim (model code reads them back through
    :func:`current_context`) and normalized at constraint time.
    """
    ctx = {"mesh": mesh, "rules": dict(rules), "plan": plan}
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(ctx)
    try:
        yield ctx
    finally:
        stack.pop()


def _spec_for(shape, names, mesh, rules):
    """Resolve logical names to a PartitionSpec, first-come-first-served.

    Each mesh axis may be claimed by at most one dim; an axis is dropped
    when the dim size is not divisible by it (GSPMD would pad — we prefer
    the unsharded layout), keeping any divisible prefix of a multi-axis
    rule.
    """
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, names):
        axes = _as_axes(rules.get(name)) if name is not None else ()
        picked = []
        size = 1
        for a in axes:
            if a in used or a not in mesh.shape:
                continue
            nxt = size * mesh.shape[a]
            if dim % nxt != 0:
                break
            picked.append(a)
            size = nxt
        used.update(picked)
        if not picked:
            entries.append(None)
        elif len(picked) == 1:
            entries.append(picked[0])
        else:
            entries.append(tuple(picked))
    return P(*entries)


def logical_constraint(x, *names):
    """Constrain ``x``'s layout by logical dim names (None = unsharded).

    Identity outside a sharding context or outside a jit trace.
    """
    ctx = current_context()
    if ctx is None or len(names) != getattr(x, "ndim", -1):
        return x
    mesh, rules = ctx["mesh"], ctx["rules"]
    spec = _spec_for(x.shape, names, mesh, rules)
    if not isinstance(x, jax.core.Tracer):
        # eager arrays: the constraint is a layout hint for the compiler;
        # committing data here would silently devolve into a device_put.
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# MoE expert-parallel apply
# ---------------------------------------------------------------------------

# sharding of the MoE parameter pytree under the expert plan: router
# replicated, gate/up sharded over d_ff, down sharded over its d_ff input —
# the partial-sum layout moe_ffn documents (one psum, inserted by GSPMD).
_MOE_PARAM_DIMS = {
    "router": (None, None),
    "w_gate": (None, None, "model"),
    "w_up": (None, None, "model"),
    "w_down": (None, "model", None),
}


def moe_apply(fn, params, x):
    """Run an MoE layer ``fn(params, x2d) -> (y2d, aux)`` under the active
    expert-parallel plan (DESIGN.md §3), or plainly when no plan is bound.
    """
    ctx = current_context()
    plan = ctx.get("plan") if ctx else None
    if plan is None or not isinstance(x, jax.core.Tracer):
        return fn(params, x)
    mesh = plan["mesh"]
    model = plan["model_axis"]
    data = tuple(plan["data_axes"])

    def pin(leaf, dims):
        entries = []
        for d, tag in zip(leaf.shape, dims):
            if tag == "model" and model in mesh.shape and \
                    d % mesh.shape[model] == 0:
                entries.append(model)
            else:
                entries.append(None)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, P(*entries))
        )

    params = {
        k: pin(v, _MOE_PARAM_DIMS.get(k, (None,) * v.ndim))
        for k, v in params.items()
    }
    n_data = prod(mesh.shape[a] for a in data if a in mesh.shape)
    tok_spec = data if n_data > 1 and x.shape[0] % n_data == 0 else None
    x = jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(tok_spec, None))
    )
    y, aux = fn(params, x)
    y = jax.lax.with_sharding_constraint(
        y, NamedSharding(mesh, P(tok_spec, None))
    )
    return y, aux
