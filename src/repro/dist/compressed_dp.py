"""Compressed data-parallel all-reduce: int8 gradients + error feedback.

4x fewer ICI bytes on the DP axis; the quantization residual is carried in
an error state and re-added next step, so the optimizer stays unbiased over
time (DESIGN.md §3).  Builds on the same compress/decompress pair the
optimizer exposes (repro.optim.optimizers).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["compressed_psum_mean", "init_error_state"]


def init_error_state(params):
    """Zero residual per gradient leaf (f32 regardless of param dtype)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _compress_leaf(g, err, axis_name):
    gf = g.astype(jnp.float32) + err
    # common scale across the DP axis so every shard dequantizes the psum
    # identically (bitwise-equal means on all shards)
    scale = lax.pmax(jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12), axis_name)
    scale = scale / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compressed_psum_mean(grads, err_state, axis_name: str, n_shards: int):
    """Per-leaf int8-quantized psum-mean over ``axis_name``.

    grads / err_state: matching pytrees of per-shard gradient contributions
    and error-feedback residuals.  Returns (mean pytree, new err pytree).
    Must be called inside shard_map over ``axis_name``.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = treedef.flatten_up_to(err_state)
    means, new_errs = [], []
    for g, e in zip(leaves, errs):
        q, scale, ne = _compress_leaf(g, e, axis_name)
        total = lax.psum(q.astype(jnp.int32), axis_name)
        means.append(total.astype(jnp.float32) * scale / n_shards)
        new_errs.append(ne)
    return (
        jax.tree_util.tree_unflatten(treedef, means),
        jax.tree_util.tree_unflatten(treedef, new_errs),
    )
