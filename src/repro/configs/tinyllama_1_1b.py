"""tinyllama-1.1b [arXiv:2401.02385]: 22L d_model=2048 32H (GQA kv=4)
d_ff=5632 vocab=32000 — llama2 architecture, RMSNorm + SwiGLU + RoPE."""
import jax.numpy as jnp
from ..models.transformer import TransformerConfig

ARCH_ID = "tinyllama-1.1b"
FAMILY = "lm"


def make_config(dtype=jnp.bfloat16, **kw):
    return TransformerConfig(
        name=ARCH_ID, n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=5632, vocab=32000, head_dim=64, qkv_bias=False, norm="rmsnorm",
        act="silu", rope_theta=10_000.0, tie_embeddings=False, dtype=dtype,
        **kw,
    )


def smoke_config(**kw):
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=256, norm="rmsnorm",
        tie_embeddings=False, **kw,
    )
