"""Architecture registry: --arch <id> resolution + cell enumeration."""

from __future__ import annotations

from . import (
    command_r_plus_104b,
    equiformer_v2,
    gatedgcn,
    grok_1_314b,
    mace,
    meshgraphnet,
    phi3_5_moe_42b,
    qwen2_7b,
    tinyllama_1_1b,
    two_tower_retrieval,
)
from .shapes import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES, SKIPPED_CELLS

__all__ = ["ARCHS", "get_module", "shapes_for", "cells", "SKIPPED_CELLS"]

_MODULES = [
    command_r_plus_104b,
    tinyllama_1_1b,
    qwen2_7b,
    grok_1_314b,
    phi3_5_moe_42b,
    equiformer_v2,
    gatedgcn,
    meshgraphnet,
    mace,
    two_tower_retrieval,
]

ARCHS = {m.ARCH_ID: m for m in _MODULES}


def get_module(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}"
        )
    return ARCHS[arch_id]


def shapes_for(arch_id: str) -> dict:
    fam = get_module(arch_id).FAMILY
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[fam]


def cells(include_skipped: bool = False):
    """Yield (arch_id, shape_name, skipped_reason | None)."""
    for arch_id in ARCHS:
        for shape_name in shapes_for(arch_id):
            reason = SKIPPED_CELLS.get((arch_id, shape_name))
            if reason is None or include_skipped:
                yield arch_id, shape_name, reason
