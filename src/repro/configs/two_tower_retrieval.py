"""two-tower-retrieval [Yi et al., RecSys'19]: embed_dim=256 tower MLP
1024-512-256, dot interaction, sampled softmax with logQ correction."""
from ..models.recsys import TwoTowerConfig

ARCH_ID = "two-tower-retrieval"
FAMILY = "recsys"


def make_config(**kw):
    return TwoTowerConfig(
        name=ARCH_ID, embed_dim=256, tower_mlp=(1024, 512, 256),
        n_user_fields=8, bag_len=16, user_vocab=2_000_000,
        item_vocab=2_000_000, n_dense=13, **kw,
    )


def smoke_config(**kw):
    return TwoTowerConfig(
        name=ARCH_ID + "-smoke", embed_dim=16, tower_mlp=(32, 16),
        n_user_fields=3, bag_len=4, user_vocab=500, item_vocab=500,
        n_dense=5, **kw,
    )
