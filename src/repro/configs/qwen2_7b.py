"""qwen2-7b [arXiv:2407.10671]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — QKV bias, RMSNorm + SwiGLU + RoPE(1e6)."""
import jax.numpy as jnp
from ..models.transformer import TransformerConfig

ARCH_ID = "qwen2-7b"
FAMILY = "lm"


def make_config(dtype=jnp.bfloat16, **kw):
    return TransformerConfig(
        name=ARCH_ID, n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab=152064, head_dim=128, qkv_bias=True,
        norm="rmsnorm", act="silu", rope_theta=1_000_000.0,
        tie_embeddings=False, dtype=dtype, **kw,
    )


def smoke_config(**kw):
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=256, qkv_bias=True, norm="rmsnorm",
        tie_embeddings=False, **kw,
    )
