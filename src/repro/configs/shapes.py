"""The assigned input-shape cells (40 total across 10 architectures).

Each family has its own shape set; ``long_500k`` is skipped for the five
pure-full-attention LM archs per the assignment (noted in DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses

__all__ = ["LMShape", "GraphShape", "RecsysShape", "LM_SHAPES", "GNN_SHAPES",
           "RECSYS_SHAPES", "SKIPPED_CELLS"]


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    mode: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    needs_subquadratic: bool = False


@dataclasses.dataclass(frozen=True)
class GraphShape:
    name: str
    mode: str          # full | sampled | batched
    n_nodes: int
    n_edges: int
    d_feat: int = 0
    n_classes: int = 0
    batch_nodes: int = 0          # sampled mode
    fanout: tuple = ()
    batch_graphs: int = 1         # batched-small-graphs mode
    edge_chunks: int = 1          # memory plan for the big cells


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    mode: str          # train | serve | retrieval
    batch: int
    n_candidates: int = 0


LM_SHAPES = {
    "train_4k": LMShape("train_4k", "train", 4_096, 256),
    "prefill_32k": LMShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": LMShape("decode_32k", "decode", 32_768, 128),
    "long_500k": LMShape("long_500k", "decode", 524_288, 1,
                         needs_subquadratic=True),
}

GNN_SHAPES = {
    "full_graph_sm": GraphShape(
        "full_graph_sm", "full", 2_708, 10_556, d_feat=1_433, n_classes=7
    ),
    "minibatch_lg": GraphShape(
        "minibatch_lg", "sampled", 232_965, 114_615_892, d_feat=602,
        n_classes=41, batch_nodes=1_024, fanout=(15, 10),
    ),
    "ogb_products": GraphShape(
        "ogb_products", "full", 2_449_029, 61_859_140, d_feat=100,
        n_classes=47, edge_chunks=64,
    ),
    "molecule": GraphShape(
        "molecule", "batched", 30, 64, batch_graphs=128,
    ),
}

RECSYS_SHAPES = {
    "train_batch": RecsysShape("train_batch", "train", 65_536),
    "serve_p99": RecsysShape("serve_p99", "serve", 512),
    "serve_bulk": RecsysShape("serve_bulk", "serve", 262_144),
    "retrieval_cand": RecsysShape("retrieval_cand", "retrieval", 1,
                                  n_candidates=1_000_000),
}

# (arch, shape) cells not run, with the reason recorded for EXPERIMENTS.md
SKIPPED_CELLS = {
    (arch, "long_500k"): (
        "long_500k requires sub-quadratic attention; this arch is pure "
        "full (GQA) attention — skipped per assignment rule"
    )
    for arch in [
        "command-r-plus-104b", "tinyllama-1.1b", "qwen2-7b",
        "grok-1-314b", "phi3.5-moe-42b-a6.6b",
    ]
}
