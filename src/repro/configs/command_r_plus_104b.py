"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-plus; unverified]:
64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000 — parallel
attn||FFN block, LayerNorm, no biases, tied embeddings."""
import jax.numpy as jnp
from ..models.transformer import TransformerConfig

ARCH_ID = "command-r-plus-104b"
FAMILY = "lm"


def make_config(dtype=jnp.bfloat16, **kw):
    return TransformerConfig(
        name=ARCH_ID, n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=33792, vocab=256000, head_dim=128, qkv_bias=False,
        norm="layernorm", parallel_block=True, act="silu",
        rope_theta=75_000_000.0, tie_embeddings=True, logit_scale=0.0625,
        dtype=dtype, **kw,
    )


def smoke_config(**kw):
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=256, vocab=512, head_dim=16, qkv_bias=False,
        norm="layernorm", parallel_block=True, act="silu",
        tie_embeddings=True, logit_scale=0.0625, **kw,
    )
