"""meshgraphnet [arXiv:2010.03409]: 15L d_hidden=128 sum aggregation,
2-layer MLPs, encode-process-decode."""
from ..models.gnn.meshgraphnet import MeshGraphNetConfig

ARCH_ID = "meshgraphnet"
FAMILY = "gnn"
NEEDS_GEOMETRY = False


def make_config(d_node_in=8, d_edge_in=4, d_out=3, **kw):
    return MeshGraphNetConfig(
        name=ARCH_ID, n_layers=15, d_hidden=128, mlp_layers=2,
        d_node_in=d_node_in, d_edge_in=d_edge_in, d_out=d_out, **kw,
    )


def smoke_config(**kw):
    return MeshGraphNetConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16, mlp_layers=2,
        d_node_in=8, d_edge_in=4, d_out=3, **kw,
    )
