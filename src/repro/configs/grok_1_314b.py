"""grok-1-314b [hf:xai-org/grok-1; unverified]: 64L d_model=6144 48H
(GQA kv=8) vocab=131072, MoE 8 experts top-2 with d_ff=32768 per expert;
attention + output logit soft-capping at 30."""
import jax.numpy as jnp
from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig

ARCH_ID = "grok-1-314b"
FAMILY = "lm"


def make_config(dtype=jnp.bfloat16, **kw):
    return TransformerConfig(
        name=ARCH_ID, n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab=131072, head_dim=128, qkv_bias=False,
        norm="rmsnorm", act="gelu", rope_theta=10_000.0,
        attn_softcap=30.0, logit_softcap=30.0, tie_embeddings=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32768, act="gelu"),
        dtype=dtype, **kw,
    )


def smoke_config(**kw):
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, norm="rmsnorm", act="gelu",
        attn_softcap=30.0, logit_softcap=30.0, tie_embeddings=True,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=128, act="gelu"), **kw,
    )
