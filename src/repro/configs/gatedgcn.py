"""gatedgcn [arXiv:2003.00982]: 16L d_hidden=70 gated aggregation."""
from ..models.gnn.gatedgcn import GatedGCNConfig

ARCH_ID = "gatedgcn"
FAMILY = "gnn"
NEEDS_GEOMETRY = False


def make_config(d_in=1433, n_classes=7, **kw):
    return GatedGCNConfig(
        name=ARCH_ID, n_layers=16, d_hidden=70, d_in=d_in,
        n_classes=n_classes, **kw,
    )


def smoke_config(**kw):
    return GatedGCNConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_hidden=16, d_in=12,
        n_classes=4, **kw,
    )
