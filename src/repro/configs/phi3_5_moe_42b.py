"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]: 32L
d_model=4096 32H (GQA kv=8) vocab=32064, MoE 16 experts top-2 with
d_ff=6400 per expert; LayerNorm + attention bias (PhiMoE)."""
import jax.numpy as jnp
from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"
FAMILY = "lm"


def make_config(dtype=jnp.bfloat16, **kw):
    return TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=6400, vocab=32064, head_dim=128, qkv_bias=True,
        norm="layernorm", act="silu", rope_theta=10_000.0,
        tie_embeddings=False,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400, act="silu"),
        dtype=dtype, **kw,
    )


def smoke_config(**kw):
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab=256, qkv_bias=True, norm="layernorm",
        tie_embeddings=False,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=96), **kw,
    )
