"""mace [arXiv:2206.07697]: 2L d_hidden=128 l_max=2 correlation=3 n_rbf=8
E(3)-equivariant ACE higher-order message passing."""
from ..models.gnn.mace import MACEConfig

ARCH_ID = "mace"
FAMILY = "gnn"
NEEDS_GEOMETRY = True


def make_config(**kw):
    return MACEConfig(
        name=ARCH_ID, n_layers=2, d_hidden=128, l_max=2, correlation=3,
        n_rbf=8, **kw,
    )


def smoke_config(**kw):
    return MACEConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_hidden=8, l_max=2,
        correlation=3, n_rbf=4, n_species=5, **kw,
    )
