"""equiformer-v2 [arXiv:2306.12059]: 12L d_hidden=128 l_max=6 m_max=2
8 heads — SO(2)-eSCN equivariant graph attention."""
from ..models.gnn.equiformer_v2 import EquiformerV2Config

ARCH_ID = "equiformer-v2"
FAMILY = "gnn"
NEEDS_GEOMETRY = True


def make_config(**kw):
    return EquiformerV2Config(
        name=ARCH_ID, n_layers=12, d_hidden=128, l_max=6, m_max=2,
        n_heads=8, **kw,
    )


def smoke_config(**kw):
    return EquiformerV2Config(
        name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16, l_max=2, m_max=2,
        n_heads=2, n_species=5, **kw,
    )
