"""Append-only write-ahead update journal (DESIGN.md §2.13).

Durability half one: every ``DiffusionSession.commit()`` first appends
the batch's logical op groups here, then mutates the graph.  A crash at
any point after the append loses no committed mutation — ``open()``
replays the journal tail on top of the latest snapshot, and the redo of
each record goes through the same ``UpdateBatch.apply`` compiled program
the live commit used, so the recovered state is bitwise-equal.

Frame format (little-endian), one frame per record::

    magic   4s   b"RJ1\\n"
    seq     u64  strictly increasing record number
    length  u32  payload byte count
    digest  16s  blake2b-16 of (seq || payload)
    payload      5x u32 op-group counts, then the op arrays:
                 vadds  int64 [n,3]  (gid, owner shard, local slot)
                 vdels  int64 [n]
                 eadds  int64 [n,2] + float64 [n]  (u, v) + weight
                 edels  int64 [n,2]
                 touch  int64 [n]

The payload is the *logical* batch (the lists ``_pack_ops`` consumes),
not the padded device arrays: replay rebuilds an ``UpdateBatch`` and
re-packs, so NameServer allocation, replica routing, and the compaction
policy all re-run exactly as they did live.  Weights are journaled as
float64 (the Python float the caller passed) so the replayed float32
narrowing is bit-identical.

Torn tails: a crash mid-append leaves a partial frame; the opening scan
validates magic, length bounds, digest, and seq monotonicity, and
physically truncates the file at the first bad frame.  Everything before
it is intact (each frame is self-checking), so a torn tail costs at most
the one record whose commit never finished.

fsync policy: ``"always"`` (default) fsyncs every append — a record is
durable when ``commit()`` returns; ``"batch"`` flushes to the OS but
lets the kernel schedule the disk write (journal survives process death,
not power loss); ``"never"`` leaves appends in the stdio buffer until
close/truncate (fastest, weakest).

Snapshot coordination: seqs are never reused — a snapshot taken at
``next_seq == s`` is tagged ``s``, and recovery replays records with
``seq >= s`` on top of it.  ``truncate(keep_from_seq)`` garbage-collects
the journal head up to the *oldest retained* snapshot, so falling back
past a corrupt snapshot still finds every record it needs.
"""

from __future__ import annotations

import hashlib
import os
import struct
from typing import Iterator, NamedTuple

import numpy as np

from . import chaos

__all__ = ["UpdateJournal", "OpRecord", "JournalError"]

_MAGIC = b"RJ1\n"
_HEADER = struct.Struct("<4sQI16s")      # magic, seq, length, digest
_MAX_PAYLOAD = 1 << 30                   # sanity bound for the scan

FSYNC_POLICIES = ("always", "batch", "never")


class JournalError(RuntimeError):
    """A structurally invalid journal operation (not a torn tail)."""


class OpRecord(NamedTuple):
    """One journaled commit: the logical op groups of an UpdateBatch."""

    vadds: np.ndarray    # int64 [n, 3] (gid, shard, local)
    vdels: np.ndarray    # int64 [n]
    eadds: np.ndarray    # int64 [n, 2] (u, v)
    ea_w: np.ndarray     # float64 [n] weights, aligned with eadds
    edels: np.ndarray    # int64 [n, 2] (u, v)
    touch: np.ndarray    # int64 [n]

    @classmethod
    def from_batch(cls, batch) -> "OpRecord":
        """Capture an UpdateBatch's pending ops (before apply clears them)."""
        return cls.from_ops(batch._vadds, batch._vdels, batch._eadds,
                            batch._edels, batch._touch)

    @classmethod
    def from_ops(cls, vadds, vdels, eadds, edels, touch) -> "OpRecord":
        i8 = np.int64
        return cls(
            vadds=np.asarray(list(vadds), i8).reshape(-1, 3),
            vdels=np.asarray(list(vdels), i8).reshape(-1),
            eadds=np.asarray([(u, v) for u, v, _ in eadds], i8).reshape(-1, 2),
            ea_w=np.asarray([w for _, _, w in eadds], np.float64).reshape(-1),
            edels=np.asarray(list(edels), i8).reshape(-1, 2),
            touch=np.asarray(list(touch), i8).reshape(-1),
        )

    @property
    def n_ops(self) -> int:
        return (self.vadds.shape[0] + self.vdels.shape[0]
                + self.eadds.shape[0] + self.edels.shape[0]
                + self.touch.shape[0])


def _encode(rec: OpRecord) -> bytes:  # analysis: allow(host-loop): WAL serialization is host I/O by design, never inside a diffusion round
    counts = struct.pack(
        "<5I", rec.vadds.shape[0], rec.vdels.shape[0], rec.eadds.shape[0],
        rec.edels.shape[0], rec.touch.shape[0])
    parts = [counts]
    for arr, dt in ((rec.vadds, "<i8"), (rec.vdels, "<i8"),
                    (rec.eadds, "<i8"), (rec.ea_w, "<f8"),
                    (rec.edels, "<i8"), (rec.touch, "<i8")):
        parts.append(np.ascontiguousarray(arr, dt).tobytes())
    return b"".join(parts)


def _decode(payload: bytes) -> OpRecord:
    n_va, n_vd, n_ea, n_ed, n_t = struct.unpack_from("<5I", payload, 0)
    off = struct.calcsize("<5I")

    def take(n, shape, dt):  # analysis: allow(host-sync): decodes host bytes — np only, no device values
        nonlocal off
        nbytes = int(np.prod(shape, dtype=np.int64)) * n * 8
        a = np.frombuffer(payload, dt, count=n * int(np.prod(shape)),
                          offset=off).reshape((n,) + shape).copy()
        off += nbytes
        return a

    return OpRecord(
        vadds=take(n_va, (3,), "<i8"),
        vdels=take(n_vd, (), "<i8").reshape(-1),
        eadds=take(n_ea, (2,), "<i8"),
        ea_w=take(n_ea, (), "<f8").reshape(-1),
        edels=take(n_ed, (2,), "<i8"),
        touch=take(n_t, (), "<i8").reshape(-1),
    )


def _digest(seq: int, payload: bytes) -> bytes:
    return hashlib.blake2b(struct.pack("<Q", seq) + payload,
                           digest_size=16).digest()


class UpdateJournal:
    """One append-only journal file; see the module docstring."""

    def __init__(self, path: str, fsync: str = "always"):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(
                f"fsync must be one of {FSYNC_POLICIES}, got {fsync!r}")
        self.path = str(path)
        self.fsync = fsync
        self._scan_and_repair()
        self._f = open(self.path, "ab")
        # the last append's file offset, for rollback of a failed apply
        self._last_off: int | None = None

    # -- opening scan -----------------------------------------------------

    def _scan_and_repair(self) -> None:
        """Walk the frames; truncate the file at the first bad one."""
        self._next_seq = 0
        self._last_seq: int | None = None
        if not os.path.exists(self.path):
            open(self.path, "ab").close()
            self._size = 0
            return
        size = os.path.getsize(self.path)
        good = 0
        with open(self.path, "rb") as f:
            while True:
                head = f.read(_HEADER.size)
                if len(head) < _HEADER.size:
                    break
                magic, seq, length, digest = _HEADER.unpack(head)
                if (magic != _MAGIC or length > _MAX_PAYLOAD
                        or good + _HEADER.size + length > size):
                    break
                payload = f.read(length)
                if len(payload) < length or _digest(seq, payload) != digest:
                    break
                if self._last_seq is not None and seq <= self._last_seq:
                    break       # non-monotonic seq: treat as corruption
                self._last_seq = seq
                good += _HEADER.size + length
        if good < size:
            with open(self.path, "rb+") as f:
                f.truncate(good)
        self._size = good
        self._next_seq = 0 if self._last_seq is None else self._last_seq + 1

    # -- append / rollback -------------------------------------------------

    @property
    def next_seq(self) -> int:
        return self._next_seq

    def append(self, rec: OpRecord) -> int:
        """Append one record; returns its seq.  Durable per the fsync
        policy when this returns."""
        payload = _encode(rec)
        seq = self._next_seq
        frame = _HEADER.pack(_MAGIC, seq, len(payload),
                             _digest(seq, payload)) + payload
        off = self._size
        chaos.chaos_write(self._f, frame, "journal.append")
        if self.fsync == "always":
            self._f.flush()
            os.fsync(self._f.fileno())
        elif self.fsync == "batch":
            self._f.flush()
        self._size = off + len(frame)
        self._last_off = off
        self._last_seq = seq
        self._next_seq = seq + 1
        return seq

    def rollback(self, seq: int) -> None:
        """Drop the most recent record (its apply failed before taking
        effect); only the last append can be rolled back."""
        if self._last_off is None or seq != self._last_seq:
            raise JournalError(
                f"can only roll back the last appended record "
                f"(seq {self._last_seq}), not {seq}")
        self._f.flush()
        self._f.truncate(self._last_off)
        self._f.seek(self._last_off)
        self._size = self._last_off
        self._next_seq = seq            # seq is reusable: it never hit disk
        self._last_seq = None
        self._last_off = None

    # -- replay / GC -------------------------------------------------------

    def replay(self, from_seq: int = 0) -> Iterator[tuple[int, OpRecord]]:
        """Yield (seq, record) for every record with ``seq >= from_seq``.

        The opening scan already truncated any torn tail, so every frame
        read here is digest-verified and whole."""
        self._f.flush()
        with open(self.path, "rb") as f:
            read = 0
            while read < self._size:
                head = f.read(_HEADER.size)
                _, seq, length, _ = _HEADER.unpack(head)
                payload = f.read(length)
                read += _HEADER.size + length
                if seq >= from_seq:
                    yield seq, _decode(payload)

    def truncate(self, keep_from_seq: int) -> None:
        """Garbage-collect the head: drop records with seq < keep_from_seq
        (atomically, via a tmp file + rename)."""
        self._f.flush()
        tmp = self.path + ".tmp"
        kept_last: int | None = None
        with open(self.path, "rb") as src, open(tmp, "wb") as out:
            read = 0
            while read < self._size:
                head = src.read(_HEADER.size)
                _, seq, length, _ = _HEADER.unpack(head)
                payload = src.read(length)
                read += _HEADER.size + length
                if seq >= keep_from_seq:
                    out.write(head + payload)
                    kept_last = seq
            out.flush()
            os.fsync(out.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        self._f = open(self.path, "ab")
        self._size = os.path.getsize(self.path)
        self._last_seq = kept_last
        self._last_off = None
        # next_seq is unchanged: seqs are never reused across a GC

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            if self.fsync != "never":
                os.fsync(self._f.fileno())
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __len__(self) -> int:
        return sum(1 for _ in self.replay())
