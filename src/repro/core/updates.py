"""Batched graph mutation — the paper's seven primitives, vectorized and
**device-resident** (DESIGN.md §2.9).

:class:`UpdateBatch` collects vertex/edge add/delete/touch operations and
applies them to a :class:`~repro.core.graph.ShardedGraph` with **one
compiled program per batch shape**: the whole apply — slot matching,
cumsum-based free-slot allocation, field scatters, and the incremental
CSR patching (tombstones + staged delta blocks) — runs as a single
:func:`jax.jit` (``apply_updates``) over op arrays padded to a
power-of-two size ladder, so repeated batch shapes never recompile and
the steady-state commit does **zero device->host transfers**: commit
cost is O(batch) scatters, not the O(E log E) stream re-sort the eager
``with_csr`` rebuild pays.  Group order matches the sequential
primitives in ``dynamic.py``:

    vertex adds -> edge deletes -> vertex deletes -> edge adds -> touches

Semantics notes (mirroring the sequential primitives):

* edge deletes remove the first matching live slot per occurrence — a
  batch deleting the same (u, v) pair twice removes two parallel edges;
* edge adds fill the lowest free slots of the source's cell, in order
  (device-side: the rank-th free slot found by a cumsum over the free
  mask — no host readback of the edge stream);
* vertex deletes drop the vertex's out-edges and mask + degree-fix its
  in-edges across all cells;
* id allocation happens eagerly at ``add_vertex`` time (through the
  NameServer), so new ids are usable by later ops in the same batch.

Compaction policy: staging falls back to the eager ``with_csr`` rebuild
when a cell's delta segment would overflow, or when its tombstones
exceed ``TOMBSTONE_COMPACT_FRACTION`` of its edge slots — amortizing the
sort over many O(batch) commits.  The policy check reads only the [S]
counters (O(cells) scalars, not the edge stream).
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import TOMBSTONE_COMPACT_FRACTION

__all__ = ["UpdateBatch", "AppliedUpdates", "apply_updates"]


class AppliedUpdates(NamedTuple):
    """What a batch did — consumed by the session's incremental repair."""

    vertex_adds: tuple        # ((gid, shard, local), ...)
    vertex_deletes: tuple     # (gid, ...)
    edge_adds: tuple          # ((u, v, w), ...)
    edge_deletes: tuple       # ((u, v), ...)
    touched: tuple            # (gid, ...)

    @property
    def has_deletes(self) -> bool:
        return bool(self.vertex_deletes or self.edge_deletes)

    @property
    def n_ops(self) -> int:
        return (len(self.vertex_adds) + len(self.vertex_deletes)
                + len(self.edge_adds) + len(self.edge_deletes)
                + len(self.touched))


def _pow2(n: int) -> int:
    """Pad a group size up the power-of-two ladder (0 stays 0), so a
    stream of similarly-sized batches reuses one compiled apply."""
    return 1 << (n - 1).bit_length() if n else 0


def _pad(a: np.ndarray, k: int, fill) -> jnp.ndarray:
    out = np.full((k,), fill, a.dtype)
    out[: a.shape[0]] = a
    return jnp.asarray(out)


@partial(jax.jit, static_argnames=("stage",))
def apply_updates(sg, ops: dict, stage: bool):
    """The whole batched apply as one compiled program.

    ``ops`` holds the padded op-group arrays (any group may be absent);
    padding rows carry out-of-range indices so every scatter drops them.
    ``stage`` (static) selects incremental CSR patching — tombstones for
    the delete groups, staged delta entries for the add group; False
    leaves the views untouched for a caller-side eager rebuild.

    Returns ``(sg, del_ok, add_ok)``: which edge-delete ops matched a
    live edge (phantom deletes are no-ops) and which edge adds found a
    free slot (False => the cell's edge memory is full and the caller
    must reject the batch).
    """
    np_ = sg.n_per_shard
    ep = sg.edges_per_shard
    i32 = jnp.int32

    if "va_s" in ops:
        s, l, g = ops["va_s"], ops["va_l"], ops["va_g"]
        sg = dataclasses.replace(
            sg,
            node_ok=sg.node_ok.at[s, l].set(True, mode="drop"),
            gid=sg.gid.at[s, l].set(g, mode="drop"),
            out_degree=sg.out_degree.at[s, l].set(0, mode="drop"),
        )

    del_ok = None
    if "ed_su" in ops:
        su, lu, vg, occ = (ops["ed_su"], ops["ed_lu"], ops["ed_vg"],
                           ops["ed_occ"])
        match = (
            (sg.src_local[su] == lu[:, None])
            & (sg.dst_gid[su] == vg[:, None])
            & sg.edge_ok[su]
        )                                                   # [K, Ep]
        # the occ-th occurrence of a pair takes the occ-th matching slot
        # (first-match semantics): the slot where the running match count
        # reaches occ+1 — a cumsum + argmax, not a per-row argsort (whose
        # O(K Ep log Ep) would rival the full rebuild this path replaces)
        hit = match & (jnp.cumsum(match, axis=1) == (occ + 1)[:, None])
        slot = jnp.argmax(hit, axis=1).astype(i32)
        rows = jnp.arange(su.shape[0])
        del_ok = hit[rows, slot]
        # non-matching rows would land on an arbitrary live slot and race
        # with real deletes at the same index (duplicate scatter indices
        # with conflicting values are unordered in XLA) — route them out
        # of bounds instead, where scatter drops them.
        slot = jnp.where(del_ok, slot, ep)
        sg = dataclasses.replace(
            sg,
            edge_ok=sg.edge_ok.at[su, slot].set(False, mode="drop"),
            out_degree=sg.out_degree.at[su, lu].add(
                -del_ok.astype(i32), mode="drop"),
        )
        if stage:
            sg = sg.with_edge_tombstones(su, slot, del_ok)

    if "vd_s" in ops:
        s, l = ops["vd_s"], ops["vd_l"]
        dv = jnp.zeros((sg.n_shards, np_), bool).at[s, l].set(
            True, mode="drop")
        dead_out = sg.edge_ok & jnp.take_along_axis(dv, sg.src_local,
                                                    axis=1)
        dead_in = sg.edge_ok & dv[sg.dst_shard, sg.dst_local]
        deg = jax.vmap(
            lambda d, sl, m: d.at[sl].add(-m.astype(i32))
        )(sg.out_degree, sg.src_local, dead_in & ~dead_out)
        sg = dataclasses.replace(
            sg,
            edge_ok=sg.edge_ok & ~dead_out & ~dead_in,
            node_ok=sg.node_ok.at[s, l].set(False, mode="drop"),
            out_degree=deg.at[s, l].set(0, mode="drop"),
        )
        if stage:
            sg = sg.with_slot_tombstones(dead_out | dead_in)

    add_ok = None
    if "ea_su" in ops:
        su, lu, sv, lv, vg, w, rank = (
            ops["ea_su"], ops["ea_lu"], ops["ea_sv"], ops["ea_lv"],
            ops["ea_vg"], ops["ea_w"], ops["ea_rank"])
        valid = rank >= 0
        # lowest free slots per cell, in arrival order: the op's rank
        # among its cell's adds picks the rank-th free slot — located by
        # a per-cell searchsorted over the free-mask cumsum (a [S, K]
        # table, not a [K, Ep] gather), all device-side (the old path
        # pulled the whole edge_ok stream to the host every batch)
        free_cum = jnp.cumsum((~sg.edge_ok).astype(i32), axis=1)  # [S, Ep]
        targets = jnp.arange(1, su.shape[0] + 1, dtype=i32)
        slot_tab = jax.vmap(
            lambda c: jnp.searchsorted(c, targets).astype(i32)
        )(free_cum)                                               # [S, K]
        slot = slot_tab[su, jnp.clip(rank, 0)]
        have = free_cum[su, -1] > rank
        add_ok = have | ~valid
        ok = valid & have
        slot = jnp.where(ok, slot, ep)
        sg = dataclasses.replace(
            sg,
            src_local=sg.src_local.at[su, slot].set(lu, mode="drop"),
            dst_shard=sg.dst_shard.at[su, slot].set(sv, mode="drop"),
            dst_local=sg.dst_local.at[su, slot].set(lv, mode="drop"),
            dst_gid=sg.dst_gid.at[su, slot].set(vg, mode="drop"),
            weight=sg.weight.at[su, slot].set(w, mode="drop"),
            edge_ok=sg.edge_ok.at[su, slot].set(True, mode="drop"),
            out_degree=sg.out_degree.at[su, lu].add(
                ok.astype(i32), mode="drop"),
        )
        if stage:
            sg = sg.with_staged_edges(su, slot, lu, sv * np_ + lv, rank,
                                      ok)
    return sg, del_ok, add_ok


class UpdateBatch:
    """Collect mutations; apply them as one compiled scatter program.

    Build one through :meth:`repro.core.session.DiffusionSession.update`
    (the session then repairs its cached programs on ``commit()``), or
    standalone with a :class:`~repro.core.dynamic.NameServer`.
    """

    def __init__(self, ns):
        self.ns = ns
        self._vadds: list[tuple[int, int, int]] = []
        self._vdels: list[int] = []
        self._eadds: list[tuple[int, int, float]] = []
        self._edels: list[tuple[int, int]] = []
        self._touch: list[int] = []

    def __len__(self) -> int:
        return (len(self._vadds) + len(self._vdels) + len(self._eadds)
                + len(self._edels) + len(self._touch))

    # -- the seven primitives (peek is a read; see session.peek) ----------

    def add_vertex(self, shard: int | None = None) -> int:
        """Reserve a vertex slot (eager id allocation); returns the gid."""
        if shard is None:
            shard = self.ns.best_shard()
        gid, s, l = self.ns.allocate(shard)
        self._vadds.append((gid, s, l))
        return gid

    def delete_vertex(self, gid: int):
        self._vdels.append(int(gid))
        return self

    def touch_vertex(self, gid: int):
        """Re-activate ``gid`` at the next commit (the relax seed)."""
        self._touch.append(int(gid))
        return self

    def add_edge(self, u: int, v: int, w: float = 1.0):
        self._eadds.append((int(u), int(v), float(w)))
        return self

    def delete_edge(self, u: int, v: int):
        self._edels.append((int(u), int(v)))
        return self

    def touch_edge(self, u: int):
        """Re-emit on all of u's out-edges at the next commit."""
        return self.touch_vertex(u)

    # -- host-side packing -------------------------------------------------

    def _pack_ops(self, sg) -> tuple[dict, dict]:
        """Resolve gids and pack each op group into padded device arrays
        (power-of-two ladder; padding rows scatter out of range).
        Returns ``(ops, per_cell)`` — the second holds host-side per-cell
        add/delete counts for the compaction policy, so the policy never
        reads the freshly uploaded device arrays back."""
        ns = self.ns
        np_ = sg.n_per_shard
        n_shards = sg.n_shards
        ops: dict = {}
        per_cell = {"adds": np.zeros(n_shards, np.int64),
                    "dels": np.zeros(n_shards, np.int64)}

        if self._vadds:
            k = _pow2(len(self._vadds))
            g, s, l = (np.array([t[i] for t in self._vadds], np.int32)
                       for i in (0, 1, 2))
            ops["va_s"] = _pad(s, k, 0)
            ops["va_l"] = _pad(l, k, np_)        # pad -> dropped
            ops["va_g"] = _pad(g, k, 0)

        if self._edels:
            k = _pow2(len(self._edels))
            n = len(self._edels)
            su = np.empty(n, np.int32)
            lu = np.empty(n, np.int32)
            vg = np.empty(n, np.int32)
            occ = np.empty(n, np.int32)   # occurrence index per (u, v)
            seen: Counter = Counter()
            for j, (u, v) in enumerate(self._edels):
                # split sources: probe the member cell the rank hash
                # stored this (u, v) edge in (build and add used it too)
                su[j], lu[j] = ns.route_edge(u, v)
                vg[j] = v
                occ[j] = seen[(u, v)]
                seen[(u, v)] += 1
            ops["ed_su"] = _pad(su, k, 0)
            ops["ed_lu"] = _pad(lu, k, np_)      # pad matches no src_local
            ops["ed_vg"] = _pad(vg, k, -1)       # ... and no dst_gid
            ops["ed_occ"] = _pad(occ, k, 0)
            per_cell["dels"] = np.bincount(su, minlength=n_shards)

        if self._vdels:
            # a split hub dies at ALL member slots (out-edges are stored
            # across members), so expand each gid to its member pairs
            pairs: list[tuple[int, int]] = []
            for gid in self._vdels:
                pairs.extend(ns.members_of(gid) or [ns.resolve(gid)])
            k = _pow2(len(pairs))
            s = np.array([p[0] for p in pairs], np.int32)
            l = np.array([p[1] for p in pairs], np.int32)
            ops["vd_s"] = _pad(s, k, 0)
            ops["vd_l"] = _pad(l, k, np_)        # pad -> dropped

        if self._eadds:
            k = _pow2(len(self._eadds))
            n = len(self._eadds)
            su = np.empty(n, np.int32)
            lu = np.empty(n, np.int32)
            sv = np.empty(n, np.int32)
            lv = np.empty(n, np.int32)
            vg = np.empty(n, np.int32)
            w = np.empty(n, np.float32)
            rank = np.empty(n, np.int32)         # index among cell's adds
            cell_rank: Counter = Counter()       # must NOT shadow per_cell
            for j, (u, v, wj) in enumerate(self._eadds):
                # split endpoints route by the rank hash (same slots the
                # partition-time build picks — incremental == rebuild)
                su[j], lu[j] = ns.route_edge(u, v)
                sv[j], lv[j] = ns.route_target(v, u)
                vg[j], w[j] = v, wj
                rank[j] = cell_rank[int(su[j])]
                cell_rank[int(su[j])] += 1
            ops["ea_su"] = _pad(su, k, 0)
            ops["ea_lu"] = _pad(lu, k, np_)      # pad -> degree add drops
            ops["ea_sv"] = _pad(sv, k, 0)
            ops["ea_lv"] = _pad(lv, k, 0)
            ops["ea_vg"] = _pad(vg, k, 0)
            ops["ea_w"] = _pad(w, k, 0.0)
            ops["ea_rank"] = _pad(rank, k, -1)   # -1 marks padding
            per_cell["adds"] = np.bincount(su, minlength=n_shards)
        return ops, per_cell

    # -- vectorized apply --------------------------------------------------

    def apply(self, sg, incremental: bool | None = None) -> tuple:
        """Apply every collected op; returns (new sg, AppliedUpdates).

        ``incremental=None`` (default) patches the CSR views in place
        (tombstones + staged delta blocks) when the graph carries them
        and the compaction policy allows, falling back to the eager
        ``with_csr`` rebuild otherwise; ``False`` forces the eager
        rebuild (the pre-incremental behaviour, kept for benchmarking
        and as an escape hatch)."""
        topo = bool(self._edels or self._vdels or self._eadds)
        stage = incremental is not False and topo and (
            sg.csr_perm is not None and sg.delta_count is not None
            and sg.delta_width > 0)
        ops, per_cell = self._pack_ops(sg)   # one resolve pass for both
        if stage:
            # compaction / capacity policy: O(cells) counter reads only
            # (per-cell op counts were tallied host-side while packing)
            dc = np.asarray(jax.device_get(sg.delta_count), np.int64)
            tc = np.asarray(jax.device_get(sg.tomb_count), np.int64)
            overflow = np.any(dc + per_cell["adds"] > sg.delta_width)
            crowded = np.any(
                tc + per_cell["dels"]
                > TOMBSTONE_COMPACT_FRACTION * sg.edges_per_shard)
            if (overflow or crowded) and np.any(dc + tc):
                # accumulated dirt tripped the policy: fold it out with
                # the merge compaction (views are consistent here) and
                # retry staging into the fresh delta segment — only a
                # batch too big for an *empty* segment forces the eager
                # full rebuild below
                sg = sg.with_csr()
                overflow = np.any(per_cell["adds"] > sg.delta_width)
                crowded = np.any(
                    per_cell["dels"]
                    > TOMBSTONE_COMPACT_FRACTION * sg.edges_per_shard)
            if overflow or crowded:
                stage = False
        if incremental is True and topo and not stage:
            raise ValueError(
                "incremental apply requested but the graph carries no "
                "delta-capable CSR views (call with_csr()) or the "
                "compaction policy demands a rebuild")
        new_sg, del_ok, add_ok = apply_updates(sg, ops, stage=stage)
        if add_ok is not None:
            bad = np.flatnonzero(~np.asarray(jax.device_get(add_ok)))
            if bad.size:
                j = int(bad[0])
                cell = self.ns.resolve(self._eadds[j][0])[0]
                raise RuntimeError(
                    f"compute cell {cell} has no free edge slots "
                    f"(batched edge_add #{j})"
                )
        if topo and not stage:
            # eager rebuild (compaction): apply_updates(stage=False)
            # mutated topology without patching the views, so drop them
            # first — the merge compaction must never read stale streams
            new_sg = new_sg.invalidate_csr().with_csr()
        elif stage and self._vdels:
            # vertex deletes tombstone a data-dependent number of edges
            # (every in/out edge of the victim) that the pre-apply
            # crowding bound cannot count; re-check the committed
            # counters (O(cells) scalars) so density never exceeds the
            # policy bound for longer than this one batch
            tc2 = np.asarray(jax.device_get(new_sg.tomb_count), np.int64)
            if np.any(tc2 > TOMBSTONE_COMPACT_FRACTION
                      * sg.edges_per_shard):
                new_sg = new_sg.with_csr()

        # NameServer slot release happens only after every group applied
        # cleanly: if edge adds raise (cell full), the graph is unchanged
        # and the whole batch can be retried or amended without the name
        # server having drifted from the graph.
        for gid in self._vdels:
            self.ns.release(gid)

        # edge_deletes records only ops that removed a live edge, so a
        # phantom delete is a no-op for downstream incremental repair
        # (deleting (source, source) must not invalidate the SSSP tree —
        # the source is self-parented as a sentinel).
        if del_ok is not None:
            ok_host = np.asarray(jax.device_get(del_ok))
            deleted = tuple(e for j, e in enumerate(self._edels)
                            if ok_host[j])
        else:
            deleted = ()
        applied = AppliedUpdates(
            vertex_adds=tuple(self._vadds),
            vertex_deletes=tuple(self._vdels),
            edge_adds=tuple(self._eadds),
            edge_deletes=deleted,
            touched=tuple(self._touch),
        )
        self._vadds, self._vdels = [], []
        self._eadds, self._edels, self._touch = [], [], []
        return new_sg, applied
