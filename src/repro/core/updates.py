"""Batched graph mutation — the paper's seven primitives, vectorized.

:class:`UpdateBatch` collects vertex/edge add/delete/touch operations and
applies them to a :class:`~repro.core.graph.ShardedGraph` with **one
scatter per array field per op group** instead of one ``.at[]`` dispatch
chain per edge.  Update-heavy traffic (the paper's streaming workloads)
pays O(#fields) kernel launches per batch rather than O(#updates), while
producing the exact same graph as the sequential primitives in
``dynamic.py`` applied in group order:

    vertex adds -> edge deletes -> vertex deletes -> edge adds -> touches

Semantics notes (mirroring the sequential primitives):

* edge deletes remove the first matching live slot per occurrence — a
  batch deleting the same (u, v) pair twice removes two parallel edges;
* edge adds fill the lowest free slots of the source's cell, in order;
* vertex deletes drop the vertex's out-edges and mask + degree-fix its
  in-edges across all cells;
* id allocation happens eagerly at ``add_vertex`` time (through the
  NameServer), so new ids are usable by later ops in the same batch.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["UpdateBatch", "AppliedUpdates"]


class AppliedUpdates(NamedTuple):
    """What a batch did — consumed by the session's incremental repair."""

    vertex_adds: tuple        # ((gid, shard, local), ...)
    vertex_deletes: tuple     # (gid, ...)
    edge_adds: tuple          # ((u, v, w), ...)
    edge_deletes: tuple       # ((u, v), ...)
    touched: tuple            # (gid, ...)

    @property
    def has_deletes(self) -> bool:
        return bool(self.vertex_deletes or self.edge_deletes)

    @property
    def n_ops(self) -> int:
        return (len(self.vertex_adds) + len(self.vertex_deletes)
                + len(self.edge_adds) + len(self.edge_deletes)
                + len(self.touched))


class UpdateBatch:
    """Collect mutations; apply them as vectorized scatters.

    Build one through :meth:`repro.core.session.DiffusionSession.update`
    (the session then repairs its cached programs on ``commit()``), or
    standalone with a :class:`~repro.core.dynamic.NameServer`.
    """

    def __init__(self, ns):
        self.ns = ns
        self._vadds: list[tuple[int, int, int]] = []
        self._vdels: list[int] = []
        self._eadds: list[tuple[int, int, float]] = []
        self._edels: list[tuple[int, int]] = []
        self._touch: list[int] = []

    def __len__(self) -> int:
        return (len(self._vadds) + len(self._vdels) + len(self._eadds)
                + len(self._edels) + len(self._touch))

    # -- the seven primitives (peek is a read; see session.peek) ----------

    def add_vertex(self, shard: int | None = None) -> int:
        """Reserve a vertex slot (eager id allocation); returns the gid."""
        if shard is None:
            shard = self.ns.best_shard()
        gid, s, l = self.ns.allocate(shard)
        self._vadds.append((gid, s, l))
        return gid

    def delete_vertex(self, gid: int):
        self._vdels.append(int(gid))
        return self

    def touch_vertex(self, gid: int):
        """Re-activate ``gid`` at the next commit (the relax seed)."""
        self._touch.append(int(gid))
        return self

    def add_edge(self, u: int, v: int, w: float = 1.0):
        self._eadds.append((int(u), int(v), float(w)))
        return self

    def delete_edge(self, u: int, v: int):
        self._edels.append((int(u), int(v)))
        return self

    def touch_edge(self, u: int):
        """Re-emit on all of u's out-edges at the next commit."""
        return self.touch_vertex(u)

    # -- vectorized apply --------------------------------------------------

    def apply(self, sg) -> tuple:
        """Apply every collected op; returns (new sg, AppliedUpdates)."""
        if self._vadds:
            g, s, l = (np.array([t[i] for t in self._vadds], np.int32)
                       for i in (0, 1, 2))
            sg = dataclasses.replace(
                sg,
                node_ok=sg.node_ok.at[s, l].set(True),
                gid=sg.gid.at[s, l].set(jnp.asarray(g)),
                out_degree=sg.out_degree.at[s, l].set(0),
            )

        deleted: list[tuple[int, int]] = []
        if self._edels:
            sg = self._apply_edge_deletes(sg, deleted)

        if self._vdels:
            sg = self._apply_vertex_deletes(sg)

        if self._eadds:
            sg = self._apply_edge_adds(sg)

        if self._edels or self._vdels or self._eadds:
            sg = sg.with_csr()     # topology changed: refresh the CSR view

        # NameServer slot release happens only after every group applied
        # cleanly: if edge adds raise (cell full), the graph is unchanged
        # and the whole batch can be retried or amended without the name
        # server having drifted from the graph.
        for gid in self._vdels:
            self.ns.release(gid)

        # edge_deletes records only ops that removed a live edge, so a
        # phantom delete is a no-op for downstream incremental repair
        # (deleting (source, source) must not invalidate the SSSP tree —
        # the source is self-parented as a sentinel).
        applied = AppliedUpdates(
            vertex_adds=tuple(self._vadds),
            vertex_deletes=tuple(self._vdels),
            edge_adds=tuple(self._eadds),
            edge_deletes=tuple(deleted),
            touched=tuple(self._touch),
        )
        self._vadds, self._vdels = [], []
        self._eadds, self._edels, self._touch = [], [], []
        return sg, applied

    def _apply_edge_deletes(self, sg, deleted: list):
        ns = self.ns
        K = len(self._edels)
        su = np.empty(K, np.int32)
        lu = np.empty(K, np.int32)
        vg = np.empty(K, np.int32)
        occ = np.empty(K, np.int32)       # occurrence index per (u, v) pair
        seen: Counter = Counter()
        for j, (u, v) in enumerate(self._edels):
            su[j], lu[j] = ns.resolve(u)
            vg[j] = v
            occ[j] = seen[(u, v)]
            seen[(u, v)] += 1
        match = (
            (sg.src_local[su] == lu[:, None])
            & (sg.dst_gid[su] == vg[:, None])
            & sg.edge_ok[su]
        )                                                   # [K, Ep]
        # matching slots first (ascending), stable; the occ-th occurrence
        # of a pair takes the occ-th matching slot — first-match semantics
        order = jnp.argsort(~match, axis=1, stable=True)
        rows = jnp.arange(K)
        slot = order[rows, occ]
        ok = match[rows, slot]
        ok_host = np.asarray(ok)
        deleted.extend(e for j, e in enumerate(self._edels) if ok_host[j])
        # non-matching rows would land on an arbitrary live slot and race
        # with real deletes at the same index (duplicate scatter indices
        # with conflicting values are unordered in XLA) — route them out
        # of bounds instead, where scatter drops them.
        slot = jnp.where(ok, slot, sg.edges_per_shard)
        return dataclasses.replace(
            sg,
            edge_ok=sg.edge_ok.at[su, slot].set(False, mode="drop"),
            out_degree=sg.out_degree.at[su, lu].add(-ok.astype(jnp.int32)),
        )

    def _apply_vertex_deletes(self, sg):
        ns = self.ns
        s = np.empty(len(self._vdels), np.int32)
        l = np.empty(len(self._vdels), np.int32)
        for j, gid in enumerate(self._vdels):
            s[j], l[j] = ns.resolve(gid)
        dv = jnp.zeros((sg.n_shards, sg.n_per_shard), bool).at[s, l].set(True)
        dead_out = sg.edge_ok & jnp.take_along_axis(dv, sg.src_local, axis=1)
        dead_in = sg.edge_ok & dv[sg.dst_shard, sg.dst_local]
        deg = jax.vmap(
            lambda d, sl, m: d.at[sl].add(-m.astype(jnp.int32))
        )(sg.out_degree, sg.src_local, dead_in & ~dead_out)
        return dataclasses.replace(
            sg,
            edge_ok=sg.edge_ok & ~dead_out & ~dead_in,
            node_ok=sg.node_ok.at[s, l].set(False),
            out_degree=deg.at[s, l].set(0),
        )

    def _apply_edge_adds(self, sg):
        ns = self.ns
        K = len(self._eadds)
        su = np.empty(K, np.int32)
        lu = np.empty(K, np.int32)
        sv = np.empty(K, np.int32)
        lv = np.empty(K, np.int32)
        vg = np.empty(K, np.int32)
        w = np.empty(K, np.float32)
        for j, (u, v, wj) in enumerate(self._eadds):
            su[j], lu[j] = ns.resolve(u)
            sv[j], lv[j] = ns.resolve(v)
            vg[j], w[j] = v, wj
        # lowest free slots per cell, in arrival order == repeated argmax
        free = ~np.asarray(sg.edge_ok)
        slot = np.empty(K, np.int32)
        cursor = {int(c): iter(np.flatnonzero(free[int(c)]))
                  for c in np.unique(su)}
        for j in range(K):
            try:
                slot[j] = next(cursor[int(su[j])])
            except StopIteration:
                raise RuntimeError(
                    f"compute cell {int(su[j])} has no free edge slots "
                    f"(batched edge_add #{j})"
                ) from None
        return dataclasses.replace(
            sg,
            src_local=sg.src_local.at[su, slot].set(jnp.asarray(lu)),
            dst_shard=sg.dst_shard.at[su, slot].set(jnp.asarray(sv)),
            dst_local=sg.dst_local.at[su, slot].set(jnp.asarray(lv)),
            dst_gid=sg.dst_gid.at[su, slot].set(jnp.asarray(vg)),
            weight=sg.weight.at[su, slot].set(jnp.asarray(w)),
            edge_ok=sg.edge_ok.at[su, slot].set(True),
            out_degree=sg.out_degree.at[su, lu].add(1),
        )
