"""Event-driven reference engine — the paper's semantics, literally.

Processes one operon (active message) at a time from a LIFO or FIFO queue,
exactly like one HPX-5 worker (the paper notes each HPX process owns a LIFO
queue).  Uses the real Dijkstra–Scholten detector with per-message acks, so
the paper's "extra acknowledgment message for each diffusion message" cost
is measured, not simulated.

This engine is the *oracle* for the batched engines: same fixed point, exact
action counts for the Actions-Normalized metric, and the DS-vs-counting
termination equivalence test.

**Scope (test-only oracle).** This is a deliberately host-bound,
message-at-a-time interpreter — O(actions) Python dispatch, ~seconds per
call at a few thousand vertices.  It is capped at ``n <=
EVENT_ORACLE_MAX_N`` (4096) vertices, excluded from every benchmarked
path, and exists to pin down two contracts the batched engines are
tested against (DESIGN.md §2.13):

* **priority order** — the queue discipline (``schedule="lifo" |
  "fifo"``) fixes a *total* order of vertex actions.  The batched
  engines relax whole frontiers per round instead; the oracle proves
  their fixed points are order-independent (selection monoids: bitwise;
  sum monoids: up to float re-association), which is exactly the
  property that makes bulk-asynchronous execution legal.
* **termination** — real per-message Dijkstra–Scholten acks here,
  counting detection there; the suite asserts both fire at the same
  quiescent point and DS never fires early.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, NamedTuple

from .termination import DijkstraScholten

__all__ = ["EventStats", "run_event", "event_sssp", "event_diffuse",
           "build_adjacency", "EVENT_ORACLE_MAX_N"]

# re-scoped per ROADMAP: the generic oracle is test-only — it runs the
# program one Python-dispatched message at a time, so beyond a few
# thousand vertices it is minutes of host time that no benchmark or
# production path should ever pay silently
EVENT_ORACLE_MAX_N = 4096


class EventStats(NamedTuple):
    actions: int          # diffusion messages processed (paper's metric)
    acks: int             # DS acknowledgement overhead messages
    max_queue: int
    ds_terminated: bool   # DS verdict at the end (must be True)
    ds_was_premature: bool  # DS claimed termination while work remained (must be False)
    converged: bool = True  # the oracle runs to quiescence (no round
                            #   budget); present for parity with
                            #   DiffuseStats.converged


def build_adjacency(src, dst, weight, n: int):
    """Edge arrays -> adjacency list [(neighbor, weight), ...] per vertex."""
    adj: list[list] = [[] for _ in range(n)]
    for s, d, w in zip(src, dst, weight):
        adj[int(s)].append((int(d), float(w)))
    return adj


class _DS(DijkstraScholten):
    """DS with cascade detach for the run-to-completion actor setting."""

    def __init__(self, n):
        super().__init__(n)
        self.running: int | None = None

    def _ack(self, node: int):
        self.acks += 1
        if node == self.ENV:
            self.env_deficit -= 1
            return
        self.deficit[node] -= 1
        self.try_detach(node)

    def try_detach(self, node: int):
        if (
            node != self.running
            and self.deficit[node] == 0
            and self.parent[node] is not None
        ):
            p = self.parent[node]
            self.parent[node] = None
            self._ack(p)


def run_event(
    n: int,
    handler: Callable,
    init_msgs: list[tuple[int, object]],
    schedule: str = "lifo",
):
    """Run a message-driven computation to quiescence.

    handler(v, msg) -> list[(dst, msg)] — the vertex action: applies the
    predicate, possibly mutates its vertex state (captured by the caller's
    closure), and returns the new diffusion messages.
    """
    ds = _DS(n)
    q: deque = deque()
    for dst, msg in init_msgs:
        ds.on_send(ds.ENV)
        q.append((dst, msg, ds.ENV))

    actions = 0
    max_queue = len(q)
    premature = False
    while q:
        if ds.terminated() and q:
            premature = True  # DS must never fire early
        v, msg, sender = q.pop() if schedule == "lifo" else q.popleft()
        actions += 1
        ds.on_receive(v, sender)
        ds.running = v
        out = handler(v, msg)
        for dst, m in out:
            ds.on_send(v)
            q.append((dst, m, v))
        ds.running = None
        ds.try_detach(v)
        max_queue = max(max_queue, len(q))
    return EventStats(
        actions=actions,
        acks=ds.acks,
        max_queue=max_queue,
        ds_terminated=ds.terminated(),
        ds_was_premature=premature,
    )


def event_diffuse(prog, src, dst, weight, n: int, node_ok=None,
                  schedule: str = "lifo"):
    """Run *any* lowered :class:`~.programs.VertexProgram` one message at
    a time — the generic host oracle behind ``engine="event"``.

    The same emit/receive/on_send functions the batched engines trace are
    executed here on per-vertex scalars, so every program registered
    through the ``@diffusive`` extension point gets the event engine (and
    its real Dijkstra–Scholten termination) for free.  Selection-monoid
    programs (min/max) reproduce the batched fixed point exactly; sum
    programs agree to float re-association.

    Test-only oracle: capped at ``n <= EVENT_ORACLE_MAX_N`` (see the
    module docstring for the priority-order contract it pins down).

    Returns (state dict of [n] numpy arrays, EventStats).
    """
    import types

    import numpy as np

    if n > EVENT_ORACLE_MAX_N:
        raise ValueError(
            f"event_diffuse is a host-bound test oracle capped at "
            f"n <= {EVENT_ORACLE_MAX_N} vertices (got n={n}); it "
            f"interprets one message at a time in Python and would take "
            f"minutes here — use engine='sharded' or 'spmd' for real "
            f"workloads")

    adj = build_adjacency(src, dst, weight, n)
    deg = np.zeros(n, np.int32)
    for s in np.asarray(src):
        deg[int(s)] += 1
    ok = (np.ones(n, bool) if node_ok is None
          else np.asarray(node_ok, bool).copy())

    view = types.SimpleNamespace(
        gid=np.arange(n, dtype=np.int32), node_ok=ok, out_degree=deg
    )
    vstate0, active0 = prog.init(view)
    state = {k: np.asarray(v).copy() for k, v in vstate0.items()}
    active0 = np.asarray(active0)

    def vertex(v):
        return {k: a[v] for k, a in state.items()}

    def fire(v):
        """The vertex action: emit along v's out-edges, then the sender
        transition — one diffusion step of the paper's vertex_func."""
        vs = vertex(v)
        outs = []
        for u, w in adj[v]:
            m = prog.emit(vs, np.float32(w), np.int32(v), np.int32(u))
            pay = (int(prog.payload(vs, np.int32(v)))
                   if prog.with_payload else None)
            outs.append((u, (np.asarray(m, prog.msg_dtype)[()], pay)))
        new = prog.on_send(vs, True)
        for k in state:
            state[k][v] = np.asarray(new[k], state[k].dtype)[()]
        return outs

    def handler(v, msg):
        val, pay = msg
        out, activated = prog.receive(vertex(v), val, True, pay, ok[v])
        for k in state:
            state[k][v] = np.asarray(out[k], state[k].dtype)[()]
        return fire(v) if bool(activated) else []

    init_msgs = []
    for v in np.flatnonzero(active0):
        init_msgs.extend(fire(int(v)))
    stats = run_event(n, handler, init_msgs, schedule=schedule)
    return state, stats


def event_sssp(adj, n: int, source: int, schedule: str = "lifo"):
    """The paper's Code Listing 1, executed message-by-message."""
    import math

    dist = [math.inf] * n
    dist[source] = 0.0

    def handler(v, d):
        if d < dist[v]:                    # the predicate
            dist[v] = d
            return [(u, d + w) for u, w in adj[v]]   # the diffusion
        return []

    init = [(u, dist[source] + w) for u, w in adj[source]]
    stats = run_event(n, handler, init, schedule=schedule)
    return dist, stats
