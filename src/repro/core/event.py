"""Event-driven reference engine — the paper's semantics, literally.

Processes one operon (active message) at a time from a LIFO or FIFO queue,
exactly like one HPX-5 worker (the paper notes each HPX process owns a LIFO
queue).  Uses the real Dijkstra–Scholten detector with per-message acks, so
the paper's "extra acknowledgment message for each diffusion message" cost
is measured, not simulated.

This engine is the *oracle* for the batched engines: same fixed point, exact
action counts for the Actions-Normalized metric, and the DS-vs-counting
termination equivalence test.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, NamedTuple

from .termination import DijkstraScholten

__all__ = ["EventStats", "run_event", "event_sssp", "build_adjacency"]


class EventStats(NamedTuple):
    actions: int          # diffusion messages processed (paper's metric)
    acks: int             # DS acknowledgement overhead messages
    max_queue: int
    ds_terminated: bool   # DS verdict at the end (must be True)
    ds_was_premature: bool  # DS claimed termination while work remained (must be False)


def build_adjacency(src, dst, weight, n: int):
    """Edge arrays -> adjacency list [(neighbor, weight), ...] per vertex."""
    adj: list[list] = [[] for _ in range(n)]
    for s, d, w in zip(src, dst, weight):
        adj[int(s)].append((int(d), float(w)))
    return adj


class _DS(DijkstraScholten):
    """DS with cascade detach for the run-to-completion actor setting."""

    def __init__(self, n):
        super().__init__(n)
        self.running: int | None = None

    def _ack(self, node: int):
        self.acks += 1
        if node == self.ENV:
            self.env_deficit -= 1
            return
        self.deficit[node] -= 1
        self.try_detach(node)

    def try_detach(self, node: int):
        if (
            node != self.running
            and self.deficit[node] == 0
            and self.parent[node] is not None
        ):
            p = self.parent[node]
            self.parent[node] = None
            self._ack(p)


def run_event(
    n: int,
    handler: Callable,
    init_msgs: list[tuple[int, object]],
    schedule: str = "lifo",
):
    """Run a message-driven computation to quiescence.

    handler(v, msg) -> list[(dst, msg)] — the vertex action: applies the
    predicate, possibly mutates its vertex state (captured by the caller's
    closure), and returns the new diffusion messages.
    """
    ds = _DS(n)
    q: deque = deque()
    for dst, msg in init_msgs:
        ds.on_send(ds.ENV)
        q.append((dst, msg, ds.ENV))

    actions = 0
    max_queue = len(q)
    premature = False
    while q:
        if ds.terminated() and q:
            premature = True  # DS must never fire early
        v, msg, sender = q.pop() if schedule == "lifo" else q.popleft()
        actions += 1
        ds.on_receive(v, sender)
        ds.running = v
        out = handler(v, msg)
        for dst, m in out:
            ds.on_send(v)
            q.append((dst, m, v))
        ds.running = None
        ds.try_detach(v)
        max_queue = max(max_queue, len(q))
    return EventStats(
        actions=actions,
        acks=ds.acks,
        max_queue=max_queue,
        ds_terminated=ds.terminated(),
        ds_was_premature=premature,
    )


def event_sssp(adj, n: int, source: int, schedule: str = "lifo"):
    """The paper's Code Listing 1, executed message-by-message."""
    import math

    dist = [math.inf] * n
    dist[source] = 0.0

    def handler(v, d):
        if d < dist[v]:                    # the predicate
            dist[v] = d
            return [(u, d + w) for u, w in adj[v]]   # the diffusion
        return []

    init = [(u, dist[source] + w) for u, w in adj[source]]
    stats = run_event(n, handler, init, schedule=schedule)
    return dist, stats
