"""DiffusionSession — one message-driven front door for the whole system.

The paper's thesis is that static queries, graph mutation, and incremental
recomputation belong to **one** programming model (diffusive computation),
not three code paths.  The session realizes that (DESIGN.md §2.4):

* it owns the :class:`ShardedGraph`, the :class:`NameServer` (the paper's
  hardware name server), and cached per-program vertex state;
* **queries** go through one interface — ``session.query("sssp",
  source=0)`` — for every registered program (SSSP / BFS / CC / PPR /
  PageRank / triangle counting), on any execution backend
  (``engine="sharded" | "event" | "spmd"``);
* **mutations** accumulate in an :class:`UpdateBatch` (the seven
  primitives of §VI, batched) and land with ``session.commit()``, which
  applies them as **one compiled, device-resident scatter program** that
  patches the blocked-CSR views in place (tombstones + staged delta
  blocks — O(batch), no stream re-sort; DESIGN.md §2.9) and then
  *repairs* every cached program by re-diffusing only the affected
  frontier — the generic form of the paper's dynamic-graph processing.
  ``max_cache_entries=`` bounds the query cache with LRU eviction for
  long-running streaming sessions.

Repair strategies (per registered program, picked to reproduce the
from-scratch fixed point exactly):

* ``parents``   — shortest-path trees: deleted tree edges invalidate
  their downstream subtree via parent-pointer chasing through the global
  namespace, then every still-finite vertex re-emits once (SSSP).
* ``component`` — label diffusions: deletes reset every vertex of the
  affected components to its init label; all live vertices re-emit (CC).
* ``restart``   — residual-push programs (PPR / PageRank): their
  finite-eps fixed point is push-order-dependent, so only a fresh
  diffusion reproduces the from-scratch bits; insert-only traffic on
  monotone programs still takes the warm frontier path.

Engine matrix (DESIGN.md §2.5): ``sharded`` is the bulk-asynchronous
logical engine (default, any program); ``spmd`` shard_maps one compute
cell per mesh device (any program, needs >= n_cells devices); ``event``
is the message-at-a-time host oracle with real Dijkstra–Scholten
termination — a generic interpreter runs any registered program, with
handwritten fast oracles for SSSP/BFS.

Programs are declarative, user-registrable specs (programs.py, DESIGN.md
§2.7); ``query`` accepts registry names, ``@diffusive`` handles, bound
queries, or raw lowered programs, and a pluralized lane param
(``sources=[...]``) fans out into multi-query lanes of one diffusion
with per-lane cache entries.

Orthogonally, ``backend="xla" | "pallas"`` (DESIGN.md §2.6) picks the
relaxation-kernel implementation inside the sharded/spmd engines, and
``sweep="pull" | "push" | "auto"`` (DESIGN.md §2.8) the sweep direction —
dense destination-sorted pull, frontier-compacted source-sorted push, or
the per-round direction selector.  Every combination produces
bitwise-identical fixed points, so both are pure execution choices;
commit()-time repairs resume from tiny frontiers and therefore default
to the push sweep.
"""

from __future__ import annotations

import dataclasses
import json
import os
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import chaos
from .diffuse import (
    DiffuseStats,
    _sg_as_dict,
    diffuse,
    diffuse_from,
    exact_streams_for,
    logical_view,
    make_spmd_diffuse,
)
from .dynamic import NameServer, _invalidate_subtrees
from .graph import ShardedGraph, from_edges
from .journal import OpRecord, UpdateJournal
from .partition import Partitioned, ReplicaInfo, partition
from .relax import RELAX_BACKENDS, RELAX_SWEEPS
from .programs import (
    PROGRAMS,
    BoundQuery,
    ProgramHandle,
    ProgramSpec,
    VertexProgram,
    _fn_key,
    freeze_kwargs,
    make_laned,
    register_program,
)
from .updates import AppliedUpdates, UpdateBatch

__all__ = [
    "DiffusionSession",
    "ProgramSpec",
    "Result",
    "register_program",
    "PROGRAMS",
    "ConvergenceError",
    "ConvergenceWarning",
    "ValidationError",
    "JournalReplayError",
]

ENGINES = ("sharded", "event", "spmd")
ON_BUDGET = ("raise", "warn", "partial")

SNAPSHOT_FORMAT = 1
_JOURNAL_FILE = "journal.bin"


def _json_np(o):
    """json.dumps fallback: numpy scalars in cached query kwargs."""
    if isinstance(o, np.generic):
        return o.item()
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


class ConvergenceError(RuntimeError):
    """A diffusion hit its max_rounds budget before quiescence
    (``on_budget="raise"``)."""


class ConvergenceWarning(UserWarning):
    """Budget-exhaustion warning (``on_budget="warn"``, the default)."""


class ValidationError(RuntimeError):
    """A query result violated its program's Field schema (``validate=``):
    NaN in a float field, or a value outside the field's domain."""


class JournalReplayError(RuntimeError):
    """Journal replay diverged from the snapshot (e.g. a replayed vertex
    allocation produced a different id) — the store is inconsistent."""


class Result(NamedTuple):
    values: np.ndarray          # per-vertex result in global vertex order
    stats: Any                  # DiffuseStats | EventStats | None (cached)
    extra: dict


def _event_sssp(session, source: int = 0, unit_weights: bool = False,
                **_):
    from .event import build_adjacency, event_sssp

    src, dst, w = session.edge_list()
    if unit_weights:
        w = np.ones_like(w)
    n = session.n_ids
    dist, st = event_sssp(build_adjacency(src, dst, w, n), n, source)
    return np.array(dist), st


def _run_triangles(session, engine=None, **kwargs):
    from .triangles import triangle_count_bitset

    src, dst, _ = session.edge_list()
    count = int(triangle_count_bitset(src, dst, session.n_ids))
    return Result(values=np.array(count), stats=None,
                  extra={"triangles": count})


# The diffusive programs register themselves in programs.py via the
# @diffusive decorator; here we attach the session-level extras the
# decorator cannot know about — the host event-engine oracles and the
# non-diffusive custom queries.
PROGRAMS["sssp"] = PROGRAMS["sssp"]._replace(event_fn=_event_sssp)
PROGRAMS["bfs"] = PROGRAMS["bfs"]._replace(
    event_fn=lambda session, **kw: _event_sssp(session, unit_weights=True,
                                               **kw))
register_program(ProgramSpec(
    "triangles", None, "", run_fn=_run_triangles,
))


@dataclasses.dataclass
class _Entry:
    """One cached (program, kwargs) fixed point."""

    spec: ProgramSpec
    prog: VertexProgram | None
    value_key: str
    kwargs: dict
    vstate: Any
    stats: Any
    engine: str
    backend: str = "xla"
    delta: float | None = None   # delta-stepping gate, kept across repairs
    sweep: str | None = None     # explicit sweep knob; None = defaulted
                                 #   (queries use the session's, repairs
                                 #   default to the push sweep)
    raw: Any = None              # run_fn programs (triangles): the cached
                                 #   Result itself; repaired by recount


class CommitInfo(NamedTuple):
    applied: AppliedUpdates
    repairs: dict               # query key -> (strategy, stats)


class DiffusionSession:
    """Stateful front door: build once, query / mutate / commit forever."""

    def __init__(self, part: Partitioned, ns: NameServer | None = None,
                 engine: str = "sharded", backend: str = "xla",
                 sweep: str = "pull", max_local_iters: int = 64,
                 max_rounds: int = 10_000,
                 max_cache_entries: int | None = None,
                 on_budget: str = "warn", validate: bool = False,
                 journal_fsync: str = "always", snapshot_keep: int = 3):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {engine!r}")
        if backend not in RELAX_BACKENDS:
            raise ValueError(f"backend must be one of {RELAX_BACKENDS}, "
                             f"got {backend!r}")
        if sweep not in RELAX_SWEEPS:
            raise ValueError(f"sweep must be one of {RELAX_SWEEPS}, "
                             f"got {sweep!r}")
        if max_cache_entries is not None and max_cache_entries < 1:
            raise ValueError("max_cache_entries must be >= 1 (or None "
                             "for an unbounded cache)")
        if on_budget not in ON_BUDGET:
            raise ValueError(f"on_budget must be one of {ON_BUDGET}, "
                             f"got {on_budget!r}")
        self.part = part
        self._ns = ns                # lazily built: queries don't need one
        self.engine = engine
        self.backend = backend
        self.sweep = sweep
        self.max_local_iters = max_local_iters
        self.max_rounds = max_rounds
        # LRU query cache: a long-running streaming session sees an
        # unbounded stream of (program, source, backend, sweep) variants;
        # max_cache_entries bounds the retained fixed points — an evicted
        # entry simply recomputes on its next query and is no longer
        # repaired by commit().  Insertion order doubles as recency
        # (hits reinsert).
        self.max_cache_entries = max_cache_entries
        # convergence watchdog (DESIGN.md §2.13): what to do when a
        # diffusion exhausts max_rounds before quiescence, and whether to
        # schema-check results against each program's Field domains
        self.on_budget = on_budget
        self.validate = validate
        self._cache: dict[tuple, _Entry] = {}
        self._pending: UpdateBatch | None = None
        self._spmd_fns: dict = {}
        # durability (DESIGN.md §2.13): armed by save()/open()
        self._dur_dir: str | None = None
        self._ckpt = None                       # CheckpointManager
        self._journal: UpdateJournal | None = None
        self._journal_fsync = journal_fsync
        self._snapshot_keep = snapshot_keep

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_edges(cls, src, dst, n_nodes: int, weight=None,
                   n_cells: int = 4, strategy: str = "block",
                   edge_slack: float = 0.0, node_slack: float = 0.0,
                   engine: str = "sharded",
                   replica_threshold: int | str | None = None,
                   **kw) -> "DiffusionSession":
        """Build + partition a graph over n_cells compute cells.

        ``edge_slack`` / ``node_slack`` reserve free capacity slots per
        cell for the dynamic primitives (paper §VI).
        ``replica_threshold`` enables skew-aware hub splitting
        (rhizomes, DESIGN.md §2.12): ``"auto"`` or an int degree bound."""
        g = from_edges(src, dst, n_nodes, weight,
                       edge_slack=edge_slack, node_slack=node_slack)
        part = partition(g, n_cells, strategy=strategy,
                         replica_threshold=replica_threshold)
        return cls(part, engine=engine, **kw)

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def sg(self):
        return self.part.sg

    @property
    def ns(self) -> NameServer:
        """The global namespace (built on first mutation/resolution)."""
        if self._ns is None:
            self._ns = NameServer(self.part)
        return self._ns

    @property
    def n_cells(self) -> int:
        return self.sg.n_shards

    @property
    def n_ids(self) -> int:
        """Size of the global id space (capacity + dynamically added)."""
        if self._ns is not None:
            return int(self._ns.owner.shape[0])
        return int(np.asarray(self.part.owner).shape[0])

    def _layout(self):
        if self._ns is not None:
            return self._ns.owner, self._ns.local
        return np.asarray(self.part.owner), np.asarray(self.part.local)

    def to_global(self, values) -> np.ndarray:
        """[S, Np] shard layout -> [n_ids] gid order (via the name server,
        so dynamically added vertices resolve too).

        Dead ids (free capacity slots, deleted vertices) keep a stale
        slot mapping and may alias a live vertex's value — mask with
        :meth:`live_ids` when iterating the full id space."""
        owner, local = self._layout()
        return np.asarray(values)[owner, local]

    def live_ids(self) -> np.ndarray:
        """[n_ids] bool: ids currently naming a live vertex."""
        owner, local = self._layout()
        ok = np.asarray(self.sg.node_ok)[owner, local]
        gid = np.asarray(self.sg.gid)[owner, local]
        return ok & (gid == np.arange(owner.shape[0]))

    def edge_list(self):
        """Host copy of the live edge set as (src_gid, dst_gid, weight)."""
        sg = self.sg
        ok = np.asarray(sg.edge_ok)
        src_gid = np.asarray(sg.gid)[
            np.arange(sg.n_shards)[:, None], np.asarray(sg.src_local)
        ]
        return (src_gid[ok].astype(np.int32),
                np.asarray(sg.dst_gid)[ok].astype(np.int32),
                np.asarray(sg.weight)[ok].astype(np.float32))

    # ------------------------------------------------------------------
    # static queries
    # ------------------------------------------------------------------

    def _key(self, name: str, engine: str, kwargs: dict,
             backend: str = "xla", delta: float | None = None,
             sweep: str = "pull") -> tuple:
        # freeze_kwargs canonicalizes unhashable values (list-valued
        # ``sources`` etc.) into deterministic tuples
        key = (name, engine, freeze_kwargs(kwargs))
        # default (xla, ungated, pull) keys stay in the PR-1 shape so
        # adopt()/peek() callers keep working; variants get suffixed keys.
        # sweep variants are bitwise-identical fixed points, but they key
        # separately like backend so a caller can hold both warm.
        if backend != "xla":
            key = key + (backend,)
        if delta is not None:
            key = key + (("delta", delta),)
        if sweep != "pull":
            key = key + (("sweep", sweep),)
        if self.sg.replica_members is not None:
            # hub-replica graphs hold the same fixed points only up to
            # FP reassociation for sum monoids — keep their entries
            # distinct from an unsplit graph a caller might adopt() into
            key = key + (("replicas",),)
        return key

    def _cache_get(self, key) -> _Entry | None:
        """Cache lookup that refreshes recency (LRU via insertion order)."""
        entry = self._cache.pop(key, None)
        if entry is not None:
            self._cache[key] = entry
        return entry

    def _cache_put(self, key, entry: _Entry):
        """Insert most-recent; evict the least-recently-used entries
        beyond ``max_cache_entries`` (evictees just recompute on their
        next query and stop being repaired by commit())."""
        self._cache.pop(key, None)
        self._cache[key] = entry
        if self.max_cache_entries is not None:
            while len(self._cache) > self.max_cache_entries:
                self._cache.pop(next(iter(self._cache)))

    def _resolve(self, prog, kwargs: dict):
        """One registry path for every way of naming a program — a
        registry string, a :class:`ProgramHandle` (``sssp``), a
        :class:`BoundQuery` (``sssp(source=3)``), or a raw lowered
        :class:`VertexProgram` — used by ``query`` and ``peek`` alike.
        Returns (spec, name, merged kwargs, adhoc VertexProgram | None).
        """
        if isinstance(prog, VertexProgram):
            return None, None, kwargs, prog
        if isinstance(prog, BoundQuery):
            name, kwargs = prog.name, {**prog.kwargs, **kwargs}
        elif isinstance(prog, ProgramHandle):
            name = prog.name
        else:
            name = prog
        if name not in PROGRAMS:
            raise KeyError(
                f"unknown program {name!r}; registered: "
                f"{sorted(PROGRAMS)} (@diffusive or register_program to "
                f"add)")
        return PROGRAMS[name], name, kwargs, None

    def query(self, prog, engine: str | None = None,
              backend: str | None = None, sweep: str | None = None,
              refresh: bool = False, value_key: str | None = None,
              delta: float | None = None, validate: bool | None = None,
              **kwargs):
        """Run (or serve from cache) a named or ad-hoc vertex program.

        ``prog`` is a registry name ("sssp", "cc", "ppr", "pagerank",
        "bfs", "widest", "reach", "triangles", ...), a program handle or
        bound query from the :func:`~.programs.diffusive` decorator
        (``query(sssp(source=3))``), or a raw :class:`VertexProgram`
        (then ``value_key`` selects the result field).
        ``sharded``/``spmd`` fixed points are cached and repaired
        incrementally by later ``commit()`` calls; ``event`` (the host
        oracle) and custom ``run_fn`` queries recompute on every call —
        they always see the current graph and hold no device state to
        repair.

        **Multi-query lanes:** pluralizing a program's lane param —
        ``query("sssp", sources=[s0, s1, ...])`` or
        ``query(sssp(sources=[...]))`` — runs all B queries as lanes of a
        *single* diffusion (one edge sweep per sub-iteration serves every
        lane) and returns a list of per-source Results.  Each lane's
        fixed point is bitwise-identical to the corresponding
        single-source query, and each is cached under its single-source
        key, so later ``commit()`` repairs and ``peek``/``query`` hits
        treat lanes exactly like individually-issued queries.

        ``backend`` picks the relaxation kernel ("xla" | "pallas") and
        ``sweep`` the direction ("pull" | "push" | "auto" — dense,
        frontier-compacted, or per-round selected; all bitwise-identical);
        ``delta`` enables the delta-stepping priority gate for programs
        with a priority, and is remembered so commit()'s incremental
        repair re-diffuses under the same gate.

        A diffusion that exhausts ``max_rounds`` before quiescence
        surfaces ``stats.converged == False`` and triggers the session's
        ``on_budget`` policy ("raise" | "warn" | "partial").
        ``validate=`` (per-call override of the session default) checks
        the returned values against the program's Field schema — NaN and
        out-of-domain values on live vertices raise
        :class:`ValidationError` (DESIGN.md §2.13).
        """
        engine = engine or self.engine
        explicit_backend = backend
        backend = backend or self.backend
        explicit_sweep = sweep
        sweep = sweep or self.sweep
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {engine!r}")
        if backend not in RELAX_BACKENDS:
            raise ValueError(f"backend must be one of {RELAX_BACKENDS}, "
                             f"got {backend!r}")
        if sweep not in RELAX_SWEEPS:
            raise ValueError(f"sweep must be one of {RELAX_SWEEPS}, "
                             f"got {sweep!r}")
        if delta is not None and engine != "sharded":
            raise ValueError(
                "delta-stepping is only gated on engine='sharded'; the "
                f"{engine!r} engine would silently run ungated")
        if explicit_backend is not None and engine == "event":
            raise ValueError(
                "the event oracle runs on the host and has no relax "
                "backend; backend= would be silently ignored")
        if explicit_sweep is not None and engine == "event":
            raise ValueError(
                "the event oracle runs on the host and has no sweep "
                "direction; sweep= would be silently ignored")

        spec, name, kwargs, adhoc = self._resolve(prog, kwargs)
        if adhoc is not None:
            if value_key is None:
                raise ValueError("value_key= is required for a raw "
                                 "VertexProgram")
            spec = ProgramSpec(f"adhoc:{id(adhoc)}", lambda: adhoc,
                               value_key)
            name = spec.name
        elif spec.run_fn is not None:
            # custom (non-diffusive) queries go through the same cache /
            # commit()-repair door: the Result is cached whole and
            # repaired by a restart-style rerun ("recount") on commit
            if (explicit_backend is not None or explicit_sweep is not None
                    or delta is not None):
                raise ValueError(
                    f"{name!r} is a custom run_fn query with no "
                    f"relaxation sweep; backend=/sweep=/delta= would be "
                    f"silently ignored")
            key = self._key(name, engine, kwargs)
            if not refresh:
                hit = self._cache_get(key)
                if hit is not None:
                    return hit.raw
            res = spec.run_fn(self, engine=engine, **kwargs)
            self._cache_put(key, _Entry(spec, None, spec.value_key,
                                        dict(kwargs), None, res.stats,
                                        engine, raw=res))
            return res

        lane_kw = spec.lane_param + "s" if spec.lane_param else None
        if lane_kw and lane_kw in kwargs:
            lane_vals = list(kwargs.pop(lane_kw))
            return self._query_lanes(spec, name, lane_vals, kwargs, engine,
                                     backend, refresh, delta, value_key,
                                     sweep, explicit_sweep, validate)

        key = self._key(name, engine, kwargs, backend, delta, sweep)
        if not refresh:
            hit = self._cache_get(key)
            if hit is not None:
                res = self._result(hit)
                # re-validate on every serve: a poisoned cached state
                # (chaos.poison_vstate, a bad repair) is caught at read
                # time, not just at compute time
                self._maybe_validate(hit, res, validate,
                                     f"query {name!r} (cached)")
                return res

        if engine == "event":
            if spec.event_fn is not None:
                values, st = spec.event_fn(self, **kwargs)
            elif spec.factory is not None:
                # generic oracle: any @diffusive program runs
                # message-at-a-time on the host (event.py)
                from .event import event_diffuse

                program = (adhoc if adhoc is not None
                           else spec.factory(**kwargs))
                src, dst, w = self.edge_list()
                state, st = event_diffuse(program, src, dst, w, self.n_ids,
                                          node_ok=self.live_ids())
                vk = value_key or spec.value_key
                values = state[vk]
            else:
                raise ValueError(
                    f"program {name!r} has no event-engine oracle and no "
                    f"factory; use engine='sharded' or 'spmd'")
            return Result(values=values, stats=st,
                          extra={"live": self.live_ids()})

        program = adhoc if adhoc is not None else spec.factory(**kwargs)
        vk = value_key or spec.value_key
        vstate, stats = self._run_diffusion(program, engine, backend, delta,
                                            sweep)
        entry = _Entry(spec, program, vk, dict(kwargs), vstate, stats,
                       engine, backend=backend, delta=delta,
                       sweep=explicit_sweep)
        self._cache_put(key, entry)
        self._enforce_budget(stats, f"query {name!r}")
        res = self._result(entry)
        self._maybe_validate(entry, res, validate, f"query {name!r}")
        return res

    def _compact_for(self, program: VertexProgram | None):
        """Sum-combine diffusions must see compacted (delta-free) streams
        to stay bitwise-equal to a full rebuild (DESIGN.md §2.9) —
        delegate the policy to :func:`~.diffuse.exact_streams_for` and
        *persist* its result, so every later query and repair reuses the
        clean graph instead of re-sorting per call; min/max programs
        consume the incremental views directly and come back unchanged."""
        if program is not None:
            self.part.sg = exact_streams_for(self.sg, program)

    def _run_diffusion(self, program: VertexProgram, engine: str,
                       backend: str, delta, sweep: str = "pull"):
        self._compact_for(program)
        if engine == "sharded":
            return diffuse(
                self.sg, program, max_local_iters=self.max_local_iters,
                max_rounds=self.max_rounds, delta=delta, backend=backend,
                sweep=sweep)
        return self._run_spmd(program, backend, sweep)

    def _query_lanes(self, spec: ProgramSpec, name: str, lane_vals: list,
                     kwargs: dict, engine: str, backend: str,
                     refresh: bool, delta, value_key: str | None = None,
                     sweep: str = "pull",
                     explicit_sweep: str | None = None,
                     validate: bool | None = None) -> list:
        """Fan a pluralized lane param out into B lanes of one diffusion.

        The laned fixed point is split lane-by-lane into ordinary
        single-query cache entries (``vstate`` leaves [S, L, Np] ->
        [S, Np]), so commit()-time repair splices and re-diffuses each
        lane exactly like a query that was issued on its own.  A push /
        auto sweep ORs every lane's senders into one shared active set —
        one compaction serves all lanes.
        """
        per_lane = [dict(kwargs, **{spec.lane_param: v}) for v in lane_vals]
        keys = [self._key(name, engine, kw, backend, delta, sweep)
                for kw in per_lane]
        if not refresh and all(k in self._cache for k in keys):
            return [self._result(self._cache_get(k)) for k in keys]

        if engine == "event":
            # the host oracle is message-at-a-time; lanes degrade to a loop
            return [self.query(name, engine=engine, refresh=refresh,
                               value_key=value_key, **kw)
                    for kw in per_lane]

        progs = tuple(spec.factory(**kw) for kw in per_lane)
        laned = make_laned(progs)
        vstate, stats = self._run_diffusion(laned, engine, backend, delta,
                                            sweep)
        self._enforce_budget(stats, f"query {name!r} "
                                    f"({len(lane_vals)} lanes)")

        vk = value_key or spec.value_key
        results = []
        for i, (kw, key) in enumerate(zip(per_lane, keys)):
            # slicing lane i uploads the literal index — an O(1) h2d per
            # lane, legal under the sanitizer (which guards d2h syncs
            # and retraces); keep the d2h direction guarded
            with jax.transfer_guard_host_to_device("allow"):
                lane_state = jax.tree_util.tree_map(lambda a: a[:, i],
                                                    vstate)
            entry = _Entry(spec, progs[i], vk, kw, lane_state,
                           stats, engine, backend=backend, delta=delta,
                           sweep=explicit_sweep)
            self._cache_put(key, entry)
            res = self._result(entry)
            self._maybe_validate(entry, res, validate,
                                 f"query {name!r} lane {i}")
            results.append(res)
        return results

    def adopt(self, name: str, vstate, stats=None, engine: str = "sharded",
              backend: str | None = None, delta: float | None = None,
              sweep: str | None = None, **kwargs) -> tuple:
        """Register an existing fixed point with the session so commit()
        repairs it (on the session's backend unless overridden); returns
        the cache key."""
        spec = PROGRAMS[name]
        prog = spec.factory(**kwargs)
        backend = backend or self.backend
        key = self._key(name, engine, kwargs, backend, delta,
                        sweep or self.sweep)
        self._cache_put(key, _Entry(spec, prog, spec.value_key,
                                    dict(kwargs), vstate, stats, engine,
                                    backend=backend, delta=delta,
                                    sweep=sweep))
        return key

    def vertex_state(self, name: str, engine: str | None = None,
                     backend: str | None = None, delta: float | None = None,
                     sweep: str | None = None, **kwargs):
        """The cached [S, Np]-layout vertex-state pytree of a query."""
        key = self._key(name, engine or self.engine, kwargs,
                        backend or self.backend, delta,
                        sweep or self.sweep)
        entry = self._cache_get(key)    # reads keep the entry warm (LRU)
        if entry is None:
            raise KeyError(
                f"no cached fixed point for {name!r} with {kwargs} — "
                f"never queried, or evicted by max_cache_entries; "
                f"query() recomputes it")
        if entry.vstate is None:
            raise ValueError(
                f"{name!r} is a custom run_fn query; it caches a whole "
                f"Result (query() serves it), not a vertex-state pytree")
        return entry.vstate

    def _run_spmd(self, program: VertexProgram, backend: str = "xla",
                  sweep: str = "pull"):
        self._compact_for(program)
        S = self.n_cells
        if len(jax.devices()) < S:
            raise RuntimeError(
                f"engine='spmd' needs >= {S} devices (one per compute "
                f"cell); this process has {len(jax.devices())}. Set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={S} "
                f"before importing jax, or use engine='sharded'.")
        from ..launch.mesh import mesh_context

        # the per-device fn traces prog.init *inside* shard_map, so the
        # cache key needs the init identity on top of the program's
        # (init-excluding) structural equality — see VertexProgram.__eq__
        fkey = (program, _fn_key(program.init), S, backend, sweep)
        if fkey not in self._spmd_fns:
            mesh = jax.make_mesh((S,), ("cells",))
            self._spmd_fns[fkey] = (mesh, make_spmd_diffuse(
                mesh, program, self.sg, axis_name="cells",
                max_local_iters=self.max_local_iters,
                max_rounds=self.max_rounds, backend=backend, sweep=sweep))
        mesh, fn = self._spmd_fns[fkey]
        with mesh_context(mesh):
            return fn(_sg_as_dict(self.sg, with_push=sweep != "pull"))

    def _result(self, entry: _Entry) -> Result:
        values = self.to_global(entry.vstate[entry.value_key])
        extra = {k: self.to_global(v) for k, v in entry.vstate.items()
                 if k != entry.value_key}
        extra["live"] = self.live_ids()
        return Result(values=values, stats=entry.stats, extra=extra)

    # ------------------------------------------------------------------
    # the seven primitives, batched
    # ------------------------------------------------------------------

    def update(self) -> UpdateBatch:
        """The pending mutation batch (created lazily)."""
        if self._pending is None:
            self._pending = UpdateBatch(self.ns)
        return self._pending

    def add_vertex(self, shard: int | None = None) -> int:
        return self.update().add_vertex(shard)

    def delete_vertex(self, gid: int):
        self.update().delete_vertex(gid)
        return self

    def add_edge(self, u: int, v: int, w: float = 1.0):
        self.update().add_edge(u, v, w)
        return self

    def delete_edge(self, u: int, v: int):
        self.update().delete_edge(u, v)
        return self

    def touch(self, gid: int):
        self.update().touch_vertex(gid)
        return self

    def peek(self, u: int, prog="sssp", **kwargs):
        """The paper's peek primitive: u's per-out-edge neighbour values
        of a cached program's result (NaN on dead slots).

        ``prog`` goes through the same registry path as :meth:`query` —
        a name string, a program handle, or a bound query
        (``sess.peek(0, sssp(source=3))``) all resolve identically."""
        from .dynamic import peek as _peek

        engine = kwargs.pop("engine", None) or self.engine
        backend = kwargs.pop("backend", None) or self.backend
        sweep_kw = kwargs.pop("sweep", None)
        sweep = sweep_kw or self.sweep
        delta = kwargs.pop("delta", None)
        if engine == "event":
            raise ValueError(
                "peek reads a cached shard-layout state; the event oracle "
                "holds none — use engine='sharded' or 'spmd'")
        spec, name, kwargs, adhoc = self._resolve(prog, kwargs)
        if adhoc is not None:
            raise ValueError(
                "peek needs a registered program (name, handle, or bound "
                "query), not a raw VertexProgram")
        if spec.run_fn is not None:
            raise ValueError(
                f"peek reads a cached shard-layout vertex state; the "
                f"custom query {name!r} caches a whole Result and holds "
                f"none")
        lane_kw = spec.lane_param + "s" if spec.lane_param else None
        if lane_kw and lane_kw in kwargs:
            raise ValueError(
                f"peek reads one cached fixed point; a lane batch caches "
                f"per source — peek with {spec.lane_param}=<one of "
                f"{lane_kw}> instead")
        key = self._key(name, engine, kwargs, backend, delta, sweep)
        if key not in self._cache:
            # fall back to the unique cached variant of this program (and,
            # when kwargs were given, of these kwargs) — a delta/backend/
            # engine-variant entry serves a plain peek instead of paying a
            # fresh diffusion
            kw = freeze_kwargs(kwargs)
            same = [k for k in self._cache
                    if k[0] == name and (not kwargs or k[2] == kw)]
            if len(same) == 1:
                key = same[0]
            else:
                self.query(name, engine=engine, backend=backend,
                           sweep=sweep_kw, delta=delta, **kwargs)
        entry = self._cache_get(key)    # reads keep the entry warm (LRU)
        return _peek(self.sg, entry.vstate[entry.value_key], self.ns, u)

    # ------------------------------------------------------------------
    # commit: apply the batch + incremental repair
    # ------------------------------------------------------------------

    def commit(self, max_local_iters: int | None = None) -> CommitInfo:
        """Apply the pending UpdateBatch (vectorized) and repair every
        cached program fixed point by frontier re-diffusion.

        When a journal is armed (after :meth:`save`/:meth:`open`) the
        batch is journaled **before** it mutates any state — write-ahead
        logging.  A crash after the append but before the apply simply
        redoes the record at :meth:`open` (replay is deterministic, so
        redo converges to the same bits); an apply that *fails* (e.g. a
        full compute cell) rolls the record back so the journal never
        claims an op the store rejected."""
        return self._commit(max_local_iters, journal=True)

    def _commit(self, max_local_iters: int | None = None,
                journal: bool = True) -> CommitInfo:
        mli = max_local_iters or self.max_local_iters
        if self._pending is None or len(self._pending) == 0:
            applied = AppliedUpdates((), (), (), (), ())
        else:
            seq = None
            if journal and self._journal is not None:
                # snapshot the op lists BEFORE apply (apply clears them)
                rec = OpRecord.from_batch(self._pending)
                seq = self._journal.append(rec)
                chaos.point("commit.journal-appended")
            try:
                self.part.sg, applied = self._pending.apply(self.part.sg)
            except Exception:
                # the store rejected the batch — un-journal it (ChaosKill
                # is a BaseException and deliberately escapes this)
                if seq is not None:
                    self._journal.rollback(seq)
                raise
            self._pending = None
            chaos.point("commit.applied")

        repairs = {}
        for key, entry in list(self._cache.items()):
            if applied.n_ops == 0:
                repairs[key] = ("noop", None)
                continue
            repairs[key] = self._repair_entry(entry, applied, mli)
        if applied.n_ops:
            chaos.point("commit.repaired")
        for key, (strategy, stats) in repairs.items():
            if stats is not None:
                self._enforce_budget(stats, f"commit repair ({strategy}) "
                                            f"of {key[0]!r}")
        return CommitInfo(applied=applied, repairs=repairs)

    def _repair_entry(self, entry: _Entry, applied: AppliedUpdates,
                      mli: int):
        sg = self.sg
        if entry.spec.run_fn is not None:
            # custom queries (triangles): restart-style recount against
            # the committed graph — cached and repaired like any program
            res = entry.spec.run_fn(self, engine=entry.engine,
                                    **entry.kwargs)
            entry.raw, entry.stats = res, res.stats
            return ("recount", res.stats)
        strategy = entry.spec.repair
        if not applied.has_deletes and entry.spec.monotone:
            strategy = "frontier"
        elif strategy == "parents" and "parent" not in entry.vstate:
            strategy = "restart"

        if strategy == "restart":
            self._compact_for(entry.prog)
            sg = self.sg            # _compact_for may have persisted
            if entry.engine == "spmd":
                vstate, stats = self._run_spmd(entry.prog, entry.backend,
                                               entry.sweep or self.sweep)
            else:
                vstate, stats = diffuse(sg, entry.prog,
                                        max_local_iters=mli,
                                        max_rounds=self.max_rounds,
                                        delta=entry.delta,
                                        backend=entry.backend,
                                        sweep=entry.sweep or self.sweep)
            entry.vstate, entry.stats = vstate, stats
            return ("restart", stats)

        vstate, active = self._warm_state(entry, applied, strategy)
        # resume under the entry's own delta gate + kernel backend, so the
        # repair diffusion is work-gated exactly like the original query.
        # Warm repairs resume from a tiny frontier, so they default to the
        # frontier-compacted push sweep (an explicit query sweep wins) —
        # bitwise-identical, O(frontier-adjacent edges) per round.
        vstate, stats = diffuse_from(sg, entry.prog, vstate, active,
                                     max_local_iters=mli,
                                     max_rounds=self.max_rounds,
                                     delta=entry.delta,
                                     backend=entry.backend,
                                     sweep=entry.sweep or "push")
        entry.vstate, entry.stats = vstate, stats
        return (strategy, stats)

    # -- repair state builders -------------------------------------------

    def _slots(self, gids) -> tuple[np.ndarray, np.ndarray]:
        s = np.array([self.ns.resolve(g)[0] for g in gids], np.int32)
        l = np.array([self.ns.resolve(g)[1] for g in gids], np.int32)
        return s, l

    def _splice_init(self, entry: _Entry, vstate, gids):
        """Reset the given vertices' state to the program's init values
        (fresh slots may hold stale state from a previously deleted
        occupant)."""
        if not gids:
            return vstate
        init_v, _ = entry.prog.init(logical_view(self.sg))
        s, l = self._slots(gids)
        return jax.tree_util.tree_map(
            lambda cur, ini: cur.at[s, l].set(ini[s, l]), vstate, init_v
        )

    def _base_frontier(self, applied: AppliedUpdates):
        """Insert source endpoints + touched + newly added vertices."""
        sg = self.sg
        active = jnp.zeros((sg.n_shards, sg.n_per_shard), bool)
        gids = ([u for u, _, _ in applied.edge_adds]
                + list(applied.touched)
                + [g for g, _, _ in applied.vertex_adds])
        if gids:
            s, l = self._slots(gids)
            active = active.at[s, l].set(True)
        return active & sg.node_ok

    def _warm_state(self, entry: _Entry, applied: AppliedUpdates,
                    strategy: str):
        sg = self.sg
        vstate = entry.vstate
        # new vertices (and reused slots) start from init state
        fresh = [g for g, _, _ in applied.vertex_adds]
        vstate = self._splice_init(entry, vstate, fresh)
        active = self._base_frontier(applied)

        if strategy == "frontier":
            return vstate, active

        if strategy == "parents":
            # roots: deleted tree edges + orphans of deleted vertices
            parent = vstate["parent"]
            roots = []
            dead = set(applied.vertex_deletes)
            for u, v in applied.edge_deletes:
                sv, lv = self.ns.resolve(v)
                if int(parent[sv, lv]) == u:
                    roots.append(v)
            if dead:
                par_np = self.to_global(parent)
                for v in range(par_np.shape[0]):
                    if int(par_np[v]) in dead and v not in dead:
                        roots.append(v)
            dist = vstate["dist"]
            parent_a = parent
            if roots or dead:
                all_roots = list(dict.fromkeys(roots)) + list(dead)
                invalid = _invalidate_subtrees(
                    self.part, self.ns, vstate, all_roots)
                dist = jnp.where(invalid, jnp.inf, dist)
                parent_a = jnp.where(invalid, -1, parent_a)
                # every still-finite vertex re-emits once; receivers'
                # predicates discard non-improvements (pure diffusion)
                active = active | (jnp.isfinite(dist) & sg.node_ok)
            out = dict(vstate)
            out["dist"], out["parent"] = dist, parent_a
            return out, active

        if strategy == "component":
            comp = vstate[entry.value_key]
            affected = set()
            for u, v in applied.edge_deletes:
                for g in (u, v):
                    s_, l_ = self.ns.resolve(g)
                    affected.add(int(comp[s_, l_]))
            for g in applied.vertex_deletes:
                s_, l_ = self.ns.resolve(g)
                affected.add(int(comp[s_, l_]))
            if affected:
                init_v, _ = entry.prog.init(logical_view(sg))
                aff = jnp.isin(comp, jnp.asarray(sorted(affected),
                                                 comp.dtype))
                comp = jnp.where(aff, init_v[entry.value_key], comp)
                # all live vertices re-emit so cross-component inflow
                # re-arrives; min-combine discards non-improvements
                active = active | sg.node_ok
            out = dict(vstate)
            out[entry.value_key] = comp
            return out, active

        raise ValueError(f"unknown repair strategy {strategy!r}")

    # ------------------------------------------------------------------
    # convergence watchdog + result validation (DESIGN.md §2.13)
    # ------------------------------------------------------------------

    def _enforce_budget(self, stats, context: str) -> None:
        """Apply the on_budget policy to a diffusion's converged flag."""
        conv = getattr(stats, "converged", None)
        if conv is None or self.on_budget == "partial":
            return
        # explicit d2h transfer: legal under the runtime sanitizer's
        # transfer guard (same idiom as exact_streams_for)
        if bool(jax.device_get(conv)):
            return
        msg = (f"{context} exhausted max_rounds={self.max_rounds} before "
               f"quiescence — the fixed point is PARTIAL "
               f"(stats.converged=False); raise max_rounds, or accept "
               f"partial results with on_budget='partial'")
        if self.on_budget == "raise":
            raise ConvergenceError(msg)
        warnings.warn(msg, ConvergenceWarning)

    def _maybe_validate(self, entry: _Entry, res: Result,
                        validate: bool | None, context: str) -> None:
        on = self.validate if validate is None else validate
        if on:
            self._validate_result(entry, res, context)

    def _validate_result(self, entry: _Entry, res: Result,
                         context: str) -> None:
        """Schema-check a Result against its program's Field domains.

        Lowered from each Field declaration (programs.py): NaN is always
        invalid for float fields; a declared ``domain=(lo, hi)`` bounds
        the legal values (None = unbounded on that side); undeclared int
        domains default to the payload range ``[-1, n_ids)`` (gid
        payloads plus the -1 sentinel).  Only live vertices are checked —
        dead slots legitimately hold stale bits."""
        fields = getattr(entry.prog, "fields", None)
        if fields is None:
            return
        live = np.asarray(res.extra["live"])
        for fname, field in fields:
            if fname == entry.value_key:
                arr = res.values
            elif fname in res.extra:
                arr = res.extra[fname]
            else:
                continue
            a = np.asarray(arr)[live]
            if a.size == 0:
                continue
            lo = hi = None
            if np.issubdtype(a.dtype, np.floating):
                nan = np.isnan(a)
                if nan.any():
                    raise ValidationError(
                        f"{context}: field {fname!r} holds NaN on "
                        f"{int(nan.sum())} live vertices")
                if field.domain is not None:
                    lo, hi = field.domain
            else:
                lo, hi = (field.domain if field.domain is not None
                          else (-1, self.n_ids - 1))
            if lo is not None and bool((a < lo).any()):
                raise ValidationError(
                    f"{context}: field {fname!r} holds values below "
                    f"{lo} on live vertices (min {a.min()})")
            if hi is not None and bool((a > hi).any()):
                raise ValidationError(
                    f"{context}: field {fname!r} holds values above "
                    f"{hi} on live vertices (max {a.max()})")

    # ------------------------------------------------------------------
    # durability: snapshot + write-ahead journal (DESIGN.md §2.13)
    # ------------------------------------------------------------------

    def _attach(self, directory: str) -> None:
        # lazy import: checkpoint.manager imports core.chaos, which
        # executes core/__init__ (and therefore this module)
        from ..checkpoint.manager import CheckpointManager

        directory = os.path.abspath(directory)
        if self._dur_dir is not None:
            if directory != self._dur_dir:
                raise ValueError(
                    f"session is already durable at {self._dur_dir}; "
                    f"cannot re-home it to {directory}")
            return
        os.makedirs(directory, exist_ok=True)
        self._dur_dir = directory
        self._ckpt = CheckpointManager(directory, keep=self._snapshot_keep)
        self._journal = UpdateJournal(
            os.path.join(directory, _JOURNAL_FILE),
            fsync=self._journal_fsync)

    def save(self, directory: str | None = None) -> int:
        """Snapshot the full session and arm the write-ahead journal.

        The first call names the durability directory; later calls may
        omit it.  The snapshot captures everything :meth:`open` needs to
        resume **bitwise-equal**: the graph arrays (both CSR views,
        delta/tombstone state, replica maps), the partition, the name
        server (including free-list order), every reconstructible cached
        fixed point (vstate + stats), and the session's engine/backend/
        sweep/watchdog settings.  Writes go through
        :class:`CheckpointManager` (atomic tmp-dir rename + digest
        manifest + retention), so a crash mid-save never damages the
        previous snapshot.  After a successful save the journal head is
        garbage-collected up to the *oldest retained* snapshot —
        falling back past a corrupt snapshot still finds every record
        it needs.  Returns the snapshot step (= the journal seq the
        snapshot is consistent with).

        Uncommitted pending ops are **not** captured — commit() first to
        make them durable (they journal at commit).
        """
        if directory is None:
            directory = self._dur_dir
        if directory is None:
            raise ValueError(
                "save() needs a directory the first time "
                "(session.save('/path/to/dir'))")
        if self._pending is not None and len(self._pending):
            warnings.warn(
                "save() with uncommitted pending updates: the snapshot "
                "captures committed state only — commit() first to make "
                "the pending batch durable")
        self._attach(directory)
        step = self._journal.next_seq
        tree, meta = self._snapshot_tree()
        meta["format"] = SNAPSHOT_FORMAT
        meta_bytes = json.dumps(meta, default=_json_np).encode()
        tree["session_meta"] = np.frombuffer(meta_bytes, np.uint8).copy()
        self._ckpt.save(step, tree, wait=True)
        steps = self._ckpt.all_steps()
        if steps:
            self._journal.truncate(min(steps))
        return step

    def _snapshot_tree(self) -> tuple[dict, dict]:
        """-> (flat leaf dict, JSON-ready metadata) for one snapshot."""
        sg = self.sg
        tree: dict[str, Any] = {}
        for k, v in sg.state_dict().items():
            tree[f"graph/{k}"] = v
        tree["part/owner"] = np.asarray(self.part.owner)
        tree["part/local"] = np.asarray(self.part.local)
        rep = getattr(self.part, "replica", None)
        if rep is not None:
            for f in ReplicaInfo._fields:
                tree[f"replica/{f}"] = np.asarray(getattr(rep, f))
        if self._ns is not None:
            for k, v in self._ns.state_dict().items():
                tree[f"ns/{k}"] = v
        meta = {
            "engine": self.engine,
            "backend": self.backend,
            "sweep": self.sweep,
            "max_local_iters": self.max_local_iters,
            "max_rounds": self.max_rounds,
            "max_cache_entries": self.max_cache_entries,
            "on_budget": self.on_budget,
            "validate": self.validate,
            "snapshot_keep": self._snapshot_keep,
            "graph_meta": sg.meta_dict(),
            "n_real": int(self.part.n_real),
            "has_ns": self._ns is not None,
            "has_replica": rep is not None,
            "cache": [],
        }
        for i, entry in enumerate(self._cache.values()):
            name = entry.spec.name
            if name.startswith("adhoc:") or name not in PROGRAMS:
                warnings.warn(
                    f"snapshot skips cache entry {name!r}: ad-hoc "
                    f"programs are not reconstructible by name (the "
                    f"query recomputes after open())")
                continue
            em: dict[str, Any] = {
                "name": name,
                "value_key": entry.value_key,
                "kwargs": entry.kwargs,
                "engine": entry.engine,
                "backend": entry.backend,
                "delta": entry.delta,
                "sweep": entry.sweep,
                # the cache key resolved a defaulted sweep to the
                # session's — record the resolved value so open()
                # rebuilds the identical key
                "key_sweep": entry.sweep or self.sweep,
            }
            if entry.spec.run_fn is not None:
                em["kind"] = "run_fn"
                em["extra_scalars"] = {}
                em["extra_arrays"] = []
                tree[f"cache/{i}/raw"] = np.asarray(entry.raw.values)
                for k, v in entry.raw.extra.items():
                    if isinstance(v, np.ndarray):
                        em["extra_arrays"].append(k)
                        tree[f"cache/{i}/extra/{k}"] = v
                    else:
                        em["extra_scalars"][k] = v
            else:
                em["kind"] = "diffuse"
                em["vstate_fields"] = list(entry.vstate.keys())
                for f, leaf in entry.vstate.items():
                    tree[f"cache/{i}/vstate/{f}"] = leaf
                if isinstance(entry.stats, DiffuseStats):
                    em["stats"] = "diffuse"
                    for f in DiffuseStats._fields:
                        tree[f"cache/{i}/stats/{f}"] = getattr(
                            entry.stats, f)
                else:
                    em["stats"] = None
            meta["cache"].append(em)
        return tree, meta

    @classmethod
    def open(cls, directory: str, journal_fsync: str = "always",
             step: int | None = None) -> "DiffusionSession":
        """Recover a session: latest valid snapshot + journal-tail replay.

        A damaged latest snapshot (torn manifest, missing leaf, digest
        mismatch) falls back to the previous retained one; the journal's
        opening scan truncates any torn tail; then every journaled commit
        with ``seq >= snapshot step`` is redone through the same compiled
        apply + cache-repair path the live commits used.  The recovered
        session is bitwise-equal to one that never crashed — graph
        arrays, cache keys, and query results alike."""
        from ..checkpoint.manager import CheckpointManager

        directory = os.path.abspath(directory)
        ckpt = CheckpointManager(directory)
        arrays, loaded_step = ckpt.restore_flat(step=step)
        meta = json.loads(bytes(bytearray(arrays.pop("session_meta"))))
        if meta.get("format") != SNAPSHOT_FORMAT:
            raise IOError(
                f"snapshot format {meta.get('format')!r} is not "
                f"{SNAPSHOT_FORMAT} (newer writer?)")
        graph_arrays = {k.split("/", 1)[1]: v for k, v in arrays.items()
                        if k.startswith("graph/")}
        sg = ShardedGraph.from_state(graph_arrays, meta["graph_meta"])
        replica = None
        if meta["has_replica"]:
            replica = ReplicaInfo(*(np.asarray(arrays[f"replica/{f}"])
                                    for f in ReplicaInfo._fields))
        part = Partitioned(sg, arrays["part/owner"], arrays["part/local"],
                           n_real=meta["n_real"], replica=replica)
        ns = None
        if meta["has_ns"]:
            ns_arrays = {k.split("/", 1)[1]: v for k, v in arrays.items()
                         if k.startswith("ns/")}
            ns = NameServer.from_state(ns_arrays, sg.n_shards,
                                       replica=replica)
        sess = cls(part, ns=ns, engine=meta["engine"],
                   backend=meta["backend"], sweep=meta["sweep"],
                   max_local_iters=meta["max_local_iters"],
                   max_rounds=meta["max_rounds"],
                   max_cache_entries=meta["max_cache_entries"],
                   on_budget=meta["on_budget"], validate=meta["validate"],
                   journal_fsync=journal_fsync,
                   snapshot_keep=meta.get("snapshot_keep", 3))
        ckpt.keep = sess._snapshot_keep
        sess._restore_cache(meta["cache"], arrays)
        sess._dur_dir = directory
        sess._ckpt = ckpt
        sess._journal = UpdateJournal(
            os.path.join(directory, _JOURNAL_FILE), fsync=journal_fsync)
        sess._replay_journal(loaded_step)
        return sess

    def _restore_cache(self, cache_meta: list, arrays: dict) -> None:
        for i, em in enumerate(cache_meta):
            name = em["name"]
            if name not in PROGRAMS:
                warnings.warn(
                    f"snapshot cache entry {name!r} is no longer in the "
                    f"program registry; skipping (it recomputes on query)")
                continue
            spec = PROGRAMS[name]
            kwargs = dict(em["kwargs"])
            if em["kind"] == "run_fn":
                extra = dict(em["extra_scalars"])
                for k in em["extra_arrays"]:
                    extra[k] = np.asarray(arrays[f"cache/{i}/extra/{k}"])
                res = Result(values=np.asarray(arrays[f"cache/{i}/raw"]),
                             stats=None, extra=extra)
                key = self._key(name, em["engine"], kwargs)
                self._cache_put(key, _Entry(spec, None, em["value_key"],
                                            kwargs, None, None,
                                            em["engine"], raw=res))
                continue
            prog = spec.factory(**kwargs)
            vstate = {f: jnp.asarray(arrays[f"cache/{i}/vstate/{f}"])
                      for f in em["vstate_fields"]}
            stats = None
            if em["stats"] == "diffuse":
                stats = DiffuseStats(*(
                    jnp.asarray(arrays[f"cache/{i}/stats/{f}"])
                    for f in DiffuseStats._fields))
            key = self._key(name, em["engine"], kwargs, em["backend"],
                            em["delta"], em["key_sweep"])
            self._cache_put(key, _Entry(
                spec, prog, em["value_key"], kwargs, vstate, stats,
                em["engine"], backend=em["backend"], delta=em["delta"],
                sweep=em["sweep"]))

    def _replay_journal(self, from_seq: int) -> int:
        """Redo journaled commits on top of the snapshot (WAL recovery).

        Each record rebuilds an UpdateBatch and runs the normal commit
        path (journaling disabled), so NameServer allocation, replica
        routing, compaction policy, and cache repairs all re-run exactly
        as they did live.  Vertex adds allocate gids *eagerly* at
        ``add_vertex`` time — before the commit that journals them — so
        a snapshot may already contain a journaled allocation; those are
        verified and reused, anything newer is re-allocated and must
        come out identical (gids are monotonic, never reused)."""
        replayed = 0
        for _seq, rec in self._journal.replay(from_seq):
            batch = UpdateBatch(self.ns)
            for gid, s, l in rec.vadds.tolist():
                if gid < self.ns._next:
                    if self.ns.resolve(gid) != (s, l):
                        raise JournalReplayError(
                            f"replayed vertex add gid={gid} resolves to "
                            f"{self.ns.resolve(gid)}, journal says "
                            f"({s}, {l})")
                else:
                    got = self.ns.allocate(int(s))
                    if got != (gid, s, l):
                        raise JournalReplayError(
                            f"replayed allocation produced {got}, "
                            f"journal says ({gid}, {s}, {l})")
                batch._vadds.append((int(gid), int(s), int(l)))
            for g in rec.vdels.tolist():
                batch.delete_vertex(g)
            for (u, v), w in zip(rec.eadds.tolist(), rec.ea_w.tolist()):
                batch.add_edge(u, v, w)
            for u, v in rec.edels.tolist():
                batch.delete_edge(u, v)
            for g in rec.touch.tolist():
                batch.touch_vertex(g)
            self._pending = batch
            self._commit(journal=False)
            replayed += 1
        return replayed

    def close(self) -> None:
        """Flush + close the journal (snapshots need no close)."""
        if self._journal is not None:
            self._journal.close()
            self._journal = None
