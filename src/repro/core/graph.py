"""Graph containers for the diffusive-computation engine.

Two containers:

* :class:`Graph` — a flat edge-list graph with *capacity slots* so that the
  paper's dynamic primitives (edge/vertex add/delete) are O(1) functional
  updates that never change array shapes (no recompilation).
* :class:`ShardedGraph` — the graph partitioned over "compute cells" (the
  paper's CCs = mesh devices / logical shards).  Every array carries a leading
  shard axis ``S``; vertices live on exactly one shard and edges live with the
  shard that owns their *source* vertex (messages flow src -> dst, so the
  emitting side holds the edge, mirroring the paper's "computation moves to
  where the data lives").
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Graph", "ShardedGraph", "from_edges", "DEFAULT_EDGE_BLOCK",
           "DELTA_BLOCK_FRACTION", "TOMBSTONE_COMPACT_FRACTION"]

# Edge-block width of the blocked-CSR view.  128 matches the TPU lane width
# (and segment_reduce's dense-rank tile); the Pallas edge_relax kernel and
# its XLA reference both combine within blocks of exactly this many edges.
DEFAULT_EDGE_BLOCK = 128

# Delta-segment policy (DESIGN.md §2.9).  A rebuild reserves staged delta
# blocks for this fraction of the sorted stream (>= 1 block), and the
# session/update layer triggers a compacting rebuild once tombstones
# exceed the same fraction of a cell's edge slots — so the incremental
# views' extra sweep cost is bounded at ~25% while commits stay O(batch).
DELTA_BLOCK_FRACTION = 0.25
TOMBSTONE_COMPACT_FRACTION = 0.25

# with_csr() compaction switches from the full re-argsort to the staged
# merge (sorted-prefix compact + delta-only sort + searchsorted merge) once
# the per-cell stream is at least this wide: below it the merge's extra
# elementwise passes cost more than the sort they avoid.
MERGE_COMPACT_MIN_WIDTH = 4096


def default_delta_blocks(edges_per_shard: int, block: int) -> int:
    """Staged-delta capacity (in blocks) reserved by a rebuild."""
    nb = -(-edges_per_shard // block)
    return max(1, int(nb * DELTA_BLOCK_FRACTION))


def build_csr(dst_shard, dst_local, edge_ok, n_shards: int, n_per_shard: int,
              block: int):
    """Destination-sorted blocked-CSR permutation of per-shard edge slots.

    Sort key per live edge is the flat destination ``dst_shard * Np +
    dst_local`` (so one combine pass produces the whole [S, Np] message
    table); dead/padding slots sort last.  Returns

    * ``perm``  [S, Eb] int32 — sorted position -> original edge slot,
    * ``key``   [S, Eb] int32 — sorted destination key, ``-1`` on dead and
      padding positions (always trailing),

    with ``Eb`` = edge capacity rounded up to a multiple of ``block`` so
    every kernel block is fully resident.  Pure jnp — safe inside jit and
    cheap enough to rerun on every topology change.
    """
    ep = dst_shard.shape[-1]
    eb = -(-ep // block) * block
    sentinel = n_shards * n_per_shard
    key = jnp.where(edge_ok, dst_shard * n_per_shard + dst_local, sentinel)
    perm = jnp.argsort(key, axis=-1, stable=True).astype(jnp.int32)
    skey = jnp.take_along_axis(key, perm, axis=-1)
    skey = jnp.where(skey >= sentinel, -1, skey).astype(jnp.int32)
    pad = eb - ep
    if pad:
        perm = jnp.pad(perm, ((0, 0), (0, pad)))
        skey = jnp.pad(skey, ((0, 0), (0, pad)), constant_values=-1)
    return perm, skey


def build_push_csr(src_local, edge_ok, csr_perm, n_per_shard: int,
                   block: int):
    """Source-sorted blocked-CSR permutation — the "push" twin of
    :func:`build_csr`.

    Sort key per live edge is the *source* local index, so every vertex's
    out-edges form one contiguous run and a frontier's out-edge blocks
    can be gathered without touching the rest of the stream; dead/padding
    slots sort last.  Returns

    * ``perm``  [S, Eb] int32 — push position -> original edge slot,
    * ``src``   [S, Eb] int32 — sorted source local index, ``-1`` on dead
      and padding positions (always trailing),
    * ``pos``   [S, Eb] int32 — the same edge's position in the
      *destination-sorted* stream of ``csr_perm`` (``-1`` on dead/pad) —
      what lets a push sweep scatter its messages back into the dense
      stream layout so the sum monoid's fixed scan order is preserved
      bit for bit.

    ``csr_perm`` is the matching destination-sorted permutation from
    :func:`build_csr` (only its first ``Ep`` columns — the real argsort —
    are read).  Pure jnp, same cost class as the pull sort.
    """
    s_, ep = src_local.shape
    eb = -(-ep // block) * block
    key = jnp.where(edge_ok, src_local, n_per_shard)
    perm = jnp.argsort(key, axis=-1, stable=True).astype(jnp.int32)
    ssrc = jnp.take_along_axis(key, perm, axis=-1)
    ssrc = jnp.where(ssrc >= n_per_shard, -1, ssrc).astype(jnp.int32)
    # invert the destination sort: edge slot -> dense stream position
    rows = jnp.arange(s_, dtype=jnp.int32)[:, None]
    inv = jnp.zeros((s_, ep), jnp.int32).at[rows, csr_perm[:, :ep]].set(
        jnp.broadcast_to(jnp.arange(ep, dtype=jnp.int32), (s_, ep))
    )
    pos = jnp.take_along_axis(inv, perm, axis=-1)
    pos = jnp.where(ssrc >= 0, pos, -1)
    pad = eb - ep
    if pad:
        perm = jnp.pad(perm, ((0, 0), (0, pad)))
        ssrc = jnp.pad(ssrc, ((0, 0), (0, pad)), constant_values=-1)
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    return perm, ssrc, pos


@partial(jax.jit, static_argnames=("sw", "dwid", "ep"))
def _merge_compact_views(csr_key, csr_perm, csr_live, push_src, push_perm,  # analysis: allow(int64): traced under enable_x64 by _merge_compact — the with-block is at the call site
                         edge_ok, *, sw: int, dwid: int, ep: int):
    """Jitted body of :meth:`ShardedGraph._merge_compact` — one fused
    program per (S, width) shape, so the merge's many elementwise passes
    don't pay eager dispatch at scale.  Must be traced under ``enable_x64``
    (the composites are int64); all outputs are int32.

    XLA:CPU executes 2-D index scatters slowly, so every scatter here is a
    flattened 1-D scatter, and the (key, slot) pair is carried as the single
    int64 composite ``key * (ep + 1) + slot`` — exactly invertible by
    divmod — so each view needs only 4 scatters total."""
    s_ = edge_ok.shape[0]
    w = sw + dwid
    i32 = jnp.int32
    idx_a = jnp.arange(sw, dtype=i32)[None, :]
    idx_b = jnp.arange(dwid, dtype=i32)[None, :]
    idx_w = jnp.arange(w, dtype=i32)[None, :]
    row_off_w = (jnp.arange(s_, dtype=i32) * w)[:, None]
    row_off_ep = (jnp.arange(s_, dtype=i32) * ep)[:, None]
    oob_w = s_ * w
    oob_ep = s_ * ep
    dead_m = ~edge_ok
    slot_ids = jnp.broadcast_to(jnp.arange(ep, dtype=i32), (s_, ep))
    dead_rank = jnp.cumsum(dead_m, axis=1).astype(i32) - 1
    n_dead = jnp.sum(dead_m, axis=1).astype(i32)

    def flat_set(dest, pos, valid, row_off, oob, vals):
        """dest[s, pos] = vals where valid, via a flattened 1-D scatter."""
        flat = jnp.where(valid, pos + row_off, oob)
        return dest.reshape(-1).at[flat.reshape(-1)].set(
            vals.reshape(-1), mode="drop").reshape(dest.shape)

    def compact_merge(key, perm, live, dead_val):
        """One view's (key, perm, live-mask) -> merged (key, perm,
        [S, ep] slot -> new position inverse)."""
        comp_base = jnp.asarray(ep + 1, jnp.int64)
        big = jnp.asarray(1 << 60, jnp.int64)
        live_a = live[:, :sw] & (key[:, :sw] != dead_val)
        comp_src = jnp.where(
            live_a,
            key[:, :sw].astype(jnp.int64) * comp_base + perm[:, :sw],
            big)
        pos_a0 = jnp.cumsum(live_a, axis=1).astype(i32) - 1
        comp_a = flat_set(jnp.full((s_, sw), big, jnp.int64),
                          pos_a0, live_a,
                          (jnp.arange(s_, dtype=i32) * sw)[:, None],
                          s_ * sw, comp_src)
        n_a = jnp.sum(live_a, axis=1).astype(i32)

        live_b = live[:, sw:] & (key[:, sw:] != dead_val)
        comp_src_b = jnp.where(
            live_b,
            key[:, sw:].astype(jnp.int64) * comp_base + perm[:, sw:],
            big)
        comp_b = jnp.sort(comp_src_b, axis=1)
        n_b = jnp.sum(live_b, axis=1).astype(i32)

        ins_a = jax.vmap(jnp.searchsorted)(comp_b, comp_a).astype(i32)
        ins_b = jax.vmap(jnp.searchsorted)(comp_a, comp_b).astype(i32)
        pos_a = idx_a + ins_a
        pos_b = idx_b + ins_b

        merged = jnp.full((s_, w), big, jnp.int64)
        merged = flat_set(merged, pos_a, idx_a < n_a[:, None],
                          row_off_w, oob_w, comp_a)
        merged = flat_set(merged, pos_b, idx_b < n_b[:, None],
                          row_off_w, oob_w, comp_b)
        n_live = n_a + n_b
        live_pos = idx_w < n_live[:, None]
        new_key = jnp.where(
            live_pos, (merged // comp_base).astype(i32), dead_val)
        new_perm = jnp.where(
            live_pos, (merged % comp_base).astype(i32), 0)
        # dead slots tail the live region in ascending slot order — the
        # stable argsort's tie-break on the shared sentinel key
        dead_pos = (n_live[:, None] + dead_rank).astype(i32)
        new_perm = flat_set(new_perm, dead_pos, dead_m, row_off_w, oob_w,
                            slot_ids)
        # positions [0, n_live + n_dead) hold each slot id exactly once,
        # so the inverse is one scatter of position keyed by slot
        occupied = idx_w < (n_live + n_dead)[:, None]
        inv = flat_set(jnp.zeros((s_, ep), i32),
                       jnp.where(occupied, new_perm, ep),
                       occupied, row_off_ep, oob_ep,
                       jnp.broadcast_to(idx_w, (s_, w)))
        return new_key, new_perm, inv

    key, perm, inv = compact_merge(csr_key, csr_perm, csr_live, -1)
    psrc, pperm, pinv = compact_merge(push_src, push_perm, push_src >= 0, -1)
    ppos = jnp.where(
        psrc >= 0,
        jnp.take_along_axis(
            inv, jnp.clip(pperm, 0, ep - 1).astype(i32), axis=-1),
        -1)
    return key, perm, inv, psrc, pperm, pinv, ppos


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "weight", "edge_ok", "node_ok"],
    meta_fields=["n_nodes"],
)
@dataclasses.dataclass(frozen=True)
class Graph:
    """Flat directed edge-list graph with capacity slots.

    ``src/dst/weight`` have length = edge *capacity*; slots with
    ``edge_ok == False`` are free (their src/dst are 0 and must be masked).
    Undirected graphs are stored with both directions materialized.
    """

    src: jnp.ndarray       # [Ecap] int32
    dst: jnp.ndarray       # [Ecap] int32
    weight: jnp.ndarray    # [Ecap] float32
    edge_ok: jnp.ndarray   # [Ecap] bool
    node_ok: jnp.ndarray   # [Ncap] bool
    n_nodes: int           # static vertex capacity

    @property
    def edge_capacity(self) -> int:
        return int(self.src.shape[0])

    def n_edges(self) -> jnp.ndarray:
        """Dynamic count of live edges."""
        return jnp.sum(self.edge_ok.astype(jnp.int32))

    def degrees(self) -> jnp.ndarray:
        """Out-degree per vertex (live edges only)."""
        return jax.ops.segment_sum(
            self.edge_ok.astype(jnp.int32), self.src, num_segments=self.n_nodes
        )


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    weight: np.ndarray | None = None,
    edge_slack: float = 0.0,
    node_slack: float = 0.0,
) -> Graph:
    """Build a :class:`Graph` from host edge arrays, with optional slack
    capacity for dynamic updates (fraction of initial size)."""
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    e = src.shape[0]
    if weight is None:
        weight = np.ones(e, np.float32)
    weight = np.asarray(weight, np.float32)
    ecap = e + int(np.ceil(e * edge_slack))
    ncap = n_nodes + int(np.ceil(n_nodes * node_slack))
    pad = ecap - e
    return Graph(
        src=jnp.asarray(np.concatenate([src, np.zeros(pad, np.int32)])),
        dst=jnp.asarray(np.concatenate([dst, np.zeros(pad, np.int32)])),
        weight=jnp.asarray(np.concatenate([weight, np.zeros(pad, np.float32)])),
        edge_ok=jnp.asarray(
            np.concatenate([np.ones(e, bool), np.zeros(pad, bool)])
        ),
        node_ok=jnp.asarray(
            np.concatenate([np.ones(n_nodes, bool), np.zeros(ncap - n_nodes, bool)])
        ),
        n_nodes=ncap,
    )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "src_local",
        "dst_shard",
        "dst_local",
        "dst_gid",
        "weight",
        "edge_ok",
        "node_ok",
        "gid",
        "out_degree",
        "csr_perm",
        "csr_key",
        "csr_live",
        "csr_inv",
        "push_perm",
        "push_src",
        "push_pos",
        "push_inv",
        "delta_count",
        "tomb_count",
        "replica_of",
        "replica_group",
        "replica_members",
    ],
    meta_fields=["n_shards", "n_per_shard", "n_nodes", "csr_block",
                 "delta_blocks"],
)
@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Graph partitioned over S compute cells.

    Every data array has leading shard axis ``S``.  Edge slots are padded per
    shard to the max shard edge count; ``edge_ok`` masks padding and deleted
    edges.  ``gid`` maps (shard, local) -> original vertex id; ``dst_gid`` is
    the global id of each edge's destination (used for payload messages such
    as parent pointers).

    ``csr_perm``/``csr_key`` are the destination-sorted "pull" blocked-CSR
    view (:func:`build_csr`): the per-shard edge stream sorted by
    ``(dst_shard, dst_local)`` and padded to a ``csr_block`` multiple —
    the layout the dense relaxation kernels assume.
    ``push_perm``/``push_src``/``push_pos`` are its source-sorted "push"
    twin (:func:`build_push_csr`): the same edges sorted by source local
    index, so an active frontier's out-edges live in a few contiguous
    blocks that a sparse sweep can gather without streaming the rest
    (DESIGN.md §2.8).

    **Delta-segment incremental maintenance (DESIGN.md §2.9):** both views
    carry ``delta_blocks`` staged blocks *appended after* the sorted
    stream, so topology changes never pay the O(E log E) re-sort:

    * deletes become in-place **tombstones** — ``csr_live`` drops to
      False at the edge's dense position (the structural ``csr_key`` is
      kept so the scan paths' run layout stays sorted) and ``push_src``
      drops to ``-1`` at its push position (:meth:`with_edge_tombstones`
      / :meth:`with_slot_tombstones`);
    * adds land at the next free **staged delta** position of their
      cell's delta segment, identically in both views
      (:meth:`with_staged_edges`; ``delta_count`` is the per-cell
      cursor), which the relaxation kernels consume as extra
      frontier-activated blocks;
    * ``csr_inv``/``push_inv`` map an edge slot back to its stream
      positions so a delete is an O(1) scatter;
    * a full :meth:`with_csr` rebuild ("compaction") folds tombstones
      out and delta edges into sorted position; the update layer
      triggers it when a cell's delta segment overflows or its
      ``tomb_count`` passes ``TOMBSTONE_COMPACT_FRACTION`` of its slots.

    Both views are built at partition time and patched together by
    ``UpdateBatch.apply`` and the sequential per-edge primitives;
    :meth:`invalidate_csr` remains the escape hatch that drops *both*
    views (the engines then rebuild lazily at the next diffusion), so
    ``csr_view()``/``push_view()`` raise on a graph invalidated that way
    until ``with_csr()`` is called.
    """

    src_local: jnp.ndarray   # [S, Ep] int32 — local index of the edge source
    dst_shard: jnp.ndarray   # [S, Ep] int32 — owner shard of the destination
    dst_local: jnp.ndarray   # [S, Ep] int32 — local index at the owner shard
    dst_gid: jnp.ndarray     # [S, Ep] int32 — global id of the destination
    weight: jnp.ndarray      # [S, Ep] float32
    edge_ok: jnp.ndarray     # [S, Ep] bool
    node_ok: jnp.ndarray     # [S, Np] bool
    gid: jnp.ndarray         # [S, Np] int32 — global id of each local vertex
    out_degree: jnp.ndarray  # [S, Np] int32 — live out-degree
    n_shards: int
    n_per_shard: int
    n_nodes: int             # number of real (unpadded) vertices
    csr_perm: jnp.ndarray | None = None  # [S, W] int32 stream pos -> slot
    csr_key: jnp.ndarray | None = None   # [S, W] int32 structural dst key|-1
    csr_live: jnp.ndarray | None = None  # [S, W] bool live (not tombstone)
    csr_inv: jnp.ndarray | None = None   # [S, Ep] int32 slot -> dense pos
    push_perm: jnp.ndarray | None = None  # [S, W] int32 push pos -> slot
    push_src: jnp.ndarray | None = None   # [S, W] int32 sorted src | -1
    push_pos: jnp.ndarray | None = None   # [S, W] int32 dense pos | -1
    push_inv: jnp.ndarray | None = None   # [S, Ep] int32 slot -> push pos
    delta_count: jnp.ndarray | None = None  # [S] int32 staged adds per cell
    tomb_count: jnp.ndarray | None = None   # [S] int32 tombstones per cell
    # Hub-replica ("rhizome") maps, None on unsplit graphs (DESIGN.md
    # §2.12).  A split hub occupies one *member* slot per assigned cell;
    # member 0 is the primary slot the NameServer resolves.
    replica_of: jnp.ndarray | None = None      # [S, Np] int32 hub gid at
                                               #   non-primary member slots,
                                               #   -1 elsewhere
    replica_group: jnp.ndarray | None = None   # [S, Np] int32 group index at
                                               #   every member slot, -1 else
    replica_members: jnp.ndarray | None = None  # [G, Rmax] int32 flat member
                                                #   keys (s*Np + l), member 0
                                                #   = primary, -1 = pad
    csr_block: int = DEFAULT_EDGE_BLOCK
    delta_blocks: int = -1               # staged blocks; -1 = policy default

    @property
    def edges_per_shard(self) -> int:
        return int(self.src_local.shape[1])

    # -- snapshot serialization (session durability, DESIGN.md §2.13) ------

    _META_FIELDS = ("n_shards", "n_per_shard", "n_nodes", "csr_block",
                    "delta_blocks")

    def state_dict(self) -> dict:
        """Every non-None data array by field name — the snapshot leaves.

        Both CSR views, the delta/tombstone counters, and the replica
        maps are included verbatim, so a restored graph is bitwise-equal
        *including* its incremental view state (dirty segments and all)."""
        out = {}
        for f in dataclasses.fields(self):
            if f.name in self._META_FIELDS:
                continue
            v = getattr(self, f.name)
            if v is not None:
                out[f.name] = v
        return out

    def meta_dict(self) -> dict:
        """The static geometry, JSON-ready (snapshot manifest metadata)."""
        return {name: int(getattr(self, name)) for name in self._META_FIELDS}

    @classmethod
    def from_state(cls, arrays: dict, meta: dict) -> "ShardedGraph":
        """Rebuild from :meth:`state_dict` arrays + :meth:`meta_dict`.

        ``arrays`` values may be numpy (fresh off a checkpoint) — they
        are uploaded with their saved dtypes; absent optional fields
        restore as None."""
        kw = dict(meta)
        for f in dataclasses.fields(cls):
            if f.name in cls._META_FIELDS:
                continue
            if f.name in arrays:
                kw[f.name] = jnp.asarray(arrays[f.name])
        return cls(**kw)

    @property
    def sorted_width(self) -> int:
        """Width of the *sorted* region of both views (Eb): edge capacity
        rounded up to a ``csr_block`` multiple.  The staged delta region
        occupies ``[sorted_width, sorted_width + delta_width)``."""
        return -(-self.edges_per_shard // self.csr_block) * self.csr_block

    @property
    def delta_width(self) -> int:
        """Per-cell staged-delta capacity in edge slots."""
        return max(self.delta_blocks, 0) * self.csr_block

    def with_csr(self, block: int | None = None,
                 delta_blocks: int | None = None) -> "ShardedGraph":
        """Rebuild ("compact") both blocked-CSR views from the current
        topology: tombstones fold out, staged delta edges land in sorted
        position, and a fresh (empty) delta segment of ``delta_blocks``
        staged blocks is appended to each view.

        When the graph already carries consistent views (every mutation
        patched them — the tombstone/delta invariant) and the geometry is
        unchanged, the rebuild is a *merge* (DESIGN.md §2.10): the live
        sorted prefix is already in (key, slot) order, so compaction is a
        rank/compact pass plus a sort of only the small delta segment and
        a two-way ``searchsorted`` merge — bitwise-identical output to
        the full stable argsort at a fraction of its cost.  Graphs whose
        views were dropped (:meth:`invalidate_csr`) take the full-sort
        path, which is also the only path reachable in-trace."""
        block = block or self.csr_block
        if delta_blocks is None:
            delta_blocks = self.delta_blocks
        if delta_blocks < 0:
            delta_blocks = default_delta_blocks(self.edges_per_shard, block)
        if (self.csr_perm is not None and self.delta_count is not None
                and not isinstance(self.delta_count, jax.core.Tracer)
                and block == self.csr_block
                and delta_blocks == self.delta_blocks):
            # every mutation path either patches the views and bumps a
            # counter, or drops the views entirely — so zero counters on
            # present views means they are already exactly what a rebuild
            # would produce.  Host policy read via device_get (not an
            # implicit bool()) so it stays legal under
            # jax.transfer_guard("disallow"); a traced graph skips the
            # shortcut and takes the trace-safe full-sort path below.
            dc = jax.device_get(self.delta_count)  # analysis: allow(host-sync): per-compaction policy counters, guard-legal
            tc = (jax.device_get(self.tomb_count)  # analysis: allow(host-sync): per-compaction policy counters, guard-legal
                  if self.tomb_count is not None else None)
            if not dc.any() and (tc is None or not tc.any()):  # analysis: allow(host-sync): counters already host-side (device_get above)
                return self
            if self.sorted_width >= MERGE_COMPACT_MIN_WIDTH:
                return self._merge_compact()
        s_, ep = self.src_local.shape
        perm, key = build_csr(self.dst_shard, self.dst_local, self.edge_ok,
                              self.n_shards, self.n_per_shard, block)
        pperm, psrc, ppos = build_push_csr(
            self.src_local, self.edge_ok, perm, self.n_per_shard, block)
        dw = delta_blocks * block
        if dw:
            pad = ((0, 0), (0, dw))
            perm = jnp.pad(perm, pad)
            key = jnp.pad(key, pad, constant_values=-1)
            pperm = jnp.pad(pperm, pad)
            psrc = jnp.pad(psrc, pad, constant_values=-1)
            ppos = jnp.pad(ppos, pad, constant_values=-1)
        # slot -> stream position inverses (O(batch) delete tombstoning);
        # only live slots' entries are ever read — the first ep stream
        # positions hold the real argsort, so scattering through them
        # covers every slot
        rows = jnp.arange(s_, dtype=jnp.int32)[:, None]
        pos = jnp.broadcast_to(jnp.arange(ep, dtype=jnp.int32), (s_, ep))
        inv = jnp.zeros((s_, ep), jnp.int32).at[rows, perm[:, :ep]].set(pos)
        pinv = jnp.zeros((s_, ep), jnp.int32).at[rows, pperm[:, :ep]].set(pos)
        zero = jnp.zeros((s_,), jnp.int32)
        return dataclasses.replace(
            self, csr_perm=perm, csr_key=key, csr_live=key >= 0,
            csr_inv=inv, push_perm=pperm, push_src=psrc, push_pos=ppos,
            push_inv=pinv, delta_count=zero, tomb_count=zero,
            csr_block=block, delta_blocks=delta_blocks,
        )

    def _merge_compact(self) -> "ShardedGraph":
        """Compact both views by merging instead of re-sorting.

        The sorted region's live entries are already in ascending
        ``(key, slot)`` composite order — exactly the order a stable
        argsort of the full key stream would produce (its tie-break *is*
        slot order, and slots are unique per cell) — so folding the
        tombstones out is a cumsum/scatter compact, only the delta
        segment (<= ``delta_width`` entries) is sorted, and the two
        ascending streams meet through a pair of vmapped
        ``searchsorted`` calls.  Dead slots fill the tail in ascending
        slot order, reproducing the full rebuild bit for bit.  Pure jnp
        and shape-static."""
        from jax.experimental import enable_x64

        # the (key, slot) composites need 64-bit ints at scale; every
        # *stored* array stays int32/bool — only jitted intermediates are
        # wide, so the x64 flag never leaks outside this call
        with enable_x64():
            key, perm, inv, psrc, pperm, pinv, ppos = _merge_compact_views(
                self.csr_key, self.csr_perm, self.csr_live,
                self.push_src, self.push_perm, self.edge_ok,
                sw=self.sorted_width, dwid=self.delta_width,
                ep=self.edges_per_shard)
        zero = jnp.zeros((self.src_local.shape[0],), jnp.int32)
        return dataclasses.replace(
            self, csr_perm=perm, csr_key=key, csr_live=key >= 0,
            csr_inv=inv, push_perm=pperm, push_src=psrc, push_pos=ppos,
            push_inv=pinv, delta_count=zero, tomb_count=zero,
        )

    def layout_bytes(self) -> dict:
        """Host-side accounting of the device layout's byte footprint.

        ``edge_stream`` is the per-slot edge fields, ``csr_views`` both
        blocked-CSR views (and their inverses/counters), ``node`` the
        vertex-slot arrays.  ``live_edge_bytes`` is the floor: live
        edges x bytes-per-edge-slot — the degree-aware capacity model
        keeps ``edge_stream`` within ~2x of it even on skewed families
        (DESIGN.md §2.10)."""
        def nbytes(*arrays):
            return int(sum(a.size * a.dtype.itemsize
                           for a in arrays if a is not None))

        edge_stream = nbytes(self.src_local, self.dst_shard, self.dst_local,
                             self.dst_gid, self.weight, self.edge_ok)
        slot_bytes = edge_stream // max(1, self.n_shards
                                        * self.edges_per_shard)
        live_edges = int(jnp.sum(self.edge_ok))
        return {
            "edge_stream": edge_stream,
            "csr_views": nbytes(self.csr_perm, self.csr_key, self.csr_live,
                                self.csr_inv, self.push_perm, self.push_src,
                                self.push_pos, self.push_inv,
                                self.delta_count, self.tomb_count),
            "node": nbytes(self.node_ok, self.gid, self.out_degree),
            "live_edges": live_edges,
            "live_edge_bytes": live_edges * slot_bytes,
            "total": edge_stream + nbytes(
                self.csr_perm, self.csr_key, self.csr_live, self.csr_inv,
                self.push_perm, self.push_src, self.push_pos, self.push_inv,
                self.delta_count, self.tomb_count, self.node_ok, self.gid,
                self.out_degree),
        }

    def invalidate_csr(self) -> "ShardedGraph":
        """Drop both CSR views without paying the re-sorts — the escape
        hatch for callers that batch many mutations outside the
        tombstone/delta patch path.  The rebuild happens in-trace on a
        local copy — an invalidated graph re-sorts on *every* diffusion
        until the caller persists it with :meth:`with_csr`; the batched
        ``UpdateBatch.apply`` and the per-edge primitives instead patch
        the views in place (tombstones + staged deltas) so mutated
        graphs never carry that recurring cost.  Pull and push views are
        always dropped together — a graph can never carry one stale
        view."""
        return dataclasses.replace(self, csr_perm=None, csr_key=None,
                                   csr_live=None, csr_inv=None,
                                   push_perm=None, push_src=None,
                                   push_pos=None, push_inv=None,
                                   delta_count=None, tomb_count=None)

    # -- incremental view maintenance (DESIGN.md §2.9) --------------------

    def with_edge_tombstones(self, shard, slot, ok) -> "ShardedGraph":
        """Tombstone K edges at ``(shard, slot)`` (``ok`` masks no-ops) in
        both views: O(K) scatters through the slot->position inverses.
        The dense position keeps its structural ``csr_key`` (the scan
        paths' run layout stays sorted) and drops ``csr_live``; the push
        position drops ``push_src`` to ``-1`` (its own validity
        sentinel)."""
        ep = self.edges_per_shard
        w = self.csr_key.shape[-1]
        sl = jnp.clip(slot, 0, ep - 1)
        dpos = jnp.where(ok, self.csr_inv[shard, sl], w)
        ppos = jnp.where(ok, self.push_inv[shard, sl], w)
        return dataclasses.replace(
            self,
            csr_live=self.csr_live.at[shard, dpos].set(False, mode="drop"),
            push_src=self.push_src.at[shard, ppos].set(-1, mode="drop"),
            tomb_count=self.tomb_count.at[shard].add(
                ok.astype(jnp.int32), mode="drop"),
        )

    def with_slot_tombstones(self, dead) -> "ShardedGraph":
        """Tombstone every edge slot in the ``dead`` [S, Ep] mask (the
        vertex-delete path, where the doomed set is discovered as a
        mask): one O(E) elementwise pass over both views, no sort."""
        at_dense = jnp.take_along_axis(
            dead, jnp.clip(self.csr_perm, 0, self.edges_per_shard - 1),
            axis=-1)
        newly = self.csr_live & at_dense
        at_push = jnp.take_along_axis(
            dead, jnp.clip(self.push_perm, 0, self.edges_per_shard - 1),
            axis=-1) & (self.push_src >= 0)
        return dataclasses.replace(
            self,
            csr_live=self.csr_live & ~at_dense,
            push_src=jnp.where(at_push, -1, self.push_src),
            tomb_count=self.tomb_count
            + jnp.sum(newly, axis=-1).astype(jnp.int32),
        )

    def with_staged_edges(self, shard, slot, src_local, dst_key, rank,
                          ok) -> "ShardedGraph":
        """Stage K freshly-written edges (``(shard, slot)`` already hold
        their fields) into the delta segment of both views: position =
        ``sorted_width + delta_count[shard] + rank`` (``rank`` = the
        op's index among this batch's adds to the same cell).  O(K)
        scatters; the caller must have checked capacity
        (``delta_count + adds-per-cell <= delta_width``)."""
        es = self.sorted_width
        w = self.csr_key.shape[-1]
        ep = self.edges_per_shard
        dpos = jnp.where(ok, es + self.delta_count[shard] + rank, w)
        islot = jnp.where(ok, slot, ep)
        i32 = jnp.int32
        return dataclasses.replace(
            self,
            csr_perm=self.csr_perm.at[shard, dpos].set(
                slot.astype(i32), mode="drop"),
            csr_key=self.csr_key.at[shard, dpos].set(
                dst_key.astype(i32), mode="drop"),
            csr_live=self.csr_live.at[shard, dpos].set(True, mode="drop"),
            csr_inv=self.csr_inv.at[shard, islot].set(
                dpos.astype(i32), mode="drop"),
            push_perm=self.push_perm.at[shard, dpos].set(
                slot.astype(i32), mode="drop"),
            push_src=self.push_src.at[shard, dpos].set(
                src_local.astype(i32), mode="drop"),
            push_pos=self.push_pos.at[shard, dpos].set(
                dpos.astype(i32), mode="drop"),
            push_inv=self.push_inv.at[shard, islot].set(
                dpos.astype(i32), mode="drop"),
            delta_count=self.delta_count.at[shard].add(
                ok.astype(i32), mode="drop"),
        )

    def csr_view(self) -> dict:
        """The destination-sorted edge streams the relax backends consume.

        [S, W] gathers of the edge fields through ``csr_perm`` (W =
        sorted region + staged delta segment); positions with
        ``csr_key == -1`` (dead / padding / tombstoned / free delta)
        carry garbage and must be masked by the key.  ``csr_key`` here is
        the *live-masked* key (tombstones read ``-1``); ``csr_skey``
        keeps the structural sorted key so the scan paths'
        ``searchsorted`` run layout survives tombstoning (the delta
        segment of ``csr_skey`` is unsorted — the kernels consume it
        through a separate scatter pass, never the scan).
        """
        if self.csr_perm is None:
            raise ValueError("ShardedGraph has no CSR view; call with_csr()")
        take = lambda a: jnp.take_along_axis(a, self.csr_perm, axis=-1)
        return {
            "csr_key": jnp.where(self.csr_live, self.csr_key, -1),
            "csr_skey": self.csr_key,
            "csr_src": take(self.src_local),
            "csr_weight": take(self.weight),
            "csr_dst_gid": take(self.dst_gid),
        }

    def push_view(self) -> dict:
        """The source-sorted edge streams the push sweep consumes.

        [S, W] gathers of the edge fields through ``push_perm``;
        positions with ``push_src == -1`` (dead / padding / tombstoned)
        carry garbage and must be masked.  ``push_pos`` maps each push
        position back to its slot in the destination-sorted stream of
        :meth:`csr_view` (staged delta edges map to their own delta
        position — the two views stage identically).
        """
        if self.push_perm is None:
            raise ValueError("ShardedGraph has no push view; call with_csr()")
        take = lambda a: jnp.take_along_axis(a, self.push_perm, axis=-1)
        key = take(self.dst_shard) * self.n_per_shard + take(self.dst_local)
        return {
            "push_src": self.push_src,
            "push_key": jnp.where(self.push_src >= 0, key, -1),
            "push_weight": take(self.weight),
            "push_dst_gid": take(self.dst_gid),
            "push_pos": self.push_pos,
        }

    def n_edges(self) -> jnp.ndarray:
        # int32 accumulator on purpose: without enable_x64 a jnp.int64
        # cast silently degrades to 32-bit anyway, and edge-slot counts
        # fit int32 at every scale this layout can hold in memory
        return jnp.sum(self.edge_ok.astype(jnp.int32))

    def scatter_from_global(self, values: jnp.ndarray, owner, local, fill=0):
        """Map a [n_nodes] global array to [S, Np] shard layout."""
        out = jnp.full((self.n_shards, self.n_per_shard), fill, values.dtype)
        return out.at[owner, local].set(values)

    def gather_to_global(self, values: jnp.ndarray, owner, local):
        """Map a [S, Np] shard-layout array back to [n_nodes] global order."""
        return values[owner, local]
