"""Graph containers for the diffusive-computation engine.

Two containers:

* :class:`Graph` — a flat edge-list graph with *capacity slots* so that the
  paper's dynamic primitives (edge/vertex add/delete) are O(1) functional
  updates that never change array shapes (no recompilation).
* :class:`ShardedGraph` — the graph partitioned over "compute cells" (the
  paper's CCs = mesh devices / logical shards).  Every array carries a leading
  shard axis ``S``; vertices live on exactly one shard and edges live with the
  shard that owns their *source* vertex (messages flow src -> dst, so the
  emitting side holds the edge, mirroring the paper's "computation moves to
  where the data lives").
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Graph", "ShardedGraph", "from_edges", "DEFAULT_EDGE_BLOCK"]

# Edge-block width of the blocked-CSR view.  128 matches the TPU lane width
# (and segment_reduce's dense-rank tile); the Pallas edge_relax kernel and
# its XLA reference both combine within blocks of exactly this many edges.
DEFAULT_EDGE_BLOCK = 128


def build_csr(dst_shard, dst_local, edge_ok, n_shards: int, n_per_shard: int,
              block: int):
    """Destination-sorted blocked-CSR permutation of per-shard edge slots.

    Sort key per live edge is the flat destination ``dst_shard * Np +
    dst_local`` (so one combine pass produces the whole [S, Np] message
    table); dead/padding slots sort last.  Returns

    * ``perm``  [S, Eb] int32 — sorted position -> original edge slot,
    * ``key``   [S, Eb] int32 — sorted destination key, ``-1`` on dead and
      padding positions (always trailing),

    with ``Eb`` = edge capacity rounded up to a multiple of ``block`` so
    every kernel block is fully resident.  Pure jnp — safe inside jit and
    cheap enough to rerun on every topology change.
    """
    ep = dst_shard.shape[-1]
    eb = -(-ep // block) * block
    sentinel = n_shards * n_per_shard
    key = jnp.where(edge_ok, dst_shard * n_per_shard + dst_local, sentinel)
    perm = jnp.argsort(key, axis=-1, stable=True).astype(jnp.int32)
    skey = jnp.take_along_axis(key, perm, axis=-1)
    skey = jnp.where(skey >= sentinel, -1, skey).astype(jnp.int32)
    pad = eb - ep
    if pad:
        perm = jnp.pad(perm, ((0, 0), (0, pad)))
        skey = jnp.pad(skey, ((0, 0), (0, pad)), constant_values=-1)
    return perm, skey


def build_push_csr(src_local, edge_ok, csr_perm, n_per_shard: int,
                   block: int):
    """Source-sorted blocked-CSR permutation — the "push" twin of
    :func:`build_csr`.

    Sort key per live edge is the *source* local index, so every vertex's
    out-edges form one contiguous run and a frontier's out-edge blocks
    can be gathered without touching the rest of the stream; dead/padding
    slots sort last.  Returns

    * ``perm``  [S, Eb] int32 — push position -> original edge slot,
    * ``src``   [S, Eb] int32 — sorted source local index, ``-1`` on dead
      and padding positions (always trailing),
    * ``pos``   [S, Eb] int32 — the same edge's position in the
      *destination-sorted* stream of ``csr_perm`` (``-1`` on dead/pad) —
      what lets a push sweep scatter its messages back into the dense
      stream layout so the sum monoid's fixed scan order is preserved
      bit for bit.

    ``csr_perm`` is the matching destination-sorted permutation from
    :func:`build_csr` (only its first ``Ep`` columns — the real argsort —
    are read).  Pure jnp, same cost class as the pull sort.
    """
    s_, ep = src_local.shape
    eb = -(-ep // block) * block
    key = jnp.where(edge_ok, src_local, n_per_shard)
    perm = jnp.argsort(key, axis=-1, stable=True).astype(jnp.int32)
    ssrc = jnp.take_along_axis(key, perm, axis=-1)
    ssrc = jnp.where(ssrc >= n_per_shard, -1, ssrc).astype(jnp.int32)
    # invert the destination sort: edge slot -> dense stream position
    rows = jnp.arange(s_, dtype=jnp.int32)[:, None]
    inv = jnp.zeros((s_, ep), jnp.int32).at[rows, csr_perm[:, :ep]].set(
        jnp.broadcast_to(jnp.arange(ep, dtype=jnp.int32), (s_, ep))
    )
    pos = jnp.take_along_axis(inv, perm, axis=-1)
    pos = jnp.where(ssrc >= 0, pos, -1)
    pad = eb - ep
    if pad:
        perm = jnp.pad(perm, ((0, 0), (0, pad)))
        ssrc = jnp.pad(ssrc, ((0, 0), (0, pad)), constant_values=-1)
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    return perm, ssrc, pos


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "weight", "edge_ok", "node_ok"],
    meta_fields=["n_nodes"],
)
@dataclasses.dataclass(frozen=True)
class Graph:
    """Flat directed edge-list graph with capacity slots.

    ``src/dst/weight`` have length = edge *capacity*; slots with
    ``edge_ok == False`` are free (their src/dst are 0 and must be masked).
    Undirected graphs are stored with both directions materialized.
    """

    src: jnp.ndarray       # [Ecap] int32
    dst: jnp.ndarray       # [Ecap] int32
    weight: jnp.ndarray    # [Ecap] float32
    edge_ok: jnp.ndarray   # [Ecap] bool
    node_ok: jnp.ndarray   # [Ncap] bool
    n_nodes: int           # static vertex capacity

    @property
    def edge_capacity(self) -> int:
        return int(self.src.shape[0])

    def n_edges(self) -> jnp.ndarray:
        """Dynamic count of live edges."""
        return jnp.sum(self.edge_ok.astype(jnp.int32))

    def degrees(self) -> jnp.ndarray:
        """Out-degree per vertex (live edges only)."""
        return jax.ops.segment_sum(
            self.edge_ok.astype(jnp.int32), self.src, num_segments=self.n_nodes
        )


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    weight: np.ndarray | None = None,
    edge_slack: float = 0.0,
    node_slack: float = 0.0,
) -> Graph:
    """Build a :class:`Graph` from host edge arrays, with optional slack
    capacity for dynamic updates (fraction of initial size)."""
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    e = src.shape[0]
    if weight is None:
        weight = np.ones(e, np.float32)
    weight = np.asarray(weight, np.float32)
    ecap = e + int(np.ceil(e * edge_slack))
    ncap = n_nodes + int(np.ceil(n_nodes * node_slack))
    pad = ecap - e
    return Graph(
        src=jnp.asarray(np.concatenate([src, np.zeros(pad, np.int32)])),
        dst=jnp.asarray(np.concatenate([dst, np.zeros(pad, np.int32)])),
        weight=jnp.asarray(np.concatenate([weight, np.zeros(pad, np.float32)])),
        edge_ok=jnp.asarray(
            np.concatenate([np.ones(e, bool), np.zeros(pad, bool)])
        ),
        node_ok=jnp.asarray(
            np.concatenate([np.ones(n_nodes, bool), np.zeros(ncap - n_nodes, bool)])
        ),
        n_nodes=ncap,
    )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "src_local",
        "dst_shard",
        "dst_local",
        "dst_gid",
        "weight",
        "edge_ok",
        "node_ok",
        "gid",
        "out_degree",
        "csr_perm",
        "csr_key",
        "push_perm",
        "push_src",
        "push_pos",
    ],
    meta_fields=["n_shards", "n_per_shard", "n_nodes", "csr_block"],
)
@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Graph partitioned over S compute cells.

    Every data array has leading shard axis ``S``.  Edge slots are padded per
    shard to the max shard edge count; ``edge_ok`` masks padding and deleted
    edges.  ``gid`` maps (shard, local) -> original vertex id; ``dst_gid`` is
    the global id of each edge's destination (used for payload messages such
    as parent pointers).

    ``csr_perm``/``csr_key`` are the destination-sorted "pull" blocked-CSR
    view (:func:`build_csr`): the per-shard edge stream sorted by
    ``(dst_shard, dst_local)`` and padded to a ``csr_block`` multiple —
    the layout the dense relaxation kernels assume.
    ``push_perm``/``push_src``/``push_pos`` are its source-sorted "push"
    twin (:func:`build_push_csr`): the same edges sorted by source local
    index, so an active frontier's out-edges live in a few contiguous
    blocks that a sparse sweep can gather without streaming the rest
    (DESIGN.md §2.8).  Both views are built at partition time and kept
    current together by ``UpdateBatch.apply`` (eager :meth:`with_csr`);
    the sequential per-edge primitives instead :meth:`invalidate_csr`
    *both* views and the engines rebuild lazily at the next diffusion, so
    ``csr_view()``/``push_view()`` raise on a graph mutated that way
    until ``with_csr()`` is called.
    """

    src_local: jnp.ndarray   # [S, Ep] int32 — local index of the edge source
    dst_shard: jnp.ndarray   # [S, Ep] int32 — owner shard of the destination
    dst_local: jnp.ndarray   # [S, Ep] int32 — local index at the owner shard
    dst_gid: jnp.ndarray     # [S, Ep] int32 — global id of the destination
    weight: jnp.ndarray      # [S, Ep] float32
    edge_ok: jnp.ndarray     # [S, Ep] bool
    node_ok: jnp.ndarray     # [S, Np] bool
    gid: jnp.ndarray         # [S, Np] int32 — global id of each local vertex
    out_degree: jnp.ndarray  # [S, Np] int32 — live out-degree
    n_shards: int
    n_per_shard: int
    n_nodes: int             # number of real (unpadded) vertices
    csr_perm: jnp.ndarray | None = None  # [S, Eb] int32 sorted pos -> slot
    csr_key: jnp.ndarray | None = None   # [S, Eb] int32 sorted dst key | -1
    push_perm: jnp.ndarray | None = None  # [S, Eb] int32 push pos -> slot
    push_src: jnp.ndarray | None = None   # [S, Eb] int32 sorted src | -1
    push_pos: jnp.ndarray | None = None   # [S, Eb] int32 dense pos | -1
    csr_block: int = DEFAULT_EDGE_BLOCK

    @property
    def edges_per_shard(self) -> int:
        return int(self.src_local.shape[1])

    def with_csr(self, block: int | None = None) -> "ShardedGraph":
        """Rebuild both blocked-CSR views (pull + push) from the current
        topology."""
        block = block or self.csr_block
        perm, key = build_csr(self.dst_shard, self.dst_local, self.edge_ok,
                              self.n_shards, self.n_per_shard, block)
        pperm, psrc, ppos = build_push_csr(
            self.src_local, self.edge_ok, perm, self.n_per_shard, block)
        return dataclasses.replace(
            self, csr_perm=perm, csr_key=key, push_perm=pperm,
            push_src=psrc, push_pos=ppos, csr_block=block,
        )

    def invalidate_csr(self) -> "ShardedGraph":
        """Drop both CSR views without paying the re-sorts.  Used by the
        sequential per-edge primitives so a k-update loop defers the sort
        to the next diffusion (via ``_sg_as_dict``) instead of sorting k
        times.  The rebuild happens in-trace on a local copy — an
        invalidated graph re-sorts on *every* diffusion until the caller
        persists it with :meth:`with_csr`; the batched
        ``UpdateBatch.apply`` rebuilds eagerly so committed graphs never
        carry that recurring cost.  Pull and push views are always
        dropped together — a graph can never carry one stale view."""
        return dataclasses.replace(self, csr_perm=None, csr_key=None,
                                   push_perm=None, push_src=None,
                                   push_pos=None)

    def csr_view(self) -> dict:
        """The destination-sorted edge streams the relax backends consume.

        [S, Eb] gathers of the edge fields through ``csr_perm``; positions
        with ``csr_key == -1`` (dead/padding) carry garbage and must be
        masked by the key.
        """
        if self.csr_perm is None:
            raise ValueError("ShardedGraph has no CSR view; call with_csr()")
        take = lambda a: jnp.take_along_axis(a, self.csr_perm, axis=-1)
        return {
            "csr_key": self.csr_key,
            "csr_src": take(self.src_local),
            "csr_weight": take(self.weight),
            "csr_dst_gid": take(self.dst_gid),
        }

    def push_view(self) -> dict:
        """The source-sorted edge streams the push sweep consumes.

        [S, Eb] gathers of the edge fields through ``push_perm``;
        positions with ``push_src == -1`` (dead/padding) carry garbage
        and must be masked.  ``push_pos`` maps each push position back to
        its slot in the destination-sorted stream of :meth:`csr_view`.
        """
        if self.push_perm is None:
            raise ValueError("ShardedGraph has no push view; call with_csr()")
        take = lambda a: jnp.take_along_axis(a, self.push_perm, axis=-1)
        key = take(self.dst_shard) * self.n_per_shard + take(self.dst_local)
        return {
            "push_src": self.push_src,
            "push_key": jnp.where(self.push_src >= 0, key, -1),
            "push_weight": take(self.weight),
            "push_dst_gid": take(self.dst_gid),
            "push_pos": self.push_pos,
        }

    def n_edges(self) -> jnp.ndarray:
        return jnp.sum(self.edge_ok.astype(jnp.int64))

    def scatter_from_global(self, values: jnp.ndarray, owner, local, fill=0):
        """Map a [n_nodes] global array to [S, Np] shard layout."""
        out = jnp.full((self.n_shards, self.n_per_shard), fill, values.dtype)
        return out.at[owner, local].set(values)

    def gather_to_global(self, values: jnp.ndarray, owner, local):
        """Map a [S, Np] shard-layout array back to [n_nodes] global order."""
        return values[owner, local]
