"""Bulk-asynchronous diffusive execution engine.

TPU-native realization of the paper's diffusive computation (DESIGN.md §2):

* Each **compute cell** (= logical shard / mesh device) owns a vertex block
  and the out-edges of those vertices.
* Inside a *round*, every cell runs **local relaxation sub-iterations to
  local quiescence** — unordered, data-driven work exactly like the paper's
  asynchronous diffusion, but vectorized.  Cross-cell messages ("operons")
  accumulate into per-destination **outboxes**, coalesced with the program's
  combine :class:`~.monoid.Monoid` (min for SSSP — duplicate relaxations
  merge in the mailbox, the TPU analogue of the paper's many-small-messages
  traffic).
* The relaxation step itself (gather ``vstate[src]`` → ``prog.emit`` →
  segment-combine by destination) is delegated to a pluggable backend
  (``backend="xla" | "pallas"`` — see relax.py): both consume the graph's
  destination-sorted blocked-CSR edge stream and return the same combined
  per-destination message table bit for bit, so the engine's while-loop
  structure is backend-independent.
* At the round boundary the outboxes are exchanged (``all_to_all`` on a real
  mesh; an axis-reduce in the single-device logical engine) and receivers run
  the program's predicate to decide whether to (re)activate — Code Listing
  1's ``if v.distance >= distance``.
* Termination = global quiescence: no vertex active and no operon in flight
  (the paper's §V.A step 6), detected by counting — see termination.py.

**Multi-query lanes** (DESIGN.md §2.7): a program built by
:func:`~.programs.make_laned` carries ``lanes=L`` and lane-stacked vertex
state (per shard: [L, Np] leaves).  The engine then broadcasts the whole
gather→emit→combine over lanes — one edge sweep serves L queries — with
outboxes gaining a lane axis and quiescence tracked per lane: a converged
lane is masked out of message generation while the slowest lanes finish.
Because emit/receive are identical across lanes and extra (quiescent)
rounds are bitwise no-ops, each lane reproduces its single-query fixed
point exactly.

``max_local_iters=1`` degenerates the engine to classic BSP; larger values
give the paper's asynchronous behaviour.  The benchmark suite uses this knob
to reproduce the paper's async-vs-BSP comparison.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .graph import DEFAULT_EDGE_BLOCK, ShardedGraph
from .partition import Partitioned
from .programs import VertexProgram
from .relax import (
    DEFAULT_PUSH_THRESHOLD,
    active_push_blocks,
    make_relax,
    push_caps,
    select_bucket,
)
from .termination import quiescent

__all__ = [
    "diffuse",
    "diffuse_from",
    "exact_streams_for",
    "DiffuseStats",
    "FRONTIER_LOG_CAP",
    "diffuse_spmd_step",
    "make_spmd_diffuse",
    "logical_view",
]

# Per-round introspection buffers (frontier size, chosen direction) record
# the first FRONTIER_LOG_CAP rounds; later rounds overwrite the last slot.
FRONTIER_LOG_CAP = 512


class DiffuseStats(NamedTuple):
    rounds: jnp.ndarray            # global exchange rounds
    local_iters: jnp.ndarray       # total local sub-iterations (all cells)
    actions: jnp.ndarray           # edge-messages emitted (paper's "actions")
    remote_actions: jnp.ndarray    # actions crossing a cell boundary
    operons_sent: jnp.ndarray      # coalesced cross-cell mailbox entries sent
    operons_delivered: jnp.ndarray # ... and delivered (DS invariant: equal)
    max_frontier: jnp.ndarray      # introspection: peak active count
    push_iters: jnp.ndarray        # local sub-iterations swept via push
    frontier_log: jnp.ndarray      # [FRONTIER_LOG_CAP] active count per
                                   #   round (-1 = round never ran)
    dir_log: jnp.ndarray           # [FRONTIER_LOG_CAP] direction chosen at
                                   #   round start: 1 push, 0 pull, -1 n/a
    converged: jnp.ndarray         # bool: True = real quiescence (empty
                                   #   frontier + empty mailboxes), False =
                                   #   the max_rounds budget cut the loop
                                   #   at a non-fixed point


def _stats0() -> DiffuseStats:
    z = jnp.zeros((), jnp.int32)
    log = jnp.full((FRONTIER_LOG_CAP,), -1, jnp.int32)
    return DiffuseStats(z, z, z, z, z, z, z, z, log, log,
                        jnp.zeros((), bool))


def _gate(prog, vstate, active, threshold):
    """Delta-stepping-style priority gate: only vertices whose priority is
    within the current bucket fire (beyond-paper optimization; None
    threshold or priority-less programs = the paper's ungated diffusion).
    Laned runs carry a per-lane threshold [L, 1] that broadcasts."""
    if prog.priority is None or threshold is None:
        return active
    return active & (prog.priority(vstate) <= threshold)


def _local_iter_shard(prog: VertexProgram, np_, s_, my_shard, sg_s, st, relax,
                      threshold=None, lane_live=None, bucket=None,
                      member_full=None):
    """One local relaxation sub-iteration, per-shard view (vmapped over S).

    The gather→emit→segment-combine step is delegated to ``relax`` (built by
    :func:`repro.core.relax.make_relax`): it maps this cell's vertex block +
    destination-sorted CSR edge stream to the combined [S, Np] message table
    ([S, L, Np] for laned programs).  Row ``my_shard`` is applied as the
    local inbox inside this sub-iteration; the other rows merge into the
    cross-cell outbox.  ``lane_live`` masks converged lanes out of message
    generation.

    ``member_full`` ([S, Np] bool, or None) marks hub-replica member slots
    (DESIGN.md §2.12).  Messages destined for a member slot are *never*
    delivered mid-round — even from the slot's own cell — but are held in
    the outbox for the round-boundary replica merge, so every member of a
    group applies the identical merged message exactly once per round and
    the members stay state-mirrored.
    """
    (vstate, active, outbox, outbox_has, outbox_pay) = st
    monoid = prog.monoid
    ident = monoid.identity(prog.msg_dtype)

    senders = _gate(prog, vstate, active, threshold)
    if lane_live is not None:
        senders = senders & lane_live[:, None]
    table, cnt, pay = relax(vstate, senders, sg_s, bucket)
    mine = (jnp.arange(s_, dtype=jnp.int32) == my_shard).reshape(
        (s_,) + (1,) * (table.ndim - 1))
    if member_full is None:
        keep_local = mine
    else:
        member_dst = member_full.reshape(
            (s_,) + (1,) * (table.ndim - 2) + (np_,))
        keep_local = mine & ~member_dst

    inbox = jnp.take(table, my_shard, axis=0)
    has_local = jnp.take(cnt, my_shard, axis=0) > 0
    pay_in = jnp.take(pay, my_shard, axis=0) if prog.with_payload else None
    if member_full is not None:
        member_row = jnp.take(member_full, my_shard, axis=0)    # [Np]
        has_local = has_local & ~member_row
        inbox = jnp.where(member_row, ident, inbox)
        if prog.with_payload:
            pay_in = jnp.where(member_row, -1, pay_in)

    contrib = jnp.where(keep_local, ident, table)
    contrib_has = (cnt > 0) & ~keep_local
    if prog.with_payload:
        pay_contrib = jnp.where(keep_local, -1, pay)
        take_new = contrib_has & monoid.improves(contrib, outbox)
        outbox_pay = jnp.where(take_new, pay_contrib, outbox_pay)
    outbox = monoid.merge(outbox, contrib, contrib_has)
    outbox_has = outbox_has | contrib_has

    vstate = prog.on_send(vstate, senders)
    vstate, activated = prog.receive(
        vstate, inbox, has_local, pay_in, sg_s["node_ok"]
    )
    activated = activated | (active & ~senders)   # withheld stay active

    n_send = jnp.sum(cnt)                          # sending edges (actions)
    counts = {
        "actions": n_send,
        "remote": n_send - jnp.sum(jnp.where(keep_local, cnt, 0)),
    }
    return (vstate, activated, outbox, outbox_has, outbox_pay), counts


def _sg_as_dict(sg: ShardedGraph, with_push: bool = False):
    """ShardedGraph -> the engine-facing array dict: the per-cell vertex
    block (``node_ok``/``gid``/``out_degree``) plus the destination-sorted
    pull streams the relax backends consume (``csr_key`` live-masked,
    ``csr_skey`` structural — see DESIGN.md §2.9) — and, when
    ``with_push`` (any sweep that can compact), the source-sorted push
    streams too (built on demand for graphs with invalidated views).
    The unsorted edge arrays always stay out, and the push streams stay
    out of pull sweeps for the same reason — the engine never reads
    them, and under shard_map they would be real per-device inputs
    inflating edge-stream transfer/residency."""
    if sg.csr_perm is None or (with_push and sg.push_perm is None):
        sg = sg.with_csr()
    d = {
        "node_ok": sg.node_ok,
        "gid": sg.gid,
        "out_degree": sg.out_degree,
    }
    d.update(sg.csr_view())
    if with_push:
        d.update(sg.push_view())
    if sg.replica_members is not None:
        d["replica_members"] = sg.replica_members
    return d


# --------------------------------------------------------------------------
# Hub replicas ("rhizomes", DESIGN.md §2.12): engine-side merge machinery.
# All members of a split hub mirror one vertex state; the engines enforce it
# by (a) suppressing mid-round delivery at member slots (_local_iter_shard),
# (b) merging member partials through the monoid once per round at the
# exchange and re-broadcasting the merged message to every member, and
# (c) re-broadcasting vstate/active from the primary at diffusion entry so
# adopted/repaired states (which only touch primaries) re-mirror for free.
# --------------------------------------------------------------------------

def _replica_maps(rmem, S: int, Np: int):
    """[G, Rmax] flat member keys -> (member_mask [S, Np] bool marking every
    member slot, rsrc [S*Np] int32 mapping each slot to its group primary's
    flat key — identity outside groups)."""
    tot = S * Np
    valid = rmem >= 0
    tgt = jnp.where(valid, rmem, tot)
    member_mask = jnp.zeros((tot,), bool).at[tgt].set(True, mode="drop")
    prim = jnp.broadcast_to(rmem[:, :1], rmem.shape).astype(jnp.int32)
    rsrc = jnp.arange(tot, dtype=jnp.int32).at[tgt].set(prim, mode="drop")
    return member_mask.reshape(S, Np), rsrc


def _broadcast_from_primary(tree, rsrc, S: int, Np: int):
    """Copy each group primary's value over all its member slots (identity
    elsewhere); leaves are [S, (L,), Np]."""
    def bcast(x):
        lead = x.shape[1:-1]
        flat = jnp.moveaxis(x, 0, -2).reshape(lead + (S * Np,))
        flat = flat[..., rsrc]
        return jnp.moveaxis(flat.reshape(lead + (S, Np)), -2, 0)
    return jax.tree_util.tree_map(bcast, tree)


def _merge_replicas(monoid, with_payload: bool, ident, rmem, S: int, Np: int,
                    inbox, has, pay):
    """Round-boundary replica merge on per-destination-reduced inboxes
    ([S, (L,), Np]): gather each group's member entries in fixed member
    order, fold them through the monoid (``reduce_rows`` — the same fixed
    tree order as the exchange reduce, so sum programs stay deterministic),
    and scatter the merged message back to *all* member slots.  Runs
    identically in the logical engine and (on all_gather'ed rows) in the
    SPMD engine, so both produce bit-identical merges."""
    tot = S * Np
    lead = inbox.shape[1:-1]
    R = rmem.shape[1]

    def flat(x):
        return jnp.moveaxis(x, 0, -2).reshape(lead + (tot,))

    def unflat(x):
        return jnp.moveaxis(x.reshape(lead + (S, Np)), -2, 0)

    fi, fh = flat(inbox), flat(has)
    valid = rmem >= 0                              # [G, R]
    idx = jnp.clip(rmem, 0)
    vals = fi[..., idx]                            # [..., G, R]
    hm = fh[..., idx] & valid
    # invalid members gather garbage through the clip — force to identity
    vals = jnp.where(hm, vals, ident)
    vr = jnp.moveaxis(vals, -1, 0)                 # [R, ..., G]
    hr = jnp.moveaxis(hm, -1, 0)
    merged = monoid.reduce_rows(vr, hr, axis=0)    # [..., G]
    has_g = jnp.any(hr, axis=0)
    tgt = jnp.where(valid, rmem, tot)
    fi = fi.at[..., tgt].set(
        jnp.broadcast_to(merged[..., None], merged.shape + (R,)),
        mode="drop")
    fh = fh.at[..., tgt].set(
        jnp.broadcast_to(has_g[..., None], has_g.shape + (R,)),
        mode="drop")
    out_pay = None
    if with_payload:
        fp = flat(pay)
        pr = jnp.moveaxis(fp[..., idx], -1, 0)     # [R, ..., G]
        best = monoid.argbest(vr, axis=0)          # [..., G]
        pay_g = jnp.take_along_axis(pr, best[None], axis=0)[0]
        fp = fp.at[..., tgt].set(
            jnp.broadcast_to(pay_g[..., None], pay_g.shape + (R,)),
            mode="drop")
        out_pay = unflat(fp)
    return unflat(fi), unflat(fh), out_pay


def logical_view(sg: ShardedGraph):
    """The program-init view of a (possibly hub-split) graph: ``node_ok``
    counts each hub once (False at non-primary member slots) and
    ``out_degree`` carries the *group-total* degree at every member slot,
    so degree-normalized emits (PPR / PageRank) divide by the hub's real
    out-degree.  Unsplit graphs pass through unchanged; the engine's
    entry broadcast then mirrors the primary's init state over members."""
    if sg.replica_members is None:
        return sg
    import types as _types

    S, Np = sg.n_shards, sg.n_per_shard
    tot = S * Np
    rmem = sg.replica_members
    nonprim = jnp.where(rmem[:, 1:] >= 0, rmem[:, 1:], tot)
    node_ok = sg.node_ok & ~(
        jnp.zeros((tot,), bool).at[nonprim].set(True, mode="drop")
        .reshape(S, Np))
    valid = rmem >= 0
    flatdeg = sg.out_degree.reshape(tot)
    share = jnp.where(valid, flatdeg[jnp.clip(rmem, 0)], 0)
    total = share.sum(axis=1)                      # [G]
    deg = flatdeg.at[jnp.where(valid, rmem, tot)].set(
        jnp.broadcast_to(total[:, None], rmem.shape).astype(flatdeg.dtype),
        mode="drop").reshape(S, Np)
    return _types.SimpleNamespace(gid=sg.gid, node_ok=node_ok,
                                  out_degree=deg)


@partial(jax.jit, static_argnames=("prog", "max_local_iters", "max_rounds",
                                   "delta", "backend", "sweep",
                                   "push_threshold"))
def _run_rounds(sg: ShardedGraph, prog: VertexProgram, vstate0, active0,
                max_local_iters: int, max_rounds: int, delta=None,
                backend: str = "xla", sweep: str = "pull",
                push_threshold: float = DEFAULT_PUSH_THRESHOLD):
    S, Np = sg.n_shards, sg.n_per_shard
    L = prog.lanes
    lane = (L,) if L else ()
    if sg.csr_perm is None or (sweep != "pull" and sg.push_perm is None):
        sg = sg.with_csr()          # invalidated views: rebuild in-trace
    sgd = _sg_as_dict(sg, with_push=sweep != "pull")
    # the [G, Rmax] member table rides outside the per-shard vmap below
    rmem = sgd.pop("replica_members", None)
    if rmem is not None:
        member_mask, rsrc = _replica_maps(rmem, S, Np)
        # entry broadcast: callers (init, adopt, commit-repair splices)
        # only maintain primary slots — mirror them over the members
        vstate0 = _broadcast_from_primary(vstate0, rsrc, S, Np)
        active0 = _broadcast_from_primary(active0, rsrc, S, Np)
    else:
        member_mask = None
    relax = make_relax(prog, S, Np, sg.csr_block, backend, sweep,
                       push_threshold, delta_e=sg.delta_width)
    nb = sgd["csr_key"].shape[-1] // sg.csr_block
    n_caps = len(push_caps(nb))
    monoid = prog.monoid
    ident = monoid.identity(prog.msg_dtype)

    outbox0 = jnp.full((S, S) + lane + (Np,), ident, prog.msg_dtype)
    has0 = jnp.zeros((S, S) + lane + (Np,), bool)
    pay0 = (jnp.full((S, S) + lane + (Np,), -1, jnp.int32)
            if prog.with_payload else None)

    stats0 = _stats0()

    shard_ids = jnp.arange(S, dtype=jnp.int32)
    use_gate = delta is not None and prog.priority is not None

    def _bucket_of(vstate, active, thr, lane_live):
        """The direction selector: gated sending frontier -> per-cell
        active push-block counts -> shared bucket index (see relax.py)."""
        gated = jax.vmap(lambda vs, a: _gate(prog, vs, a, thr))(vstate,
                                                                active)
        if lane_live is not None:
            gated = gated & lane_live[None, :, None]
        counts = active_push_blocks(gated, sgd["push_src"], sg.csr_block)
        return select_bucket(counts, nb, sweep, push_threshold)

    def round_cond(c):
        st, stats = c
        _, active, _, outbox_has, _ = st
        return (~quiescent(jnp.sum(active.astype(jnp.int32)),
                           jnp.sum(outbox_has.astype(jnp.int32)))) & (
            stats.rounds < max_rounds
        )

    def round_body(c):
        st, stats = c
        if use_gate:
            # bucket threshold: min active priority + delta, per round —
            # computed per lane so a gated laned run reproduces each
            # single-query bucket sequence exactly
            prio = jax.vmap(prog.priority)(st[0])
            masked = jnp.where(st[1], prio, jnp.inf)
            if L:
                thr = jnp.min(masked, axis=(0, masked.ndim - 1))[:, None] + delta
            else:
                thr = jnp.min(masked) + delta
        else:
            thr = jnp.inf
        # per-lane quiescence: converged lanes stop generating messages
        lane_live = jnp.any(st[1], axis=(0, st[1].ndim - 1)) if L else None

        # round-start introspection: frontier size here; the direction is
        # logged by the first local sub-iteration from the bucket it
        # actually dispatches (the frontier may grow mid-round; only the
        # opening choice is logged — push_iters counts the rest)
        li = jnp.minimum(stats.rounds, FRONTIER_LOG_CAP - 1)
        stats = stats._replace(
            frontier_log=stats.frontier_log.at[li].set(
                jnp.sum(st[1].astype(jnp.int32))),
        )

        def local_cond(c2):
            st2, stats2, liters = c2
            gated = jax.vmap(lambda vs, a: _gate(prog, vs, a,
                                                 thr if use_gate else None))(
                st2[0], st2[1])
            return jnp.any(gated) & (liters < max_local_iters)

        def local_body(c2):
            st2, stats2, liters = c2
            if sweep != "pull":
                bucket = _bucket_of(st2[0], st2[1],
                                    thr if use_gate else None, lane_live)
                is_push = jnp.where(bucket < n_caps, 1, 0).astype(jnp.int32)
            else:
                bucket, is_push = None, jnp.zeros((), jnp.int32)
            local_iter = jax.vmap(
                lambda i, g, s: _local_iter_shard(
                    prog, Np, S, i, g, s, relax,
                    thr if use_gate else None, lane_live, bucket,
                    member_full=member_mask,
                ),
                in_axes=(0, 0, 0),
            )
            st2, counts = local_iter(shard_ids, sgd, st2)
            stats2 = stats2._replace(
                local_iters=stats2.local_iters + 1,
                actions=stats2.actions + jnp.sum(counts["actions"]),
                remote_actions=stats2.remote_actions
                + jnp.sum(counts["remote"]),
                max_frontier=jnp.maximum(
                    stats2.max_frontier, jnp.sum(st2[1].astype(jnp.int32))
                ),
                push_iters=stats2.push_iters + is_push,
                dir_log=stats2.dir_log.at[li].set(
                    jnp.where(liters == 0, is_push, stats2.dir_log[li])),
            )
            return st2, stats2, liters + 1

        st, stats, _ = lax.while_loop(
            local_cond, local_body, (st, stats, jnp.zeros((), jnp.int32))
        )
        vstate, active, outbox, outbox_has, outbox_pay = st
        # ---- exchange: deliver every outbox to its destination cell ----
        n_ops = jnp.sum(outbox_has.astype(jnp.int32))
        inbox_all = monoid.reduce_rows(outbox, outbox_has, axis=0)
        has_all = outbox_has.any(axis=0)
        pay_all = None
        if prog.with_payload:
            src_idx = monoid.argbest(outbox, axis=0)
            pay_all = jnp.take_along_axis(outbox_pay, src_idx[None], axis=0)[0]
        if rmem is not None:
            # replica merge, folded into the exchange: member partials
            # combine through the monoid and the merged message lands on
            # every member slot before receive
            inbox_all, has_all, pay_all = _merge_replicas(
                monoid, prog.with_payload, ident, rmem, S, Np,
                inbox_all, has_all, pay_all)
        recv = jax.vmap(
            lambda vs, ib, hs, pl, nok: prog.receive(vs, ib, hs, pl, nok)
        )
        vstate, activated = recv(vstate, inbox_all, has_all, pay_all,
                                 sgd["node_ok"])
        active = active | activated
        outbox = jnp.full_like(outbox, ident)
        outbox_has = jnp.zeros_like(outbox_has)
        if prog.with_payload:
            outbox_pay = jnp.full_like(outbox_pay, -1)
        stats = stats._replace(
            rounds=stats.rounds + 1,
            operons_sent=stats.operons_sent + n_ops,
            operons_delivered=stats.operons_delivered + n_ops,
            max_frontier=jnp.maximum(
                stats.max_frontier, jnp.sum(active.astype(jnp.int32))
            ),
        )
        return (vstate, active, outbox, outbox_has, outbox_pay), stats

    st0 = (vstate0, active0, outbox0, has0, pay0)
    (st, stats) = lax.while_loop(round_cond, round_body, (st0, stats0))
    # budget watchdog: the loop exits on quiescence OR rounds == max_rounds;
    # re-evaluating the predicate on the final state tells the two apart
    _, active_f, _, outbox_has_f, _ = st
    stats = stats._replace(converged=quiescent(
        jnp.sum(active_f.astype(jnp.int32)),
        jnp.sum(outbox_has_f.astype(jnp.int32))))
    return st[0], stats


def exact_streams_for(sg: ShardedGraph, prog: VertexProgram) -> ShardedGraph:
    """Compact a dirty graph before a **sum-combine** diffusion.

    Min/max fixed points consume tombstones and staged delta blocks
    bitwise-identically to a full rebuild (order-free monoids), but a
    floating sum reassociates when the staged edges' contributions fold
    in through the delta scatter instead of their sorted run positions —
    so sum programs compact first, keeping the "incremental == rebuild,
    bitwise" contract across the whole program matrix.  Cheap host check
    of the per-cell counters; a traced graph skips it (the delta path is
    still exact-to-tolerance) and an already-clean graph pays nothing.
    Callers that own the graph (the session) persist the compacted copy
    so the sort is paid once per dirty epoch, not per query.
    """
    if (prog.combine != "sum" or sg.csr_perm is None
            or sg.delta_count is None):
        return sg
    if isinstance(sg.delta_count, jax.core.Tracer):
        return sg
    # intentional O(cells) policy read: device_get (not int()/.item()) so
    # a warm query stays legal under jax.transfer_guard("disallow")
    dc = jax.device_get(sg.delta_count)  # analysis: allow(host-sync): per-query policy counters, guard-legal
    tc = jax.device_get(sg.tomb_count)   # analysis: allow(host-sync): per-query policy counters, guard-legal
    dirty = (dc.max(initial=0) + tc.max(initial=0)) > 0
    return sg.with_csr() if dirty else sg


def diffuse(
    part: Partitioned | ShardedGraph,
    prog: VertexProgram,
    max_local_iters: int = 64,
    max_rounds: int = 10_000,
    delta=None,
    backend: str = "xla",
    sweep: str = "pull",
    push_threshold: float = DEFAULT_PUSH_THRESHOLD,
):
    """Run a diffusive computation to quiescence.

    Returns (vertex_state pytree in [S, Np] layout — [S, L, Np] for laned
    programs — and DiffuseStats).  Equivalent of the paper's
    ``hpx_diffuse`` (Code Listing 3): the program carries
    vertex_func/predicate; the terminator is the engine's built-in
    counting quiescence detector.  ``backend`` selects the relaxation
    kernel and ``sweep`` the direction — dense pull, frontier-compacted
    push, or the per-sub-iteration ``"auto"`` selector (see relax.py);
    every choice reaches the same fixed point bitwise.

    The initial ``(vstate, active)`` is computed *eagerly* and enters
    the jitted fixed-point loop as traced arrays: combined with
    :class:`~.programs.VertexProgram`'s init-excluding structural
    equality, every query that differs only in its init parameters
    (``sssp(source=k)`` for any k of the same graph shape) reuses one
    ``_run_rounds`` compilation — zero retraces across sources.
    """
    sg = part.sg if isinstance(part, Partitioned) else part
    sg = exact_streams_for(sg, prog)
    # init runs concretely (not traced), so its per-query scalar
    # constants (e.g. the source id) upload h2d here; that O(1) upload
    # is legal under the sanitizer, whose contract guards d2h syncs and
    # retraces — leave the d2h direction of any ambient guard in force.
    with jax.transfer_guard_host_to_device("allow"):
        vstate0, active0 = prog.init(logical_view(sg))
    return _run_rounds(sg, prog, vstate0, active0, max_local_iters,
                       max_rounds, delta, backend, sweep, push_threshold)


def diffuse_from(
    part: Partitioned | ShardedGraph,
    prog: VertexProgram,
    vstate,
    active,
    max_local_iters: int = 64,
    max_rounds: int = 10_000,
    delta=None,
    backend: str = "xla",
    sweep: str = "pull",
    push_threshold: float = DEFAULT_PUSH_THRESHOLD,
):
    """Resume / continue a diffusion from an explicit (state, frontier).

    Used by the dynamic-graph repair path (incremental SSSP) — the paper's
    point that diffusive computations restart from *within* the data rather
    than from a central coordinator.  ``delta`` applies the same
    delta-stepping priority gate as :func:`diffuse`, so a gated query's
    incremental repair runs gated too.  Repairs resume from a *tiny*
    frontier, which is exactly where ``sweep="push"`` turns the O(E)
    per-round sweep into O(frontier-adjacent edges) — the session's
    repair path defaults to it."""
    sg = part.sg if isinstance(part, Partitioned) else part
    sg = exact_streams_for(sg, prog)
    return _run_rounds(sg, prog, vstate, active, max_local_iters, max_rounds,
                       delta, backend, sweep, push_threshold)


# --------------------------------------------------------------------------
# SPMD device engine: one compute cell per mesh device, shard_map + all_to_all
# --------------------------------------------------------------------------

def diffuse_spmd_step(prog: VertexProgram, axis_name: str, n_shards: int,
                      n_per_shard: int, max_local_iters: int, max_rounds: int,
                      block_e: int = DEFAULT_EDGE_BLOCK,
                      backend: str = "xla", sweep: str = "pull",
                      push_threshold: float = DEFAULT_PUSH_THRESHOLD,
                      delta_e: int = 0):
    """Build the per-device diffusion function for use inside shard_map.

    The returned fn takes per-device blocks of the ShardedGraph arrays
    (leading dim 1 = this device's shard, including the ``csr_*``/
    ``push_*`` sorted edge streams) and runs rounds of (local relax ->
    all_to_all operon exchange -> receive) until a psum'd quiescence check
    fires.  The local while_loop has device-dependent trip count — cells
    genuinely run ahead of each other between exchanges.  The relaxation
    step dispatches to the same ``backend``/``sweep`` implementations as
    the logical engine; the direction selector runs *per device* on the
    local frontier (no collective — the sweep branches contain none), so
    a cell with a dense frontier pulls while its sparse neighbours push.
    Laned programs carry their lane axis through the all_to_all unchanged.
    """
    S, Np = n_shards, n_per_shard
    L = prog.lanes
    lane = (L,) if L else ()
    relax = make_relax(prog, S, Np, block_e, backend, sweep, push_threshold,
                       delta_e=delta_e)
    monoid = prog.monoid
    ident_f = lambda: monoid.identity(prog.msg_dtype)

    def per_device(sgd):
        import types as _types

        my_shard = lax.axis_index(axis_name).astype(jnp.int32)
        sgd = dict(sgd)
        # replicated [G, Rmax] member table (P() spec — no device axis)
        rmem = sgd.pop("replica_members", None)
        sg_s = {k: v[0] for k, v in sgd.items()}

        if rmem is not None:
            member_mask, rsrc = _replica_maps(rmem, S, Np)
            tot = S * Np
            # logical init view for this device's row: node_ok counts each
            # hub once; out_degree carries group totals (cross-device sum)
            nonprim = (rsrc != jnp.arange(tot, dtype=jnp.int32)).reshape(
                S, Np)
            deg_all = lax.all_gather(sg_s["out_degree"], axis_name)
            flatdeg = deg_all.reshape(tot)
            valid = rmem >= 0
            share = jnp.where(valid, flatdeg[jnp.clip(rmem, 0)], 0)
            deg_log = flatdeg.at[jnp.where(valid, rmem, tot)].set(
                jnp.broadcast_to(share.sum(axis=1)[:, None], rmem.shape
                                 ).astype(flatdeg.dtype),
                mode="drop").reshape(S, Np)
            view_nok = sg_s["node_ok"] & ~jnp.take(nonprim, my_shard, axis=0)
            view_deg = jnp.take(deg_log, my_shard, axis=0)
        else:
            member_mask = None
            view_nok = sg_s["node_ok"]
            view_deg = sg_s["out_degree"]

        # init needs [S, Np]-shaped thinking; emulate with this shard's block
        view = _types.SimpleNamespace(gid=sg_s["gid"], node_ok=view_nok,
                                      out_degree=view_deg)
        vstate, active = prog.init(view)
        if rmem is not None:
            # entry broadcast: mirror primary init state over member slots
            # (members may live on other devices — gather, map, re-slice)
            def _bcast_row(x):
                full = _broadcast_from_primary(
                    lax.all_gather(x, axis_name), rsrc, S, Np)
                return jnp.take(full, my_shard, axis=0)

            vstate = jax.tree_util.tree_map(_bcast_row, vstate)
            active = _bcast_row(active)
        outbox = jnp.full((S,) + lane + (Np,), ident_f(), prog.msg_dtype)
        outbox_has = jnp.zeros((S,) + lane + (Np,), bool)
        outbox_pay = (jnp.full((S,) + lane + (Np,), -1, jnp.int32)
                      if prog.with_payload else None)
        stats = _stats0()
        nb = sg_s["csr_key"].shape[-1] // block_e
        n_caps = len(push_caps(nb))

        def _bucket_of(act):
            counts = active_push_blocks(act, sg_s["push_src"], block_e)
            return select_bucket(counts, nb, sweep, push_threshold)

        def round_cond(c):
            _, _, global_live, stats = c
            return (global_live > 0) & (stats.rounds < max_rounds)

        def round_body(c):
            st, _, global_live, stats = c
            if L:
                # per-lane global quiescence: psum'd lane frontiers mask
                # converged lanes out of message generation
                lane_live = lax.psum(
                    jnp.sum(st[1].astype(jnp.int32), axis=-1), axis_name
                ) > 0
            else:
                lane_live = None

            # round-start introspection: the psum'd frontier is already in
            # hand (replicated); the direction is this device's opening
            # pick, logged by the first local sub-iteration and pmax'd
            # into the log at the end
            li = jnp.minimum(stats.rounds, FRONTIER_LOG_CAP - 1)
            stats = stats._replace(
                frontier_log=stats.frontier_log.at[li].set(
                    global_live.astype(jnp.int32)),
            )

            def local_cond(c2):
                st2, stats2, liters = c2
                return jnp.any(st2[1]) & (liters < max_local_iters)

            def local_body(c2):
                st2, stats2, liters = c2
                if sweep != "pull":
                    act = (st2[1] if lane_live is None
                           else st2[1] & lane_live[:, None])
                    bucket = _bucket_of(act)
                    is_push = jnp.where(bucket < n_caps, 1, 0).astype(
                        jnp.int32)
                else:
                    bucket, is_push = None, jnp.zeros((), jnp.int32)
                st2, counts = _local_iter_shard(prog, Np, S, my_shard, sg_s,
                                                st2, relax, None, lane_live,
                                                bucket,
                                                member_full=member_mask)
                stats2 = stats2._replace(
                    local_iters=stats2.local_iters + 1,
                    actions=stats2.actions + counts["actions"],
                    remote_actions=stats2.remote_actions + counts["remote"],
                    push_iters=stats2.push_iters + is_push,
                    dir_log=stats2.dir_log.at[li].set(
                        jnp.where(liters == 0, is_push,
                                  stats2.dir_log[li])),
                )
                return st2, stats2, liters + 1

            st, stats, _ = lax.while_loop(
                local_cond, local_body, (st, stats, jnp.zeros((), jnp.int32))
            )
            vstate, active, outbox, outbox_has, outbox_pay = st
            n_ops = jnp.sum(outbox_has.astype(jnp.int32))
            # exchange: row t of my outbox goes to device t
            rec = lax.all_to_all(outbox, axis_name, split_axis=0,
                                 concat_axis=0, tiled=True)
            rec_has = lax.all_to_all(
                outbox_has.astype(jnp.int8), axis_name, split_axis=0,
                concat_axis=0, tiled=True,
            ) > 0
            inbox = monoid.reduce_rows(rec, rec_has, axis=0)
            has = rec_has.any(axis=0)
            pay = None
            if prog.with_payload:
                rec_pay = lax.all_to_all(outbox_pay, axis_name, split_axis=0,
                                         concat_axis=0, tiled=True)
                idx = monoid.argbest(rec, axis=0)
                pay = jnp.take_along_axis(rec_pay, idx[None], axis=0)[0]
                outbox_pay = jnp.full_like(outbox_pay, -1)
            if rmem is not None:
                # replica merge on the gathered [S, ...] rows — the exact
                # computation the logical engine runs, then re-slice this
                # device's row, so both engines merge bit-identically
                ib = lax.all_gather(inbox, axis_name)
                hs = lax.all_gather(has, axis_name)
                pa = (lax.all_gather(pay, axis_name)
                      if prog.with_payload else None)
                ib, hs, pa = _merge_replicas(
                    monoid, prog.with_payload, ident_f(), rmem, S, Np,
                    ib, hs, pa)
                inbox = jnp.take(ib, my_shard, axis=0)
                has = jnp.take(hs, my_shard, axis=0)
                if prog.with_payload:
                    pay = jnp.take(pa, my_shard, axis=0)
            vstate, activated = prog.receive(vstate, inbox, has, pay,
                                             sg_s["node_ok"])
            active = active | activated
            outbox = jnp.full((S,) + lane + (Np,), ident_f(), prog.msg_dtype)
            outbox_has = jnp.zeros((S,) + lane + (Np,), bool)
            live = lax.psum(jnp.sum(active.astype(jnp.int32)), axis_name)
            delivered = lax.psum(n_ops, axis_name)
            stats = stats._replace(
                rounds=stats.rounds + 1,
                operons_sent=stats.operons_sent + n_ops,
                operons_delivered=stats.operons_delivered + delivered,
            )
            return (vstate, active, outbox, outbox_has, outbox_pay), None, live, stats

        live0 = lax.psum(jnp.sum(active.astype(jnp.int32)), axis_name)
        st0 = (vstate, active, outbox, outbox_has, outbox_pay)
        st, _, live_f, stats = lax.while_loop(
            round_cond, round_body, (st0, None, live0, stats)
        )
        vfinal = jax.tree_util.tree_map(lambda a: a[None], st[0])
        stats = stats._replace(
            # live_f is already a psum — replicated, so every device
            # reports the same budget-vs-quiescence verdict
            converged=(live_f == 0),
            actions=lax.psum(stats.actions, axis_name),
            remote_actions=lax.psum(stats.remote_actions, axis_name),
            operons_sent=lax.psum(stats.operons_sent, axis_name),
            local_iters=lax.pmax(stats.local_iters, axis_name),
            max_frontier=lax.pmax(stats.max_frontier, axis_name),
            push_iters=lax.pmax(stats.push_iters, axis_name),
            dir_log=lax.pmax(stats.dir_log, axis_name),
        )
        return vfinal, stats

    return per_device


def make_spmd_diffuse(mesh, prog: VertexProgram, sg_template,
                      axis_name: str = "cells", max_local_iters: int = 64,
                      max_rounds: int = 10_000, backend: str = "xla",
                      block_e: int | None = None, sweep: str = "pull",
                      push_threshold: float = DEFAULT_PUSH_THRESHOLD,
                      delta_blocks: int | None = None):
    """Wrap the per-device engine in shard_map over ``axis_name``.

    ``sg_template`` may be a ShardedGraph or a dict of (ShapeDtypeStruct)
    arrays matching :func:`_sg_as_dict` — the latter is what the dry-run
    uses; dict templates must carry the ``csr_*`` and ``push_*`` stream
    fields, padded to a multiple of ``block_e`` (pass it when the streams
    were built with a non-default :meth:`ShardedGraph.with_csr` block).
    ``delta_blocks`` is the staged-delta capacity baked into the streams
    (taken from a ShardedGraph template automatically; dict templates
    default to 0 = delta-free).
    Returns a function (sgd dict) -> (vertex_state [S, Np] layout, stats).
    """
    import types as _types

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    if isinstance(sg_template, ShardedGraph):
        if sg_template.csr_perm is None or (
                sweep != "pull" and sg_template.push_perm is None):
            sg_template = sg_template.with_csr()
        sgd_t = _sg_as_dict(sg_template, with_push=sweep != "pull")
        block_e = block_e or sg_template.csr_block
        if delta_blocks is None:
            delta_blocks = max(sg_template.delta_blocks, 0)
    else:
        sgd_t = dict(sg_template)
        block_e = block_e or DEFAULT_EDGE_BLOCK
    delta_blocks = delta_blocks or 0
    if sgd_t["csr_key"].shape[-1] % block_e:
        raise ValueError(
            f"csr streams of width {sgd_t['csr_key'].shape[-1]} are not a "
            f"multiple of block_e={block_e}; pass the block the template "
            f"was padded with")
    S = sgd_t["gid"].shape[0]
    Np = sgd_t["gid"].shape[1]

    per_device = diffuse_spmd_step(
        prog, axis_name, S, Np, max_local_iters, max_rounds,
        block_e=block_e, backend=backend, sweep=sweep,
        push_threshold=push_threshold, delta_e=delta_blocks * block_e,
    )

    # Derive the vertex-state pytree structure from prog.init (shape-only).
    def _init_struct(gid, node_ok, out_degree):
        view = _types.SimpleNamespace(
            gid=gid, node_ok=node_ok, out_degree=out_degree
        )
        return prog.init(view)

    vstate_struct, _ = jax.eval_shape(
        _init_struct, sgd_t["gid"], sgd_t["node_ok"], sgd_t["out_degree"]
    )
    # graph arrays shard one cell per device; the [G, Rmax] replica member
    # table (when present) is replicated — every device needs every group
    in_specs = ({k: (P() if k == "replica_members" else P(axis_name))
                 for k in sgd_t},)
    out_specs = (
        jax.tree_util.tree_map(lambda _: P(axis_name), vstate_struct),
        DiffuseStats(*[P()] * len(DiffuseStats._fields)),
    )
    return shard_map(
        per_device,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
    )
