"""Termination detection for diffusive computations.

The paper (§V.A step 6, §V.B) requires detecting the moment when *no vertex
is active and no message is in transit*.  Its HPX-5 implementation uses the
Dijkstra–Scholten (DS) spanning-tree algorithm, paying one acknowledgement
per diffusion message.

This module provides both detectors used in the framework:

* :func:`quiescent` — **counting detection** for the batched engines.  Our
  transports (outbox exchange / ``all_to_all``) are lossless and the engine
  can observe global state with one ``psum``, so quiescence is exactly
  ``active == 0 ∧ sent == delivered``; the DS tree exists in HPX because no
  such cheap global observation exists there (DESIGN.md §2).
* :class:`DijkstraScholten` — a faithful per-message DS detector (parent
  pointers, deficit counters, ack messages) used by the event-driven
  reference engine in event.py, validated against counting detection in the
  test suite.
"""

from __future__ import annotations

__all__ = ["quiescent", "DijkstraScholten"]


def quiescent(active_count, inflight_count):
    """Global quiescence predicate for the batched engines."""
    return (active_count == 0) & (inflight_count == 0)


class DijkstraScholten:
    """Classic Dijkstra–Scholten termination detection (host-side).

    Node 'environment' (-1) is the root that injects the initial diffusion
    messages.  Every computation message is acknowledged; the first message a
    disengaged node receives makes the sender its parent.  A node sends the
    ack to its parent only once it is passive and its own deficit is zero —
    the engagement tree collapses leaf-first, and when the root's deficit
    reaches zero, the computation has terminated (no actives, no in-flight).
    """

    ENV = -1

    def __init__(self, n_nodes: int):
        self.parent = [None] * n_nodes   # None = disengaged
        self.deficit = [0] * n_nodes     # unacked messages sent by each node
        self.env_deficit = 0
        self.acks = 0                    # ack message count (paper's overhead)

    # -- hooks called by the event engine ---------------------------------
    def on_send(self, sender: int):  # analysis: allow(mutation): host-side Dijkstra–Scholten accountant, not a traced action body
        if sender == self.ENV:
            self.env_deficit += 1
        else:
            self.deficit[sender] += 1

    def on_receive(self, receiver: int, sender: int) -> bool:
        """Returns True if the receiver should ack immediately (already
        engaged); False if the sender became the receiver's parent."""
        if self.parent[receiver] is None and self.deficit[receiver] == 0:
            self.parent[receiver] = sender
            return False
        self._ack(sender)
        return True

    def maybe_detach(self, node: int, is_active: bool):
        """Called when a node goes passive; collapses the tree if possible."""
        if (
            not is_active
            and self.parent[node] is not None
            and self.deficit[node] == 0
        ):
            p = self.parent[node]
            self.parent[node] = None
            self._ack(p)

    def _ack(self, node: int):
        self.acks += 1
        if node == self.ENV:
            self.env_deficit -= 1
        else:
            self.deficit[node] -= 1

    def terminated(self) -> bool:
        return self.env_deficit == 0

    def invariant_ok(self) -> bool:
        """Tree-consistency invariant: engaged nodes have a parent chain."""
        return all(d >= 0 for d in self.deficit) and self.env_deficit >= 0
