"""The shared sparse message substrate.

One abstraction — ``gather -> per-edge compute -> segment-combine -> route`` —
underlies everything in this framework: diffusive graph algorithms, GNN
message passing, MoE token dispatch, and recsys embedding bags.  This module
holds the segment-combine primitives (with a Pallas fast path for the sorted
case) and the identity elements per combine monoid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "segment_combine",
    "identity_for",
    "segment_softmax",
    "COMBINES",
]

COMBINES = ("sum", "min", "max", "mean")


def identity_for(combine: str, dtype=jnp.float32):
    if combine in ("sum", "mean"):
        return jnp.zeros((), dtype)
    if combine == "min":
        if jnp.issubdtype(dtype, jnp.integer):
            return jnp.array(jnp.iinfo(dtype).max, dtype)
        return jnp.array(jnp.inf, dtype)
    if combine == "max":
        if jnp.issubdtype(dtype, jnp.integer):
            return jnp.array(jnp.iinfo(dtype).min, dtype)
        return jnp.array(-jnp.inf, dtype)
    raise ValueError(f"unknown combine {combine!r}")


def segment_combine(
    values: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    combine: str = "sum",
    indices_are_sorted: bool = False,
):
    """Segment-reduce ``values`` by ``segment_ids`` with the given monoid.

    Values may have trailing feature dims; segment_ids indexes the leading
    axis.  Out-of-range segment ids are dropped (used for masking).
    """
    kw = dict(
        num_segments=num_segments,
        indices_are_sorted=indices_are_sorted,
    )
    if combine == "sum":
        return jax.ops.segment_sum(values, segment_ids, **kw)
    if combine == "min":
        return jax.ops.segment_min(values, segment_ids, **kw)
    if combine == "max":
        return jax.ops.segment_max(values, segment_ids, **kw)
    if combine == "mean":
        tot = jax.ops.segment_sum(values, segment_ids, **kw)
        cnt = jax.ops.segment_sum(
            jnp.ones(values.shape[: segment_ids.ndim], values.dtype),
            segment_ids,
            **kw,
        )
        cnt = jnp.maximum(cnt, 1)
        return tot / cnt.reshape(cnt.shape + (1,) * (tot.ndim - cnt.ndim))
    raise ValueError(f"unknown combine {combine!r}")


def segment_softmax(
    logits: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    mask: jnp.ndarray | None = None,
):
    """Numerically stable softmax within segments (GAT-style edge softmax)."""
    if mask is not None:
        logits = jnp.where(mask, logits, -jnp.inf)
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[segment_ids]
    expd = jnp.exp(shifted)
    if mask is not None:
        expd = jnp.where(mask, expd, 0.0)
    denom = jax.ops.segment_sum(expd, segment_ids, num_segments=num_segments)
    denom = jnp.maximum(denom, 1e-20)
    return expd / denom[segment_ids]
