"""Rhizomes — skew-aware hub splitting (DESIGN.md §2.12).

Power-law graphs concentrate a hub's edges into the one compute cell that
owns the vertex, so that cell's blocked-CSR stream (and with it the whole
sweep, which is sized by the max cell load) scales with the skew tail
instead of the mean.  Following the Rhizomes companion paper
(arxiv 2402.06086), a vertex whose live degree exceeds
``replica_threshold`` is split into R *member* slots spread over distinct
cells: member 0 is the primary (the slot the NameServer resolves), members
1..R-1 are replicas.  The hub's out-edges are stored across members and
its in-edges are retargeted across members, both by the deterministic
:func:`member_rank` hash — so a later ``edge_delete(u, v)`` probes exactly
the cell the build (or an earlier ``edge_add``) used, keeping
incremental == rebuild bitwise.

All members mirror the same vertex state: the engines suppress local
inbox delivery at member slots and merge member partials through the
program's monoid once per round at the exchange, re-broadcasting the
merged value to every member (core/diffuse.py).  This module holds only
the pure split policy: the hash, the threshold rule, and the member-count
rule — shared by partition, the update pipeline, and the benchmarks.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "member_rank",
    "resolve_replica_threshold",
    "replica_counts",
]

_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)

# Auto threshold = max cell load targeted at this fraction of the mean
# per-cell live-edge load (an eighth), floored at one CSR block — below a
# block the split can't shorten any run.
AUTO_THRESHOLD_DIVISOR = 8


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (vectorized uint64); wraps mod 2^64."""
    x = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * _M1
        x = (x ^ (x >> np.uint64(27))) * _M2
        return x ^ (x >> np.uint64(31))


def member_rank(hub_gid, other_gid, n_members):
    """Deterministic member index in [0, n_members) for an edge touching a
    split hub, keyed on the (hub, other endpoint) pair.

    Used for both roles of an edge: the *storage* member of a split
    source u is ``member_rank(u, v, R_u)`` and the *target* member of a
    split destination v is ``member_rank(v, u, R_v)``.  ``n_members`` may
    be an array (per-hub R); entries of 1 always map to member 0, so
    unsplit endpoints can go through the same call.
    """
    h = np.asarray(hub_gid, np.uint64)
    o = np.asarray(other_gid, np.uint64)
    with np.errstate(over="ignore"):
        key = _mix64((h << np.uint64(32)) ^ o)
    r = np.asarray(n_members, np.uint64)
    return (key % np.maximum(r, np.uint64(1))).astype(np.int32)


def resolve_replica_threshold(replica_threshold, n_live_edges: int,
                              n_shards: int, block: int) -> int | None:
    """Normalize the user-facing knob to a concrete degree threshold.

    ``None`` disables splitting; ``"auto"`` targets an eighth of the mean
    per-cell live-edge load (min one CSR block); an int passes through
    (min 1 so R = ceil(deg/thr) stays finite).
    """
    if replica_threshold is None:
        return None
    if replica_threshold == "auto":
        mean_cell_load = n_live_edges // max(n_shards, 1)
        return max(block, mean_cell_load // AUTO_THRESHOLD_DIVISOR)
    thr = int(replica_threshold)
    if thr < 1:
        raise ValueError(f"replica_threshold must be >= 1 or 'auto', "
                         f"got {replica_threshold!r}")
    return thr


def replica_counts(total_degree: np.ndarray, threshold: int,
                   n_shards: int) -> np.ndarray:
    """Members per vertex: 1 (unsplit) below the threshold, else
    ceil(degree / threshold) capped at one member per cell."""
    deg = np.asarray(total_degree, np.int64)
    r = -(-deg // max(threshold, 1))
    r = np.where(deg > threshold, r, 1)
    return np.minimum(np.maximum(r, 1), n_shards).astype(np.int32)
