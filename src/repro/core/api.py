"""Public API — the framework's equivalent of the paper's ``hpx_diffuse``.

    hpx_diffuse(vertex_id, vertex_func, args..., terminator, predicate)
      ==>
    diffuse(graph, program, n_cells=..., engine=...)

where the program bundles vertex_func + predicate (programs.py) and the
terminator is the engine's quiescence detector (termination.py).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .diffuse import DiffuseStats, diffuse as _diffuse_sharded
from .event import build_adjacency, event_sssp
from .generators import make_graph_family
from .graph import Graph, from_edges
from .partition import Partitioned, partition
from .programs import (
    VertexProgram,
    bfs_program,
    cc_program,
    ppr_program,
    sssp_program,
)

__all__ = [
    "build",
    "run",
    "sssp",
    "bfs",
    "connected_components",
    "personalized_pagerank",
    "pagerank",
    "Result",
]


class Result(NamedTuple):
    values: np.ndarray          # per-vertex result in global vertex order
    stats: DiffuseStats
    extra: dict


def build(
    src,
    dst,
    n_nodes: int,
    weight=None,
    n_cells: int = 4,
    strategy: str = "block",
    edge_slack: float = 0.0,
    node_slack: float = 0.0,
) -> Partitioned:
    """Build + partition a graph over n_cells compute cells.

    ``edge_slack`` / ``node_slack`` reserve free capacity slots per cell for
    dynamic updates (the paper's vertex/edge add primitives)."""
    g = from_edges(
        src, dst, n_nodes, weight, edge_slack=edge_slack, node_slack=node_slack
    )
    return partition(g, n_cells, strategy=strategy)


def run(
    part: Partitioned,
    prog: VertexProgram,
    value_key: str,
    max_local_iters: int = 64,
    max_rounds: int = 10_000,
) -> Result:
    vstate, stats = _diffuse_sharded(
        part, prog, max_local_iters=max_local_iters, max_rounds=max_rounds
    )
    values = np.asarray(part.to_global_layout(vstate[value_key]))[: part.n_real]
    extra = {
        k: np.asarray(part.to_global_layout(v))[: part.n_real]
        for k, v in vstate.items()
        if k != value_key
    }
    return Result(values=values, stats=stats, extra=extra)


def sssp(part: Partitioned, source: int, track_parents: bool = True,
         max_local_iters: int = 64) -> Result:
    return run(part, sssp_program(source, track_parents), "dist",
               max_local_iters=max_local_iters)


def bfs(part: Partitioned, source: int, max_local_iters: int = 64) -> Result:
    return run(part, bfs_program(source), "dist",
               max_local_iters=max_local_iters)


def connected_components(part: Partitioned, max_local_iters: int = 64) -> Result:
    return run(part, cc_program(), "comp", max_local_iters=max_local_iters)


def personalized_pagerank(part: Partitioned, source: int, alpha: float = 0.15,
                          eps: float = 1e-5, max_local_iters: int = 64) -> Result:
    return run(part, ppr_program(source, alpha, eps), "rank",
               max_local_iters=max_local_iters)


def pagerank(part: Partitioned, alpha: float = 0.15, eps: float = 1e-7,
             max_local_iters: int = 64) -> Result:
    from .programs import pagerank_program

    return run(part, pagerank_program(alpha, eps), "rank",
               max_local_iters=max_local_iters)
