"""Stateless convenience API — thin wrappers over :class:`DiffusionSession`.

The session (session.py) is the real front door — the framework's
equivalent of the paper's ``hpx_diffuse``::

    hpx_diffuse(vertex_id, vertex_func, args..., terminator, predicate)
      ==>
    DiffusionSession.query(prog, engine=...)

These free functions keep the original one-shot call style
(``sssp(part, 0)``) for scripts and notebooks; each builds a transient
session, so both styles share one execution path (DESIGN.md §2.4).
"""

from __future__ import annotations

from .graph import from_edges
from .partition import Partitioned, partition
from .programs import VertexProgram
from .session import DiffusionSession, Result

__all__ = [
    "build",
    "run",
    "sssp",
    "bfs",
    "connected_components",
    "personalized_pagerank",
    "pagerank",
    "widest_path",
    "reachable",
    "Result",
]


def build(
    src,
    dst,
    n_nodes: int,
    weight=None,
    n_cells: int = 4,
    strategy: str = "block",
    edge_slack: float = 0.0,
    node_slack: float = 0.0,
    replica_threshold: int | str | None = None,
) -> Partitioned:
    """Build + partition a graph over n_cells compute cells.

    ``edge_slack`` / ``node_slack`` reserve free capacity slots per cell for
    dynamic updates (the paper's vertex/edge add primitives).
    ``replica_threshold`` enables skew-aware hub splitting (DESIGN.md
    §2.12): int = degree cutoff, "auto" = scale with per-cell edge load,
    None = unsplit."""
    g = from_edges(
        src, dst, n_nodes, weight, edge_slack=edge_slack, node_slack=node_slack
    )
    return partition(g, n_cells, strategy=strategy,
                     replica_threshold=replica_threshold)


def _trim(part: Partitioned, res: Result) -> Result:
    return Result(
        values=res.values[: part.n_real],
        stats=res.stats,
        extra={k: v[: part.n_real] for k, v in res.extra.items()},
    )


def run(
    part: Partitioned,
    prog: VertexProgram,
    value_key: str,
    max_local_iters: int = 64,
    max_rounds: int = 10_000,
    backend: str = "xla",
    sweep: str = "pull",
) -> Result:
    sess = DiffusionSession(part, max_local_iters=max_local_iters,
                            max_rounds=max_rounds, backend=backend,
                            sweep=sweep)
    return _trim(part, sess.query(prog, value_key=value_key))


def _named(part: Partitioned, name: str, max_local_iters: int,
           backend: str = "xla", sweep: str = "pull", **kwargs):
    sess = DiffusionSession(part, max_local_iters=max_local_iters,
                            backend=backend, sweep=sweep)
    res = sess.query(name, **kwargs)
    if isinstance(res, list):                 # multi-query lanes
        return [_trim(part, r) for r in res]
    return _trim(part, res)


def sssp(part: Partitioned, source, track_parents: bool = True,
         max_local_iters: int = 64, backend: str = "xla",
         sweep: str = "pull") -> Result:
    """Single-source shortest paths; a list-valued ``source`` fans out
    into query lanes sharing one diffusion (one Result per source)."""
    kw = ({"sources": list(source)} if isinstance(source, (list, tuple))
          else {"source": source})
    return _named(part, "sssp", max_local_iters, backend, sweep,
                  track_parents=track_parents, **kw)


def bfs(part: Partitioned, source, max_local_iters: int = 64,
        backend: str = "xla", sweep: str = "pull") -> Result:
    kw = ({"sources": list(source)} if isinstance(source, (list, tuple))
          else {"source": source})
    return _named(part, "bfs", max_local_iters, backend, sweep, **kw)


def connected_components(part: Partitioned, max_local_iters: int = 64,
                         backend: str = "xla",
                         sweep: str = "pull") -> Result:
    return _named(part, "cc", max_local_iters, backend, sweep)


def personalized_pagerank(part: Partitioned, source, alpha: float = 0.15,
                          eps: float = 1e-5, max_local_iters: int = 64,
                          backend: str = "xla",
                          sweep: str = "pull") -> Result:
    """Forward-push PPR; a list-valued ``source`` runs one lane per
    source through a single sum-combine diffusion."""
    kw = ({"sources": list(source)} if isinstance(source, (list, tuple))
          else {"source": source})
    return _named(part, "ppr", max_local_iters, backend, sweep,
                  alpha=alpha, eps=eps, **kw)


def pagerank(part: Partitioned, alpha: float = 0.15, eps: float = 1e-7,
             max_local_iters: int = 64, backend: str = "xla",
             sweep: str = "pull") -> Result:
    return _named(part, "pagerank", max_local_iters, backend, sweep,
                  alpha=alpha, eps=eps)


def widest_path(part: Partitioned, source: int, track_parents: bool = False,
                max_local_iters: int = 64, backend: str = "xla",
                sweep: str = "pull") -> Result:
    """Max-bottleneck (widest) path widths from ``source`` — a max-combine
    diffusion registered through the public @diffusive extension point."""
    return _named(part, "widest", max_local_iters, backend, sweep,
                  source=source, track_parents=track_parents)


def reachable(part: Partitioned, sources, max_local_iters: int = 64,
              backend: str = "xla", sweep: str = "pull") -> Result:
    """Reachability from a vertex set (one diffusion, all sources at
    once); ``values[v] == 1`` iff some source reaches v."""
    return _named(part, "reach", max_local_iters, backend, sweep,
                  sources=tuple(int(s) for s in sources))
