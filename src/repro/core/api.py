"""Stateless convenience API — thin wrappers over :class:`DiffusionSession`.

The session (session.py) is the real front door — the framework's
equivalent of the paper's ``hpx_diffuse``::

    hpx_diffuse(vertex_id, vertex_func, args..., terminator, predicate)
      ==>
    DiffusionSession.query(prog, engine=...)

These free functions keep the original one-shot call style
(``sssp(part, 0)``) for scripts and notebooks; each builds a transient
session, so both styles share one execution path (DESIGN.md §2.4).
"""

from __future__ import annotations

from .graph import from_edges
from .partition import Partitioned, partition
from .programs import VertexProgram
from .session import DiffusionSession, Result

__all__ = [
    "build",
    "run",
    "sssp",
    "bfs",
    "connected_components",
    "personalized_pagerank",
    "pagerank",
    "Result",
]


def build(
    src,
    dst,
    n_nodes: int,
    weight=None,
    n_cells: int = 4,
    strategy: str = "block",
    edge_slack: float = 0.0,
    node_slack: float = 0.0,
) -> Partitioned:
    """Build + partition a graph over n_cells compute cells.

    ``edge_slack`` / ``node_slack`` reserve free capacity slots per cell for
    dynamic updates (the paper's vertex/edge add primitives)."""
    g = from_edges(
        src, dst, n_nodes, weight, edge_slack=edge_slack, node_slack=node_slack
    )
    return partition(g, n_cells, strategy=strategy)


def _trim(part: Partitioned, res: Result) -> Result:
    return Result(
        values=res.values[: part.n_real],
        stats=res.stats,
        extra={k: v[: part.n_real] for k, v in res.extra.items()},
    )


def run(
    part: Partitioned,
    prog: VertexProgram,
    value_key: str,
    max_local_iters: int = 64,
    max_rounds: int = 10_000,
    backend: str = "xla",
) -> Result:
    sess = DiffusionSession(part, max_local_iters=max_local_iters,
                            max_rounds=max_rounds, backend=backend)
    return _trim(part, sess.query(prog, value_key=value_key))


def _named(part: Partitioned, name: str, max_local_iters: int,
           backend: str = "xla", **kwargs) -> Result:
    sess = DiffusionSession(part, max_local_iters=max_local_iters,
                            backend=backend)
    return _trim(part, sess.query(name, **kwargs))


def sssp(part: Partitioned, source: int, track_parents: bool = True,
         max_local_iters: int = 64, backend: str = "xla") -> Result:
    return _named(part, "sssp", max_local_iters, backend, source=source,
                  track_parents=track_parents)


def bfs(part: Partitioned, source: int, max_local_iters: int = 64,
        backend: str = "xla") -> Result:
    return _named(part, "bfs", max_local_iters, backend, source=source)


def connected_components(part: Partitioned, max_local_iters: int = 64,
                         backend: str = "xla") -> Result:
    return _named(part, "cc", max_local_iters, backend)


def personalized_pagerank(part: Partitioned, source: int, alpha: float = 0.15,
                          eps: float = 1e-5, max_local_iters: int = 64,
                          backend: str = "xla") -> Result:
    return _named(part, "ppr", max_local_iters, backend, source=source,
                  alpha=alpha, eps=eps)


def pagerank(part: Partitioned, alpha: float = 0.15, eps: float = 1e-7,
             max_local_iters: int = 64, backend: str = "xla") -> Result:
    return _named(part, "pagerank", max_local_iters, backend, alpha=alpha,
                  eps=eps)
