"""Triangle counting + the paper's CCA cost model (§VI.A, Table III).

Three implementations:

* :func:`triangle_count_exact` — host-side sorted-adjacency intersection
  (the oracle).
* :func:`triangle_count_bitset` — vectorized JAX version: each vertex's
  adjacency row packed into uint32 bitset lanes; a triangle check is the
  popcount of ``row(u) & row(v)`` over live edges.  This is the TPU analogue
  of the paper's *peek* primitive — a vertex observing its neighbours'
  neighbourhoods in bulk.
* :func:`cca_cost_model` — the paper's analytic hops model (equations 1–3):
  sequential = 2·wedges + triangles hops; parallel = 2 + triangles hops.

``PAPER_TABLE_III`` reproduces the paper's speculative analysis on the
published Twitter / WDC-2012 / Graph500-scale-24 counts.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "triangle_count_exact",
    "triangle_count_bitset",
    "wedge_count",
    "cca_cost_model",
    "CcaCost",
    "PAPER_TABLE_III",
]


def triangle_count_exact(src: np.ndarray, dst: np.ndarray, n: int) -> int:
    """Exact count via forward-edge intersection (compact-forward)."""
    # forward orientation u < v removes duplicates
    fwd = src < dst
    s, d = np.asarray(src)[fwd], np.asarray(dst)[fwd]
    order = np.lexsort((d, s))
    s, d = s[order], d[order]
    starts = np.searchsorted(s, np.arange(n))
    ends = np.searchsorted(s, np.arange(n) + 1)
    count = 0
    for u, v in zip(s, d):
        a0, a1 = starts[u], ends[u]
        b0, b1 = starts[v], ends[v]
        # sorted intersection of N+(u) and N+(v)
        count += np.intersect1d(
            d[a0:a1], d[b0:b1], assume_unique=True
        ).shape[0]
    return int(count)


def triangle_count_bitset(src, dst, n: int) -> jnp.ndarray:
    """Vectorized triangle count; requires n <= ~16384 (bitset rows)."""
    lanes = -(-n // 32)
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    word = (dst // 32).astype(jnp.int32)
    bit = (dst % 32).astype(jnp.uint32)
    flat = src * lanes + word
    vals = jnp.left_shift(jnp.uint32(1), bit)
    # distinct (src, dst) pairs (deduped upstream) => each bit appears once
    # per word, so scatter-add == bitwise-or here.
    packed = jnp.zeros((n * lanes,), jnp.uint32).at[flat].add(vals)
    rows = packed.reshape(n, lanes)

    inter = rows[src] & rows[dst]                       # [E, lanes]
    # popcount each uint32 lane
    x = inter
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    pc = (x * jnp.uint32(0x01010101)) >> 24
    per_edge = pc.sum(axis=1)
    # each triangle counted once per directed edge of its 3 undirected edges
    # (6 directed) => divide by 6
    return per_edge.sum() // 6


def wedge_count(degrees: np.ndarray) -> int:
    d = np.asarray(degrees, np.int64)
    return int((d * (d - 1) // 2).sum())


class CcaCost(NamedTuple):
    seq_hops: float
    par_hops: float
    speedup: float


def cca_cost_model(wedges: float, triangles: float) -> CcaCost:
    """Paper equations (1)-(3): hops-based sequential vs parallel time."""
    seq = 2.0 * wedges + 1.0 * triangles
    par = 2.0 + 1.0 * triangles
    return CcaCost(seq_hops=seq, par_hops=par, speedup=seq / par)


# Published counts used by the paper's Table III (vertices, triangles, wedges)
PAPER_TABLE_III = {
    "twitter": dict(vertices=4.16e7, triangles=3.48e10, wedges=1.478e11),
    "wdc2012": dict(vertices=3.56e9, triangles=9.65e12, wedges=1.226e13),
    "graph500_s24": dict(vertices=1.71e10, triangles=5.05e13, wedges=2.46e14),
}
