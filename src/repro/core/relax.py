"""Relaxation backends + direction-optimizing sweeps — the engine's
gather→emit→segment-combine step as a pluggable interface.

The diffusive engines (logical sharded and SPMD shard_map — diffuse.py) run
the same bulk-asynchronous while-loop structure; what differs per backend is
only how one cell turns its vertex block + edge stream into the combined
per-destination message table:

* ``"xla"``     — segment ops over the sorted stream (flat for the
  order-free min/max monoids, segmented scan for sum); the default and
  the CPU/GPU production path.
* ``"pallas"``  — the fused ``kernels/edge_relax`` kernel: vertex block
  pinned in VMEM across the edge sweep, dense-rank in-block combine
  (interpret mode off-TPU, so CI exercises the same code path).

Orthogonally, ``sweep`` picks the *direction* (DESIGN.md §2.8):

* ``"pull"`` — the dense sweep over the whole destination-sorted stream
  (every edge visited, inactive senders masked); O(E) per sub-iteration.
* ``"push"`` — the frontier-compacted sweep over the source-sorted push
  stream: only the blocks holding an active sender's out-edges are
  gathered, so a sparse round costs O(frontier-adjacent edges).  The
  compaction capacity is bucketed to a power-of-two ladder
  (:func:`push_caps`) and selected *per sub-iteration* by the engine via
  ``lax.switch`` — every bucket traces once, none recompiles at runtime.
* ``"auto"``  — per-sub-iteration direction selector: push while the
  measured active-block count stays under ``push_threshold * n_blocks``,
  dense pull otherwise (the direction-optimizing rule of Beamer-style
  BFS, generalized to every program).

All sweep × backend combinations return bitwise-identical tables (see
kernels/edge_relax), so both knobs are pure execution choices — every
future perf kernel (delta-bucketed relaxation, rhizome splitting of heavy
vertices) slots in as another entry here without touching engine or
program code.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "RELAX_BACKENDS",
    "RELAX_SWEEPS",
    "DEFAULT_PUSH_THRESHOLD",
    "make_relax",
    "push_caps",
    "active_push_blocks",
    "select_bucket",
]

# the one registry of relaxation backends; kernels/edge_relax re-exports it
RELAX_BACKENDS = ("xla", "pallas")

# sweep directions understood by make_relax / the engines / the session
RELAX_SWEEPS = ("pull", "push", "auto")

# auto picks push while active blocks <= threshold * total blocks
DEFAULT_PUSH_THRESHOLD = 0.5


def push_caps(n_blocks: int) -> tuple:
    """The power-of-two compaction-bucket ladder for a cell with
    ``n_blocks`` push blocks: (1, 2, 4, ..., n_blocks).  Static shapes —
    each bucket is traced exactly once into its ``lax.switch`` branch, so
    a frontier of any size runs without recompiling."""
    caps = []
    c = 1
    while c < n_blocks:
        caps.append(c)
        c *= 2
    caps.append(n_blocks)
    return tuple(caps)


def active_push_blocks(senders, push_src, block_e: int):
    """Per-cell count of push blocks touched by the sending frontier.

    ``senders`` is [..., Np] bool (optionally with a lane axis at -2 —
    lanes OR into one shared active set); ``push_src`` is the matching
    [..., Eb] source-sorted stream.  Cheap elementwise work (one bool
    gather + a block-any); the engines run it every sub-iteration to
    drive :func:`select_bucket`.
    """
    if senders.ndim == push_src.ndim + 1:        # laned: OR over lanes
        senders = senders.any(axis=-2)
    ok = push_src >= 0
    act = jnp.take_along_axis(senders, jnp.clip(push_src, 0), axis=-1) & ok
    nb = push_src.shape[-1] // block_e
    blk = act.reshape(act.shape[:-1] + (nb, block_e)).any(axis=-1)
    return jnp.sum(blk, axis=-1)


def select_bucket(n_active_blocks, n_blocks: int, sweep: str,
                  push_threshold: float = DEFAULT_PUSH_THRESHOLD):
    """Pick the per-sub-iteration direction: a compaction-bucket index
    into :func:`push_caps` (push), or ``len(push_caps(n_blocks))`` (the
    dense pull branch).

    ``n_active_blocks`` may carry leading axes (per-cell counts); the
    bucket is shared across cells — ``lax.switch`` under the logical
    engine's shard vmap only stays a true conditional while its index is
    unbatched — so the max count picks it, guaranteeing no cell's
    frontier overflows its bucket.
    """
    caps = push_caps(n_blocks)
    count = jnp.max(n_active_blocks).astype(jnp.int32)
    if sweep == "pull":
        return jnp.int32(len(caps))
    k = jnp.searchsorted(jnp.asarray(caps, jnp.int32), count, side="left")
    k = jnp.minimum(k, len(caps) - 1).astype(jnp.int32)
    if sweep == "push":
        return k
    dense = count > jnp.int32(max(1, int(push_threshold * n_blocks)))
    return jnp.where(dense, jnp.int32(len(caps)), k)


def make_relax(prog, n_shards: int, n_per_shard: int, block_e: int,
               backend: str = "xla", sweep: str = "pull",
               push_threshold: float = DEFAULT_PUSH_THRESHOLD,
               delta_e: int = 0) -> Callable:
    """Build the per-cell relaxation step for ``prog`` on ``backend``.

    The returned function maps one cell's (vstate [Np] pytree, senders
    [Np] bool, sg_s dict with the ``csr_*``/``push_*`` sorted streams,
    and — for push/auto sweeps — the scalar ``bucket`` chosen by
    :func:`select_bucket`) to

        table [S, Np]  combined messages per destination (identity = none)
        cnt   [S, Np]  int32 sending-edge count per destination
        pay   [S, Np]  int32 argbest payload, or None

    over the flat destination key space — row ``my_shard`` is the local
    inbox, the other rows are outbox contributions.  vmap it over cells in
    the logical engine (keep ``bucket`` unbatched); call it per device
    under shard_map in SPMD.

    For a laned program (``prog.lanes = L`` — see
    :func:`~.programs.make_laned`) the cell's vstate leaves/senders are
    [L, Np] and the kernel broadcasts the whole sweep over lanes against
    one shared edge stream; outputs become [S, L, Np].

    ``sweep="pull"`` reproduces the dense sweep exactly (``bucket`` is
    ignored); ``"push"``/``"auto"`` stage one ``lax.switch`` over the
    compaction ladder + the dense branch, dispatching on ``bucket`` at
    runtime with zero recompiles.  Every branch returns the same table
    bitwise (tests/test_sweep.py), so the direction is invisible to
    programs.

    ``delta_e`` (static) is the width of the graph's staged delta
    segment (``ShardedGraph.delta_width``, DESIGN.md §2.9): the scan
    paths scan only the sorted region and fold the staged blocks in
    through a scatter; 0 = delta-free streams.
    """
    if backend not in RELAX_BACKENDS:
        raise ValueError(
            f"backend must be one of {RELAX_BACKENDS}, got {backend!r}")
    if sweep not in RELAX_SWEEPS:
        raise ValueError(
            f"sweep must be one of {RELAX_SWEEPS}, got {sweep!r}")
    # deferred import: kernels ←→ core import cycles resolve at call time
    from ..kernels.edge_relax.ops import edge_relax, edge_relax_push

    n_keys = n_shards * n_per_shard
    interpret = backend == "pallas" and jax.default_backend() != "tpu"

    def _shape(table, cnt, pay):
        if prog.lanes:
            # [L, n_keys] -> [S, L, Np]: destination shard leads so row
            # my_shard is still the local inbox
            shp = (-1, n_shards, n_per_shard)
            table = jnp.swapaxes(table.reshape(shp), 0, 1)
            cnt = jnp.swapaxes(cnt.reshape(shp), 0, 1)
            pay = (jnp.swapaxes(pay.reshape(shp), 0, 1)
                   if pay is not None else None)
        else:
            table = table.reshape(n_shards, n_per_shard)
            cnt = cnt.reshape(n_shards, n_per_shard)
            pay = (pay.reshape(n_shards, n_per_shard)
                   if pay is not None else None)
        return table, cnt, pay

    def _dense(vstate, senders, sg_s):
        return edge_relax(
            prog, vstate, senders, sg_s["gid"],
            sg_s["csr_key"], sg_s["csr_src"], sg_s["csr_weight"],
            sg_s["csr_dst_gid"],
            n_keys=n_keys, block_e=block_e, backend=backend,
            interpret=interpret, skey=sg_s.get("csr_skey"),
            delta_e=delta_e,
        )

    if sweep == "pull":
        def relax(vstate, senders, sg_s, bucket=None):
            del bucket
            return _shape(*_dense(vstate, senders, sg_s))
        return relax

    def _push(vstate, senders, sg_s, cap: int):
        sg_push = {k: sg_s[k] for k in ("push_src", "push_key",
                                        "push_weight", "push_dst_gid",
                                        "push_pos")}
        return edge_relax_push(
            prog, vstate, senders, sg_s["gid"], sg_push, sg_s["csr_key"],
            n_keys=n_keys, block_e=block_e, cap=cap, backend=backend,
            interpret=interpret, skey=sg_s.get("csr_skey"),
            delta_e=delta_e,
        )

    def relax(vstate, senders, sg_s, bucket=None):
        if bucket is None:
            raise ValueError(
                f"sweep={sweep!r} relaxation needs the per-iteration "
                "bucket from select_bucket(); only sweep='pull' runs "
                "without one")
        nb = sg_s["push_src"].shape[-1] // block_e
        caps = push_caps(nb)
        branches = [
            (lambda c: lambda args: _push(*args, cap=c))(cap)
            for cap in caps
        ]
        branches.append(lambda args: _dense(*args))
        out = lax.switch(jnp.clip(bucket, 0, len(caps)), branches,
                         (vstate, senders, sg_s))
        return _shape(*out)

    return relax
