"""Relaxation backends — the engine's gather→emit→segment-combine step as a
pluggable interface.

The diffusive engines (logical sharded and SPMD shard_map — diffuse.py) run
the same bulk-asynchronous while-loop structure; what differs per backend is
only how one cell turns its vertex block + destination-sorted edge stream
into the combined per-destination message table:

* ``"xla"``     — segment ops over the sorted stream (flat for the
  order-free min/max monoids, blocked reference for sum); the default and
  the CPU/GPU production path.
* ``"pallas"``  — the fused ``kernels/edge_relax`` kernel: vertex block
  pinned in VMEM across the edge sweep, dense-rank in-block combine
  (interpret mode off-TPU, so CI exercises the same code path).

Both backends return bitwise-identical tables (see kernels/edge_relax), so
``backend=`` is a pure execution choice — every future perf kernel
(delta-bucketed relaxation, rhizome splitting of heavy vertices) slots in
as another entry here without touching engine or program code.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["RELAX_BACKENDS", "make_relax"]

# the one registry of relaxation backends; kernels/edge_relax re-exports it
RELAX_BACKENDS = ("xla", "pallas")


def make_relax(prog, n_shards: int, n_per_shard: int, block_e: int,
               backend: str = "xla") -> Callable:
    """Build the per-cell relaxation step for ``prog`` on ``backend``.

    The returned function maps one cell's (vstate [Np] pytree, senders
    [Np] bool, sg_s dict with the ``csr_*`` sorted streams) to

        table [S, Np]  combined messages per destination (identity = none)
        cnt   [S, Np]  int32 sending-edge count per destination
        pay   [S, Np]  int32 argbest payload, or None

    over the flat destination key space — row ``my_shard`` is the local
    inbox, the other rows are outbox contributions.  vmap it over cells in
    the logical engine; call it per device under shard_map in SPMD.

    For a laned program (``prog.lanes = L`` — see
    :func:`~.programs.make_laned`) the cell's vstate leaves/senders are
    [L, Np] and the kernel broadcasts the whole sweep over lanes against
    one shared edge stream; outputs become [S, L, Np].
    """
    if backend not in RELAX_BACKENDS:
        raise ValueError(
            f"backend must be one of {RELAX_BACKENDS}, got {backend!r}")
    # deferred import: kernels ←→ core import cycles resolve at call time
    from ..kernels.edge_relax.ops import edge_relax

    n_keys = n_shards * n_per_shard
    interpret = backend == "pallas" and jax.default_backend() != "tpu"

    def relax(vstate, senders, sg_s):
        table, cnt, pay = edge_relax(
            prog, vstate, senders, sg_s["gid"],
            sg_s["csr_key"], sg_s["csr_src"], sg_s["csr_weight"],
            sg_s["csr_dst_gid"],
            n_keys=n_keys, block_e=block_e, backend=backend,
            interpret=interpret,
        )
        if prog.lanes:
            # [L, n_keys] -> [S, L, Np]: destination shard leads so row
            # my_shard is still the local inbox
            shp = (-1, n_shards, n_per_shard)
            table = jnp.swapaxes(table.reshape(shp), 0, 1)
            cnt = jnp.swapaxes(cnt.reshape(shp), 0, 1)
            pay = (jnp.swapaxes(pay.reshape(shp), 0, 1)
                   if pay is not None else None)
        else:
            table = table.reshape(n_shards, n_per_shard)
            cnt = cnt.reshape(n_shards, n_per_shard)
            pay = (pay.reshape(n_shards, n_per_shard)
                   if pay is not None else None)
        return table, cnt, pay

    return relax
