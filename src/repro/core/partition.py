"""Vertex partitioners: map a Graph onto S compute cells.

The paper's "logical locality" (Strategy 2) says graph topology, not address
adjacency, is the locality that matters.  The ``locality`` partitioner
approximates it with a BFS traversal order so that topologically close
vertices land on the same cell, minimizing cross-cell operon traffic; the
``hash`` partitioner is the adversarial baseline (no locality); ``block``
keeps the generator's vertex order.

The build path is sized for graph500 s18-s20 inputs (DESIGN.md §2.10):
everything is vectorized numpy (no per-vertex or per-shard Python loops),
cells are cut by a degree-aware capacity budget so the per-cell edge
capacity tracks the *mean* cell load instead of the skew tail, and edges are
placed in ``(owner, dst_key)`` order by ONE stable sort — which makes the
placed slot order itself the destination-sorted pull-CSR stream, so both
blocked-CSR views are assembled directly on the host without any device
argsort.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from .graph import Graph, ShardedGraph, default_delta_blocks, DEFAULT_EDGE_BLOCK
from .rhizome import member_rank, replica_counts, resolve_replica_threshold

__all__ = ["partition", "Partitioned", "ReplicaInfo"]

# Above this vertex count ``strategy="locality"`` falls back to ``block``:
# the BFS order no longer pays for itself at that scale (and the generator
# families we run there are label-permuted RMAT, where BFS locality is weak).
LOCALITY_FALLBACK_NODES = 1 << 20

# Equal-vertex chunking is kept (it preserves the strategy's neighborhood
# contiguity) until its max-cell edge count exceeds this multiple of the
# mean — past that, the skew tail would dominate the per-cell capacity, so
# the cut switches to the degree-aware budget (DESIGN.md §2.10).
CAPACITY_SKEW_THRESHOLD = 1.75


class ReplicaInfo(NamedTuple):
    """Host-side view of the hub-replica split (DESIGN.md §2.12), consumed
    by the NameServer and the update pipeline to route edges of split hubs
    with the same :func:`~.rhizome.member_rank` hash the build used."""

    hub_gid: np.ndarray     # [G] int32 — split vertex ids
    members_s: np.ndarray   # [G, Rmax] int32 member cell, -1 pad
    members_l: np.ndarray   # [G, Rmax] int32 member local slot, -1 pad
    n_members: np.ndarray   # [G] int32 live member count per hub
    group_of: np.ndarray    # [n] int32 gid -> group index, -1 unsplit


class Partitioned:
    """ShardedGraph plus the global<->local maps needed to move data in/out."""

    def __init__(
        self, sg: ShardedGraph, owner: np.ndarray, local: np.ndarray,
        n_real: int | None = None, replica: ReplicaInfo | None = None,
    ):
        self.sg = sg
        self.owner = jnp.asarray(owner)   # [n_nodes] int32
        self.local = jnp.asarray(local)   # [n_nodes] int32
        # original (pre-slack) vertex count; capacity slots come after
        self.n_real = int(n_real) if n_real is not None else int(owner.shape[0])
        self.replica = replica

    def to_shard_layout(self, values, fill):
        """[n_nodes] global array -> [S, Np] shard layout."""
        out = jnp.full(
            (self.sg.n_shards, self.sg.n_per_shard), fill, jnp.asarray(values).dtype
        )
        return out.at[self.owner, self.local].set(values)

    def to_global_layout(self, values):
        """[S, Np] shard layout -> [n_nodes] global array."""
        return values[self.owner, self.local]


def _bfs_order(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """BFS traversal order over all components (host side, vectorized).

    Level-synchronous: each whole frontier's neighbor lists are gathered in
    one repeat/advanced-index pass and deduplicated with ``np.unique``, so
    the Python-level work is O(diameter) per component instead of
    O(vertices + edges).
    """
    order = np.argsort(src, kind="stable")
    s_sorted, d_sorted = src[order], dst[order]
    starts = np.searchsorted(s_sorted, np.arange(n))
    ends = np.searchsorted(s_sorted, np.arange(n) + 1)
    visited = np.zeros(n, bool)
    out = np.empty(n, np.int64)
    k = 0
    root = 0
    while k < n:
        while root < n and visited[root]:  # amortized O(n) root scan
            root += 1
        visited[root] = True
        frontier = np.array([root], np.int64)
        while frontier.size:
            out[k:k + frontier.size] = frontier
            k += frontier.size
            cnt = ends[frontier] - starts[frontier]
            total = int(cnt.sum())
            if not total:
                break
            # gather the concatenated neighbor lists of the whole frontier
            offs = np.cumsum(cnt) - cnt
            idx = np.repeat(starts[frontier] - offs, cnt) + np.arange(total)
            nbrs = d_sorted[idx]
            nbrs = nbrs[~visited[nbrs]]
            # first-occurrence dedup in discovery order: with a FIFO
            # queue the traversal is exactly level-synchronous, so this
            # reproduces the sequential BFS order bit for bit
            _, first = np.unique(nbrs, return_index=True)
            nbrs = nbrs[np.sort(first)]
            visited[nbrs] = True
            frontier = nbrs
    return out


def _degree_aware_cut(live_deg_sorted: np.ndarray, n_shards: int):
    """Cut an ordered vertex sequence into ``n_shards`` contiguous chunks
    balanced by cost = out_degree + t (t = mean live degree, min 1), so a
    cell's edge count tracks the budget instead of the skew tail while its
    vertex count stays within ~2x of even.  Returns the per-rank cell id.
    """
    n_live = live_deg_sorted.shape[0]
    if n_live == 0:
        return np.empty(0, np.int64)
    t = max(1, int(live_deg_sorted.sum()) // n_live)
    cost = live_deg_sorted.astype(np.int64) + t
    prefix = np.cumsum(cost) - cost            # exclusive prefix sum
    budget = -(-int(cost.sum()) // n_shards)
    return np.minimum(prefix // budget, n_shards - 1)


def partition(
    graph: Graph,
    n_shards: int,
    strategy: str = "block",
    seed: int = 0,
    replica_threshold: int | str | None = None,
) -> Partitioned:
    """Partition ``graph`` over ``n_shards`` compute cells.

    strategy: 'block' | 'hash' | 'locality'

    ``replica_threshold`` enables skew-aware hub splitting ("rhizomes",
    DESIGN.md §2.12): every live vertex whose total live degree exceeds
    the threshold (``"auto"`` = an eighth of the mean per-cell edge load,
    min one CSR block) is split into R = ceil(degree / threshold) member
    slots on distinct cells.  Its out-edges are *stored* across members
    and its in-edges *retargeted* across members via the deterministic
    :func:`~.rhizome.member_rank` hash, so no single cell's edge stream
    carries the skew tail; the diffusion engines keep the members
    state-mirrored by merging their partials through the program's
    monoid once per round (core/diffuse.py).  ``None`` (default) keeps
    the unsplit layout.
    """
    n = graph.n_nodes
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.weight)
    eok = np.asarray(graph.edge_ok)
    nok = np.asarray(graph.node_ok)

    # Order *live* vertices by the chosen strategy; spread free capacity
    # slots evenly over the cells so dynamic vertex_add works everywhere.
    live = np.where(nok)[0]
    n_live = live.shape[0]
    if strategy == "locality" and n > LOCALITY_FALLBACK_NODES:
        strategy = "block"
    if strategy == "block":
        live_sorted = live
    elif strategy == "hash":
        rng = np.random.default_rng(seed)
        live_sorted = live[rng.permutation(n_live)]
    elif strategy == "locality":
        order = _bfs_order(src[eok], dst[eok], n)
        pos = np.empty(n, np.int64)
        pos[order] = np.arange(n)
        live_sorted = live[np.argsort(pos[live], kind="stable")]
    else:  # pragma: no cover
        raise ValueError(f"unknown strategy {strategy!r}")

    # Contiguous chunking of the ordered live vertices.  Equal-vertex
    # chunks by default (old behavior: preserves neighborhood contiguity
    # exactly); when that concentrates the hub tail into one cell past
    # CAPACITY_SKEW_THRESHOLD x the mean edge load, switch to the
    # degree-aware budget so capacity tracks live edges instead of skew.
    live_deg = np.bincount(src[eok], minlength=n)
    # Hub split policy (rhizomes): R members per vertex, decided on total
    # live degree (out-edges drive the storage load, in-edges the combine
    # runs; both are distributed across members below).
    thr = resolve_replica_threshold(replica_threshold, int(eok.sum()),
                                    n_shards, DEFAULT_EDGE_BLOCK)
    if thr is not None:
        in_deg = np.bincount(dst[eok], minlength=n)
        n_members = np.where(
            nok[:n], replica_counts(live_deg + in_deg, thr, n_shards), 1
        ).astype(np.int32)
        # the cut budgets on *post-split* storage degree: a split hub's
        # primary cell keeps only ~1/R of its out-edges
        deg_for_cut = live_deg // np.maximum(n_members, 1)
    else:
        n_members = None
        deg_for_cut = live_deg
    deg_ranked = deg_for_cut[live_sorted]
    q = max(1, -(-n_live // n_shards))
    eq_cells = np.minimum(np.arange(n_live) // q, n_shards - 1)
    eq_load = np.bincount(eq_cells, weights=deg_ranked, minlength=n_shards)
    mean_load = max(1.0, float(deg_ranked.sum()) / n_shards)
    eq_skewed = eq_load.max(initial=0.0) > CAPACITY_SKEW_THRESHOLD * mean_load
    if thr is not None and not eq_skewed and not (n_members > 1).any():
        # nothing crosses the threshold AND the equal-chunk layout is
        # already edge-balanced (flat degree distribution): the strided
        # dealing below would sacrifice neighborhood contiguity for a
        # balance the graph already has, so fall back to the unsplit
        # layout — replicas on == off by construction.  With a skewed
        # tail the dealing stays on even when nothing splits: spreading
        # the (sub-threshold) heavy vertices is most of the win at small
        # cell counts, where per-cell capacity dwarfs any single degree.
        thr = None
        n_members = None
    if thr is not None:
        # splitting caps every vertex's post-split degree near thr, so the
        # equal-chunk ratio check no longer trips — yet a chunk dense with
        # capped hubs (power-law hubs cluster at low gids) still carries
        # several times the mean.  A replica_threshold is an explicit ask
        # for edge balance: deal vertices over cells in degree order,
        # boustrophedon so the within-stride spread cancels.  Vertex
        # counts come out exactly even (Np == ceil(n_live/S) — the engine
        # cost has an S^2*Np exchange-table term, so ragged chunks are
        # pure overhead) and each cell's edge sum is a snake-strided
        # sample of the sorted (split-capped) degree sequence, uniform to
        # within one capped degree.  Neighborhood contiguity is
        # sacrificed — cross-cell traffic rides the dense exchange whose
        # cost is shape-driven, so remote fraction is free here.
        deg_order = np.argsort(-deg_ranked, kind="stable")
        pos = np.arange(n_live)
        blk, off = pos // n_shards, pos % n_shards
        snake = np.where(blk % 2 == 0, off, n_shards - 1 - off)
        cell_strided = np.empty(n_live, np.int64)
        cell_strided[deg_order] = snake
        # re-pack the rank order to contiguous cell chunks: the slot math
        # below (local = rank - cell start) assumes a sorted cell_of_rank
        repack = np.argsort(cell_strided, kind="stable")
        live_sorted = live_sorted[repack]
        deg_ranked = deg_ranked[repack]
        cell_of_rank = cell_strided[repack]
    elif eq_skewed:
        cell_of_rank = _degree_aware_cut(deg_ranked, n_shards)
    else:
        cell_of_rank = eq_cells
    cell_counts = np.bincount(cell_of_rank, minlength=n_shards)
    starts = np.concatenate([[0], np.cumsum(cell_counts)])[:-1]
    owner = np.zeros(n, np.int32)
    local = np.zeros(n, np.int32)
    r = np.arange(n_live)
    owner[live_sorted] = cell_of_rank.astype(np.int32)
    local[live_sorted] = (r - starts[cell_of_rank]).astype(np.int32)

    # Replica members for split hubs: member 0 is the primary slot placed
    # above; members 1..R-1 go greedily to the least-edge-loaded cell not
    # already hosting a member of the same group (heaviest hubs first, so
    # the big shares seed the balance).  Hashed or round-robin offsets
    # would pile correlated shares onto the same cells — power-law hubs
    # cluster at low gids, and with ~2 replicas per cell a binomial
    # pileup of threshold-sized shares re-creates the very skew the
    # split removes.  The greedy pass is a host loop over replicas only
    # (not vertices or edges) and partition() is not engine-hot.  Locals
    # append after each cell's live run via vectorized grouped ranking.
    hubs = (np.where(n_members > 1)[0] if n_members is not None
            else np.empty(0, np.int64))
    G = hubs.shape[0]
    rep_counts = np.zeros(n_shards, np.int64)
    if G:
        R_h = n_members[hubs].astype(np.int64)
        Rmax = int(R_h.max())
        n_rep = int((R_h - 1).sum())
        heavy = np.argsort(-live_deg[hubs], kind="stable")  # groups, desc
        est = np.bincount(owner, weights=deg_for_cut,
                          minlength=n_shards).astype(np.float64)
        gg = np.empty(n_rep, np.int64)                 # group per replica
        kk = np.empty(n_rep, np.int64)                 # member index 1..R-1
        rep_cell = np.empty(n_rep, np.int64)
        slot_of = np.concatenate([[0], np.cumsum(R_h - 1)])
        blocked = np.zeros(n_shards, np.float64)
        for g in heavy:
            share = float(live_deg[hubs[g]]) / float(R_h[g])
            blocked[:] = 0.0
            blocked[owner[hubs[g]]] = np.inf           # primary's cell
            for k in range(1, int(R_h[g])):
                c = int(np.argmin(est + blocked))
                j = slot_of[g] + k - 1
                gg[j], kk[j], rep_cell[j] = g, k, c
                est[c] += share
                blocked[c] = np.inf                    # distinct cells
        rep_counts = np.bincount(rep_cell, minlength=n_shards)
        order_r = np.argsort(rep_cell, kind="stable")
        rep_starts = np.concatenate([[0], np.cumsum(rep_counts)])[:-1]
        within_r = np.arange(n_rep) - rep_starts[rep_cell[order_r]]
        rep_local = np.empty(n_rep, np.int64)
        rep_local[order_r] = cell_counts[rep_cell[order_r]] + within_r

    n_per = max(int((cell_counts + rep_counts).max(initial=0)),
                -(-(n + int(rep_counts.sum())) // n_shards))
    # free (dead) slots fill the remaining (shard, local) positions in
    # row-major order — pure scatter, no Python loop over dead vertices
    dead = np.where(~nok)[0]
    if dead.size:
        free_per_cell = n_per - cell_counts - rep_counts
        cumfree = np.cumsum(free_per_cell)
        k = np.arange(dead.size)
        cell = np.searchsorted(cumfree, k, side="right")
        within = k - (cumfree[cell] - free_per_cell[cell])
        owner[dead] = cell.astype(np.int32)
        local[dead] = (cell_counts[cell] + rep_counts[cell]
                       + within).astype(np.int32)

    # Member tables + routing maps (host side, shared with the update
    # pipeline through Partitioned.replica / NameServer).
    replica = None
    if G:
        members_s = np.full((G, Rmax), -1, np.int32)
        members_l = np.full((G, Rmax), -1, np.int32)
        members_s[:, 0] = owner[hubs]
        members_l[:, 0] = local[hubs]
        members_s[gg, kk] = rep_cell.astype(np.int32)
        members_l[gg, kk] = rep_local.astype(np.int32)
        group_of = np.full(n, -1, np.int32)
        group_of[hubs] = np.arange(G, dtype=np.int32)
        replica = ReplicaInfo(hub_gid=hubs.astype(np.int32),
                              members_s=members_s, members_l=members_l,
                              n_members=n_members[hubs].astype(np.int32),
                              group_of=group_of)

    # Live edges, sorted ONCE by (owner cell, destination key): contiguous
    # runs per cell, already in pull-CSR order — slot order IS stream order.
    # The pair is packed into one int64 so a single radix-free argsort
    # replaces lexsort's two stable passes; ties (parallel edges to one
    # destination in one cell) may land in any order — every view below
    # and the with_csr() rebuild tie-break on the slot order this sort
    # *defines*, so any deterministic order is self-consistent.
    e_idx = np.where(eok)[0]
    e_src, e_dst, e_w = src[e_idx], dst[e_idx], w[e_idx]
    if replica is not None:
        # Storage member of a split source and target member of a split
        # destination, both via the shared rank-hash — the update
        # pipeline routes dynamic adds/deletes identically, which is
        # what keeps incremental == rebuild bitwise on split graphs.
        gu = replica.group_of[e_src]
        mu = member_rank(e_src, e_dst, n_members[e_src])
        e_owner = np.where(gu >= 0,
                           replica.members_s[np.clip(gu, 0, None), mu],
                           owner[e_src]).astype(np.int32)
        e_sl = np.where(gu >= 0,
                        replica.members_l[np.clip(gu, 0, None), mu],
                        local[e_src]).astype(np.int32)
        gv = replica.group_of[e_dst]
        mv = member_rank(e_dst, e_src, n_members[e_dst])
        e_do = np.where(gv >= 0,
                        replica.members_s[np.clip(gv, 0, None), mv],
                        owner[e_dst]).astype(np.int32)
        e_dl = np.where(gv >= 0,
                        replica.members_l[np.clip(gv, 0, None), mv],
                        local[e_dst]).astype(np.int32)
    else:
        e_owner, e_sl = owner[e_src], local[e_src]
        e_do, e_dl = owner[e_dst], local[e_dst]
    e_key = e_do.astype(np.int64) * n_per + e_dl
    order = np.argsort(
        e_owner * (np.int64(n_shards) * n_per) + e_key)
    e_src, e_dst, e_w = e_src[order], e_dst[order], e_w[order]
    e_owner, e_key = e_owner[order], e_key[order]
    e_sl, e_do, e_dl = e_sl[order], e_do[order], e_dl[order]
    counts = np.bincount(e_owner, minlength=n_shards)

    # Degree-aware capacity on the block ladder: the balanced cut keeps
    # counts.max() near the mean, so capacity tracks live edges, not the
    # old global-max padding; slack spreads evenly for dynamic edge_add.
    slack_total = int(eok.shape[0] - eok.sum())
    block = DEFAULT_EDGE_BLOCK
    epc = max(1, int(counts.max(initial=0)) + -(-slack_total // n_shards))
    ep = -(-epc // block) * block    # sorted_width == ep: no view re-pad

    S = n_shards
    src_local = np.zeros((S, ep), np.int32)
    dst_shard = np.zeros((S, ep), np.int32)
    dst_local = np.zeros((S, ep), np.int32)
    dst_gid = np.zeros((S, ep), np.int32)
    weight = np.zeros((S, ep), np.float32)
    edge_ok = np.zeros((S, ep), bool)

    # per-cell runs are contiguous after the sort, so assembly is S
    # sequential slice copies (memcpy-speed), not element scatters
    e_offsets = np.concatenate([[0], np.cumsum(counts)])
    sl = e_sl
    do_, dl = e_do, e_dl
    for s in range(S):
        lo, hi = e_offsets[s], e_offsets[s + 1]
        k = hi - lo
        src_local[s, :k] = sl[lo:hi]
        dst_shard[s, :k] = do_[lo:hi]
        dst_local[s, :k] = dl[lo:hi]
        dst_gid[s, :k] = e_dst[lo:hi]
        weight[s, :k] = e_w[lo:hi]
        edge_ok[s, :k] = True

    node_ok = np.zeros((S, n_per), bool)
    gid = np.zeros((S, n_per), np.int32)
    node_ok[owner, local] = nok[:n]
    gid[owner, local] = np.arange(n, dtype=np.int32)

    if replica is not None:
        # replica slots are live mirrors carrying the hub's gid; per-slot
        # out_degree is each member's stored share (bincount of routed
        # edges), so the push sweep's frontier-edge estimate stays honest
        node_ok[rep_cell, rep_local] = True
        gid[rep_cell, rep_local] = hubs[gg].astype(np.int32)
        deg = np.bincount(
            e_owner.astype(np.int64) * n_per + e_sl, minlength=S * n_per
        ).reshape(S, n_per).astype(np.int32)
        replica_of = np.full((S, n_per), -1, np.int32)
        replica_of[rep_cell, rep_local] = hubs[gg].astype(np.int32)
        replica_group = np.full((S, n_per), -1, np.int32)
        valid_m = replica.members_s >= 0
        replica_group[replica.members_s[valid_m],
                      replica.members_l[valid_m]] = np.broadcast_to(
            np.arange(G, dtype=np.int32)[:, None],
            valid_m.shape)[valid_m]
        replica_members = np.where(
            valid_m,
            replica.members_s.astype(np.int64) * n_per + replica.members_l,
            -1).astype(np.int32)
    else:
        deg = np.zeros((S, n_per), np.int32)
        deg[owner, local] = live_deg[:n]
        replica_of = replica_group = replica_members = None

    # Both blocked-CSR views assembled host-side, bitwise-identical to a
    # with_csr() rebuild: slots are placed in destination-key order, so the
    # pull view's sorted region is the identity permutation; the push view
    # is the one remaining stable sort (by source local index).
    delta_blocks = default_delta_blocks(ep, block)
    dw = delta_blocks * block
    width = ep + dw
    csr_perm = np.zeros((S, width), np.int32)
    csr_perm[:, :ep] = np.arange(ep, dtype=np.int32)
    csr_key = np.full((S, width), -1, np.int32)
    ek32 = e_key.astype(np.int32)
    for s in range(S):
        lo, hi = e_offsets[s], e_offsets[s + 1]
        csr_key[s, : hi - lo] = ek32[lo:hi]
    csr_inv = np.broadcast_to(np.arange(ep, dtype=np.int32), (S, ep)).copy()

    pkey = np.where(edge_ok, src_local, n_per)
    # (src, slot) composite is collision-free, so the default sort equals
    # a stable argsort of pkey bit for bit at ~half the cost
    pcomp = pkey.astype(np.int64) * ep + np.arange(ep, dtype=np.int64)
    pperm = np.argsort(pcomp, axis=1).astype(np.int32)
    psrc = np.take_along_axis(pkey, pperm, axis=1).astype(np.int32)
    psrc[psrc >= n_per] = -1
    ppos = np.where(psrc >= 0, pperm, -1)     # dense position == slot here
    pinv = np.zeros((S, ep), np.int32)
    np.put_along_axis(pinv, pperm, np.broadcast_to(
        np.arange(ep, dtype=np.int32), (S, ep)), axis=1)
    push_perm = np.zeros((S, width), np.int32)
    push_perm[:, :ep] = pperm
    push_src = np.full((S, width), -1, np.int32)
    push_src[:, :ep] = psrc
    push_pos = np.full((S, width), -1, np.int32)
    push_pos[:, :ep] = ppos

    sg = ShardedGraph(
        src_local=jnp.asarray(src_local),
        dst_shard=jnp.asarray(dst_shard),
        dst_local=jnp.asarray(dst_local),
        dst_gid=jnp.asarray(dst_gid),
        weight=jnp.asarray(weight),
        edge_ok=jnp.asarray(edge_ok),
        node_ok=jnp.asarray(node_ok),
        gid=jnp.asarray(gid),
        out_degree=jnp.asarray(deg),
        n_shards=S,
        n_per_shard=n_per,
        n_nodes=n,
        csr_perm=jnp.asarray(csr_perm),
        csr_key=jnp.asarray(csr_key),
        csr_live=jnp.asarray(csr_key >= 0),
        csr_inv=jnp.asarray(csr_inv),
        push_perm=jnp.asarray(push_perm),
        push_src=jnp.asarray(push_src),
        push_pos=jnp.asarray(push_pos),
        push_inv=jnp.asarray(pinv),
        delta_count=jnp.zeros((S,), jnp.int32),
        tomb_count=jnp.zeros((S,), jnp.int32),
        replica_of=(jnp.asarray(replica_of)
                    if replica_of is not None else None),
        replica_group=(jnp.asarray(replica_group)
                       if replica_group is not None else None),
        replica_members=(jnp.asarray(replica_members)
                         if replica_members is not None else None),
        csr_block=block,
        delta_blocks=delta_blocks,
    )
    return Partitioned(sg, owner, local, n_real=int(nok.sum()),
                       replica=replica)
