"""Vertex partitioners: map a Graph onto S compute cells.

The paper's "logical locality" (Strategy 2) says graph topology, not address
adjacency, is the locality that matters.  The ``locality`` partitioner
approximates it with a BFS traversal order so that topologically close
vertices land on the same cell, minimizing cross-cell operon traffic; the
``hash`` partitioner is the adversarial baseline (no locality); ``block``
keeps the generator's vertex order.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .graph import Graph, ShardedGraph

__all__ = ["partition", "Partitioned"]


class Partitioned:
    """ShardedGraph plus the global<->local maps needed to move data in/out."""

    def __init__(
        self, sg: ShardedGraph, owner: np.ndarray, local: np.ndarray,
        n_real: int | None = None,
    ):
        self.sg = sg
        self.owner = jnp.asarray(owner)   # [n_nodes] int32
        self.local = jnp.asarray(local)   # [n_nodes] int32
        # original (pre-slack) vertex count; capacity slots come after
        self.n_real = int(n_real) if n_real is not None else int(owner.shape[0])

    def to_shard_layout(self, values, fill):
        """[n_nodes] global array -> [S, Np] shard layout."""
        out = jnp.full(
            (self.sg.n_shards, self.sg.n_per_shard), fill, jnp.asarray(values).dtype
        )
        return out.at[self.owner, self.local].set(values)

    def to_global_layout(self, values):
        """[S, Np] shard layout -> [n_nodes] global array."""
        return values[self.owner, self.local]


def _bfs_order(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """BFS traversal order over all components (host side)."""
    order = np.argsort(src, kind="stable")
    s_sorted, d_sorted = src[order], dst[order]
    starts = np.searchsorted(s_sorted, np.arange(n))
    ends = np.searchsorted(s_sorted, np.arange(n) + 1)
    visited = np.zeros(n, bool)
    out = np.empty(n, np.int64)
    k = 0
    from collections import deque

    for root in range(n):
        if visited[root]:
            continue
        visited[root] = True
        q = deque([root])
        while q:
            v = q.popleft()
            out[k] = v
            k += 1
            for e in range(starts[v], ends[v]):
                u = d_sorted[e]
                if not visited[u]:
                    visited[u] = True
                    q.append(u)
    return out


def partition(
    graph: Graph,
    n_shards: int,
    strategy: str = "block",
    seed: int = 0,
) -> Partitioned:
    """Partition ``graph`` over ``n_shards`` compute cells.

    strategy: 'block' | 'hash' | 'locality'
    """
    n = graph.n_nodes
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.weight)
    eok = np.asarray(graph.edge_ok)
    nok = np.asarray(graph.node_ok)

    # Order *live* vertices by the chosen strategy; spread free capacity
    # slots evenly over the cells so dynamic vertex_add works everywhere.
    live = np.where(nok)[0]
    n_live = live.shape[0]
    if strategy == "block":
        live_sorted = live
    elif strategy == "hash":
        rng = np.random.default_rng(seed)
        live_sorted = live[rng.permutation(n_live)]
    elif strategy == "locality":
        order = _bfs_order(src[eok], dst[eok], n)
        pos = np.empty(n, np.int64)
        pos[order] = np.arange(n)
        live_sorted = live[np.argsort(pos[live], kind="stable")]
    else:  # pragma: no cover
        raise ValueError(f"unknown strategy {strategy!r}")

    q = -(-n_live // n_shards)            # live vertices per cell (ceil)
    n_per = max(q, -(-n // n_shards))     # room for the spread free slots
    owner = np.zeros(n, np.int32)
    local = np.zeros(n, np.int32)
    r = np.arange(n_live)
    owner[live_sorted] = (r // q).astype(np.int32)
    local[live_sorted] = (r % q).astype(np.int32)
    # free (dead) slots fill the remaining (shard, local) positions
    taken = np.zeros((n_shards, n_per), bool)
    taken[owner[live_sorted], local[live_sorted]] = True
    free_pos = np.argwhere(~taken)
    dead = np.where(~nok)[0]
    for k, v in enumerate(dead):
        owner[v], local[v] = free_pos[k % len(free_pos)]

    # Live edges only; pad per shard below.
    e_src, e_dst, e_w = src[eok], dst[eok], w[eok]
    e_owner = owner[e_src]
    order = np.argsort(e_owner, kind="stable")
    e_src, e_dst, e_w, e_owner = (
        e_src[order],
        e_dst[order],
        e_w[order],
        e_owner[order],
    )
    counts = np.bincount(e_owner, minlength=n_shards)
    # distribute free (slack) edge capacity evenly over the cells so
    # dynamic edge_add works on every cell
    slack_total = int(eok.shape[0] - eok.sum())
    ep = max(1, int(counts.max()) + -(-slack_total // n_shards))

    S = n_shards
    src_local = np.zeros((S, ep), np.int32)
    dst_shard = np.zeros((S, ep), np.int32)
    dst_local = np.zeros((S, ep), np.int32)
    dst_gid = np.zeros((S, ep), np.int32)
    weight = np.zeros((S, ep), np.float32)
    edge_ok = np.zeros((S, ep), bool)

    offsets = np.concatenate([[0], np.cumsum(counts)])
    for s in range(S):
        lo, hi = offsets[s], offsets[s + 1]
        k = hi - lo
        src_local[s, :k] = local[e_src[lo:hi]]
        dst_shard[s, :k] = owner[e_dst[lo:hi]]
        dst_local[s, :k] = local[e_dst[lo:hi]]
        dst_gid[s, :k] = e_dst[lo:hi]
        weight[s, :k] = e_w[lo:hi]
        edge_ok[s, :k] = True

    node_ok = np.zeros((S, n_per), bool)
    gid = np.zeros((S, n_per), np.int32)
    node_ok[owner, local] = nok[:n]
    gid[owner, local] = np.arange(n, dtype=np.int32)

    deg = np.zeros((S, n_per), np.int32)
    live_deg = np.bincount(e_src, minlength=n)
    deg[owner, local] = live_deg[:n]

    sg = ShardedGraph(
        src_local=jnp.asarray(src_local),
        dst_shard=jnp.asarray(dst_shard),
        dst_local=jnp.asarray(dst_local),
        dst_gid=jnp.asarray(dst_gid),
        weight=jnp.asarray(weight),
        edge_ok=jnp.asarray(edge_ok),
        node_ok=jnp.asarray(node_ok),
        gid=jnp.asarray(gid),
        out_degree=jnp.asarray(deg),
        n_shards=S,
        n_per_shard=n_per,
        n_nodes=n,
    ).with_csr()    # blocked-CSR view built once here; updates refresh it
    return Partitioned(sg, owner, local, n_real=int(nok.sum()))
