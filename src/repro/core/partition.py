"""Vertex partitioners: map a Graph onto S compute cells.

The paper's "logical locality" (Strategy 2) says graph topology, not address
adjacency, is the locality that matters.  The ``locality`` partitioner
approximates it with a BFS traversal order so that topologically close
vertices land on the same cell, minimizing cross-cell operon traffic; the
``hash`` partitioner is the adversarial baseline (no locality); ``block``
keeps the generator's vertex order.

The build path is sized for graph500 s18-s20 inputs (DESIGN.md §2.10):
everything is vectorized numpy (no per-vertex or per-shard Python loops),
cells are cut by a degree-aware capacity budget so the per-cell edge
capacity tracks the *mean* cell load instead of the skew tail, and edges are
placed in ``(owner, dst_key)`` order by ONE stable sort — which makes the
placed slot order itself the destination-sorted pull-CSR stream, so both
blocked-CSR views are assembled directly on the host without any device
argsort.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .graph import Graph, ShardedGraph, default_delta_blocks, DEFAULT_EDGE_BLOCK

__all__ = ["partition", "Partitioned"]

# Above this vertex count ``strategy="locality"`` falls back to ``block``:
# the BFS order no longer pays for itself at that scale (and the generator
# families we run there are label-permuted RMAT, where BFS locality is weak).
LOCALITY_FALLBACK_NODES = 1 << 20

# Equal-vertex chunking is kept (it preserves the strategy's neighborhood
# contiguity) until its max-cell edge count exceeds this multiple of the
# mean — past that, the skew tail would dominate the per-cell capacity, so
# the cut switches to the degree-aware budget (DESIGN.md §2.10).
CAPACITY_SKEW_THRESHOLD = 1.75


class Partitioned:
    """ShardedGraph plus the global<->local maps needed to move data in/out."""

    def __init__(
        self, sg: ShardedGraph, owner: np.ndarray, local: np.ndarray,
        n_real: int | None = None,
    ):
        self.sg = sg
        self.owner = jnp.asarray(owner)   # [n_nodes] int32
        self.local = jnp.asarray(local)   # [n_nodes] int32
        # original (pre-slack) vertex count; capacity slots come after
        self.n_real = int(n_real) if n_real is not None else int(owner.shape[0])

    def to_shard_layout(self, values, fill):
        """[n_nodes] global array -> [S, Np] shard layout."""
        out = jnp.full(
            (self.sg.n_shards, self.sg.n_per_shard), fill, jnp.asarray(values).dtype
        )
        return out.at[self.owner, self.local].set(values)

    def to_global_layout(self, values):
        """[S, Np] shard layout -> [n_nodes] global array."""
        return values[self.owner, self.local]


def _bfs_order(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """BFS traversal order over all components (host side, vectorized).

    Level-synchronous: each whole frontier's neighbor lists are gathered in
    one repeat/advanced-index pass and deduplicated with ``np.unique``, so
    the Python-level work is O(diameter) per component instead of
    O(vertices + edges).
    """
    order = np.argsort(src, kind="stable")
    s_sorted, d_sorted = src[order], dst[order]
    starts = np.searchsorted(s_sorted, np.arange(n))
    ends = np.searchsorted(s_sorted, np.arange(n) + 1)
    visited = np.zeros(n, bool)
    out = np.empty(n, np.int64)
    k = 0
    root = 0
    while k < n:
        while root < n and visited[root]:  # amortized O(n) root scan
            root += 1
        visited[root] = True
        frontier = np.array([root], np.int64)
        while frontier.size:
            out[k:k + frontier.size] = frontier
            k += frontier.size
            cnt = ends[frontier] - starts[frontier]
            total = int(cnt.sum())
            if not total:
                break
            # gather the concatenated neighbor lists of the whole frontier
            offs = np.cumsum(cnt) - cnt
            idx = np.repeat(starts[frontier] - offs, cnt) + np.arange(total)
            nbrs = d_sorted[idx]
            nbrs = nbrs[~visited[nbrs]]
            # first-occurrence dedup in discovery order: with a FIFO
            # queue the traversal is exactly level-synchronous, so this
            # reproduces the sequential BFS order bit for bit
            _, first = np.unique(nbrs, return_index=True)
            nbrs = nbrs[np.sort(first)]
            visited[nbrs] = True
            frontier = nbrs
    return out


def _degree_aware_cut(live_deg_sorted: np.ndarray, n_shards: int):
    """Cut an ordered vertex sequence into ``n_shards`` contiguous chunks
    balanced by cost = out_degree + t (t = mean live degree, min 1), so a
    cell's edge count tracks the budget instead of the skew tail while its
    vertex count stays within ~2x of even.  Returns the per-rank cell id.
    """
    n_live = live_deg_sorted.shape[0]
    if n_live == 0:
        return np.empty(0, np.int64)
    t = max(1, int(live_deg_sorted.sum()) // n_live)
    cost = live_deg_sorted.astype(np.int64) + t
    prefix = np.cumsum(cost) - cost            # exclusive prefix sum
    budget = -(-int(cost.sum()) // n_shards)
    return np.minimum(prefix // budget, n_shards - 1)


def partition(
    graph: Graph,
    n_shards: int,
    strategy: str = "block",
    seed: int = 0,
) -> Partitioned:
    """Partition ``graph`` over ``n_shards`` compute cells.

    strategy: 'block' | 'hash' | 'locality'
    """
    n = graph.n_nodes
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.weight)
    eok = np.asarray(graph.edge_ok)
    nok = np.asarray(graph.node_ok)

    # Order *live* vertices by the chosen strategy; spread free capacity
    # slots evenly over the cells so dynamic vertex_add works everywhere.
    live = np.where(nok)[0]
    n_live = live.shape[0]
    if strategy == "locality" and n > LOCALITY_FALLBACK_NODES:
        strategy = "block"
    if strategy == "block":
        live_sorted = live
    elif strategy == "hash":
        rng = np.random.default_rng(seed)
        live_sorted = live[rng.permutation(n_live)]
    elif strategy == "locality":
        order = _bfs_order(src[eok], dst[eok], n)
        pos = np.empty(n, np.int64)
        pos[order] = np.arange(n)
        live_sorted = live[np.argsort(pos[live], kind="stable")]
    else:  # pragma: no cover
        raise ValueError(f"unknown strategy {strategy!r}")

    # Contiguous chunking of the ordered live vertices.  Equal-vertex
    # chunks by default (old behavior: preserves neighborhood contiguity
    # exactly); when that concentrates the hub tail into one cell past
    # CAPACITY_SKEW_THRESHOLD x the mean edge load, switch to the
    # degree-aware budget so capacity tracks live edges instead of skew.
    live_deg = np.bincount(src[eok], minlength=n)
    deg_ranked = live_deg[live_sorted]
    q = max(1, -(-n_live // n_shards))
    eq_cells = np.minimum(np.arange(n_live) // q, n_shards - 1)
    eq_load = np.bincount(eq_cells, weights=deg_ranked, minlength=n_shards)
    mean_load = max(1.0, float(deg_ranked.sum()) / n_shards)
    if eq_load.max(initial=0.0) > CAPACITY_SKEW_THRESHOLD * mean_load:
        cell_of_rank = _degree_aware_cut(deg_ranked, n_shards)
    else:
        cell_of_rank = eq_cells
    cell_counts = np.bincount(cell_of_rank, minlength=n_shards)
    starts = np.concatenate([[0], np.cumsum(cell_counts)])[:-1]
    n_per = max(int(cell_counts.max(initial=0)), -(-n // n_shards))
    owner = np.zeros(n, np.int32)
    local = np.zeros(n, np.int32)
    r = np.arange(n_live)
    owner[live_sorted] = cell_of_rank.astype(np.int32)
    local[live_sorted] = (r - starts[cell_of_rank]).astype(np.int32)
    # free (dead) slots fill the remaining (shard, local) positions in
    # row-major order — pure scatter, no Python loop over dead vertices
    dead = np.where(~nok)[0]
    if dead.size:
        free_per_cell = n_per - cell_counts
        cumfree = np.cumsum(free_per_cell)
        k = np.arange(dead.size)
        cell = np.searchsorted(cumfree, k, side="right")
        within = k - (cumfree[cell] - free_per_cell[cell])
        owner[dead] = cell.astype(np.int32)
        local[dead] = (cell_counts[cell] + within).astype(np.int32)

    # Live edges, sorted ONCE by (owner cell, destination key): contiguous
    # runs per cell, already in pull-CSR order — slot order IS stream order.
    # The pair is packed into one int64 so a single radix-free argsort
    # replaces lexsort's two stable passes; ties (parallel edges to one
    # destination in one cell) may land in any order — every view below
    # and the with_csr() rebuild tie-break on the slot order this sort
    # *defines*, so any deterministic order is self-consistent.
    e_idx = np.where(eok)[0]
    e_src, e_dst, e_w = src[e_idx], dst[e_idx], w[e_idx]
    e_owner = owner[e_src]
    e_key = owner[e_dst].astype(np.int64) * n_per + local[e_dst]
    order = np.argsort(
        e_owner * (np.int64(n_shards) * n_per) + e_key)
    e_src, e_dst, e_w = e_src[order], e_dst[order], e_w[order]
    e_owner, e_key = e_owner[order], e_key[order]
    counts = np.bincount(e_owner, minlength=n_shards)

    # Degree-aware capacity on the block ladder: the balanced cut keeps
    # counts.max() near the mean, so capacity tracks live edges, not the
    # old global-max padding; slack spreads evenly for dynamic edge_add.
    slack_total = int(eok.shape[0] - eok.sum())
    block = DEFAULT_EDGE_BLOCK
    epc = max(1, int(counts.max(initial=0)) + -(-slack_total // n_shards))
    ep = -(-epc // block) * block    # sorted_width == ep: no view re-pad

    S = n_shards
    src_local = np.zeros((S, ep), np.int32)
    dst_shard = np.zeros((S, ep), np.int32)
    dst_local = np.zeros((S, ep), np.int32)
    dst_gid = np.zeros((S, ep), np.int32)
    weight = np.zeros((S, ep), np.float32)
    edge_ok = np.zeros((S, ep), bool)

    # per-cell runs are contiguous after the sort, so assembly is S
    # sequential slice copies (memcpy-speed), not element scatters
    e_offsets = np.concatenate([[0], np.cumsum(counts)])
    sl = local[e_src]
    do_, dl = owner[e_dst], local[e_dst]
    for s in range(S):
        lo, hi = e_offsets[s], e_offsets[s + 1]
        k = hi - lo
        src_local[s, :k] = sl[lo:hi]
        dst_shard[s, :k] = do_[lo:hi]
        dst_local[s, :k] = dl[lo:hi]
        dst_gid[s, :k] = e_dst[lo:hi]
        weight[s, :k] = e_w[lo:hi]
        edge_ok[s, :k] = True

    node_ok = np.zeros((S, n_per), bool)
    gid = np.zeros((S, n_per), np.int32)
    node_ok[owner, local] = nok[:n]
    gid[owner, local] = np.arange(n, dtype=np.int32)

    deg = np.zeros((S, n_per), np.int32)
    deg[owner, local] = live_deg[:n]

    # Both blocked-CSR views assembled host-side, bitwise-identical to a
    # with_csr() rebuild: slots are placed in destination-key order, so the
    # pull view's sorted region is the identity permutation; the push view
    # is the one remaining stable sort (by source local index).
    delta_blocks = default_delta_blocks(ep, block)
    dw = delta_blocks * block
    width = ep + dw
    csr_perm = np.zeros((S, width), np.int32)
    csr_perm[:, :ep] = np.arange(ep, dtype=np.int32)
    csr_key = np.full((S, width), -1, np.int32)
    ek32 = e_key.astype(np.int32)
    for s in range(S):
        lo, hi = e_offsets[s], e_offsets[s + 1]
        csr_key[s, : hi - lo] = ek32[lo:hi]
    csr_inv = np.broadcast_to(np.arange(ep, dtype=np.int32), (S, ep)).copy()

    pkey = np.where(edge_ok, src_local, n_per)
    # (src, slot) composite is collision-free, so the default sort equals
    # a stable argsort of pkey bit for bit at ~half the cost
    pcomp = pkey.astype(np.int64) * ep + np.arange(ep, dtype=np.int64)
    pperm = np.argsort(pcomp, axis=1).astype(np.int32)
    psrc = np.take_along_axis(pkey, pperm, axis=1).astype(np.int32)
    psrc[psrc >= n_per] = -1
    ppos = np.where(psrc >= 0, pperm, -1)     # dense position == slot here
    pinv = np.zeros((S, ep), np.int32)
    np.put_along_axis(pinv, pperm, np.broadcast_to(
        np.arange(ep, dtype=np.int32), (S, ep)), axis=1)
    push_perm = np.zeros((S, width), np.int32)
    push_perm[:, :ep] = pperm
    push_src = np.full((S, width), -1, np.int32)
    push_src[:, :ep] = psrc
    push_pos = np.full((S, width), -1, np.int32)
    push_pos[:, :ep] = ppos

    sg = ShardedGraph(
        src_local=jnp.asarray(src_local),
        dst_shard=jnp.asarray(dst_shard),
        dst_local=jnp.asarray(dst_local),
        dst_gid=jnp.asarray(dst_gid),
        weight=jnp.asarray(weight),
        edge_ok=jnp.asarray(edge_ok),
        node_ok=jnp.asarray(node_ok),
        gid=jnp.asarray(gid),
        out_degree=jnp.asarray(deg),
        n_shards=S,
        n_per_shard=n_per,
        n_nodes=n,
        csr_perm=jnp.asarray(csr_perm),
        csr_key=jnp.asarray(csr_key),
        csr_live=jnp.asarray(csr_key >= 0),
        csr_inv=jnp.asarray(csr_inv),
        push_perm=jnp.asarray(push_perm),
        push_src=jnp.asarray(push_src),
        push_pos=jnp.asarray(push_pos),
        push_inv=jnp.asarray(pinv),
        delta_count=jnp.zeros((S,), jnp.int32),
        tomb_count=jnp.zeros((S,), jnp.int32),
        csr_block=block,
        delta_blocks=delta_blocks,
    )
    return Partitioned(sg, owner, local, n_real=int(nok.sum()))
