"""Dynamic-graph primitives — the paper's seven graph operations (§VI).

    vertex add | vertex delete | vertex touch
    edge add   | edge delete   | edge touch   | peek

The paper argues these belong in the ISA of a graph machine; here they are
first-class functional ops on :class:`ShardedGraph` with *capacity slots*, so
every update is an O(1) in-place-style ``.at[]`` update that never changes
array shapes (no recompilation — the TPU analogue of "no software overhead").

``NameServer`` plays the paper's hardware name-server role: it allocates
globally unique vertex ids and resolves id -> (owner cell, local slot),
including after migrations.

``incremental_sssp`` composes the primitives into the paper's headline
capability: *dynamic* graph processing — edge inserts re-diffuse from the
endpoints; deletes invalidate the affected shortest-path subtree (via parent
pointers in the global namespace) and re-diffuse from the frontier.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .graph import ShardedGraph
from .partition import Partitioned
from .rhizome import member_rank

__all__ = [
    "NameServer",
    "vertex_add",
    "vertex_delete",
    "vertex_touch",
    "edge_add",
    "edge_delete",
    "edge_touch",
    "peek",
    "incremental_sssp",
]


class NameServer:
    """Global namespace: id allocation + id -> (owner, local) resolution."""

    def __init__(self, part: Partitioned):
        self.owner = np.asarray(part.owner).copy()
        self.local = np.asarray(part.local).copy()
        self._next = int(self.owner.shape[0])
        self.replica = getattr(part, "replica", None)
        self._free_local = {
            s: list(range(part.sg.n_per_shard - 1, -1, -1))
            for s in range(part.sg.n_shards)
        }
        # slots already taken; non-primary replica member slots are
        # permanently reserved for their hub's mirrors — a hub delete
        # frees only the primary (release() resolves to member 0), so
        # they must never enter the free lists even when node_ok is off
        taken = np.asarray(part.sg.node_ok).copy()
        if self.replica is not None:
            ms = np.asarray(self.replica.members_s)[:, 1:].ravel()
            ml = np.asarray(self.replica.members_l)[:, 1:].ravel()
            live = ms >= 0
            taken[ms[live], ml[live]] = True
        for s in range(part.sg.n_shards):
            self._free_local[s] = [
                i for i in range(part.sg.n_per_shard) if not taken[s, i]
            ]

    # -- hub-replica routing (rhizomes, DESIGN.md §2.12) -------------------

    def _member_slot(self, hub: int, other: int):
        """(shard, local) of the member slot the rank hash assigns the
        (hub, other) edge key to, or None when ``hub`` is unsplit."""
        rep = self.replica
        h = int(hub)
        if rep is None or h >= rep.group_of.shape[0]:
            return None     # gids minted after partition are never split
        g = int(rep.group_of[h])
        if g < 0:
            return None
        m = int(member_rank(h, int(other), int(rep.n_members[g])))
        return int(rep.members_s[g, m]), int(rep.members_l[g, m])

    def route_edge(self, u: int, v: int) -> tuple[int, int]:
        """Storage slot of directed edge u -> v: the member of a split u
        picked by the rank hash, else u's primary slot.  Build, add and
        delete all route through this, so incremental == rebuild."""
        return self._member_slot(u, v) or self.resolve(u)

    def route_target(self, v: int, u: int) -> tuple[int, int]:
        """Destination slot of directed edge u -> v: the member of a
        split v picked by the rank hash, else v's primary slot."""
        return self._member_slot(v, u) or self.resolve(v)

    def members_of(self, gid: int):
        """All (shard, local) member slots of a split hub (primary
        first), or None for unsplit vertices."""
        rep = self.replica
        g = int(gid)
        if rep is None or g >= rep.group_of.shape[0]:
            return None
        gi = int(rep.group_of[g])
        if gi < 0:
            return None
        return [(int(rep.members_s[gi, m]), int(rep.members_l[gi, m]))
                for m in range(int(rep.n_members[gi]))]

    def best_shard(self) -> int:
        """The compute cell with the most free vertex slots (load spread
        for dynamic vertex placement)."""
        return max(self._free_local, key=lambda s: len(self._free_local[s]))

    # -- snapshot serialization (session durability, DESIGN.md §2.13) ------

    def state_dict(self) -> dict:
        """Arrays capturing the full allocation state: owner/local maps
        plus each cell's free-slot list *in order* (allocate pops the
        front, release appends — the order is the determinism contract
        journal replay relies on)."""
        out = {"owner": np.asarray(self.owner),
               "local": np.asarray(self.local)}
        for s, free in self._free_local.items():
            out[f"free_{s}"] = np.asarray(free, np.int32)
        return out

    @classmethod
    def from_state(cls, arrays: dict, n_shards: int,
                   replica=None) -> "NameServer":
        """Rebuild from :meth:`state_dict` arrays (bitwise: same owner/
        local maps, same free-list order, same ``_next``)."""
        ns = cls.__new__(cls)
        ns.owner = np.asarray(arrays["owner"]).copy()
        ns.local = np.asarray(arrays["local"]).copy()
        ns._next = int(ns.owner.shape[0])
        ns.replica = replica
        ns._free_local = {
            s: [int(x) for x in arrays[f"free_{s}"]]
            for s in range(n_shards)
        }
        return ns

    def allocate(self, shard: int) -> tuple[int, int, int]:
        """-> (gid, owner shard, local slot). Raises if the cell is full."""
        if not self._free_local[shard]:
            raise RuntimeError(f"compute cell {shard} has no free vertex slots")
        local = self._free_local[shard].pop(0)
        gid = self._next
        self._next += 1
        self.owner = np.append(self.owner, np.int32(shard))
        self.local = np.append(self.local, np.int32(local))
        return gid, shard, local

    def resolve(self, gid: int) -> tuple[int, int]:
        return int(self.owner[gid]), int(self.local[gid])

    def release(self, gid: int):
        s, l = self.resolve(gid)
        self._free_local[s].append(l)


def vertex_add(sg: ShardedGraph, ns: NameServer, shard: int):
    """Activate a free vertex slot on ``shard``; returns (sg, gid)."""
    gid, s, l = ns.allocate(shard)
    sg = dataclasses.replace(
        sg,
        node_ok=sg.node_ok.at[s, l].set(True),
        gid=sg.gid.at[s, l].set(gid),
        out_degree=sg.out_degree.at[s, l].set(0),
    )
    return sg, gid


def _can_patch(sg: ShardedGraph) -> bool:
    """Whether the graph carries delta-capable CSR views to patch in
    place (DESIGN.md §2.9); otherwise the primitives fall back to
    :meth:`~repro.core.graph.ShardedGraph.invalidate_csr` (the escape
    hatch — the next diffusion rebuilds in-trace)."""
    return (sg.csr_perm is not None and sg.delta_count is not None
            and sg.delta_width > 0)


def vertex_delete(sg: ShardedGraph, ns: NameServer, gid: int):
    """Remove a vertex and all its out-edges (in-edges masked by node_ok).

    CSR maintenance: tombstones the doomed slots in both views in place
    (one elementwise pass — no re-sort); graphs without patchable views
    invalidate instead.  Deleting a split hub fans out over all member
    slots (out-edges are stored across members); release() then frees
    only the primary slot — mirrors stay reserved."""
    pairs = ns.members_of(gid) or [ns.resolve(gid)]
    ss = jnp.array([p[0] for p in pairs], jnp.int32)
    ll = jnp.array([p[1] for p in pairs], jnp.int32)
    dv = jnp.zeros_like(sg.node_ok).at[ss, ll].set(True)
    dead_out = sg.edge_ok & jnp.take_along_axis(dv, sg.src_local, axis=1)
    sg = dataclasses.replace(
        sg,
        node_ok=sg.node_ok.at[ss, ll].set(False),
        edge_ok=sg.edge_ok & ~dead_out,
        out_degree=sg.out_degree.at[ss, ll].set(0),
    )
    # in-edges pointing at a dead vertex are dropped at receive time via
    # node_ok; also mask them eagerly, shard by shard:
    dead_in = (sg.dst_gid == gid) & sg.edge_ok
    deg_fix = jax.vmap(
        lambda d, sl, m: d.at[sl].add(-m.astype(jnp.int32))
    )(sg.out_degree, sg.src_local, dead_in)
    sg = dataclasses.replace(
        sg, edge_ok=sg.edge_ok & ~dead_in, out_degree=deg_fix
    )
    ns.release(gid)
    if _can_patch(sg):
        from .graph import TOMBSTONE_COMPACT_FRACTION

        sg = sg.with_slot_tombstones(dead_out | dead_in)
        if int(jnp.max(sg.tomb_count)) > (TOMBSTONE_COMPACT_FRACTION
                                          * sg.edges_per_shard):
            return sg.with_csr()    # crowded with tombstones: compact
        return sg
    return sg.invalidate_csr()


def vertex_touch(sg: ShardedGraph, ns: NameServer, gids):
    """Activation mask in shard layout for the given vertex ids.
    Touching a split hub activates every member slot, so each member
    re-emits its stored out-edge share (mirrored state makes the
    per-member relax contributions identical to the unsplit emit)."""
    mask = jnp.zeros((sg.n_shards, sg.n_per_shard), bool)
    for g in np.atleast_1d(gids):
        for s, l in ns.members_of(int(g)) or [ns.resolve(int(g))]:
            mask = mask.at[s, l].set(True)
    return mask


def edge_add(sg: ShardedGraph, ns: NameServer, u: int, v: int, w: float):
    """Insert directed edge u -> v with weight w into u's cell.

    CSR maintenance: stages the new edge into both views' delta segments
    (an O(1) scatter — no re-sort), so a k-update loop no longer pays a
    sort inside every subsequent diffusion; a full delta segment
    triggers a compacting ``with_csr`` rebuild, and graphs without
    patchable views invalidate instead (the escape hatch).

    Split endpoints route through the rank hash: the edge is stored in
    the member cell ``route_edge`` picks and targets the member slot
    ``route_target`` picks — the same slots the partition-time build
    used, so a later delete probes exactly this cell."""
    su, lu = ns.route_edge(u, v)
    sv, lv = ns.route_target(v, u)
    can_patch = _can_patch(sg)
    if can_patch and int(sg.delta_count[su]) >= sg.delta_width:
        # compact BEFORE touching topology: the views are consistent
        # here, so this is the cheap merge; compacting after the write
        # would hand the merge a stale stream missing the new edge
        sg = sg.with_csr()
    free = ~sg.edge_ok[su]
    slot = jnp.argmax(free)  # first free slot
    ok = free[slot]          # False => cell's edge memory is full
    sg = dataclasses.replace(
        sg,
        src_local=sg.src_local.at[su, slot].set(jnp.where(ok, lu, sg.src_local[su, slot])),
        dst_shard=sg.dst_shard.at[su, slot].set(jnp.where(ok, sv, sg.dst_shard[su, slot])),
        dst_local=sg.dst_local.at[su, slot].set(jnp.where(ok, lv, sg.dst_local[su, slot])),
        dst_gid=sg.dst_gid.at[su, slot].set(jnp.where(ok, v, sg.dst_gid[su, slot])),
        weight=sg.weight.at[su, slot].set(jnp.where(ok, w, sg.weight[su, slot])),
        edge_ok=sg.edge_ok.at[su, slot].set(ok | sg.edge_ok[su, slot]),
        out_degree=sg.out_degree.at[su, lu].add(ok.astype(jnp.int32)),
    )
    if not bool(ok):
        raise RuntimeError(f"compute cell {su} has no free edge slots")
    if can_patch:
        # the pre-write compaction guarantees delta headroom here
        one = jnp.ones((1,), bool)
        return sg.with_staged_edges(
            jnp.array([su], jnp.int32), slot[None].astype(jnp.int32),
            jnp.array([lu], jnp.int32),
            jnp.array([sv * sg.n_per_shard + lv], jnp.int32),
            jnp.zeros((1,), jnp.int32), one)
    return sg.invalidate_csr()


def edge_delete(sg: ShardedGraph, ns: NameServer, u: int, v: int):
    """Delete directed edge u -> v (first matching live slot).

    CSR maintenance: tombstones the edge's stream positions in both
    views (an O(1) scatter through the slot inverses — no re-sort);
    heavily-tombstoned cells compact, and graphs without patchable
    views invalidate instead.  A split source probes the member cell
    the rank hash stored the edge in (no cross-member search)."""
    su, lu = ns.route_edge(u, v)
    match = (sg.src_local[su] == lu) & (sg.dst_gid[su] == v) & sg.edge_ok[su]
    slot = jnp.argmax(match)
    ok = match[slot]
    sg = dataclasses.replace(
        sg,
        edge_ok=sg.edge_ok.at[su, slot].set(
            jnp.where(ok, False, sg.edge_ok[su, slot])
        ),
        out_degree=sg.out_degree.at[su, lu].add(-ok.astype(jnp.int32)),
    )
    if _can_patch(sg):
        from .graph import TOMBSTONE_COMPACT_FRACTION

        sg = sg.with_edge_tombstones(
            jnp.array([su], jnp.int32), slot[None].astype(jnp.int32),
            ok[None])
        if int(sg.tomb_count[su]) > (TOMBSTONE_COMPACT_FRACTION
                                     * sg.edges_per_shard):
            return sg.with_csr()    # crowded with tombstones: compact
        return sg
    return sg.invalidate_csr()


def edge_touch(sg: ShardedGraph, ns: NameServer, u: int):
    """Activate a vertex so it re-emits on all out-edges (the relax seed)."""
    return vertex_touch(sg, ns, [u])


def peek(sg: ShardedGraph, values: jnp.ndarray, ns: NameServer, u: int):
    """Read the neighbours' values of vertex u (the paper's peek primitive).

    ``values`` is a [S, Np] shard-layout array (e.g. SSSP distances).
    Returns per-out-edge neighbour values, padded with NaN on dead slots.
    A split hub's out-edges live across its member cells, so the rows of
    every member concatenate: shape [R * edges_per_shard] (R = 1, the
    plain [Ep], for unsplit vertices).
    """
    pairs = ns.members_of(u) or [ns.resolve(u)]
    rows = []
    for su, lu in pairs:
        mine = (sg.src_local[su] == lu) & sg.edge_ok[su]
        nb = values[sg.dst_shard[su], sg.dst_local[su]]
        rows.append(jnp.where(mine, nb, jnp.nan))
    return rows[0] if len(rows) == 1 else jnp.concatenate(rows)


# --------------------------------------------------------------------------
# Incremental SSSP over the primitives (dynamic graph processing)
# --------------------------------------------------------------------------

def _invalidate_subtrees(part: Partitioned, ns: NameServer, vstate, root_gids):
    """Mark every vertex whose shortest-path tree passes through an
    invalidated parent edge; pointer-chase through the global namespace."""
    owner = jnp.asarray(ns.owner)
    local = jnp.asarray(ns.local)
    parent = vstate["parent"]           # [S, Np] global parent gid, -1 = none

    invalid = jnp.zeros(parent.shape, bool)
    for g in root_gids:
        s, l = ns.resolve(int(g))
        invalid = invalid.at[s, l].set(True)

    def body(c):
        inv, _ = c
        has_parent = parent >= 0
        pg = jnp.clip(parent, 0)
        parent_inv = inv[owner[pg], local[pg]] & has_parent
        new = inv | parent_inv
        return new, jnp.any(new != inv)

    def cond(c):
        return c[1]

    invalid, _ = jax.lax.while_loop(cond, body, (invalid, jnp.array(True)))
    return invalid


def incremental_sssp(
    part: Partitioned,
    ns: NameServer,
    vstate,
    source: int,
    inserts=(),
    deletes=(),
    max_local_iters: int = 64,
):
    """Apply edge updates and repair the SSSP fixed point by re-diffusion.

    inserts: iterable of (u, v, w); deletes: iterable of (u, v).
    Returns (part with updated sg, new vstate, stats of the repair
    diffusion).

    Back-compat wrapper: the batched mutation + generic frontier repair now
    live in :class:`repro.core.session.DiffusionSession` (the 'parents'
    strategy); this adopts the caller's fixed point into a transient
    session and commits one batch through the same code path.
    """
    from .session import DiffusionSession

    sess = DiffusionSession(part, ns=ns, max_local_iters=max_local_iters)
    key = sess.adopt("sssp", vstate, source=source)
    batch = sess.update()
    for u, v in deletes:
        batch.delete_edge(u, v)
    for u, v, w in inserts:
        batch.add_edge(u, v, w)
    info = sess.commit()
    _, stats = info.repairs[key]
    vstate = sess.vertex_state("sssp", source=source)
    if stats is None:
        # empty / all-phantom batch: the session skips repair, but this
        # function's contract is to always return repair-diffusion stats —
        # run the (immediately quiescent) diffusion for real counters.
        from .diffuse import diffuse_from
        from .programs import sssp_program

        vstate, stats = diffuse_from(
            part.sg, sssp_program(source, track_parents=True),
            vstate, jnp.zeros(vstate["dist"].shape, bool),
            max_local_iters=max_local_iters,
        )
    return part, vstate, stats
