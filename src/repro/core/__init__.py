# The paper's primary contribution: the diffusive-computation engine
# (memory-driven, message-driven dynamic graph processing) realized as a
# bulk-asynchronous sharded JAX system.  See DESIGN.md SS2-3.
#
# Front door: DiffusionSession (session.py) — static queries, batched
# mutation, and incremental recomputation through one message-driven API.
from .api import (
    Result,
    bfs,
    build,
    connected_components,
    pagerank,
    personalized_pagerank,
    run,
    sssp,
)
from .diffuse import DiffuseStats, diffuse, diffuse_from, make_spmd_diffuse
from .dynamic import NameServer
from .graph import Graph, ShardedGraph, from_edges
from .partition import Partitioned, partition
from .programs import (
    VertexProgram,
    bfs_program,
    cc_program,
    ppr_program,
    sssp_program,
)
from .session import (
    DiffusionSession,
    ProgramSpec,
    register_program,
)
from .updates import AppliedUpdates, UpdateBatch

__all__ = [
    "Result", "bfs", "build", "connected_components", "personalized_pagerank",
    "run", "sssp", "pagerank", "DiffuseStats", "diffuse", "diffuse_from",
    "make_spmd_diffuse", "Graph", "ShardedGraph", "from_edges",
    "Partitioned", "partition", "VertexProgram", "bfs_program",
    "cc_program", "ppr_program", "sssp_program",
    "DiffusionSession", "ProgramSpec", "register_program",
    "UpdateBatch", "AppliedUpdates", "NameServer",
]
