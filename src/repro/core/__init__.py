# The paper's primary contribution: the diffusive-computation engine
# (memory-driven, message-driven dynamic graph processing) realized as a
# bulk-asynchronous sharded JAX system.  See DESIGN.md SS2-3.
#
# Front door: DiffusionSession (session.py) — static queries, batched
# mutation, and incremental recomputation through one message-driven API.
# Programs are declarative, user-registrable specs (programs.py §2.7):
# @diffusive registers a DiffusiveProgram factory across every engine,
# kernel backend, the session cache, and commit()-time repair.
from .api import (
    Result,
    bfs,
    build,
    connected_components,
    pagerank,
    personalized_pagerank,
    reachable,
    run,
    sssp,
    widest_path,
)
from .diffuse import DiffuseStats, diffuse, diffuse_from, make_spmd_diffuse
from .dynamic import NameServer
from .graph import Graph, ShardedGraph, from_edges
from .monoid import MONOIDS, Monoid, register_monoid
from .partition import Partitioned, partition
from .programs import (
    BoundQuery,
    DiffusiveProgram,
    Field,
    VertexProgram,
    bfs_program,
    cc_program,
    diffusive,
    make_laned,
    pagerank_program,
    ppr_program,
    reach_program,
    sssp_program,
    widest_program,
)
from .journal import OpRecord, UpdateJournal
from .session import (
    ConvergenceError,
    ConvergenceWarning,
    DiffusionSession,
    JournalReplayError,
    ProgramSpec,
    ValidationError,
    register_program,
)
from .updates import AppliedUpdates, UpdateBatch

__all__ = [
    "Result", "bfs", "build", "connected_components", "personalized_pagerank",
    "run", "sssp", "pagerank", "widest_path", "reachable",
    "DiffuseStats", "diffuse", "diffuse_from",
    "make_spmd_diffuse", "Graph", "ShardedGraph", "from_edges",
    "Partitioned", "partition",
    "Monoid", "MONOIDS", "register_monoid",
    "VertexProgram", "DiffusiveProgram", "Field", "BoundQuery",
    "diffusive", "make_laned",
    "bfs_program", "cc_program", "ppr_program", "sssp_program",
    "pagerank_program", "widest_program", "reach_program",
    "DiffusionSession", "ProgramSpec", "register_program",
    "UpdateBatch", "AppliedUpdates", "NameServer",
    "UpdateJournal", "OpRecord",
    "ConvergenceError", "ConvergenceWarning", "ValidationError",
    "JournalReplayError",
]
