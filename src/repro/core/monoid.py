"""First-class combine monoids for diffusive programs.

The paper's soundness argument — any delivery order reaches the same fixed
point — rests on the message-combine operator being an associative,
commutative monoid.  PR 1/2 encoded that operator as a bare ``'min' |
'sum' | 'max'`` string scattered across engine and kernels; here it is a
first-class, *user-registrable* object carrying

* ``op``          — the elementwise combine,
* ``identity``    — the identity element per message dtype,
* ``payload``     — the optional payload rule (``'argbest'``: an int32
  payload rides along with the winning message; only meaningful for
  *selection* monoids, where the combined value equals one of its inputs),
* ``kind``        — the scatter class (``'min' | 'max' | 'sum'``) that
  implements this monoid in the segment/scatter kernels.

``kind`` is the contract with the relaxation kernels (kernels/edge_relax):
the blocked and flat combines use the native XLA scatter/segment op of the
class, so a registered monoid's ``op`` must agree with its class on the
message dtypes it is used with (e.g. logical-or over {0, 1} integers *is*
``max``; float min over a set is ``min``).  The monoid-law property test
(tests/test_programs.py) checks associativity, commutativity, identity,
and kind-consistency for every registered monoid.

Engines route every elementwise merge, row reduction, and payload
selection through the methods below, so the builtin fast paths stay
bitwise-identical to PR 2 while custom ``op``/``identity_of`` monoids fold
generically.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from .msg import identity_for

__all__ = ["Monoid", "MONOIDS", "register_monoid", "as_monoid",
           "MIN", "MAX", "SUM"]

_KINDS = ("min", "max", "sum")


@dataclasses.dataclass(frozen=True)
class Monoid:
    """Associative-commutative message combine (see module docstring).

    ``op``/``identity_of`` default to the ``kind``'s native operator; pass
    custom callables to register a new monoid of an existing scatter
    class.  Frozen + hashable, so a :class:`~.programs.VertexProgram`
    carrying one is a valid jit static argument.
    """

    name: str
    kind: str                              # scatter class: 'min'|'max'|'sum'
    op: Callable | None = None             # custom (a, b) -> combined
    identity_of: Callable | None = None    # custom dtype -> scalar
    payload: str | None = None             # 'argbest' | None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"monoid kind must be one of {_KINDS}, got {self.kind!r}")
        if self.payload not in (None, "argbest"):
            raise ValueError(f"unknown payload rule {self.payload!r}")
        if self.payload == "argbest" and self.kind == "sum":
            raise ValueError(
                "payload='argbest' needs a selection monoid (kind 'min' or"
                " 'max'); a sum-combined message is not any single input")

    # -- elementwise ----------------------------------------------------

    def identity(self, dtype):
        if self.identity_of is not None:
            return jnp.asarray(self.identity_of(dtype), dtype)
        return identity_for(self.kind, dtype)

    def elem(self, a, b):
        """Raw elementwise combine (both sides present)."""
        if self.op is not None:
            return self.op(a, b)
        if self.kind == "min":
            return jnp.minimum(a, b)
        if self.kind == "max":
            return jnp.maximum(a, b)
        return a + b

    def merge(self, a, b, b_has):
        """Fold ``b`` into accumulator ``a``; ``b_has`` masks absent
        messages (absent ``b`` positions hold the identity already for
        selection monoids, but sum and custom ops must not touch them)."""
        if self.op is None:
            if self.kind == "sum":
                return a + jnp.where(b_has, b, jnp.zeros_like(b))
            return self.elem(a, b)
        return jnp.where(b_has, self.op(a, b), a)

    def improves(self, new, old):
        """Would ``new`` replace ``old`` as the combined value?  Drives
        which message's payload rides in the outbox (selection monoids);
        sum monoids carry no payload, any contribution 'improves'."""
        if self.kind == "min":
            return new < old
        if self.kind == "max":
            return new > old
        return jnp.ones(jnp.broadcast_shapes(jnp.shape(new), jnp.shape(old)),
                        bool)

    # -- reductions -----------------------------------------------------

    def reduce_rows(self, arr, has, axis: int = 0):
        """Combine along ``axis`` (the mailbox-merge of per-source rows);
        ``has`` masks absent entries.  Builtin kinds use the native XLA
        reduction (bitwise-stable with PR 2); custom ops fold."""
        if self.op is None:
            if self.kind == "min":
                return arr.min(axis=axis)
            if self.kind == "max":
                return arr.max(axis=axis)
            return jnp.where(has, arr, jnp.zeros_like(arr)).sum(axis=axis)
        acc = jnp.take(arr, 0, axis=axis)
        acc_has = jnp.take(has, 0, axis=axis)
        for i in range(1, arr.shape[axis]):
            b, bh = jnp.take(arr, i, axis=axis), jnp.take(has, i, axis=axis)
            nxt = jnp.where(acc_has & bh, self.op(acc, b),
                            jnp.where(bh, b, acc))
            acc, acc_has = nxt, acc_has | bh
        return acc

    def argbest(self, arr, axis: int = 0):
        """Index of the winning row along ``axis`` (payload selection)."""
        if self.payload != "argbest":
            raise ValueError(
                f"monoid {self.name!r} has no payload rule; only "
                "payload='argbest' monoids select a winning message")
        return (jnp.argmin if self.kind == "min" else jnp.argmax)(
            arr, axis=axis)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

MONOIDS: dict[str, Monoid] = {}


def register_monoid(m: Monoid) -> Monoid:
    """Register a monoid for name-based lookup in program specs."""
    MONOIDS[m.name] = m
    return m


def as_monoid(m) -> Monoid:
    """Coerce a registry name or Monoid instance to a Monoid."""
    if isinstance(m, Monoid):
        return m
    if m in MONOIDS:
        return MONOIDS[m]
    raise KeyError(
        f"unknown monoid {m!r}; registered: {sorted(MONOIDS)} "
        "(register_monoid to add)")


MIN = register_monoid(Monoid("min", "min", payload="argbest"))
MAX = register_monoid(Monoid("max", "max", payload="argbest"))
SUM = register_monoid(Monoid("sum", "sum"))
