"""Vertex programs — the ``hpx_diffuse`` contract, vectorized.

The paper's Code Listing 3 primitive is::

    hpx_diffuse(vertex_id, vertex_func, args..., terminator, predicate)

A :class:`VertexProgram` carries exactly those pieces in TPU-vectorized form:

* ``emit``       — the body of ``vertex_func`` that generates messages along
                   out-edges (the diffusion),
* ``receive``    — the *predicate* + state update at the target vertex; it
                   returns which vertices (re)activate, gating new work,
* ``on_send``    — sender-side state transition when a vertex fires
                   (identity for SSSP; residual-consumption for PageRank),
* the terminator is the engine's quiescence detector (see diffuse.py /
  termination.py).

Messages are combined with an associative-commutative monoid (min/sum/max) so
delivery order cannot matter — this is what makes the paper's "no DAG, any
path to the fixed point" semantics sound under bulk-asynchronous execution.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Any

import jax.numpy as jnp

from .graph import ShardedGraph

__all__ = ["VertexProgram", "sssp_program", "bfs_program", "cc_program",
           "ppr_program", "pagerank_program"]


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """Vectorized vertex program (see module docstring).

    Shapes (per shard): vertex-state leaves are [Np]; edge args are [Ep].
    """

    combine: str                   # 'min' | 'sum' | 'max'
    msg_dtype: Any
    # (sg) -> (vstate pytree of [S, Np] leaves, active [S, Np] bool)
    init: Callable
    # (src_state pytree [Ep], weight [Ep], src_gid [Ep], dst_gid [Ep]) -> msg [Ep]
    emit: Callable
    # (vstate [Np] leaves, sent_mask [Np]) -> vstate
    on_send: Callable
    # (vstate, inbox [Np], has_msg [Np], payload [Np] int32|None, node_ok [Np])
    #   -> (vstate, activated [Np] bool)
    receive: Callable
    # optional argmin payload: (src_state [Ep], src_gid [Ep]) -> int32 [Ep]
    payload: Callable | None = None
    # optional bucket priority (delta-stepping gate): (vstate) -> f32 [Np]
    priority: Callable | None = None

    @property
    def with_payload(self) -> bool:
        return self.payload is not None


# --------------------------------------------------------------------------
# SSSP — the paper's running example (Code Listings 1, 2, 4).
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)  # stable identity => no jit recompiles
def sssp_program(source: int, track_parents: bool = True) -> VertexProgram:
    """Diffusive SSSP: msg = dist(src) + w; predicate ``msg < dist(v)``."""

    def init(sg: ShardedGraph):
        dist = jnp.where(
            sg.gid == source, 0.0, jnp.inf
        ).astype(jnp.float32)
        dist = jnp.where(sg.node_ok, dist, jnp.inf)
        vstate = {"dist": dist}
        if track_parents:
            vstate["parent"] = jnp.where(sg.gid == source, source, -1).astype(
                jnp.int32
            )
        active = (sg.gid == source) & sg.node_ok
        return vstate, active

    def emit(src_state, weight, src_gid, dst_gid):
        return src_state["dist"] + weight

    def on_send(vstate, sent):
        return vstate

    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["dist"]) & node_ok
        out = dict(vstate)
        out["dist"] = jnp.where(better, inbox, vstate["dist"])
        if track_parents and payload is not None:
            out["parent"] = jnp.where(better, payload, vstate["parent"])
        return out, better

    return VertexProgram(
        combine="min",
        msg_dtype=jnp.float32,
        init=init,
        emit=emit,
        on_send=on_send,
        receive=receive,
        payload=(lambda src_state, src_gid: src_gid) if track_parents else None,
        priority=lambda vstate: vstate["dist"],
    )


@functools.lru_cache(maxsize=256)
def bfs_program(source: int) -> VertexProgram:
    """BFS = SSSP with unit edge messages (level = hops)."""

    def init(sg: ShardedGraph):
        level = jnp.where(sg.gid == source, 0.0, jnp.inf).astype(jnp.float32)
        level = jnp.where(sg.node_ok, level, jnp.inf)
        return {"dist": level}, (sg.gid == source) & sg.node_ok

    def emit(src_state, weight, src_gid, dst_gid):
        return src_state["dist"] + 1.0

    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["dist"]) & node_ok
        return {"dist": jnp.where(better, inbox, vstate["dist"])}, better

    return VertexProgram(
        combine="min",
        msg_dtype=jnp.float32,
        init=init,
        emit=emit,
        on_send=lambda v, s: v,
        receive=receive,
    )


@functools.lru_cache(maxsize=8)
def cc_program() -> VertexProgram:
    """Connected components by min-label diffusion (all vertices start active)."""

    def init(sg: ShardedGraph):
        comp = jnp.where(sg.node_ok, sg.gid, jnp.iinfo(jnp.int32).max).astype(
            jnp.int32
        )
        return {"comp": comp}, sg.node_ok

    def emit(src_state, weight, src_gid, dst_gid):
        return src_state["comp"]

    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["comp"]) & node_ok
        return {"comp": jnp.where(better, inbox, vstate["comp"])}, better

    return VertexProgram(
        combine="min",
        msg_dtype=jnp.int32,
        init=init,
        emit=emit,
        on_send=lambda v, s: v,
        receive=receive,
    )


@functools.lru_cache(maxsize=32)
def pagerank_program(alpha: float = 0.15, eps: float = 1e-6) -> VertexProgram:
    """Global PageRank by forward push from a uniform start distribution.

    Fixed point: rank = alpha * sum_k (1-alpha)^k (W^T)^k u, i.e. PageRank
    with teleport alpha.  A *sum-combine* diffusion where every vertex is a
    source — the densest operon traffic the engine generates."""

    def init(sg):
        n = jnp.maximum(jnp.sum(sg.node_ok.astype(jnp.float32)), 1.0)
        res = jnp.where(sg.node_ok, 1.0 / n, 0.0).astype(jnp.float32)
        vstate = {
            "rank": jnp.zeros_like(res),
            "residual": res,
            "deg": jnp.maximum(sg.out_degree, 1).astype(jnp.float32),
        }
        return vstate, sg.node_ok

    def emit(src_state, weight, src_gid, dst_gid):
        return (1.0 - alpha) * src_state["residual"] / src_state["deg"]

    def on_send(vstate, sent):
        rank = vstate["rank"] + jnp.where(sent, alpha * vstate["residual"],
                                          0.0)
        residual = jnp.where(sent, 0.0, vstate["residual"])
        return {"rank": rank, "residual": residual, "deg": vstate["deg"]}

    def receive(vstate, inbox, has_msg, payload, node_ok):
        residual = vstate["residual"] + jnp.where(has_msg, inbox, 0.0)
        residual = jnp.where(node_ok, residual, 0.0)
        out = dict(vstate)
        out["residual"] = residual
        return out, (residual > eps) & node_ok

    return VertexProgram(
        combine="sum",
        msg_dtype=jnp.float32,
        init=init,
        emit=emit,
        on_send=on_send,
        receive=receive,
    )


@functools.lru_cache(maxsize=256)
def ppr_program(source: int, alpha: float = 0.15, eps: float = 1e-4) -> VertexProgram:
    """Personalized PageRank by forward push — a *sum-combine* diffusion.

    Active vertex v: rank += alpha * r(v); pushes (1-alpha) * r(v) / deg(v) to
    each neighbor; r(v) = 0.  Receiver activates when r(u) > eps.
    Monotone-terminating because total residual shrinks by alpha per push.
    """

    def init(sg: ShardedGraph):
        res = jnp.where(sg.gid == source, 1.0, 0.0).astype(jnp.float32)
        res = jnp.where(sg.node_ok, res, 0.0)
        vstate = {
            "rank": jnp.zeros_like(res),
            "residual": res,
            "deg": jnp.maximum(sg.out_degree, 1).astype(jnp.float32),
        }
        return vstate, (sg.gid == source) & sg.node_ok

    def emit(src_state, weight, src_gid, dst_gid):
        return (1.0 - alpha) * src_state["residual"] / src_state["deg"]

    def on_send(vstate, sent):
        rank = vstate["rank"] + jnp.where(sent, alpha * vstate["residual"], 0.0)
        residual = jnp.where(sent, 0.0, vstate["residual"])
        return {"rank": rank, "residual": residual, "deg": vstate["deg"]}

    def receive(vstate, inbox, has_msg, payload, node_ok):
        residual = vstate["residual"] + jnp.where(has_msg, inbox, 0.0)
        residual = jnp.where(node_ok, residual, 0.0)
        out = dict(vstate)
        out["residual"] = residual
        return out, (residual > eps) & node_ok

    return VertexProgram(
        combine="sum",
        msg_dtype=jnp.float32,
        init=init,
        emit=emit,
        on_send=on_send,
        receive=receive,
    )
