"""Diffusive programs — the ``hpx_diffuse`` contract as a declarative,
user-registrable spec.

The paper's Code Listing 3 primitive is::

    hpx_diffuse(vertex_id, vertex_func, args..., terminator, predicate)

PR 1/2 hardcoded five vectorized realizations of that contract as closure
factories only the engine authors could extend.  This module turns the
contract into a public extension point (DESIGN.md §2.7):

* :class:`DiffusiveProgram` — a *declarative spec*: a typed vertex-state
  schema (named :class:`Field`\\ s: dtype + init expression + dead-slot
  value), a first-class :class:`~.monoid.Monoid`, and pure
  ``emit / receive / on_send / priority`` functions over the named state;
* :func:`diffusive` — the registration decorator: a decorated factory is
  invocable by name through every engine (``sharded`` / ``event`` /
  ``spmd``), both kernel backends (``xla`` / ``pallas``), the session
  cache, and commit()-time repair, with zero engine changes;
* :func:`lower` — compiles a spec to the engine IR
  (:class:`VertexProgram`), whose function fields the relaxation kernels
  trace straight into their bodies;
* :func:`make_laned` — stacks B single-query programs into one program
  with a lane axis, so ``session.query(sssp(sources=[...]))`` amortizes
  B queries over a single edge sweep (multi-query lanes, DESIGN.md §2.7).

The five builtins (SSSP / BFS / CC / PPR / PageRank) are themselves
written on the public spec, as are the two proof-of-extensibility
programs ``widest`` (max-bottleneck path) and ``reach``
(multi-source reachability).

Messages are combined with an associative-commutative monoid so delivery
order cannot matter — this is what makes the paper's "no DAG, any path to
the fixed point" semantics sound under bulk-asynchronous execution.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

import numpy as np

from .monoid import Monoid, as_monoid

__all__ = [
    "Field", "DiffusiveProgram", "VertexProgram", "ProgramSpec",
    "BoundQuery", "ProgramHandle", "diffusive", "lower", "make_laned",
    "PROGRAMS", "register_program", "freeze_kwargs",
    "sssp", "bfs", "cc", "ppr", "pagerank", "widest", "reach",
    "sssp_program", "bfs_program", "cc_program", "ppr_program",
    "pagerank_program", "widest_program", "reach_program",
]


# --------------------------------------------------------------------------
# engine IR — what diffuse.py / the relax kernels consume
# --------------------------------------------------------------------------

def _closure_key(value):
    """Hashable identity of one captured value.  Nested functions key
    structurally; unhashable captures (arrays, Field schemas) fall back
    to object identity — the fallback can only *separate* two programs
    that structural equality would have merged, never wrongly merge
    them, so it is always trace-safe."""
    if callable(value) and hasattr(value, "__code__"):
        return _fn_key(value)
    try:
        hash(value)
    except TypeError:
        return ("id", id(value))
    return value


def _fn_key(fn):
    """Structural identity of a pure function: code object + captured
    closure values + defaults.  Two closures produced by re-running the
    same factory with the same parameters compare equal — they trace to
    the same jaxpr — which is what lets every ``sssp(source=k)`` share
    one ``_run_rounds`` jit cache entry (the source lives in ``init``,
    which is excluded from the program's trace identity)."""
    if fn is None:
        return None
    code = getattr(fn, "__code__", None)
    if code is None:
        return fn                       # builtins / partials: the object
    cells = tuple(_closure_key(c.cell_contents)
                  for c in (fn.__closure__ or ()))
    defaults = tuple(_closure_key(d) for d in (fn.__defaults__ or ()))
    return (code, cells, defaults)


@dataclasses.dataclass(frozen=True, eq=False)
class VertexProgram:
    """Lowered (engine-facing) vertex program.

    Shapes (per shard): vertex-state leaves are [Np] — or [L, Np] when
    ``lanes`` is set (multi-query lanes; see :func:`make_laned`) — and
    edge args are [Ep].  Serves as the jit static argument, so its
    ``__eq__`` / ``__hash__`` are *structural over everything the trace
    reads* — monoid, msg dtype, the emit/on_send/receive/payload/
    priority function structure (:func:`_fn_key`), lanes, name — and
    deliberately exclude ``init``: the engines take the initial
    ``(vstate, active)`` as *traced* inputs, so programs differing only
    in their init closure (``sssp(source=0)`` vs ``sssp(source=1)``)
    share one compiled fixed-point loop instead of retracing per
    source.  Callers that do trace ``init`` (the spmd engine, the laned
    stacker) must key their caches on ``_fn_key(prog.init)`` as well.
    """

    monoid: Monoid                 # first-class combine (min/max/sum class)
    msg_dtype: Any
    # (view) -> (vstate pytree of [.., Np] leaves, active [.., Np] bool)
    init: Callable
    # (src_state pytree [Ep], weight [Ep], src_gid [Ep], dst_gid [Ep]) -> msg
    emit: Callable
    # (vstate [Np] leaves, sent_mask [Np]) -> vstate
    on_send: Callable
    # (vstate, inbox [Np], has_msg [Np], payload [Np] int32|None, node_ok)
    #   -> (vstate, activated [Np] bool)
    receive: Callable
    # optional argbest payload: (src_state [Ep], src_gid [Ep]) -> int32 [Ep]
    payload: Callable | None = None
    # optional bucket priority (delta-stepping gate): (vstate) -> f32 [Np]
    priority: Callable | None = None
    lanes: int | None = None       # lane count; None = single-query program
    name: str = ""
    # the declarative Field schema this program was lowered from, as
    # ((name, Field), ...) — carried for the session's validate= guard;
    # None for hand-built programs (which then skip validation).  Not
    # part of the trace key: nothing the jitted loop reads depends on it.
    fields: Any = None

    def __post_init__(self):
        if not isinstance(self.monoid, Monoid):
            object.__setattr__(self, "monoid", as_monoid(self.monoid))
        if self.payload is not None and self.monoid.payload != "argbest":
            raise ValueError(
                f"program {self.name!r} carries a payload but monoid "
                f"{self.monoid.name!r} has no 'argbest' payload rule")

    def _trace_key(self) -> tuple:
        """Everything the jitted fixed-point loop reads from this
        program (``init`` excluded — it enters as traced arrays)."""
        key = self.__dict__.get("_trace_key_cache")
        if key is None:
            key = (self.monoid, np.dtype(self.msg_dtype),
                   _fn_key(self.emit), _fn_key(self.on_send),
                   _fn_key(self.receive), _fn_key(self.payload),
                   _fn_key(self.priority), self.lanes, self.name)
            object.__setattr__(self, "_trace_key_cache", key)
        return key

    def __eq__(self, other):
        if not isinstance(other, VertexProgram):
            return NotImplemented
        return self is other or self._trace_key() == other._trace_key()

    def __hash__(self):
        return hash(self._trace_key())

    @property
    def combine(self) -> str:
        """Scatter class of the monoid — the kernels' dispatch string."""
        return self.monoid.kind

    @property
    def with_payload(self) -> bool:
        return self.payload is not None


# --------------------------------------------------------------------------
# declarative spec + lowering
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Field:
    """One named vertex-state field: dtype + init expression.

    ``init`` is a scalar or a pure function of the graph view (an object
    with ``gid`` / ``node_ok`` / ``out_degree`` arrays); ``on_dead``, when
    given, overwrites dead/free vertex slots (deleted vertices and spare
    capacity) so stale slot contents can never leak into a fixed point.

    ``domain`` optionally declares the legal value range ``(lo, hi)`` of
    *live* vertices at a fixed point (None end = unbounded), consumed by
    the session's ``validate=`` post-query guard (DESIGN.md §2.13): NaN
    is always invalid for float fields; out-of-domain values (including
    an inf that the domain does not admit) fail validation.  Undeclared
    domains default to NaN-only checking for floats and the payload
    range ``[-1, n_ids)`` for ints (payloads carry gids or -1).
    """

    dtype: Any
    init: Any = 0
    on_dead: Any = None
    domain: tuple | None = None


@dataclasses.dataclass(frozen=True, eq=False)
class DiffusiveProgram:
    """Declarative diffusive-program spec (see module docstring).

    ``emit`` / ``receive`` / ``on_send`` / ``priority`` are pure functions
    over the *named* state dict declared in ``state`` — the same
    vectorized signatures as :class:`VertexProgram` (they are traced into
    the relaxation kernels unchanged by :func:`lower`).
    """

    monoid: Monoid | str
    msg_dtype: Any
    state: Any                          # mapping name -> Field (ordered)
    emit: Callable
    receive: Callable
    init_active: Callable | None = None  # (view) -> bool mask; None = all
    on_send: Callable | None = None      # None = identity
    payload: Callable | None = None
    priority: Callable | None = None


def lower(spec: DiffusiveProgram, name: str = "") -> VertexProgram:
    """Compile a declarative spec to the engine IR.

    Builds the vectorized ``init`` from the state schema: evaluate each
    field's init expression over the graph view, cast to the declared
    dtype, splat ``on_dead`` over dead slots, and intersect the initial
    frontier with ``node_ok``.

    Every spec is verified against the §2.7 authoring contract on the
    way through (abstract traces of emit/receive/on_send/priority
    against the Field schema + a seeded monoid-law check — see
    :mod:`repro.analysis.verify`); a broken spec raises
    :class:`~repro.analysis.verify.ProgramVerificationError` here, at
    build/registration time, instead of mis-executing at query time.
    Set ``REPRO_VERIFY=0`` to skip.
    """
    from ..analysis import verify as _verify  # deferred: no import cycle

    if _verify.verification_enabled():
        _verify.verify_program(spec, name=name)

    monoid = as_monoid(spec.monoid)
    fields = tuple(spec.state.items())

    def init(view):
        shape = view.gid.shape
        vstate = {}
        for fname, f in fields:  # analysis: allow(host-loop): static unroll over the declared field schema, not shards
            v = f.init(view) if callable(f.init) else f.init
            v = jnp.broadcast_to(jnp.asarray(v), shape).astype(f.dtype)
            if f.on_dead is not None:
                v = jnp.where(view.node_ok, v,
                              jnp.asarray(f.on_dead, f.dtype))
            vstate[fname] = v
        mask = (spec.init_active(view) if spec.init_active is not None
                else jnp.ones(shape, bool))
        return vstate, mask & view.node_ok

    return VertexProgram(
        monoid=monoid,
        msg_dtype=spec.msg_dtype,
        init=init,
        emit=spec.emit,
        on_send=spec.on_send or (lambda vstate, sent: vstate),
        receive=spec.receive,
        payload=spec.payload,
        priority=spec.priority,
        name=name,
        fields=fields,
    )


# --------------------------------------------------------------------------
# registry — one lookup path for names, handles, and bound queries
# --------------------------------------------------------------------------

class ProgramSpec(NamedTuple):
    """Registry entry making a program invocable by name (DESIGN.md §2.4).

    ``lane_param`` names the kwarg whose plural form fans out into query
    lanes (``source`` -> ``sources``); lane-varying params may only
    influence the init schema / initial frontier, never emit/receive.
    """

    name: str
    factory: Callable | None     # (**kwargs) -> VertexProgram
    value_key: str
    repair: str = "restart"      # 'parents' | 'component' | 'restart'
    monotone: bool = False       # insert-only warm start is sound
    event_fn: Callable | None = None   # (session, **kwargs) -> (values, st)
    run_fn: Callable | None = None     # custom query (e.g. triangles)
    lane_param: str | None = None


PROGRAMS: dict[str, ProgramSpec] = {}


def register_program(spec: ProgramSpec) -> ProgramSpec:
    PROGRAMS[spec.name] = spec
    return spec


def freeze_kwargs(kwargs: dict) -> tuple:
    """Deterministic hashable form of query/program kwargs: lists, arrays,
    sets, and dicts become sorted/ordered tuples (so ``sources=[...]``
    can key a cache instead of raising TypeError)."""
    def _freeze(v):
        if isinstance(v, (list, tuple)):
            return tuple(_freeze(x) for x in v)
        if isinstance(v, (set, frozenset)):
            return tuple(sorted(_freeze(x) for x in v))
        if isinstance(v, (np.ndarray, jnp.ndarray)):
            a = np.asarray(v)
            return a.item() if a.ndim == 0 else tuple(
                _freeze(x) for x in a.tolist())
        if isinstance(v, dict):
            return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
        if isinstance(v, np.generic):
            return v.item()
        return v
    return tuple(sorted((k, _freeze(v)) for k, v in kwargs.items()))


# Stable-identity caches of lowered programs (a rebuilt program would jit
# afresh).  Bounded like PR 1/2's lru_cache(256): a serving process that
# sees millions of distinct sources must not retain every closure forever
# — evicting merely costs the evictee a recompile on its next use.
_PROGRAM_CACHE_SIZE = 256


def _evict_oldest(cache: dict, limit: int):
    while len(cache) >= limit:
        cache.pop(next(iter(cache)))


class BoundQuery(NamedTuple):
    """A program invocation bound to its kwargs — what a
    :class:`ProgramHandle` call returns, and what ``session.query`` /
    ``session.peek`` accept interchangeably with a registry name."""

    name: str
    kwargs: dict


class ProgramHandle:
    """The object a :func:`diffusive` decoration returns.

    Calling it binds kwargs into a :class:`BoundQuery` for
    ``session.query(sssp(source=3))`` / ``query(sssp(sources=[...]))``;
    :meth:`build` lowers the spec to a cached :class:`VertexProgram`
    (stable identity per canonicalized kwargs => no jit recompiles).
    """

    def __init__(self, name: str, fn: Callable, value_key: str,
                 lane_param: str | None = None):
        self.name = name
        self.fn = fn
        self.value_key = value_key
        self.lane_param = lane_param
        self._built: dict[tuple, VertexProgram] = {}
        self.__doc__ = fn.__doc__

    def __call__(self, **kwargs) -> BoundQuery:
        return BoundQuery(self.name, dict(kwargs))

    def build(self, *args, **kwargs) -> VertexProgram:
        bound = inspect.signature(self.fn).bind(*args, **kwargs)
        bound.apply_defaults()
        key = freeze_kwargs(bound.arguments)
        if key not in self._built:
            spec = self.fn(**bound.arguments)
            if not isinstance(spec, DiffusiveProgram):
                raise TypeError(
                    f"@diffusive factory {self.name!r} must return a "
                    f"DiffusiveProgram, got {type(spec).__name__}")
            _evict_oldest(self._built, _PROGRAM_CACHE_SIZE)
            self._built[key] = lower(spec, name=self.name)
        return self._built[key]

    def __repr__(self):
        return f"<diffusive program {self.name!r}>"


def diffusive(name: str, *, value_key: str, repair: str = "restart",
              monotone: bool = False, lane_param: str | None = None):
    """Register a user-defined diffusive program (DESIGN.md §2.7).

    Decorate a factory ``(**params) -> DiffusiveProgram``; the returned
    handle is callable (binding kwargs for ``session.query``) and the
    program becomes name-invocable across all engines, kernel backends,
    the session cache, and commit()-time repair::

        @diffusive("widest", value_key="width", monotone=True,
                   lane_param="source")
        def widest(source: int):
            return DiffusiveProgram(monoid="max", ...)

    ``repair`` picks the commit()-time strategy ('parents' | 'component'
    | 'restart'); ``monotone`` allows the warm-frontier path for
    insert-only batches; ``lane_param`` enables multi-query lanes over
    the pluralized kwarg.
    """
    def deco(fn: Callable) -> ProgramHandle:
        handle = ProgramHandle(name, fn, value_key, lane_param)
        register_program(ProgramSpec(
            name, handle.build, value_key, repair=repair, monotone=monotone,
            lane_param=lane_param,
        ))
        return handle
    return deco


# --------------------------------------------------------------------------
# multi-query lanes
# --------------------------------------------------------------------------

_LANED: dict[tuple, VertexProgram] = {}


def make_laned(progs) -> VertexProgram:
    """Stack B single-query programs into one laned program.

    Vertex-state leaves and the active mask gain a lane axis (per shard:
    [Np] -> [L, Np]); emit/receive/on_send/priority come from the first
    program and broadcast over lanes, so the lane-varying kwargs (the
    registry's ``lane_param``) may only influence the init schema and the
    initial frontier.  The engines then run one edge sweep per
    sub-iteration for all B queries (DESIGN.md §2.7).

    Cached on the program tuple => stable identity, no jit recompiles
    for a repeated batch shape (bounded — see ``_PROGRAM_CACHE_SIZE``).
    """
    progs = tuple(progs)
    if not progs:
        raise ValueError("make_laned needs at least one program")
    # the laned init stacks every lane's init, so the cache key must
    # carry each program's *init identity* on top of its (init-excluding)
    # structural equality — otherwise sssp lanes [0, 1] would serve
    # lanes [2, 3]
    lkey = tuple((p, _fn_key(p.init)) for p in progs)
    if lkey in _LANED:
        return _LANED[lkey]
    _evict_oldest(_LANED, _PROGRAM_CACHE_SIZE)
    base = progs[0]
    for p in progs[1:]:
        if (p.monoid != base.monoid or p.msg_dtype != base.msg_dtype
                or (p.payload is None) != (base.payload is None)):
            raise ValueError(
                "lane programs must share monoid, msg dtype, and "
                "payload-ness (only init may vary per lane)")

    def init(view):
        outs = [p.init(view) for p in progs]
        vstate = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=-2), *[o[0] for o in outs])
        active = jnp.stack([o[1] for o in outs], axis=-2)
        return vstate, active

    laned = dataclasses.replace(
        base, init=init, lanes=len(progs),
        name=f"{base.name or 'prog'}[x{len(progs)}]",
    )
    _LANED[lkey] = laned
    return laned


# --------------------------------------------------------------------------
# the builtins, written on the public spec
# --------------------------------------------------------------------------

@diffusive("sssp", value_key="dist", repair="parents", monotone=True,
           lane_param="source")
def sssp(source: int, track_parents: bool = True) -> DiffusiveProgram:
    """Diffusive SSSP: msg = dist(src) + w; predicate ``msg < dist(v)``
    (the paper's running example, Code Listings 1, 2, 4)."""
    state = {"dist": Field(jnp.float32,
                           init=lambda v: jnp.where(v.gid == source, 0.0,
                                                    jnp.inf),
                           on_dead=jnp.inf,
                           domain=(0.0, None))}   # +inf = unreachable: legal
    if track_parents:
        state["parent"] = Field(jnp.int32,
                                init=lambda v: jnp.where(v.gid == source,
                                                         source, -1))

    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["dist"]) & node_ok
        out = dict(vstate)
        out["dist"] = jnp.where(better, inbox, vstate["dist"])
        if track_parents and payload is not None:
            out["parent"] = jnp.where(better, payload, vstate["parent"])
        return out, better

    return DiffusiveProgram(
        monoid="min",
        msg_dtype=jnp.float32,
        state=state,
        init_active=lambda v: v.gid == source,
        emit=lambda s, weight, src_gid, dst_gid: s["dist"] + weight,
        receive=receive,
        payload=(lambda s, src_gid: src_gid) if track_parents else None,
        priority=lambda vstate: vstate["dist"],
    )


@diffusive("bfs", value_key="dist", monotone=True, lane_param="source")
def bfs(source: int) -> DiffusiveProgram:
    """BFS = SSSP with unit edge messages (level = hops)."""
    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["dist"]) & node_ok
        return {"dist": jnp.where(better, inbox, vstate["dist"])}, better

    return DiffusiveProgram(
        monoid="min",
        msg_dtype=jnp.float32,
        state={"dist": Field(jnp.float32,
                             init=lambda v: jnp.where(v.gid == source, 0.0,
                                                      jnp.inf),
                             on_dead=jnp.inf,
                             domain=(0.0, None))},
        init_active=lambda v: v.gid == source,
        emit=lambda s, weight, src_gid, dst_gid: s["dist"] + 1.0,
        receive=receive,
    )


@diffusive("cc", value_key="comp", repair="component", monotone=True)
def cc() -> DiffusiveProgram:
    """Connected components by min-label diffusion (all vertices start
    active)."""
    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["comp"]) & node_ok
        return {"comp": jnp.where(better, inbox, vstate["comp"])}, better

    return DiffusiveProgram(
        monoid="min",
        msg_dtype=jnp.int32,
        state={"comp": Field(jnp.int32, init=lambda v: v.gid,
                             on_dead=jnp.iinfo(jnp.int32).max)},
        emit=lambda s, weight, src_gid, dst_gid: s["comp"],
        receive=receive,
    )


def _push_spec(residual_init, active_init, alpha: float, eps: float):
    """Shared forward-push schema for PPR / PageRank (sum-combine)."""
    def on_send(vstate, sent):
        rank = vstate["rank"] + jnp.where(sent, alpha * vstate["residual"],
                                          0.0)
        residual = jnp.where(sent, 0.0, vstate["residual"])
        return {"rank": rank, "residual": residual, "deg": vstate["deg"]}

    def receive(vstate, inbox, has_msg, payload, node_ok):
        residual = vstate["residual"] + jnp.where(has_msg, inbox, 0.0)
        residual = jnp.where(node_ok, residual, 0.0)
        out = dict(vstate)
        out["residual"] = residual
        return out, (residual > eps) & node_ok

    return DiffusiveProgram(
        monoid="sum",
        msg_dtype=jnp.float32,
        state={
            # domains are deliberately loose (total mass is 1, so 2.0
            # can never trip on legitimate float error) — the guard is
            # for Inf/NaN/garbage, not tight numerics
            "rank": Field(jnp.float32, init=0.0, domain=(0.0, 2.0)),
            "residual": Field(jnp.float32, init=residual_init, on_dead=0.0,
                              domain=(0.0, 2.0)),
            "deg": Field(jnp.float32,
                         init=lambda v: jnp.maximum(v.out_degree, 1),
                         domain=(1.0, None)),
        },
        init_active=active_init,
        emit=lambda s, weight, src_gid, dst_gid:
            (1.0 - alpha) * s["residual"] / s["deg"],
        on_send=on_send,
        receive=receive,
    )


@diffusive("ppr", value_key="rank", lane_param="source")
def ppr(source: int, alpha: float = 0.15, eps: float = 1e-4) -> DiffusiveProgram:
    """Personalized PageRank by forward push — a *sum-combine* diffusion.

    Active vertex v: rank += alpha * r(v); pushes (1-alpha) * r(v) /
    deg(v) to each neighbor; r(v) = 0.  Receiver activates when
    r(u) > eps.  Monotone-terminating because total residual shrinks by
    alpha per push."""
    return _push_spec(
        residual_init=lambda v: jnp.where(v.gid == source, 1.0, 0.0),
        active_init=lambda v: v.gid == source,
        alpha=alpha, eps=eps,
    )


@diffusive("pagerank", value_key="rank")
def pagerank(alpha: float = 0.15, eps: float = 1e-6) -> DiffusiveProgram:
    """Global PageRank by forward push from a uniform start distribution.

    Fixed point: rank = alpha * sum_k (1-alpha)^k (W^T)^k u, i.e.
    PageRank with teleport alpha.  A sum-combine diffusion where every
    vertex is a source — the densest operon traffic the engine
    generates."""
    def uniform(v):
        n = jnp.maximum(jnp.sum(v.node_ok.astype(jnp.float32)), 1.0)
        return jnp.where(v.node_ok, 1.0 / n, 0.0)

    return _push_spec(residual_init=uniform, active_init=None,
                      alpha=alpha, eps=eps)


# --------------------------------------------------------------------------
# proof of extensibility: two programs written purely through the public
# extension point (no engine, kernel, or session changes)
# --------------------------------------------------------------------------

@diffusive("widest", value_key="width", monotone=True, lane_param="source")
def widest(source: int, track_parents: bool = False) -> DiffusiveProgram:
    """Widest path (max-bottleneck): the best path maximizes the minimum
    edge weight along it.  A *max-combine* selection diffusion —
    msg = min(width(src), w); predicate ``msg > width(v)``."""
    state = {"width": Field(jnp.float32,
                            init=lambda v: jnp.where(v.gid == source,
                                                     jnp.inf, -jnp.inf),
                            on_dead=-jnp.inf)}
    if track_parents:
        state["parent"] = Field(jnp.int32,
                                init=lambda v: jnp.where(v.gid == source,
                                                         source, -1))

    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox > vstate["width"]) & node_ok
        out = dict(vstate)
        out["width"] = jnp.where(better, inbox, vstate["width"])
        if track_parents and payload is not None:
            out["parent"] = jnp.where(better, payload, vstate["parent"])
        return out, better

    return DiffusiveProgram(
        monoid="max",
        msg_dtype=jnp.float32,
        state=state,
        init_active=lambda v: v.gid == source,
        emit=lambda s, weight, src_gid, dst_gid:
            jnp.minimum(s["width"], weight),
        receive=receive,
        payload=(lambda s, src_gid: src_gid) if track_parents else None,
        priority=lambda vstate: -vstate["width"],
    )


@diffusive("reach", value_key="reached", monotone=True)
def reach(sources) -> DiffusiveProgram:
    """Reachability from a vertex set: reached(v) = 1 iff some source
    reaches v.  Logical-or over {0, 1} — a max-class monoid — seeded from
    every source at once (one diffusion, not |sources| BFS runs)."""
    srcs = tuple(int(s) for s in sources)

    def in_set(v):
        return jnp.isin(v.gid, jnp.asarray(srcs, jnp.int32))

    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox > vstate["reached"]) & node_ok
        return ({"reached": jnp.where(better, inbox, vstate["reached"])},
                better)

    return DiffusiveProgram(
        monoid="max",
        msg_dtype=jnp.int32,
        state={"reached": Field(jnp.int32,
                                init=lambda v: in_set(v).astype(jnp.int32),
                                on_dead=0)},
        init_active=in_set,
        emit=lambda s, weight, src_gid, dst_gid: s["reached"],
        receive=receive,
    )


# --------------------------------------------------------------------------
# factory aliases (PR 1/2 call style: ``sssp_program(0)``)
# --------------------------------------------------------------------------

sssp_program = sssp.build
bfs_program = bfs.build
cc_program = cc.build
ppr_program = ppr.build
pagerank_program = pagerank.build
widest_program = widest.build
reach_program = reach.build
