"""Deterministic, seeded fault injection for durability tests and benches.

The durability stack (core/journal.py, session save/open, the checkpoint
writer) is instrumented with named *chaos points* — no-op hooks that a
test can arm to simulate a process death (``ChaosKill``) or a torn write
(a partial ``write()`` followed by death) at an exact, reproducible spot:

    with chaos.harness(chaos.ChaosMonkey(kill_at=("commit.applied", 1))):
        sess.commit()          # raises ChaosKill on the 2nd hit

Instrumented code calls ``chaos.point(name)`` at kill points and routes
file appends through ``chaos.chaos_write(f, data, name)`` at tear
points.  Both are free when no harness is active (one global ``is None``
check), so the hooks stay in production paths.

Determinism: a monkey is armed with explicit ``(point, hit_index)``
coordinates; the only randomness — the tear offset when none is given —
comes from ``random.Random(seed)``.  A ``record_only`` monkey never
kills; tests use one to enumerate how many times each point fires for a
workload, then iterate killing at every coordinate.

Chaos-point catalog (see DESIGN.md §2.13):

====================================  =======================================
point                                 fires
====================================  =======================================
``journal.append``                    tear point: the full journal frame write
``commit.journal-appended``           after WAL append, before graph mutation
``commit.applied``                    after graph mutation + name release,
                                      before cache repairs
``commit.repaired``                   after cache repairs (commit complete)
``checkpoint.leaf-written``           after each snapshot leaf ``.npy`` write
``checkpoint.pre-rename``             before the atomic tmp-dir rename that
                                      publishes a snapshot
``serve.step``                        after each durable serve-loop step
====================================  =======================================
"""

from __future__ import annotations

import random
from contextlib import contextmanager

KNOWN_POINTS = (
    "journal.append",
    "commit.journal-appended",
    "commit.applied",
    "commit.repaired",
    "checkpoint.leaf-written",
    "checkpoint.pre-rename",
    "serve.step",
)


class ChaosKill(BaseException):
    """Simulated process death.

    Derives from ``BaseException`` so ordinary ``except Exception``
    recovery code in the paths under test cannot accidentally swallow
    the "crash" and keep running.
    """


class ChaosMonkey:
    """One armed fault: kill or tear at an exact (point, hit) coordinate.

    ``kill_at=(name, k)`` raises ``ChaosKill`` on the k-th (0-based) hit
    of ``point(name)``.  ``tear_at=(name, k, nbytes)`` intercepts the
    k-th ``chaos_write`` at ``name``: writes only the first ``nbytes``
    bytes (seeded-random prefix when ``nbytes`` is None), flushes, and
    raises ``ChaosKill``.  ``record_only=True`` never faults — it just
    counts hits, so a dry run enumerates the coordinates a workload
    exposes.
    """

    def __init__(self, kill_at=None, tear_at=None, record_only=False, seed=0):
        if kill_at is not None and tear_at is not None:
            raise ValueError("arm either kill_at or tear_at, not both")
        self.kill_at = tuple(kill_at) if kill_at is not None else None
        self.tear_at = tuple(tear_at) if tear_at is not None else None
        self.record_only = bool(record_only)
        self._rng = random.Random(seed)
        self.counts: dict[str, int] = {}
        self.fired: tuple | None = None  # coordinate that actually faulted

    def _count(self, name: str) -> int:
        k = self.counts.get(name, 0)
        self.counts[name] = k + 1
        return k

    def hit(self, name: str) -> None:
        k = self._count(name)
        if self.record_only or self.kill_at is None:
            return
        if (name, k) == self.kill_at:
            self.fired = (name, k)
            raise ChaosKill(f"chaos kill at {name}#{k}")

    def write(self, f, data: bytes, name: str) -> None:
        k = self._count(name)
        if (not self.record_only and self.tear_at is not None
                and (name, k) == self.tear_at[:2]):
            nbytes = self.tear_at[2]
            if nbytes is None:
                nbytes = self._rng.randrange(max(len(data), 1))
            f.write(data[: int(nbytes)])
            f.flush()
            self.fired = (name, k)
            raise ChaosKill(f"chaos tear at {name}#{k} ({nbytes}B of {len(data)}B)")
        f.write(data)


_ACTIVE: ChaosMonkey | None = None


@contextmanager
def harness(monkey: ChaosMonkey):
    """Install ``monkey`` as the process-wide fault injector for the block."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = monkey
    try:
        yield monkey
    finally:
        _ACTIVE = prev


def active() -> ChaosMonkey | None:
    return _ACTIVE


def point(name: str) -> None:
    """Kill point: no-op unless a harness is active."""
    if _ACTIVE is not None:
        _ACTIVE.hit(name)


def chaos_write(f, data: bytes, name: str) -> None:
    """Tearable write: ``f.write(data)`` unless a harness tears it."""
    if _ACTIVE is not None:
        _ACTIVE.write(f, data, name)
    else:
        f.write(data)


# ---------------------------------------------------------------------------
# post-hoc corruption helpers (operate on files already on disk)


def tear_file(path: str, nbytes: int) -> None:
    """Truncate ``path`` to its first ``nbytes`` bytes (simulated torn write)."""
    with open(path, "rb+") as f:
        f.truncate(int(nbytes))


def corrupt_file(path: str, offset: int | None = None, seed: int = 0) -> int:
    """Flip one byte of ``path`` (seeded-random offset when not given).

    Returns the corrupted offset so tests can report it on failure.
    """
    with open(path, "rb+") as f:
        f.seek(0, 2)
        size = f.tell()
        if size == 0:
            raise ValueError(f"cannot corrupt empty file {path}")
        if offset is None:
            offset = random.Random(seed).randrange(size)
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))
    return int(offset)


def poison_vstate(session, value=float("nan")) -> list:
    """Overwrite one element of every cached float vstate leaf with ``value``.

    Simulates silent in-memory corruption of cached vertex state; the
    session's ``validate=`` guard is expected to catch it at the next
    query.  Returns the list of poisoned cache keys.
    """
    import dataclasses

    import jax.numpy as jnp

    poisoned = []
    for key, entry in session._cache.items():
        if entry.vstate is None:
            continue
        vstate = dict(entry.vstate)
        hit = False
        for fname, leaf in vstate.items():
            if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
                flat = jnp.ravel(jnp.asarray(leaf))
                flat = flat.at[0].set(value)
                vstate[fname] = jnp.reshape(flat, jnp.shape(leaf))
                hit = True
                break
        if hit:
            session._cache[key] = dataclasses.replace(entry, vstate=vstate)
            poisoned.append(key)
    return poisoned
