"""Graph-family generators used by the paper's experiments (Table II).

Five families: Erdős–Rényi, Small-World (Watts–Strogatz), Scale-Free
(Barabási–Albert), Powerlaw-Clustered (Holme–Kim), and Graph500 (RMAT /
stochastic Kronecker).  All generators are host-side numpy (the data pipeline
boundary), seedable, and return symmetric (both directions) deduplicated edge
lists without self-loops, plus optional uniform random weights.

All generators are fully vectorized so graph500 s18-s20 class inputs
(hundreds of thousands to millions of vertices, tens of millions of directed
edges) build in seconds; edge streams are int32 end-to-end.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "erdos_renyi",
    "small_world",
    "scale_free",
    "powerlaw_cluster",
    "graph500_rmat",
    "GENERATORS",
    "make_graph_family",
]


def _symmetrize_dedup(src: np.ndarray, dst: np.ndarray, n: int):
    """Drop self loops, symmetrize, deduplicate. Returns (src, dst).

    Works on packed int64 keys only (one unique, no index array), so the peak
    footprint is ~2 int64 arrays of the directed edge count; output is int32.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = np.concatenate([src * n + dst, dst * n + src])
    del src, dst
    key = np.unique(key)  # sorted + deduplicated
    return (key // n).astype(np.int32), (key % n).astype(np.int32)


def erdos_renyi(n: int, avg_degree: float = 8.0, seed: int = 0):
    """G(n, m) with m = n * avg_degree / 2 undirected edges."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    src = rng.integers(0, n, size=2 * m)  # oversample; dedup trims
    dst = rng.integers(0, n, size=2 * m)
    return _symmetrize_dedup(src, dst, n)


def small_world(n: int, k: int = 8, beta: float = 0.1, seed: int = 0):
    """Watts–Strogatz: ring lattice with k neighbors, rewire prob beta."""
    rng = np.random.default_rng(seed)
    base = np.arange(n, dtype=np.int64)
    srcs, dsts = [], []
    for j in range(1, k // 2 + 1):
        s = base
        d = (base + j) % n
        rewire = rng.random(n) < beta
        d = np.where(rewire, rng.integers(0, n, size=n), d)
        srcs.append(s)
        dsts.append(d)
    return _symmetrize_dedup(np.concatenate(srcs), np.concatenate(dsts), n)


def _resolve_repeated(ref: np.ndarray, m: int) -> np.ndarray:
    """Resolve preferential-attachment picks against the virtual repeated
    array of the Batagelj–Brandes construction.

    The repeated-nodes array ``A`` is never materialized: ``A[:m]`` are the
    seed vertices ``0..m-1``, and thereafter edge ``k`` (``k = 0..E-1``)
    appends its source at position ``m + 2k`` and its target at ``m + 2k+1``.
    ``ref[k]`` is a uniform pick from ``[0, m + 2k)``; an odd-offset pick
    lands on an earlier target slot, i.e. on ``ref`` of an earlier edge, so
    picks form chains that always terminate at a seed vertex or a source
    slot.  Chain length halves the index each hop, so the loop runs
    O(log E) iterations over the full array.
    """
    t = ref.copy()
    while True:
        odd = (t >= m) & ((t - m) & 1 == 1)
        if not odd.any():
            break
        t[odd] = ref[(t[odd] - m) >> 1]
    return t


def scale_free(n: int, m: int = 4, seed: int = 0):
    """Barabási–Albert preferential attachment, fully vectorized.

    Uses the Batagelj–Brandes repeated-nodes construction: sampling a
    uniform position in the (virtual) array of all edge endpoints is
    degree-proportional sampling.  One batched RNG draw + O(log E) pointer
    resolution replaces the former per-vertex Python loop.
    """
    rng = np.random.default_rng(seed)
    if n <= m:
        e = np.empty(0, np.int64)
        return _symmetrize_dedup(e, e, max(n, 1))
    edges = (n - m) * m
    k = np.arange(edges, dtype=np.int64)
    src = m + k // m
    ref = rng.integers(0, m + 2 * k)
    t = _resolve_repeated(ref, m)
    # decode a repeated-array position into a vertex id: seeds are
    # themselves; even offsets are edge sources (m + k//m for edge k)
    dst = np.where(t < m, t, m + ((t - m) >> 1) // m)
    return _symmetrize_dedup(src, dst, n)


def powerlaw_cluster(n: int, m: int = 4, p: float = 0.5, seed: int = 0):
    """Holme–Kim: BA growth where each step closes a triangle w.p. ``p``.

    Vectorized over vertices: for each vertex's edge slot j > 0, with
    probability ``p`` the pick is redirected to the *partner endpoint* of the
    previous slot's edge (the neighbor-of-previous-target triad step); the
    partner of repeated-array position ``x >= m`` is ``m + ((x - m) ^ 1)``.
    Self-loops/duplicates this shortcut may create are removed by the final
    dedup pass, matching the generator's contract.
    """
    rng = np.random.default_rng(seed)
    if n <= m:
        e = np.empty(0, np.int64)
        return _symmetrize_dedup(e, e, max(n, 1))
    edges = (n - m) * m
    k = np.arange(edges, dtype=np.int64)
    src = m + k // m
    ref = rng.integers(0, m + 2 * k).reshape(n - m, m)
    triad = (rng.random(edges) < p).reshape(n - m, m)
    for j in range(1, m):  # m is tiny (default 4); rows stay vectorized
        prev = ref[:, j - 1]
        has_partner = prev >= m
        partner = np.where(has_partner, m + ((prev - m) ^ 1), prev)
        ref[:, j] = np.where(triad[:, j] & has_partner, partner, ref[:, j])
    t = _resolve_repeated(ref.reshape(-1), m)
    dst = np.where(t < m, t, m + ((t - m) >> 1) // m)
    return _symmetrize_dedup(src, dst, n)


def graph500_rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
):
    """Graph500 RMAT (stochastic Kronecker) generator, vectorized."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    dt = np.int32 if scale < 31 else np.int64
    src = np.zeros(m, dt)
    dst = np.zeros(m, dt)
    ab = a + b
    c_norm = c / (1.0 - ab)
    a_norm = a / ab
    for i in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 > ab
        dst_bit = np.where(src_bit, r2 > c_norm, r2 > a_norm)
        src |= src_bit.astype(dt) << dt(i)
        dst |= dst_bit.astype(dt) << dt(i)
    # graph500 permutes vertex labels to break locality
    perm = rng.permutation(n).astype(dt)
    return _symmetrize_dedup(perm[src], perm[dst], n)


GENERATORS = {
    "erdos_renyi": erdos_renyi,
    "small_world": small_world,
    "scale_free": scale_free,
    "powerlaw_cluster": powerlaw_cluster,
    "graph500": graph500_rmat,
}


def make_graph_family(name: str, n: int, seed: int = 0, weighted: bool = True):
    """Build one of the paper's five graph families at ~n vertices.

    Returns (src, dst, weight, n). ``n`` in the result is the *actual*
    vertex-id space of the returned edges — for graph500 it is the next
    power of two >= the request (never smaller), and callers must size
    labels/weights off the returned value. Weights are uniform [1, 8) as is
    customary for weighted SSSP benchmarks (Graph500 SSSP uses uniform
    weights).
    """
    if name == "erdos_renyi":
        src, dst = erdos_renyi(n, avg_degree=8, seed=seed)
    elif name == "small_world":
        src, dst = small_world(n, k=8, beta=0.1, seed=seed)
    elif name == "scale_free":
        src, dst = scale_free(n, m=4, seed=seed)
    elif name == "powerlaw_cluster":
        src, dst = powerlaw_cluster(n, m=4, p=0.5, seed=seed)
    elif name == "graph500":
        scale = max(1, int(np.ceil(np.log2(max(2, n)))))
        src, dst = graph500_rmat(scale, seed=seed)
        n = 1 << scale
    else:  # pragma: no cover
        raise ValueError(f"unknown graph family {name!r}")
    rng = np.random.default_rng(seed + 1)
    w = (1.0 + 7.0 * rng.random(src.shape[0])).astype(np.float32) if weighted else None
    return src, dst, w, n


def degree_distribution(src: np.ndarray, n: int) -> np.ndarray:
    return np.bincount(src, minlength=n)


def clustering_coefficients(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Local clustering coefficient per vertex (host-side; small graphs)."""
    adj = [set() for _ in range(n)]
    for s, d in zip(src, dst):
        adj[s].add(int(d))
    out = np.zeros(n)
    for v in range(n):
        nb = list(adj[v])
        k = len(nb)
        if k < 2:
            continue
        links = sum(1 for i, u in enumerate(nb) for w in nb[i + 1 :] if w in adj[u])
        out[v] = 2.0 * links / (k * (k - 1))
    return out
