"""Graph-family generators used by the paper's experiments (Table II).

Five families: Erdős–Rényi, Small-World (Watts–Strogatz), Scale-Free
(Barabási–Albert), Powerlaw-Clustered (Holme–Kim), and Graph500 (RMAT /
stochastic Kronecker).  All generators are host-side numpy (the data pipeline
boundary), seedable, and return symmetric (both directions) deduplicated edge
lists without self-loops, plus optional uniform random weights.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "erdos_renyi",
    "small_world",
    "scale_free",
    "powerlaw_cluster",
    "graph500_rmat",
    "GENERATORS",
    "make_graph_family",
]


def _symmetrize_dedup(src: np.ndarray, dst: np.ndarray, n: int):
    """Drop self loops, symmetrize, deduplicate. Returns (src, dst)."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    a = np.concatenate([src, dst])
    b = np.concatenate([dst, src])
    key = a.astype(np.int64) * n + b
    _, idx = np.unique(key, return_index=True)
    return a[idx].astype(np.int32), b[idx].astype(np.int32)


def erdos_renyi(n: int, avg_degree: float = 8.0, seed: int = 0):
    """G(n, m) with m = n * avg_degree / 2 undirected edges."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    src = rng.integers(0, n, size=2 * m)  # oversample; dedup trims
    dst = rng.integers(0, n, size=2 * m)
    return _symmetrize_dedup(src, dst, n)


def small_world(n: int, k: int = 8, beta: float = 0.1, seed: int = 0):
    """Watts–Strogatz: ring lattice with k neighbors, rewire prob beta."""
    rng = np.random.default_rng(seed)
    base = np.arange(n, dtype=np.int64)
    srcs, dsts = [], []
    for j in range(1, k // 2 + 1):
        s = base
        d = (base + j) % n
        rewire = rng.random(n) < beta
        d = np.where(rewire, rng.integers(0, n, size=n), d)
        srcs.append(s)
        dsts.append(d)
    return _symmetrize_dedup(np.concatenate(srcs), np.concatenate(dsts), n)


def scale_free(n: int, m: int = 4, seed: int = 0):
    """Barabási–Albert preferential attachment via the repeated-nodes trick."""
    rng = np.random.default_rng(seed)
    targets = list(range(m))
    repeated: list[int] = []
    srcs, dsts = [], []
    for v in range(m, n):
        for t in targets:
            srcs.append(v)
            dsts.append(t)
            repeated.extend([v, t])
        # next targets: m distinct picks from repeated (degree-proportional)
        targets = []
        seen = set()
        while len(targets) < m:
            x = repeated[rng.integers(0, len(repeated))]
            if x not in seen:
                seen.add(x)
                targets.append(x)
    return _symmetrize_dedup(
        np.asarray(srcs, np.int64), np.asarray(dsts, np.int64), n
    )


def powerlaw_cluster(n: int, m: int = 4, p: float = 0.5, seed: int = 0):
    """Holme–Kim: BA growth where each step closes a triangle w.p. ``p``."""
    rng = np.random.default_rng(seed)
    repeated: list[int] = list(range(m))
    adj: list[set] = [set() for _ in range(n)]
    srcs, dsts = [], []

    def add(u, v):
        srcs.append(u)
        dsts.append(v)
        adj[u].add(v)
        adj[v].add(u)
        repeated.extend([u, v])

    for v in range(m, n):
        # first edge: preferential
        t = repeated[rng.integers(0, len(repeated))]
        add(v, t)
        added = 1
        prev = t
        while added < m:
            if rng.random() < p and adj[prev]:
                # triad formation: link to a neighbor of prev
                cands = [u for u in adj[prev] if u != v and u not in adj[v]]
                if cands:
                    u = cands[rng.integers(0, len(cands))]
                    add(v, u)
                    prev = u
                    added += 1
                    continue
            u = repeated[rng.integers(0, len(repeated))]
            if u != v and u not in adj[v]:
                add(v, u)
                prev = u
                added += 1
    return _symmetrize_dedup(
        np.asarray(srcs, np.int64), np.asarray(dsts, np.int64), n
    )


def graph500_rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
):
    """Graph500 RMAT (stochastic Kronecker) generator, vectorized."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    ab = a + b
    c_norm = c / (1.0 - ab)
    a_norm = a / ab
    for i in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 > ab
        dst_bit = np.where(src_bit, r2 > c_norm, r2 > a_norm)
        src |= src_bit.astype(np.int64) << i
        dst |= dst_bit.astype(np.int64) << i
    # graph500 permutes vertex labels to break locality
    perm = rng.permutation(n)
    return _symmetrize_dedup(perm[src], perm[dst], n)


GENERATORS = {
    "erdos_renyi": erdos_renyi,
    "small_world": small_world,
    "scale_free": scale_free,
    "powerlaw_cluster": powerlaw_cluster,
    "graph500": graph500_rmat,
}


def make_graph_family(name: str, n: int, seed: int = 0, weighted: bool = True):
    """Build one of the paper's five graph families at ~n vertices.

    Returns (src, dst, weight, n). Weights are uniform [1, 8) as is customary
    for weighted SSSP benchmarks (Graph500 SSSP uses uniform weights).
    """
    if name == "erdos_renyi":
        src, dst = erdos_renyi(n, avg_degree=8, seed=seed)
    elif name == "small_world":
        src, dst = small_world(n, k=8, beta=0.1, seed=seed)
    elif name == "scale_free":
        src, dst = scale_free(n, m=4, seed=seed)
    elif name == "powerlaw_cluster":
        src, dst = powerlaw_cluster(n, m=4, p=0.5, seed=seed)
    elif name == "graph500":
        scale = max(1, int(np.round(np.log2(max(2, n)))))
        src, dst = graph500_rmat(scale, seed=seed)
        n = 1 << scale
    else:  # pragma: no cover
        raise ValueError(f"unknown graph family {name!r}")
    rng = np.random.default_rng(seed + 1)
    w = (1.0 + 7.0 * rng.random(src.shape[0])).astype(np.float32) if weighted else None
    return src, dst, w, n


def degree_distribution(src: np.ndarray, n: int) -> np.ndarray:
    return np.bincount(src, minlength=n)


def clustering_coefficients(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Local clustering coefficient per vertex (host-side; small graphs)."""
    adj = [set() for _ in range(n)]
    for s, d in zip(src, dst):
        adj[s].add(int(d))
    out = np.zeros(n)
    for v in range(n):
        nb = list(adj[v])
        k = len(nb)
        if k < 2:
            continue
        links = sum(1 for i, u in enumerate(nb) for w in nb[i + 1 :] if w in adj[u])
        out[v] = 2.0 * links / (k * (k - 1))
    return out
