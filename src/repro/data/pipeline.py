"""Host-side data pipelines: deterministic, shardable, prefetching.

Three sources (one per model family) plus a generic prefetcher:

* :class:`TokenPipeline` — LM token streams.  Backed by a memmap of token
  ids (or a synthetic deterministic generator when no corpus is mounted).
  Each host reads its own disjoint slice (shard_id / num_shards), so the
  global batch assembles without any cross-host IO.
* :class:`GraphPipeline` — full-batch graphs + neighbor-sampled blocks via
  models.sampler (the real fanout sampler).
* :class:`RecsysPipeline` — synthetic clickstream with zipfian item
  popularity and a streaming logQ (sampling-probability) estimator, the
  input to the paper-standard logQ-corrected sampled softmax.
* :class:`Prefetcher` — background thread keeping ``depth`` batches ready.
"""

from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["TokenPipeline", "RecsysPipeline", "Prefetcher"]


class TokenPipeline:
    def __init__(self, batch: int, seq_len: int, vocab: int,
                 shard_id: int = 0, num_shards: int = 1,
                 memmap_path: str | None = None, seed: int = 0):
        self.batch = batch
        self.seq = seq_len
        self.vocab = vocab
        self.shard_id = shard_id
        self.num_shards = num_shards
        self._mm = None
        if memmap_path:
            self._mm = np.memmap(memmap_path, dtype=np.int32, mode="r")
        self._rng = np.random.default_rng(seed * 1000 + shard_id)
        self._pos = shard_id * batch * seq_len

    def __iter__(self):
        return self

    def __next__(self):
        b, s = self.batch, self.seq
        if self._mm is not None:
            need = b * (s + 1)
            stride = need * self.num_shards
            if self._pos + need >= len(self._mm):
                self._pos = self.shard_id * need
            chunk = np.asarray(self._mm[self._pos:self._pos + need])
            self._pos += stride
            arr = chunk.reshape(b, s + 1)
        else:
            # synthetic: markov-ish stream so loss can actually decrease
            base = self._rng.integers(0, self.vocab, size=(b, 1))
            steps = self._rng.integers(-3, 4, size=(b, s))
            arr = (base + np.cumsum(steps, 1)) % self.vocab
            arr = np.concatenate([base % self.vocab, arr], axis=1)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}


class RecsysPipeline:
    def __init__(self, batch: int, cfg, shard_id: int = 0,
                 num_shards: int = 1, seed: int = 0):
        self.batch = batch
        self.cfg = cfg
        self._rng = np.random.default_rng(seed * 1000 + shard_id)
        # zipf over items; logQ estimated from the analytic distribution
        v = cfg.item_vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()

    def __iter__(self):
        return self

    def __next__(self):
        cfg, b = self.cfg, self.batch
        rng = self._rng
        items = rng.choice(cfg.item_vocab, size=b, p=self._p)
        out = {
            "user_ids": rng.integers(
                -1, cfg.user_vocab,
                size=(b, cfg.n_user_fields, cfg.bag_len)
            ).astype(np.int32),
            "user_dense": rng.normal(size=(b, cfg.n_dense)).astype(
                np.float32
            ),
            "item_ids": items.astype(np.int32),
            "item_dense": rng.normal(size=(b, cfg.n_dense)).astype(
                np.float32
            ),
            "item_logq": np.log(self._p[items]).astype(np.float32),
        }
        return out


class Prefetcher:
    """Background-thread prefetch of any iterator (depth-bounded)."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()

        def run():
            try:
                for x in it:
                    self._q.put(x)
            finally:
                self._q.put(self._done)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if x is self._done:
            raise StopIteration
        return x
