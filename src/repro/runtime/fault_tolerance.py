"""Fault tolerance: failure detection, straggler mitigation, preemption.

Designed for the 1000+-node regime where *something is always broken*:

* :class:`HeartbeatMonitor` — workers post heartbeats; a detector thread
  flags nodes silent for > timeout.  At JAX level a failed host manifests
  as a collective timeout; the driver's response is restore-on-survivors
  (see ElasticScaler).
* :class:`StragglerMonitor` — sliding-window step-time stats; steps slower
  than ``factor`` x the rolling median mark the epoch as straggling and fire
  a mitigation callback (the trainer's default: log + after ``patience``
  consecutive stragglers, request a re-shard without the slow host).
* :class:`PreemptionGuard` — SIGTERM/SIGINT set a flag the train loop polls;
  the loop checkpoints and exits cleanly (spot/preemptible-safe).
* :class:`ElasticScaler` — given the surviving device list, rebuilds the
  largest valid production mesh and re-lays-out a checkpoint onto it.
"""

from __future__ import annotations

import collections
import signal
import statistics
import threading
import time

import jax

__all__ = ["HeartbeatMonitor", "StragglerMonitor", "PreemptionGuard",
           "ElasticScaler", "largest_mesh_shape"]


class HeartbeatMonitor:
    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self._beats: dict[str, float] = {}
        self._lock = threading.Lock()

    def beat(self, node_id: str, t: float | None = None):
        with self._lock:
            self._beats[node_id] = time.monotonic() if t is None else t

    def dead_nodes(self, now: float | None = None):
        now = time.monotonic() if now is None else now
        with self._lock:
            return sorted(
                n for n, t in self._beats.items()
                if now - t > self.timeout_s
            )

    def alive_nodes(self, now: float | None = None):
        now = time.monotonic() if now is None else now
        with self._lock:
            return sorted(
                n for n, t in self._beats.items()
                if now - t <= self.timeout_s
            )


class StragglerMonitor:
    def __init__(self, window: int = 50, factor: float = 2.0,
                 patience: int = 5, on_straggle=None):
        self.times = collections.deque(maxlen=window)
        self.factor = factor
        self.patience = patience
        self.on_straggle = on_straggle
        self.consecutive = 0
        self.flagged_steps: list[int] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = statistics.median(self.times)
            if seconds > self.factor * med:
                is_straggler = True
                self.flagged_steps.append(step)
                self.consecutive += 1
                if (self.consecutive >= self.patience
                        and self.on_straggle is not None):
                    self.on_straggle(step, seconds, med)
                    self.consecutive = 0
            else:
                self.consecutive = 0
        self.times.append(seconds)
        return is_straggler


class PreemptionGuard:
    """SIGTERM/SIGINT -> flag; install() is idempotent and test-friendly.

    ``install()`` saves the handlers it replaces and ``uninstall()``
    restores them, so a guard never leaks its handlers past its own
    lifetime (pytest's SIGINT handling, nested guards, and embedding
    hosts all keep theirs).  The guard is also a context manager::

        with PreemptionGuard() as guard:
            while not guard.should_stop:
                step()
        # prior SIGTERM/SIGINT handlers are back here
    """

    def __init__(self):
        self._flag = threading.Event()
        self._installed = False
        self._prior: dict[int, object] = {}

    def install(self):
        if self._installed:
            return
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                prior = signal.signal(sig, lambda *_: self._flag.set())
            except ValueError:   # not main thread (tests)
                continue
            self._prior[sig] = prior
        self._installed = True

    def uninstall(self):
        """Restore the signal handlers install() replaced (idempotent)."""
        if not self._installed:
            return
        for sig, prior in self._prior.items():
            try:
                signal.signal(sig, prior)
            except (ValueError, TypeError):  # not main thread / exotic prior
                pass
        self._prior = {}
        self._installed = False

    def __enter__(self):
        self.install()
        return self

    def __exit__(self, *exc):
        self.uninstall()

    def trigger(self):           # test hook / external orchestrator
        self._flag.set()

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()


def largest_mesh_shape(n_devices: int, model_parallel: int = 16):
    """Largest (data, model) mesh on the surviving devices; shrinks model
    parallelism if necessary (elastic down-scaling policy)."""
    mp = model_parallel
    while mp > 1 and n_devices % mp != 0:
        mp //= 2
    return (max(1, n_devices // mp), mp)


class ElasticScaler:
    """Rebuild mesh + restore a checkpoint after membership change."""

    def __init__(self, checkpoint_manager, axis_names=("data", "model")):
        self.ckpt = checkpoint_manager
        self.axis_names = axis_names

    def rescale(self, target_tree, sharding_fn, devices=None, step=None):
        """devices: surviving jax devices (default: all visible).
        sharding_fn(mesh, tree_struct) -> shardings pytree."""
        devices = devices if devices is not None else jax.devices()
        shape = largest_mesh_shape(len(devices))
        mesh = jax.sharding.Mesh(
            __import__("numpy").array(devices[: shape[0] * shape[1]])
            .reshape(shape),
            self.axis_names,
        )
        structs = jax.eval_shape(lambda t: t, target_tree)
        shardings = sharding_fn(mesh, structs)
        tree, step = self.ckpt.restore(target_tree, step=step,
                                       shardings=shardings)
        return tree, mesh, step
