"""Production training driver: checkpoint/restart, preemption, stragglers.

The loop is deliberately boring — all the machinery lives in the components
it composes (CheckpointManager, PreemptionGuard, StragglerMonitor) so each
is testable in isolation (tests/test_runtime.py kills and resumes it).
"""

from __future__ import annotations

import json
import os
import time

import jax

from ..checkpoint.manager import CheckpointManager
from .fault_tolerance import PreemptionGuard, StragglerMonitor

__all__ = ["train_loop"]


def train_loop(
    step_fn,                 # (params, opt_state, step_no, batch) -> ...
    params,
    opt_state,
    data_iter,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 100,
    log_path: str | None = None,
    guard: PreemptionGuard | None = None,
    resume: bool = True,
    on_metrics=None,
):
    """Run (or resume) training; returns (params, opt_state, last_step)."""
    ckpt = CheckpointManager(ckpt_dir)
    guard = guard or PreemptionGuard()
    guard.install()
    straggler = StragglerMonitor()

    start = 0
    if resume and ckpt.latest_step() is not None:
        (params, opt_state), start = ckpt.restore((params, opt_state))
        start += 1

    logf = open(log_path, "a") if log_path else None
    step = start - 1
    import jax.numpy as jnp

    for step in range(start, n_steps):
        batch = next(data_iter)
        t0 = time.time()
        params, opt_state, metrics = step_fn(
            params, opt_state, jnp.asarray(step), batch
        )
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        straggler.record(step, dt)
        if on_metrics is not None:
            on_metrics(step, metrics, dt)
        if logf:
            logf.write(json.dumps({
                "step": step,
                "loss": float(metrics["loss"]),
                "grad_norm": float(metrics.get("grad_norm", 0.0)),
                "seconds": dt,
            }) + "\n")
            logf.flush()
        if (step + 1) % ckpt_every == 0 or step == n_steps - 1:
            ckpt.save(step, (params, opt_state))
        if guard.should_stop:
            ckpt.save(step, (params, opt_state), wait=True)
            break
    ckpt.wait()
    if logf:
        logf.close()
    return params, opt_state, step
