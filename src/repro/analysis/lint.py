"""Repo-specific AST lint pass (DESIGN.md §2.11) — rules ruff cannot
express because they depend on this engine's execution model: which
functions are reachable from the jitted hot paths, which Python loops
are static unrolls, and which wide intermediates are ``enable_x64``-
guarded.

Rule catalog
------------

``host-sync``
    Host round-trips inside functions reachable from the hot roots
    (``diffuse`` / ``diffuse_from`` / ``_run_rounds`` /
    ``diffuse_spmd_step`` / ``apply_updates`` / ``edge_relax*``):
    ``np.asarray`` / ``np.array`` materialization, ``.item()`` /
    ``.tolist()`` / ``.block_until_ready()``, ``jax.device_get``,
    ``int()`` / ``float()`` / ``bool()`` over a computed (call-bearing)
    expression, and implicit ``bool()`` of a device array via
    ``.any()`` / ``.all()`` in an ``if`` / ``while`` test.  Each of
    these forces a device->host sync (or trips
    ``jax.transfer_guard("disallow")``) when it runs per round instead
    of per query.

``host-loop``
    Python ``for`` statements in hot-reachable functions whose iterable
    is not a ``range(...)`` (static unrolls over a shape are fine;
    loops over shard/cell *containers* serialize the engine on the
    host).

``int64``
    ``jnp.int64`` / ``jnp.uint64`` used lexically outside a
    ``with enable_x64():`` block (checked file-wide, not just on hot
    paths).  Without the x64 flag jax silently degrades these to 32-bit
    — the composite-key merge paths would corrupt at scale.

``mutation``
    Assignment into a subscript (``arr[i] = ...``, ``arr[i] += ...``)
    inside an ``emit`` / ``receive`` / ``on_send`` action body.  Action
    bodies are traced into the relaxation kernels; in-place mutation of
    a captured or argument array is either a tracer error or — worse —
    a silent host-side aliasing bug.

Allowlist convention
--------------------

Append ``# analysis: allow(<rule>)`` — optionally
``# analysis: allow(<rule>): <one-line justification>`` — to the
offending line to suppress one finding, or to the ``def`` line of the
enclosing function to allow that rule for the whole body.  Several
rules may be listed comma-separated.

CLI
---

``python -m repro.analysis.lint PATH [PATH ...]`` scans ``.py`` files
under each path (building one cross-file call graph for reachability),
prints findings as ``path:line:col: rule: message``, and exits nonzero
iff any finding survives the allowlist.  Stdlib-only: it runs in the CI
lint job beside ruff without importing jax.
"""

from __future__ import annotations

import ast
import re
import sys
from dataclasses import dataclass, field
from pathlib import Path

__all__ = ["Finding", "lint_paths", "main", "RULES", "HOT_ROOTS"]

RULES = ("host-sync", "host-loop", "int64", "mutation")

# Hot roots: the jitted engine entry points plus the host orchestration
# wrappers that run once per *round-trip-free* query.  Anything they can
# reach (by name, cross-module) must stay sync-free.
HOT_ROOTS = frozenset({
    "diffuse", "diffuse_from", "_run_rounds", "diffuse_spmd_step",
    "apply_updates",
})
HOT_ROOT_PREFIXES = ("edge_relax",)

_NP_MODULE_NAMES = frozenset({"np", "numpy", "onp"})
_SYNC_METHODS = frozenset({"item", "tolist", "block_until_ready"})
_SCALARIZERS = frozenset({"int", "float", "bool"})
_ACTION_BODY_RE = re.compile(r"^(emit|receive|on_send)")
_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(([^)]*)\)")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"


@dataclass
class _Func:
    """One function/method definition plus its outgoing call names."""

    name: str
    node: ast.AST
    path: str
    def_line: int
    calls: set = field(default_factory=set)
    children: list = field(default_factory=list)


# --------------------------------------------------------------------------
# collection: functions + call edges (cross-module, name-matched)
# --------------------------------------------------------------------------

def _called_name(call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _collect_functions(tree: ast.Module, path: str) -> list[_Func]:
    """All function defs in ``tree`` with their call-name edges.

    Calls are attributed to the innermost enclosing function; nested
    defs become ``children`` (a reachable function's nested defs are
    reachable — they run inside its trace)."""
    funcs: list[_Func] = []

    def visit(node, owner: _Func | None):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn = _Func(child.name, child, path, child.lineno)
                funcs.append(fn)
                if owner is not None:
                    owner.children.append(fn)
                visit(child, fn)
            else:
                if owner is not None and isinstance(child, ast.Call):
                    name = _called_name(child)
                    if name:
                        owner.calls.add(name)
                visit(child, owner)

    visit(tree, None)
    return funcs


def _reachable(funcs: list[_Func]) -> set[int]:
    """ids of function nodes reachable from the hot roots (BFS over the
    name-matched call graph; conservative — any def matching a called
    bare/attr name is an edge target)."""
    by_name: dict[str, list[_Func]] = {}
    for fn in funcs:
        by_name.setdefault(fn.name, []).append(fn)

    def is_root(name: str) -> bool:
        return name in HOT_ROOTS or any(
            name.startswith(p) for p in HOT_ROOT_PREFIXES)

    seen: set[int] = set()
    work = [fn for fn in funcs if is_root(fn.name)]
    while work:
        fn = work.pop()
        if id(fn.node) in seen:
            continue
        seen.add(id(fn.node))
        work.extend(fn.children)       # nested defs run inside the trace
        for name in fn.calls:
            work.extend(by_name.get(name, ()))
    return seen


# --------------------------------------------------------------------------
# the rules
# --------------------------------------------------------------------------

def _walk_shallow(fn_node: ast.AST):
    """Walk a function body without descending into nested defs (they
    are linted as functions in their own right)."""
    stack = list(ast.iter_child_nodes(fn_node))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(node))


def _has_computing_call(expr: ast.AST) -> bool:
    """True when the expression contains a call other than len()/range()
    — the signature of a value that may be a device array."""
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call):
            f = sub.func
            if isinstance(f, ast.Name) and f.id in ("len", "range"):
                continue
            return True
    return False


def _check_host_sync(fn: _Func, out: list[Finding]):
    for node in _walk_shallow(fn.node):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute):
                if f.attr in _SYNC_METHODS:
                    out.append(Finding(
                        fn.path, node.lineno, node.col_offset, "host-sync",
                        f".{f.attr}() forces a device->host sync in "
                        f"hot-reachable {fn.name!r}"))
                elif (f.attr in ("asarray", "array")
                        and isinstance(f.value, ast.Name)
                        and f.value.id in _NP_MODULE_NAMES):
                    out.append(Finding(
                        fn.path, node.lineno, node.col_offset, "host-sync",
                        f"{f.value.id}.{f.attr}() materializes on host in "
                        f"hot-reachable {fn.name!r}"))
                elif f.attr == "device_get":
                    out.append(Finding(
                        fn.path, node.lineno, node.col_offset, "host-sync",
                        f"jax.device_get in hot-reachable {fn.name!r}"))
            elif isinstance(f, ast.Name):
                if f.id == "device_get":
                    out.append(Finding(
                        fn.path, node.lineno, node.col_offset, "host-sync",
                        f"device_get in hot-reachable {fn.name!r}"))
                elif f.id in _SCALARIZERS and any(
                        _has_computing_call(a) for a in node.args):
                    out.append(Finding(
                        fn.path, node.lineno, node.col_offset, "host-sync",
                        f"{f.id}() over a computed value blocks on the "
                        f"device in hot-reachable {fn.name!r}"))
        elif isinstance(node, (ast.If, ast.While)):
            for sub in ast.walk(node.test):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr in ("any", "all")):
                    out.append(Finding(
                        fn.path, sub.lineno, sub.col_offset, "host-sync",
                        f"branching on .{sub.func.attr}() implicitly "
                        f"bool()s a device array in hot-reachable "
                        f"{fn.name!r}"))


def _check_host_loop(fn: _Func, out: list[Finding]):
    for node in _walk_shallow(fn.node):
        if not isinstance(node, ast.For):
            continue
        it = node.iter
        if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                and it.func.id == "range"):
            continue                    # static unroll over a shape
        out.append(Finding(
            fn.path, node.lineno, node.col_offset, "host-loop",
            f"Python for over a non-range iterable in hot-reachable "
            f"{fn.name!r} serializes cells on the host"))


def _check_int64(tree: ast.Module, path: str, out: list[Finding],
                 def_lines: dict[int, int]):
    """File-wide: jnp 64-bit integer dtypes lexically outside a
    ``with enable_x64():`` block.  ``def_lines`` maps finding line ->
    enclosing def line for def-level allowlisting."""

    def is_x64_with(node: ast.With) -> bool:
        for item in node.items:
            c = item.context_expr
            if isinstance(c, ast.Call):
                f = c.func
                name = f.id if isinstance(f, ast.Name) else (
                    f.attr if isinstance(f, ast.Attribute) else None)
                if name == "enable_x64":
                    return True
        return False

    def scan(node, guarded: bool, defs: tuple):
        for child in ast.iter_child_nodes(node):
            g = guarded
            d = defs
            if isinstance(child, ast.With) and is_x64_with(child):
                g = True
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                d = defs + (child.lineno,)
            if (not g and isinstance(child, ast.Attribute)
                    and child.attr in ("int64", "uint64")
                    and isinstance(child.value, ast.Name)
                    and child.value.id in ("jnp", "jax")):
                out.append(Finding(
                    path, child.lineno, child.col_offset, "int64",
                    f"jnp.{child.attr} outside an enable_x64 scope "
                    f"silently degrades to 32-bit"))
                if d:
                    def_lines[child.lineno] = d
            scan(child, g, d)

    scan(tree, False, ())


def _check_mutation(funcs: list[_Func], out: list[Finding],
                    def_lines: dict[int, int]):
    """Flag subscript assignment whose base is an *argument* or
    *captured* name inside an emit/receive/on_send body.  A container
    the body itself created (``out = dict(vstate); out["k"] = ...``) is
    the idiomatic pure-update pattern and stays clean."""
    for fn in funcs:
        if not _ACTION_BODY_RE.match(fn.name):
            continue
        params = {a.arg for a in fn.node.args.args
                  + fn.node.args.kwonlyargs
                  + fn.node.args.posonlyargs}
        local_names = set()
        for node in _walk_shallow(fn.node):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                        else (t,)
                    local_names.update(e.id for e in elts
                                       if isinstance(e, ast.Name))
        for node in _walk_shallow(fn.node):
            targets = ()
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AugAssign):
                targets = (node.target,)
            for t in targets:
                for sub in ast.walk(t):
                    if not isinstance(sub, ast.Subscript):
                        continue
                    base = sub.value
                    captured = (isinstance(base, ast.Attribute)
                                or (isinstance(base, ast.Name)
                                    and (base.id in params
                                         or base.id not in local_names)))
                    if captured:
                        out.append(Finding(
                            fn.path, node.lineno, node.col_offset,
                            "mutation",
                            f"in-place subscript assignment to a captured "
                            f"or argument value inside action body "
                            f"{fn.name!r}; actions must stay pure "
                            f"(use .at[...].set)"))
                        def_lines.setdefault(node.lineno, fn.def_line)
                        break


# --------------------------------------------------------------------------
# allowlist + driver
# --------------------------------------------------------------------------

def _allow_map(source: str) -> dict[int, set]:
    allows: dict[int, set] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            allows[i] = rules
    return allows


def lint_paths(paths) -> list[Finding]:
    """Lint every ``.py`` file under ``paths`` (files or directories);
    returns the findings that survive the allowlist."""
    files = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
        else:
            raise FileNotFoundError(f"no such python file or dir: {p}")

    parsed = []
    all_funcs: list[_Func] = []
    for f in files:
        source = f.read_text()
        tree = ast.parse(source, filename=str(f))
        funcs = _collect_functions(tree, str(f))
        parsed.append((f, source, tree, funcs))
        all_funcs.extend(funcs)

    hot = _reachable(all_funcs)

    findings: list[Finding] = []
    for f, source, tree, funcs in parsed:
        raw: list[Finding] = []
        def_lines: dict[int, int] = {}      # finding line -> def line
        for fn in funcs:
            if id(fn.node) in hot:
                n0 = len(raw)
                _check_host_sync(fn, raw)
                _check_host_loop(fn, raw)
                for fd in raw[n0:]:
                    def_lines.setdefault(fd.line, fn.def_line)
        _check_int64(tree, str(f), raw, def_lines)
        _check_mutation(funcs, raw, def_lines)

        allows = _allow_map(source)

        def allowed(fd: Finding) -> bool:
            lines = [fd.line]
            defs = def_lines.get(fd.line)
            if defs is not None:
                lines.extend(defs if isinstance(defs, tuple) else (defs,))
            for line in lines:
                rules = allows.get(line, ())
                if fd.rule in rules or "*" in rules:
                    return True
            return False

        findings.extend(fd for fd in raw if not allowed(fd))

    findings.sort(key=lambda fd: (fd.path, fd.line, fd.col, fd.rule))
    return findings


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro's engine-aware AST lint pass (DESIGN.md §2.11)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    args = ap.parse_args(argv)

    findings = lint_paths(args.paths)
    for fd in findings:
        print(fd.render())
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
