"""Runtime sanitizer harness (DESIGN.md §2.11).

:func:`sanitize` is a context manager that wires, around a block of
warm-path code:

* ``jax.transfer_guard(transfers)`` — ``"disallow"`` by default, so
  implicit transfers raise instead of silently syncing.  On
  accelerator backends that includes device->host scalarization
  (``.item()``, ``float()`` / ``bool()`` on a device array); on CPU
  the d2h leg is zero-copy and unguarded, so what trips in practice
  is the h2d *re-upload* leg of a host round-trip — which every
  per-round host detour eventually takes;
* a **jit cache-miss counter** over the engine's hot compilations
  (``_run_rounds`` — the fixed-point loop — and ``apply_updates`` —
  the commit scatter): on clean exit, any growth of their jit caches
  raises :class:`RetraceError`.  Warm ``session.query()`` across
  varying sources and warm ``UpdateBatch.apply`` across same-ladder
  batches must both report zero;
* optionally ``jax.debug_nans``.

Usage::

    from repro.analysis import sanitize

    with sanitize() as rep:
        sess.query("sssp", source=7, refresh=True)
    # raised on exit if anything transferred or retraced;
    # rep.retraces() has the per-function deltas for reporting

Also exposed as the ``sanitize`` pytest fixture (tests/conftest.py)
and exercised by the CI sanitize job.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

import jax

__all__ = ["RetraceError", "SanitizeReport", "sanitize", "tracked_jits"]


class RetraceError(AssertionError):
    """A tracked hot-path jit retraced inside a sanitize() block."""


def tracked_jits() -> dict:
    """The jitted hot paths whose compile caches the sanitizer watches.

    Resolved lazily so importing repro.analysis never drags the engine
    in; uses the jit wrappers' ``_cache_size`` introspection.  The
    submodules are resolved through importlib because ``repro.core``
    re-exports a ``diffuse`` *function* that shadows the submodule on a
    ``from ..core import diffuse``."""
    import importlib

    _diffuse = importlib.import_module("repro.core.diffuse")
    _updates = importlib.import_module("repro.core.updates")

    return {
        "_run_rounds": _diffuse._run_rounds,
        "apply_updates": _updates.apply_updates,
    }


def _cache_sizes(fns: dict) -> dict:
    return {name: fn._cache_size() for name, fn in fns.items()}


@dataclass
class SanitizeReport:
    """Cache-miss accounting for one sanitize() block."""

    baseline: dict
    _fns: dict = field(repr=False, default_factory=dict)

    def retraces(self) -> dict:
        """Per-tracked-function jit cache growth since entry."""
        now = _cache_sizes(self._fns)
        return {name: now[name] - self.baseline[name] for name in now}

    def total_retraces(self) -> int:
        return sum(self.retraces().values())


@contextlib.contextmanager
def sanitize(transfers: str | None = "disallow", retraces: bool = True,
             nans: bool = False):
    """Run a block under the full sanitizer (see module docstring).

    ``transfers`` is a ``jax.transfer_guard`` level (``"disallow"``,
    ``"disallow_explicit"``, ``"log"``, ...) or None to leave transfers
    unguarded; ``retraces=False`` disables the cache-miss check (e.g.
    for a deliberately-cold block); ``nans=True`` adds
    ``jax.debug_nans``.  Yields a :class:`SanitizeReport`; on clean
    exit with ``retraces=True`` raises :class:`RetraceError` if any
    tracked hot path recompiled inside the block."""
    fns = tracked_jits()
    report = SanitizeReport(_cache_sizes(fns), fns)
    with contextlib.ExitStack() as stack:
        if transfers is not None:
            stack.enter_context(jax.transfer_guard(transfers))
        if nans:
            stack.enter_context(jax.debug_nans(True))
        yield report
    # only on clean exit — an exception from the block propagates as-is
    if retraces:
        deltas = {k: v for k, v in report.retraces().items() if v}
        if deltas:
            raise RetraceError(
                f"hot-path jit cache grew inside sanitize(): {deltas} — "
                f"a warm query/apply must reuse its compiled entry "
                f"(check VertexProgram structural equality and the "
                f"pow2 batch ladder)")
