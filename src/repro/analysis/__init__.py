"""repro.analysis — the diffusion-engine sanitizer (DESIGN.md §2.11).

Three layers, ordered by when they fire:

* :mod:`~.lint` — a repo-specific AST lint pass (stdlib-only; runnable
  as ``python -m repro.analysis.lint src/repro/core src/repro/kernels``)
  catching host syncs, Python shard loops, unguarded int64 arithmetic,
  and action-body mutation *before* the code ever runs;
* :mod:`~.verify` — the registration-time program verifier: every
  lowered :class:`~repro.core.programs.DiffusiveProgram` is abstractly
  traced against its Field schema and its monoid spot-checked, so a
  broken spec fails at build time with a precise error instead of a
  bitwise mismatch at query time;
* :mod:`~.sanitizer` — the runtime sanitizer harness: a context manager
  wiring ``jax.transfer_guard`` + a jit cache-miss counter (and
  optionally ``debug_nans``) around warm-path code that must never
  transfer or retrace.

All exports resolve lazily: the lint layer must stay importable without
jax (the CI lint job has no accelerator stack warm), and eagerly
importing ``.lint`` here would shadow ``python -m repro.analysis.lint``
with a runpy double-import warning.
"""

__all__ = [
    "Finding",
    "lint_paths",
    "ProgramVerificationError",
    "verify_program",
    "RetraceError",
    "sanitize",
    "SanitizeReport",
    "tracked_jits",
]


_LAZY = {
    "Finding": "lint",
    "lint_paths": "lint",
    "ProgramVerificationError": "verify",
    "verify_program": "verify",
    "RetraceError": "sanitizer",
    "sanitize": "sanitizer",
    "SanitizeReport": "sanitizer",
    "tracked_jits": "sanitizer",
}


def __getattr__(name):
    submodule = _LAZY.get(name)
    if submodule is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(f".{submodule}", __name__), name)
    globals()[name] = value     # cache: later lookups skip __getattr__
    return value
