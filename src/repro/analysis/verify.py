"""Registration-time program verifier (DESIGN.md §2.11).

Every :class:`~repro.core.programs.DiffusiveProgram` that lowers to the
engine IR is abstractly traced — via ``jax.eval_shape`` under
``jax.checking_leaks`` — against its declared ``Field`` schema on a
tiny synthetic geometry, and its monoid is spot-checked on seeded
concrete values.  A broken spec therefore fails at *build* time with a
precise, named error instead of surfacing as a dtype promotion, a
shape blowup, a leaked tracer, or a bitwise mismatch deep inside a
query's fixed point.

Contract checked (the §2.7 authoring contract, mechanized):

* ``init``     — returns ``(vstate, active)``; vstate keys equal the
  schema keys exactly, every leaf has the view's shape and its Field's
  dtype, ``active`` is a bool mask of the view shape;
* ``emit``     — maps per-edge source state to a ``[Ep]`` message of
  exactly ``msg_dtype`` (dtype drift would silently promote through
  the segment-combine);
* ``receive``  — returns ``(vstate', activated)`` with the same schema
  and dtypes plus a bool activation mask;
* ``on_send``  — schema- and dtype-preserving;
* ``priority`` — a ``[Np]`` floating bucket key;
* ``payload``  — a ``[Ep]`` integer payload (argbest routing index);
* dead-slot splat — every ``Field.on_dead`` value must be
  representable in the field dtype (a non-finite splat into an integer
  field can never round-trip);
* leaked tracers — all abstract traces run under
  ``jax.checking_leaks``, so an action that stashes a tracer in a
  closure or global is rejected;
* monoid laws  — seeded associativity / commutativity / identity check
  of the declared combine monoid (floats to tolerance, everything else
  bitwise).

``verify_program`` is invoked automatically from
:func:`repro.core.programs.lower` (set ``REPRO_VERIFY=0`` to opt out,
e.g. when bisecting the verifier itself); it can also be called
directly on a spec.
"""

from __future__ import annotations

import os
import types

import jax
import jax.numpy as jnp
import numpy as np

from ..core.monoid import Monoid, as_monoid

__all__ = ["ProgramVerificationError", "verify_program", "verification_enabled"]

# synthetic verification geometry: tiny, but with >1 shard and >1 block
# so broadcast mistakes cannot hide behind size-1 axes
_S, _NP, _EP = 2, 8, 16


class ProgramVerificationError(Exception):
    """A diffusive-program spec violates the §2.7 authoring contract.

    Raised at build/registration time; the message names the program,
    the offending component (init/emit/receive/on_send/priority/
    payload/monoid), and what drifted."""


def verification_enabled() -> bool:
    return os.environ.get("REPRO_VERIFY", "1") not in ("0", "false", "no")


def _err(name: str, component: str, msg: str) -> ProgramVerificationError:
    return ProgramVerificationError(
        f"program {name or '<anonymous>'!r}: {component}: {msg}")


def _dt(x) -> np.dtype:
    return np.dtype(x)


def _view_structs():
    return types.SimpleNamespace(
        gid=jax.ShapeDtypeStruct((_S, _NP), jnp.int32),
        node_ok=jax.ShapeDtypeStruct((_S, _NP), jnp.bool_),
        out_degree=jax.ShapeDtypeStruct((_S, _NP), jnp.int32),
    )


def _eval_shape(name, component, fn, *args):
    """jax.eval_shape under checking_leaks, with errors rewrapped so the
    user sees which component of which program failed."""
    try:
        with jax.checking_leaks():
            return jax.eval_shape(fn, *args)
    except ProgramVerificationError:
        raise
    except Exception as e:  # noqa: B902 - rewrap any trace-time failure
        raise _err(
            name, component,
            f"abstract trace failed ({type(e).__name__}: {e})") from e


def _check_state(name, component, got, schema, shape):
    """A returned vstate must match the declared schema exactly."""
    if not isinstance(got, dict):
        raise _err(name, component,
                   f"must return a dict vertex state, got "
                   f"{type(got).__name__}")
    want = set(schema)
    have = set(got)
    if want != have:
        missing, extra = sorted(want - have), sorted(have - want)
        raise _err(
            name, component,
            f"state keys drifted from the declared schema: "
            f"missing {missing}, unexpected {extra}")
    for k, f in schema.items():
        leaf = got[k]
        if tuple(leaf.shape) != tuple(shape):
            raise _err(
                name, component,
                f"field {k!r} has shape {tuple(leaf.shape)}, expected "
                f"{tuple(shape)}")
        if _dt(leaf.dtype) != _dt(f.dtype):
            raise _err(
                name, component,
                f"field {k!r} has dtype {_dt(leaf.dtype)}, declared "
                f"{_dt(f.dtype)}")


def _check_mask(name, component, mask, shape, what="activation mask"):
    if tuple(mask.shape) != tuple(shape):
        raise _err(name, component,
                   f"{what} has shape {tuple(mask.shape)}, expected "
                   f"{tuple(shape)}")
    if _dt(mask.dtype) != np.dtype(bool):
        raise _err(name, component,
                   f"{what} has dtype {_dt(mask.dtype)}, expected bool")


def _seeded(dtype: np.dtype, shape, rng) -> jnp.ndarray:
    if np.issubdtype(dtype, np.bool_):
        return jnp.asarray(rng.integers(0, 2, shape).astype(bool))
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.integers(1, 64, shape).astype(dtype))
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


def _check_monoid(name: str, monoid: Monoid, msg_dtype):
    """Seeded spot check of the combine's algebra.  Associativity and
    commutativity are what make delivery order irrelevant (the paper's
    any-path-to-the-fixed-point semantics); identity is what makes an
    empty mailbox a no-op."""
    dtype = _dt(msg_dtype)
    rng = np.random.default_rng(0)
    close = (np.allclose if np.issubdtype(dtype, np.floating)
             else np.array_equal)
    # Concrete seeded values (unlike the abstract traces below), so the
    # check must opt out of any ambient transfer guard: lower() runs on
    # build cache misses, which a sanitize()d warm path may legally hit
    # when it first sees a new spec.
    with jax.transfer_guard("allow"):
        a, b, c = (_seeded(dtype, (32,), rng) for _ in range(3))
        _check_monoid_laws(name, monoid, dtype, a, b, c, close)


def _check_monoid_laws(name, monoid, dtype, a, b, c, close):
    # Monoid.elem dispatches to the kind's native op when no custom
    # ``op`` is registered (the builtin MIN/MAX/SUM singletons).
    #
    # Laws are checked on the op's *range*: fold each seeded sample once
    # through op(x, identity) first.  For total ops that projection is a
    # no-op, while domain-restricted custom ops (logical-or over {0, 1}
    # is a registered max-class monoid) get normalized into the value
    # set their combine tree actually produces — full-range samples
    # would reject them for values no program ever feeds them.
    ident = monoid.identity(dtype)
    a, b, c = (monoid.elem(x, jnp.broadcast_to(ident, x.shape))
               for x in (a, b, c))
    ab_c = np.asarray(monoid.elem(monoid.elem(a, b), c))
    a_bc = np.asarray(monoid.elem(a, monoid.elem(b, c)))
    if not close(ab_c, a_bc):
        raise _err(name, "monoid",
                   f"{monoid.name!r} op is not associative on seeded "
                   f"{dtype} samples — unordered mailbox coalescing "
                   f"would depend on delivery order")
    if not close(np.asarray(monoid.elem(a, b)),
                 np.asarray(monoid.elem(b, a))):
        raise _err(name, "monoid",
                   f"{monoid.name!r} op is not commutative on seeded "
                   f"{dtype} samples")
    with_id = np.asarray(monoid.elem(a, jnp.broadcast_to(ident, a.shape)))
    if not close(with_id, np.asarray(a)):
        raise _err(name, "monoid",
                   f"{monoid.name!r} identity is not neutral: "
                   f"op(x, identity) != x on seeded {dtype} samples")


def _check_on_dead(name: str, schema):
    for k, f in schema.items():
        if f.on_dead is None:
            continue
        dtype = _dt(f.dtype)
        val = np.asarray(f.on_dead)
        if (np.issubdtype(dtype, np.integer)
                and np.issubdtype(val.dtype, np.floating)
                and not np.all(np.isfinite(val))):
            raise _err(
                name, "schema",
                f"field {k!r}: on_dead={f.on_dead!r} cannot splat into "
                f"integer dtype {dtype} (non-finite)")


def verify_program(spec, name: str = "") -> None:
    """Verify a DiffusiveProgram spec against the §2.7 contract.

    Raises :class:`ProgramVerificationError` on the first violation;
    returns None when the spec is clean.  Pure metadata + abstract
    traces + one tiny seeded monoid check — cheap enough to run on
    every :meth:`ProgramHandle.build` cache miss."""
    schema = dict(spec.state)
    monoid = as_monoid(spec.monoid)
    msg_dtype = _dt(spec.msg_dtype)
    view = _view_structs()
    vshape = (_S, _NP)

    _check_on_dead(name, schema)
    _check_monoid(name, monoid, msg_dtype)

    # ---- init: schema -> (vstate, active) over the graph view ----------
    def _init(gid, node_ok, out_degree):
        v = types.SimpleNamespace(gid=gid, node_ok=node_ok,
                                  out_degree=out_degree)
        vstate = {}
        for k, f in schema.items():
            val = f.init(v) if callable(f.init) else f.init
            val = jnp.broadcast_to(jnp.asarray(val), gid.shape).astype(
                f.dtype)
            vstate[k] = val
        mask = (spec.init_active(v) if spec.init_active is not None
                else jnp.ones(gid.shape, bool))
        return vstate, mask & node_ok

    vstate_s, active_s = _eval_shape(name, "init", _init, view.gid,
                                     view.node_ok, view.out_degree)
    _check_state(name, "init", vstate_s, schema, vshape)
    _check_mask(name, "init", active_s, vshape, "initial frontier")

    # ---- emit: per-edge source state -> [Ep] message of msg_dtype ------
    src_state = {k: jax.ShapeDtypeStruct((_EP,), f.dtype)
                 for k, f in schema.items()}
    e_f32 = jax.ShapeDtypeStruct((_EP,), jnp.float32)
    e_i32 = jax.ShapeDtypeStruct((_EP,), jnp.int32)
    msg_s = _eval_shape(name, "emit", spec.emit, src_state, e_f32, e_i32,
                        e_i32)
    if tuple(msg_s.shape) != (_EP,):
        raise _err(name, "emit",
                   f"returned shape {tuple(msg_s.shape)}, expected "
                   f"per-edge ({_EP},) — emit must stay elementwise over "
                   f"the edge stream")
    if _dt(msg_s.dtype) != msg_dtype:
        raise _err(name, "emit",
                   f"returned dtype {_dt(msg_s.dtype)}, declared "
                   f"msg_dtype {msg_dtype} — the mismatch would promote "
                   f"through every segment-combine")

    # ---- receive: (vstate, inbox, has_msg, payload, node_ok) ----------
    n_state = {k: jax.ShapeDtypeStruct((_NP,), f.dtype)
               for k, f in schema.items()}
    inbox = jax.ShapeDtypeStruct((_NP,), msg_dtype)
    has = jax.ShapeDtypeStruct((_NP,), jnp.bool_)
    pay = (jax.ShapeDtypeStruct((_NP,), jnp.int32)
           if spec.payload is not None else None)
    out_s = _eval_shape(name, "receive", spec.receive, n_state, inbox, has,
                        pay, has)
    if not (isinstance(out_s, tuple) and len(out_s) == 2):
        raise _err(name, "receive",
                   "must return (vstate, activated) — got "
                   f"{type(out_s).__name__}")
    _check_state(name, "receive", out_s[0], schema, (_NP,))
    _check_mask(name, "receive", out_s[1], (_NP,))

    # ---- replica-mergeability: empty-inbox receive is state-identity ----
    # Hub replicas (DESIGN.md §2.12) mirror one vertex's state across
    # member slots and deliver messages only through the round-boundary
    # monoid merge, so within a round every member sees receive() with
    # has_msg=False wherever the merge withheld delivery.  Mirrors stay
    # bitwise-coherent only if such an empty receive leaves the state
    # bitwise-unchanged — a receive that rewrites state unconditionally
    # would drift the members apart (SPMD devices run data-dependent
    # local trip counts) and the merged value would stop being *the*
    # vertex value.  Checked on seeded concrete values, so this needs the
    # same transfer-guard opt-out as the monoid check above.
    with jax.transfer_guard("allow"):
        rng = np.random.default_rng(7)
        nok = jnp.asarray(rng.integers(0, 2, (_NP,)).astype(bool))
        state = {}
        for k, f in schema.items():
            val = _seeded(_dt(f.dtype), (_NP,), rng)
            if f.on_dead is not None:
                val = jnp.where(nok, val,
                                jnp.asarray(f.on_dead).astype(f.dtype))
            state[k] = val
        ident_in = jnp.broadcast_to(monoid.identity(msg_dtype), (_NP,))
        no_has = jnp.zeros((_NP,), bool)
        pay0 = (jnp.full((_NP,), -1, jnp.int32)
                if spec.payload is not None else None)
        out_state, _ = spec.receive(state, ident_in, no_has, pay0, nok)
        for k in schema:
            got = np.asarray(out_state[k])[np.asarray(nok)]
            want = np.asarray(state[k])[np.asarray(nok)]
            if not np.array_equal(got, want, equal_nan=True):
                raise _err(
                    name, "receive",
                    f"field {k!r} changes under an empty inbox (has_msg "
                    f"all-False) — hub-replica mirrors (DESIGN.md §2.12) "
                    f"need receive to be state-identity when no message "
                    f"is delivered; gate every state write on has_msg")

    # ---- on_send: schema-preserving --------------------------------------
    if spec.on_send is not None:
        sent_s = _eval_shape(name, "on_send", spec.on_send, n_state, has)
        _check_state(name, "on_send", sent_s, schema, (_NP,))

    # ---- priority: [Np] floating bucket key ------------------------------
    if spec.priority is not None:
        pr_s = _eval_shape(name, "priority", spec.priority, n_state)
        if tuple(pr_s.shape) != (_NP,):
            raise _err(name, "priority",
                       f"returned shape {tuple(pr_s.shape)}, expected "
                       f"({_NP},)")
        if not np.issubdtype(_dt(pr_s.dtype), np.floating):
            raise _err(name, "priority",
                       f"returned dtype {_dt(pr_s.dtype)}; the "
                       f"delta-stepping gate needs a floating bucket key")

    # ---- payload: [Ep] integer routing index -----------------------------
    if spec.payload is not None:
        if monoid.payload != "argbest":
            raise _err(name, "payload",
                       f"program carries a payload but monoid "
                       f"{monoid.name!r} has no 'argbest' payload rule")
        pl_s = _eval_shape(name, "payload", spec.payload, src_state, e_i32)
        if tuple(pl_s.shape) != (_EP,):
            raise _err(name, "payload",
                       f"returned shape {tuple(pl_s.shape)}, expected "
                       f"({_EP},)")
        if not np.issubdtype(_dt(pl_s.dtype), np.integer):
            raise _err(name, "payload",
                       f"returned dtype {_dt(pl_s.dtype)}; argbest "
                       f"payloads are integer routing indices")
