"""Production mesh construction (the contract used by the dry-run).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_context"]


def mesh_context(mesh):
    """Enter ``mesh`` as the ambient mesh, across JAX versions.

    Newer JAX exposes ``jax.set_mesh`` / ``jax.sharding.use_mesh``; older
    versions (this container's 0.4.x) use the Mesh object itself as the
    context manager.  Every call site that needs an ambient mesh goes
    through here so the repo tracks the JAX API with one-line changes.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model for
    the two-pod (512-chip) configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
