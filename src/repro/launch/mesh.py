"""Production mesh construction (the contract used by the dry-run).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model for
    the two-pod (512-chip) configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
