"""Serving launcher: continuous-batched decode loop + durable graph loop.

``python -m repro.launch.serve --arch tinyllama-1.1b --smoke``

Implements the standard production decode loop: a prefill step admits new
requests into free KV-cache slots; the decode step advances every active
slot one token; finished sequences free their slot (continuous batching).
On CPU this runs the smoke config end-to-end; the full configs are
exercised by the decode/prefill dry-run cells.

:class:`DurableSessionLoop` is the graph-store analogue (DESIGN.md
§2.13): a streaming-update serve loop over a
:class:`~repro.core.session.DiffusionSession` with write-ahead journaled
commits, periodic snapshots, and :class:`PreemptionGuard`-driven
checkpoint-and-exit — SIGTERM lands between steps, the loop snapshots,
and the orchestrator's restart path is ``DiffusionSession.open(dir)``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..core import chaos
from ..models import transformer
from ..runtime.fault_tolerance import PreemptionGuard


class DecodeServer:
    def __init__(self, cfg, params, batch_slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.batch = batch_slots
        self.cache = transformer.init_cache(cfg, batch_slots, max_len)
        self.lens = np.zeros(batch_slots, np.int32)   # live tokens per slot
        self.active = np.zeros(batch_slots, bool)
        self._decode = jax.jit(
            lambda p, tok, cache, ln: transformer.decode_step(
                p, tok, cache, ln, cfg
            ),
            donate_argnums=(2,),
        )
        self.tokens = np.zeros((batch_slots, max_len), np.int32)

    def admit(self, prompt: np.ndarray) -> int | None:
        """Prefill a prompt into a free slot; returns slot id."""
        free = np.where(~self.active)[0]
        if len(free) == 0:
            return None
        slot = int(free[0])
        logits, cache = transformer.prefill(
            self.params, jnp.asarray(prompt[None]), self.cfg,
            max_len=self.max_len,
        )
        # merge the slot's cache rows
        for kv in ("k", "v"):
            self.cache[kv] = self.cache[kv].at[:, slot].set(cache[kv][:, 0])
        self.lens[slot] = prompt.shape[0]
        self.tokens[slot, : prompt.shape[0]] = prompt
        self.tokens[slot, prompt.shape[0]] = int(
            jnp.argmax(logits[0, -1])
        )
        self.lens[slot] += 1
        self.active[slot] = True
        return slot

    def step(self):
        """One decode step for every active slot (batched)."""
        if not self.active.any():
            return
        ln = int(self.lens[self.active].max()) - 1
        tok = jnp.asarray(
            self.tokens[np.arange(self.batch), np.maximum(self.lens - 1, 0)]
        )[:, None]
        logits, self.cache = self._decode(
            self.params, tok, self.cache, jnp.int32(ln)
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i in range(self.batch):
            if self.active[i] and self.lens[i] < self.max_len:
                self.tokens[i, self.lens[i]] = nxt[i]
                self.lens[i] += 1
                if self.lens[i] >= self.max_len:
                    self.active[i] = False

    def retire(self, slot: int):
        self.active[slot] = False
        out = self.tokens[slot, : self.lens[slot]].copy()
        self.lens[slot] = 0
        return out


class DurableSessionLoop:
    """Preemption-safe streaming-update loop over a DiffusionSession.

    Each step stages one batch of graph updates, commits it (the commit
    write-ahead journals before mutating — see session.commit), and
    snapshots every ``snapshot_every`` steps.  A SIGTERM/SIGINT observed
    by the guard stops the loop at the next step boundary with a final
    snapshot, so a spot preemption loses nothing: the journal holds every
    committed step since the last snapshot, and
    ``DiffusionSession.open(directory)`` replays it.

        loop = DurableSessionLoop(sess, "/data/store")
        loop.run(batches)           # installs/uninstalls its own guard

    ``batches`` is an iterable of callables, each staging one batch of
    ops on the session (``lambda s: s.add_edge(u, v, w)``).
    """

    def __init__(self, session, directory: str, snapshot_every: int = 16):
        self.session = session
        self.directory = directory
        self.snapshot_every = int(snapshot_every)
        self.steps = 0
        self.preempted = False
        session.save(directory)      # arm the journal + initial snapshot

    def step(self, stage) -> None:
        """Stage + commit one update batch (journaled), maybe snapshot."""
        stage(self.session)
        self.session.commit()
        self.steps += 1
        chaos.point("serve.step")
        if self.snapshot_every and self.steps % self.snapshot_every == 0:
            self.session.save()

    def run(self, batches, guard: PreemptionGuard | None = None) -> int:
        """Consume ``batches`` until exhausted or preempted; returns the
        number of steps completed.  A caller-provided guard is polled
        but not installed/uninstalled (the caller owns its lifetime)."""
        own = guard is None
        if own:
            guard = PreemptionGuard()
            guard.install()
        try:
            for stage in batches:
                self.step(stage)
                if guard.should_stop:
                    self.preempted = True
                    self.session.save()      # checkpoint-and-exit
                    break
            return self.steps
        finally:
            if own:
                guard.uninstall()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()

    mod = registry.get_module(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.make_config()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    max_len = 48 if args.smoke else 2048
    srv = DecodeServer(cfg, params, batch_slots=args.requests,
                       max_len=max_len)

    rng = np.random.default_rng(0)
    t0 = time.time()
    slots = []
    for r in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
        slots.append(srv.admit(prompt))
    for _ in range(args.gen_tokens):
        srv.step()
    n_tok = int(srv.lens.sum())
    dt = time.time() - t0
    print(f"served {args.requests} requests, {n_tok} total tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    for s in slots:
        out = srv.retire(s)
        print(f"  slot {s}: {out[:12]}...")


if __name__ == "__main__":
    main()
