"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Wires a cell (configs x shapes), the data pipeline, sharded step, and the
fault-tolerant trainer together.  On this CPU container it runs the smoke
configs end-to-end; on a real pod the same entry point drives the full
configs (the mesh/sharding path is identical — proven by the dry-run).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..data.pipeline import Prefetcher, RecsysPipeline, TokenPipeline
from ..runtime.trainer import train_loop
from .steps import build_cell


def _data_for(cell, smoke: bool):
    cfg = cell.config
    specs = cell.input_specs()
    if cell.family == "lm":
        b, s = specs["tokens"].shape
        return TokenPipeline(b, s, cfg.vocab)
    if cell.family == "recsys":
        b = specs["item_ids"].shape[0]
        return RecsysPipeline(b, cfg)
    # gnn: one fixed synthetic batch re-fed (full-batch training semantics)
    rng = np.random.default_rng(0)
    batch = jax.tree_util.tree_map(
        lambda sd: _random_like(sd, rng), specs
    )

    def forever():
        while True:
            yield batch
    return forever()


def _random_like(sd, rng):
    if sd.dtype == jnp.int32:
        hi = max(2, min(int(np.prod(sd.shape)) or 2, 50))
        return jnp.asarray(rng.integers(0, hi, size=sd.shape), jnp.int32)
    if sd.dtype == jnp.bool_:
        return jnp.ones(sd.shape, bool)
    return jnp.asarray(rng.normal(size=sd.shape) * 0.1, sd.dtype)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log", default=None)
    args = ap.parse_args()

    shape = args.shape or next(
        s for s in registry.shapes_for(args.arch)
        if registry.shapes_for(args.arch)[s].mode == "train"
    )
    cell = build_cell(args.arch, shape, smoke=args.smoke)
    assert cell.mode == "train", f"shape {shape} is not a training shape"

    params = cell.init_params(jax.random.PRNGKey(0))
    opt_state = cell.init_opt(params)
    step_fn = jax.jit(cell.step, donate_argnums=(0, 1))
    data = Prefetcher(_data_for(cell, args.smoke))

    def on_metrics(step, metrics, dt):
        print(f"step {step}: loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")

    train_loop(
        step_fn, params, opt_state, data, args.steps,
        ckpt_dir=os.path.join(args.ckpt_dir, args.arch),
        ckpt_every=args.ckpt_every, log_path=args.log,
        on_metrics=on_metrics,
    )


if __name__ == "__main__":
    main()
