import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below may now import jax.

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.registry import SKIPPED_CELLS, cells  # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.launch.steps import build_cell                # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")
ART_DIR = os.path.abspath(
    os.environ.get("REPRO_ART_DIR",
                   os.path.join(os.path.dirname(__file__), "../../..",
                                "artifacts/dryrun"))
)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")


def _bytes_of_shape(text: str) -> int:
    """Sum byte sizes of every typed shape in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind total result bytes (per device) of every collective.

    Ring-model effective ICI bytes: all-reduce moves ~2x its operand,
    all-gather/reduce-scatter/all-to-all ~1x the larger side.
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([a-z\-]+)", s)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):
                kind = k
                break
        if kind is None:
            continue
        nbytes = _bytes_of_shape(m.group(1))
        out[kind]["count"] += 1
        mult = 2.0 if kind == "all-reduce" else 1.0
        out[kind]["bytes"] += int(nbytes * mult)
    return out


def lower_and_compile(cell, mesh, compile_: bool = True):
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    with cell.context(mesh):
        params_struct = jax.eval_shape(
            lambda k: cell.init_params(k), key_struct
        )
        p_shard = cell.param_shardings(mesh, params_struct)
        batch_struct = cell.input_specs()
        b_shard = cell.batch_spec_fn(mesh)
        rep = NamedSharding(mesh, P())

        if cell.mode == "train":
            opt_struct = jax.eval_shape(cell.init_opt, params_struct)
            o_shard = cell.param_shardings(mesh, opt_struct)
            fn = jax.jit(
                cell.step,
                in_shardings=(p_shard, o_shard, rep, b_shard),
                out_shardings=(p_shard, o_shard, rep),
                donate_argnums=(0, 1),   # params/opt update in place
            )
            lowered = fn.lower(
                params_struct, opt_struct,
                jax.ShapeDtypeStruct((), jnp.int32), batch_struct,
            )
        elif cell.mode == "decode":
            # serving loop updates the KV cache in place
            fn = jax.jit(cell.step, in_shardings=(p_shard, b_shard),
                         donate_argnums=(1,))
            lowered = fn.lower(params_struct, batch_struct)
        else:
            fn = jax.jit(cell.step, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(params_struct, batch_struct)

        result = {"lowered": True}
        if not compile_:
            return result, lowered, None
        compiled = lowered.compile()
        result["compiled"] = True
        try:
            ma = compiled.memory_analysis()
            result["memory"] = {
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "generated_code_bytes": int(
                    getattr(ma, "generated_code_size_in_bytes", 0)
                ),
            }
        except Exception as exc:  # pragma: no cover
            result["memory_error"] = str(exc)
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            result["cost"] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                "transcendentals": float(ca.get("transcendentals", 0.0)),
            }
        except Exception as exc:  # pragma: no cover
            result["cost_error"] = str(exc)
        try:
            text = compiled.as_text()
            result["collectives"] = collective_stats(text)
            # scan-aware reanalysis (XLA counts while bodies once)
            import sys
            sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                            "../../.."))
            from benchmarks.hlo_analysis import analyze_hlo
            h = analyze_hlo(text)
            result["hlo"] = {
                "flops_corrected": h["flops"],
                "collective_bytes_corrected": h["collective_bytes"],
                "collectives_corrected": h["collectives"],
                "dynamic_whiles": h["dynamic_whiles"],
                "bytes_est": h.get("bytes_est", 0.0),
            }
            xla_flops = result.get("cost", {}).get("flops", 0.0)
            if xla_flops > 0 and h["flops"] > 0:
                ratio = max(1.0, h["flops"] / xla_flops)
                result["hlo"]["scan_correction_ratio"] = ratio
                result["hlo"]["bytes_accessed_corrected"] = (
                    result.get("cost", {}).get("bytes_accessed", 0.0) * ratio
                )
        except Exception as exc:  # pragma: no cover
            result["collectives_error"] = str(exc)
        return result, lowered, compiled


def run_cell(arch_id, shape_name, multi_pod=False, save=True, verbose=True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch_id, shape_name)
    t0 = time.time()
    result, lowered, compiled = lower_and_compile(cell, mesh)
    result.update(
        arch=arch_id, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        compile_seconds=round(time.time() - t0, 1),
    )
    if verbose and compiled is not None:
        print(f"  memory_analysis: {result.get('memory')}")
        print(f"  cost_analysis:   {result.get('cost')}")
        coll = result.get("collectives", {})
        tot = sum(v["bytes"] for v in coll.values())
        print(f"  collectives:     {tot/1e6:.1f} MB/device "
              f"({ {k: v['count'] for k, v in coll.items()} })")
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        tag = f"{arch_id}__{shape_name}__{result['mesh']}".replace("/", "_")
        with open(os.path.join(ART_DIR, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    todo = [
        (a, s) for a, s, skip in cells()
        if (args.arch is None or a == args.arch)
        and (args.shape is None or s == args.shape)
    ]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch_id, shape_name in todo:
        for mp in meshes:
            tag = f"{arch_id} x {shape_name} x {'2x16x16' if mp else '16x16'}"
            print(f"[dryrun] {tag}")
            try:
                run_cell(arch_id, shape_name, multi_pod=mp)
            except Exception as exc:
                failures.append((tag, str(exc)))
                print(f"  FAILED: {exc}")
                if not args.continue_on_error:
                    traceback.print_exc()
                    raise
    for arch_shape, reason in SKIPPED_CELLS.items():
        print(f"[skipped] {arch_shape}: {reason}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, "->", e[:200])
        raise SystemExit(1)
    print("\nAll dry-run cells lowered + compiled OK.")


if __name__ == "__main__":
    main()
