"""Cell builders: (architecture x input shape) -> step fn + input specs +
shardings.  Used by the dry-run (ShapeDtypeStruct lowering), the trainer,
and the benchmarks — one definition, three consumers.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import registry
from ..configs.shapes import GraphShape, LMShape, RecsysShape
from ..dist import rules as dist_rules
from ..dist.moe_parallel import make_moe_plan
from ..dist.sharding import sharding_context
from ..models import recsys as recsys_model
from ..models.gnn import (
    equiformer_v2 as eqv2_model,
    gatedgcn as gatedgcn_model,
    mace as mace_model,
    meshgraphnet as mgn_model,
)
from ..models.gnn.common import GraphBatch
from ..models.sampler import block_shapes
from ..models import transformer
from ..optim import adafactor, adamw, clip_by_global_norm

__all__ = ["Cell", "build_cell", "pad_to"]

_GNN_MODELS = {
    "equiformer-v2": eqv2_model,
    "gatedgcn": gatedgcn_model,
    "meshgraphnet": mgn_model,
    "mace": mace_model,
}

_F32 = jnp.float32
_I32 = jnp.int32

# grad-accumulation factors for the train_4k cells (memory plan)
_LM_MICROBATCHES = {
    "command-r-plus-104b": 8,
    "grok-1-314b": 4,
    "phi3.5-moe-42b-a6.6b": 4,
    "qwen2-7b": 2,
    "tinyllama-1.1b": 1,
}


def pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


class Cell(NamedTuple):
    arch_id: str
    shape_name: str
    family: str           # rules family (lm / gnn_* / recsys)
    mode: str             # train | prefill | decode | serve | retrieval
    config: Any
    init_params: Callable             # (key) -> params
    init_opt: Callable | None         # (params) -> opt_state
    step: Callable                    # see mode-specific signatures
    input_specs: Callable             # () -> pytree of ShapeDtypeStruct
    batch_spec_fn: Callable           # (mesh) -> pytree of NamedSharding
    context: Callable                 # (mesh) -> sharding_context manager

    def param_shardings(self, mesh, params_struct):
        return dist_rules.param_sharding(params_struct, mesh, self.family)


def _data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _ctx_factory(family):
    def make(mesh, moe=False):
        rules = dist_rules.logical_rules(mesh, family)
        plan = None
        if moe:
            plan = make_moe_plan(
                mesh, data_axes=_data_axes(mesh), model_axis="model",
                fsdp_axis="data",
            )
        return sharding_context(mesh, rules, plan)
    return make


def _make_train_step(loss_fn, optimizer, n_micro: int = 1):
    """Train step with optional gradient-accumulation microbatching
    (scan over micro-batches; f32 accumulator; one optimizer update)."""

    def step(params, opt_state, step_no, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]),
                batch,
            )

            acc_dtype = jnp.float32 if n_micro <= 2 else jnp.bfloat16
            import os as _os
            if _os.environ.get("REPRO_ACCUM_DTYPE") == "f32":
                acc_dtype = jnp.float32
            elif _os.environ.get("REPRO_ACCUM_DTYPE") == "bf16":
                acc_dtype = jnp.bfloat16

            def micro(acc, b):
                l, g = jax.value_and_grad(loss_fn)(params, b)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(acc_dtype), acc, g
                )
                return acc, l

            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            )
            acc, losses = jax.lax.scan(micro, acc0, mb)
            grads = jax.tree_util.tree_map(lambda a: a / n_micro, acc)
            loss = losses.mean()
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = optimizer.update(grads, opt_state, params,
                                              step_no)
        params = jax.tree_util.tree_map(
            lambda p, u: p + u.astype(p.dtype), params, updates
        )
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}
    return step


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch_id, mod, shape: LMShape, smoke: bool) -> Cell:
    cfg = mod.smoke_config() if smoke else mod.make_config()
    b, s = (2, 64) if smoke else (shape.global_batch, shape.seq_len)
    init = lambda key: transformer.init_params(key, cfg)
    is_moe = cfg.moe is not None
    ctx = _ctx_factory("lm")

    if shape.mode == "train":
        optimizer = adafactor(lr=1e-3)
        loss = lambda params, batch: transformer.loss_fn(
            params, batch["tokens"], batch["labels"], cfg
        )
        # microbatching keeps per-device transients inside HBM for the
        # big models (grad-accumulation scan; see EXPERIMENTS.md §Perf)
        n_micro = 1 if smoke else _LM_MICROBATCHES.get(arch_id, 1)
        step = _make_train_step(loss, optimizer, n_micro=n_micro)
        specs = lambda: {
            "tokens": jax.ShapeDtypeStruct((b, s), _I32),
            "labels": jax.ShapeDtypeStruct((b, s), _I32),
        }

        def batch_specs(mesh):
            sh = NamedSharding(mesh, P(_data_axes(mesh), None))
            return {"tokens": sh, "labels": sh}

        return Cell(arch_id, shape.name, "lm", "train", cfg, init,
                    optimizer.init, step, specs, batch_specs,
                    lambda mesh: ctx(mesh, is_moe))

    if shape.mode == "prefill":
        def step(params, batch):
            return transformer.prefill(params, batch["tokens"], cfg,
                                       max_len=s)
        specs = lambda: {"tokens": jax.ShapeDtypeStruct((b, s), _I32)}

        def batch_specs(mesh):
            return {"tokens": NamedSharding(mesh, P(_data_axes(mesh), None))}

        return Cell(arch_id, shape.name, "lm", "prefill", cfg, init, None,
                    step, specs, batch_specs, lambda mesh: ctx(mesh, is_moe))

    # decode: one new token against a seq_len KV cache
    import os as _os
    if not smoke and _os.environ.get("REPRO_KV_QUANT") == "int8":
        # beyond-paper: int8 KV cache — decode is KV-bandwidth-bound, so
        # this halves the dominant roofline term (EXPERIMENTS.md §Perf)
        cfg = dataclasses.replace(cfg, kv_quant=True)

    def step(params, batch):
        return transformer.decode_step(
            params, batch["token"], batch["cache"], batch["cache_len"], cfg
        )

    def specs():
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, b, s)
        )
        return {
            "token": jax.ShapeDtypeStruct((b, 1), _I32),
            "cache": cache,
            "cache_len": jax.ShapeDtypeStruct((), _I32),
        }

    def batch_specs(mesh):
        da = _data_axes(mesh)
        # KV cache: batch over data, cache SEQUENCE over the model axis
        # (kv_heads < model size); decode softmax over the sharded seq is
        # handled by GSPMD partial-reduce collectives
        cache_sh = NamedSharding(mesh, P(None, da, None, "model", None))
        cache = jax.tree_util.tree_map(
            lambda _: cache_sh,
            jax.eval_shape(lambda: transformer.init_cache(cfg, b, s)),
        )
        return {
            "token": NamedSharding(mesh, P(da, None)),
            "cache": cache,
            "cache_len": NamedSharding(mesh, P()),
        }

    return Cell(arch_id, shape.name, "lm", "decode", cfg, init, None, step,
                specs, batch_specs, lambda mesh: ctx(mesh, is_moe))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_sizes(shape: GraphShape, smoke: bool):
    if smoke:
        return 64, 256, 1
    if shape.mode == "sampled":
        n, e = block_shapes(shape.batch_nodes, shape.fanout)
        return pad_to(n, 512), pad_to(e, 512 * max(shape.edge_chunks, 1)), 1
    if shape.mode == "batched":
        return (pad_to(shape.n_nodes * shape.batch_graphs, 512),
                pad_to(shape.n_edges * shape.batch_graphs, 512),
                shape.batch_graphs)
    return (pad_to(shape.n_nodes, 512),
            pad_to(shape.n_edges, 512 * max(shape.edge_chunks, 1)), 1)


def _gnn_cell(arch_id, mod, shape: GraphShape, smoke: bool) -> Cell:
    model = _GNN_MODELS[arch_id]
    geometric = mod.NEEDS_GEOMETRY
    family = "gnn_geometric" if geometric else "gnn_scalar"
    n, e, n_graphs = _gnn_sizes(shape, smoke)
    chunks = 1 if smoke else max(shape.edge_chunks, 1)

    import os as _os
    kw = {}
    if arch_id == "gatedgcn" and not smoke:
        kw = dict(d_in=max(shape.d_feat, 1),
                  n_classes=max(shape.n_classes, 2))
    if arch_id == "meshgraphnet" and not smoke:
        kw = dict(d_node_in=max(shape.d_feat, 8))
    cfg = mod.smoke_config() if smoke else mod.make_config(**kw)
    if not smoke and _os.environ.get("REPRO_GNN_DTYPE") == "bf16":
        cfg = dataclasses.replace(cfg, dtype=jnp.bfloat16)
    if geometric and not smoke:
        cfg = dataclasses.replace(cfg, edge_chunks=chunks,
                                  remat=(shape.mode == "full"))
        if shape.n_nodes > 100_000:
            # billion-edge regime: block-diag channel mixing + shard_map
            # operon routing keep both mesh axes collective-lean, bf16
            # activations halve the replicated node table
            # (DESIGN.md §2; before/after in EXPERIMENTS.md §Perf)
            cfg = dataclasses.replace(cfg, channel_groups=16,
                                      spmd_edges=True, dtype=jnp.bfloat16)
    if arch_id == "equiformer-v2" and not smoke and shape.n_classes:
        cfg = dataclasses.replace(cfg, d_out=shape.n_classes)

    init = lambda key: model.init_params(key, cfg)
    ctx = _ctx_factory(family)

    def specs():
        base = dict(
            senders=jax.ShapeDtypeStruct((e,), _I32),
            receivers=jax.ShapeDtypeStruct((e,), _I32),
            node_mask=jax.ShapeDtypeStruct((n,), jnp.bool_),
            edge_mask=jax.ShapeDtypeStruct((e,), jnp.bool_),
        )
        if geometric:
            base["positions"] = jax.ShapeDtypeStruct((n, 3), _F32)
            base["species"] = jax.ShapeDtypeStruct((n,), _I32)
        else:
            d_in = (cfg.d_in if arch_id == "gatedgcn" else cfg.d_node_in)
            base["nodes"] = jax.ShapeDtypeStruct((n, d_in), _F32)
            if arch_id == "meshgraphnet":
                base["edges"] = jax.ShapeDtypeStruct((e, cfg.d_edge_in),
                                                     _F32)
        if shape.mode == "batched" and geometric:
            # batched small molecules: per-graph energy regression
            base["graph_ids"] = jax.ShapeDtypeStruct((n,), _I32)
            labels = jax.ShapeDtypeStruct((n_graphs,), _F32)
        elif arch_id == "mace":
            base["graph_ids"] = jax.ShapeDtypeStruct((n,), _I32)
            labels = jax.ShapeDtypeStruct((n_graphs,), _F32)
        elif arch_id == "meshgraphnet":
            labels = jax.ShapeDtypeStruct((n, cfg.d_out), _F32)
        else:
            labels = jax.ShapeDtypeStruct((n,), _I32)
        return GraphBatch(n_nodes=n, n_graphs=n_graphs, labels=labels,
                          **base)

    def batch_specs(mesh):
        r = dist_rules.logical_rules(mesh, family)
        naxes, eaxes = r["nodes"], r["edges"]
        node_sh = NamedSharding(mesh, P(naxes))
        edge_sh = NamedSharding(mesh, P(eaxes))
        node2 = NamedSharding(mesh, P(naxes, None))
        edge2 = NamedSharding(mesh, P(eaxes, None))
        rep = NamedSharding(mesh, P())

        def pick(path, leaf):
            key = str(path[-1].name if hasattr(path[-1], "name")
                      else getattr(path[-1], "key", ""))
            if key in ("senders", "receivers", "edge_mask"):
                return edge_sh
            if key == "edges":
                return edge2
            if key in ("node_mask", "species", "graph_ids"):
                return node_sh
            if key in ("nodes", "positions"):
                return node2
            if key == "labels":
                lf = leaf
                if lf.ndim == 2:
                    return node2
                if lf.shape[0] == n:
                    return node_sh
                return rep
            return rep
        return jax.tree_util.tree_map_with_path(pick, specs())

    optimizer = adamw(lr=1e-3, weight_decay=1e-5)
    loss = lambda params, batch: model.loss_fn(params, batch, cfg)
    step = _make_train_step(loss, optimizer)
    return Cell(arch_id, shape.name, family, "train", cfg, init,
                optimizer.init, step, specs, batch_specs,
                lambda mesh: ctx(mesh, False))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_cell(arch_id, mod, shape: RecsysShape, smoke: bool) -> Cell:
    cfg = mod.smoke_config() if smoke else mod.make_config()
    b = 8 if smoke else shape.batch
    # pad the candidate matrix so it tiles over every mesh configuration
    nc = 128 if smoke else pad_to(shape.n_candidates, 512)
    init = lambda key: recsys_model.init_params(key, cfg)
    ctx = _ctx_factory("recsys")
    f, l_, nd = cfg.n_user_fields, cfg.bag_len, cfg.n_dense

    def base_specs():
        return {
            "user_ids": jax.ShapeDtypeStruct((b, f, l_), _I32),
            "user_dense": jax.ShapeDtypeStruct((b, nd), _F32),
        }

    if shape.mode == "train":
        optimizer = adamw(lr=1e-3)
        loss = lambda params, batch: recsys_model.loss_fn(params, batch, cfg)
        step = _make_train_step(loss, optimizer)

        def specs():
            out = base_specs()
            out.update(
                item_ids=jax.ShapeDtypeStruct((b,), _I32),
                item_dense=jax.ShapeDtypeStruct((b, nd), _F32),
                item_logq=jax.ShapeDtypeStruct((b,), _F32),
            )
            return out

        def batch_specs(mesh):
            da = _data_axes(mesh)
            return {
                "user_ids": NamedSharding(mesh, P(da, None, None)),
                "user_dense": NamedSharding(mesh, P(da, None)),
                "item_ids": NamedSharding(mesh, P(da)),
                "item_dense": NamedSharding(mesh, P(da, None)),
                "item_logq": NamedSharding(mesh, P(da)),
            }

        return Cell(arch_id, shape.name, "recsys", "train", cfg, init,
                    optimizer.init, step, specs, batch_specs,
                    lambda mesh: ctx(mesh, False))

    if shape.mode == "serve":
        def step(params, batch):
            return recsys_model.score(params, batch, cfg)

        def specs():
            out = base_specs()
            out.update(
                item_ids=jax.ShapeDtypeStruct((b,), _I32),
                item_dense=jax.ShapeDtypeStruct((b, nd), _F32),
            )
            return out

        def batch_specs(mesh):
            da = _data_axes(mesh)
            return {
                "user_ids": NamedSharding(mesh, P(da, None, None)),
                "user_dense": NamedSharding(mesh, P(da, None)),
                "item_ids": NamedSharding(mesh, P(da)),
                "item_dense": NamedSharding(mesh, P(da, None)),
            }

        return Cell(arch_id, shape.name, "recsys", "serve", cfg, init, None,
                    step, specs, batch_specs, lambda mesh: ctx(mesh, False))

    # retrieval: 1 query vs n_candidates
    def step(params, batch):
        return recsys_model.retrieval_topk(params, batch, cfg, k=100)

    def specs():
        out = base_specs()
        out["cand_emb"] = jax.ShapeDtypeStruct((nc, cfg.embed_dim), _F32)
        return out

    def batch_specs(mesh):
        da = _data_axes(mesh)
        return {
            "user_ids": NamedSharding(mesh, P(None, None, None)),
            "user_dense": NamedSharding(mesh, P(None, None)),
            "cand_emb": NamedSharding(mesh, P(da + ("model",), None)),
        }

    return Cell(arch_id, shape.name, "recsys", "retrieval", cfg, init, None,
                step, specs, batch_specs, lambda mesh: ctx(mesh, False))


def build_cell(arch_id: str, shape_name: str, smoke: bool = False) -> Cell:
    mod = registry.get_module(arch_id)
    shape = registry.shapes_for(arch_id)[shape_name]
    if mod.FAMILY == "lm":
        return _lm_cell(arch_id, mod, shape, smoke)
    if mod.FAMILY == "gnn":
        return _gnn_cell(arch_id, mod, shape, smoke)
    return _recsys_cell(arch_id, mod, shape, smoke)
