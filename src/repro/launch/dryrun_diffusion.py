import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)
# ^ first lines, before any jax import (see dryrun.py)

"""Dry-run of the PAPER'S OWN workload at production scale: diffusive SSSP
on a Graph500-class RMAT graph, one compute cell per chip.

    python -m repro.launch.dryrun_diffusion --scale 26 [--multi-pod]

Lowers + compiles the shard_map SPMD diffusion engine (local relaxation
while-loops with device-dependent trip counts, all_to_all operon exchange,
psum termination detection) for 256 cells (one pod) or 512 (two pods,
'cells' spanning the pod axis), with ShapeDtypeStruct graph shards — no
allocation.  Proves the paper's execution model lowers to a coherent
collective schedule on real hardware meshes.
"""

import argparse   # noqa: E402
import json       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.diffuse import make_spmd_diffuse  # noqa: E402
from repro.core.programs import sssp_program      # noqa: E402
from repro.launch.mesh import mesh_context        # noqa: E402


def build_specs(scale: int, n_cells: int, edge_factor: int = 16,
                with_push: bool = False):
    from repro.core.graph import DEFAULT_EDGE_BLOCK

    n = 1 << scale
    e = n * edge_factor * 2          # symmetrized
    np_ = n // n_cells
    ep = e // n_cells
    eb = -(-ep // DEFAULT_EDGE_BLOCK) * DEFAULT_EDGE_BLOCK   # CSR padding
    S = n_cells
    i32 = jnp.int32
    # the engine-facing view (diffuse._sg_as_dict): vertex block + the
    # destination-sorted pull streams (ShardedGraph.csr_view) and — for
    # push/auto sweeps only, mirroring _sg_as_dict — the source-sorted
    # push streams (ShardedGraph.push_view)
    specs = {
        "node_ok": jax.ShapeDtypeStruct((S, np_), jnp.bool_),
        "gid": jax.ShapeDtypeStruct((S, np_), i32),
        "out_degree": jax.ShapeDtypeStruct((S, np_), i32),
        "csr_key": jax.ShapeDtypeStruct((S, eb), i32),
        "csr_skey": jax.ShapeDtypeStruct((S, eb), i32),
        "csr_src": jax.ShapeDtypeStruct((S, eb), i32),
        "csr_weight": jax.ShapeDtypeStruct((S, eb), jnp.float32),
        "csr_dst_gid": jax.ShapeDtypeStruct((S, eb), i32),
    }
    if with_push:
        specs.update({
            "push_src": jax.ShapeDtypeStruct((S, eb), i32),
            "push_key": jax.ShapeDtypeStruct((S, eb), i32),
            "push_weight": jax.ShapeDtypeStruct((S, eb), jnp.float32),
            "push_dst_gid": jax.ShapeDtypeStruct((S, eb), i32),
            "push_pos": jax.ShapeDtypeStruct((S, eb), i32),
        })
    return specs, np_, ep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=26)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--max-local-iters", type=int, default=64)
    ap.add_argument("--sweep", default="pull",
                    choices=("pull", "push", "auto"),
                    help="sweep direction of the relaxation step "
                         "(DESIGN.md §2.8)")
    args = ap.parse_args()

    n_cells = 512 if args.multi_pod else 256
    mesh = jax.make_mesh((n_cells,), ("cells",))
    sgd, np_, ep = build_specs(args.scale, n_cells,
                               with_push=args.sweep != "pull")
    print(f"[diffusion dry-run] RMAT scale={args.scale}: "
          f"{1 << args.scale:,} vertices, {n_cells} cells, "
          f"{np_:,} vertices + {ep:,} edges per cell")

    prog = sssp_program(0, track_parents=False)
    fn = make_spmd_diffuse(mesh, prog, sgd, axis_name="cells",
                           max_local_iters=args.max_local_iters,
                           sweep=args.sweep)
    with mesh_context(mesh):
        lowered = jax.jit(fn).lower(sgd)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    print("memory_analysis:",
          {k: int(getattr(ma, k + "_size_in_bytes", 0))
           for k in ("argument", "output", "temp")})
    try:
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "../../.."))
        from benchmarks.hlo_analysis import analyze_hlo
        h = analyze_hlo(compiled.as_text())
        print(f"collective bytes/round-program: "
              f"{h['collective_bytes']/1e6:.1f} MB/device; "
              f"dynamic whiles (diffusion rounds + local relaxation): "
              f"{h['dynamic_whiles']}")
        coll = {k: v["count"] for k, v in h["collectives"].items()
                if v["count"]}
        print("collective schedule:", coll)
        out = {
            "scale": args.scale, "n_cells": n_cells,
            "per_cell_vertices": np_, "per_cell_edges": ep,
            "collectives": h["collectives"],
            "dynamic_whiles": h["dynamic_whiles"],
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        }
        art = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                           "../../..", "artifacts"))
        os.makedirs(art, exist_ok=True)
        tag = f"diffusion_sssp_s{args.scale}_{n_cells}cells"
        with open(os.path.join(art, tag + ".json"), "w") as f:
            json.dump(out, f, indent=1)
    except Exception as exc:
        print("analysis skipped:", exc)
    print("diffusion dry-run OK")


if __name__ == "__main__":
    main()
