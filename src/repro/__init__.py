"""repro: diffusive graph processing (CCA, CS.DC 2022) as a production
multi-pod JAX framework.  See DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"
