"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Uses the full production stack: config system, data pipeline, fault-
tolerant trainer (checkpoint/resume), AdamW + cosine schedule.  The ~100M
config is a scaled tinyllama; pass --tiny for a seconds-scale smoke run.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data.pipeline import Prefetcher, TokenPipeline
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.optim import adamw, clip_by_global_norm, cosine_schedule
from repro.runtime.trainer import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = TransformerConfig(n_layers=2, d_model=128, n_heads=4,
                                n_kv_heads=2, d_ff=256, vocab=512)
        batch, seq = 8, 128
    else:
        # ~100M params: 12L x 768 (gpt2-small-like, llama-style blocks)
        cfg = TransformerConfig(n_layers=12, d_model=768, n_heads=12,
                                n_kv_heads=4, d_ff=2048, vocab=32000,
                                tie_embeddings=True)
        batch, seq = 8, 512
    print(f"params: {cfg.param_count()/1e6:.1f}M")

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(lr=cosine_schedule(3e-4, args.steps, warmup=20),
                weight_decay=0.01)
    opt_state = opt.init(params)

    def step(params, opt_state, step_no, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch["tokens"], batch["labels"], cfg)
        )(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        upd, opt_state = opt.update(grads, opt_state, params, step_no)
        params = jax.tree_util.tree_map(
            lambda p, u: p + u.astype(p.dtype), params, upd)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    data = Prefetcher(TokenPipeline(batch, seq, cfg.vocab))
    losses = []

    def on_metrics(s, m, dt):
        losses.append(float(m["loss"]))
        if s % 10 == 0:
            print(f"step {s:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  {dt*1e3:.0f} ms")

    train_loop(jax.jit(step, donate_argnums=(0, 1)), params, opt_state,
               data, args.steps, args.ckpt, ckpt_every=100,
               on_metrics=on_metrics)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'DECREASED' if losses[-1] < losses[0] else 'no progress'})")


if __name__ == "__main__":
    main()
