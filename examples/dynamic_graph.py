"""Dynamic graph processing — the paper's headline capability.

Streams edge insertions/deletions into a live SSSP fixed point through the
:class:`DiffusionSession` API: updates accumulate in a batch (the seven
graph primitives of §VI, applied as vectorized scatters), and ``commit()``
repairs the cached fixed point by re-diffusion from the affected frontier
(no global recompute).

    PYTHONPATH=src python examples/dynamic_graph.py
"""

import numpy as np

from repro.core import DiffusionSession
from repro.core.event import build_adjacency, event_sssp
from repro.core.generators import make_graph_family

rng = np.random.default_rng(0)
src, dst, w, n = make_graph_family("small_world", 800, seed=1)
sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=8,
                                   strategy="locality",
                                   edge_slack=0.3, node_slack=0.05)

res = sess.query("sssp", source=0)
print(f"initial diffusion: rounds={int(res.stats.rounds)} "
      f"actions={int(res.stats.actions)}")

edges = {(int(s), int(d)): float(x) for s, d, x in zip(src, dst, w)}
for batch_id in range(5):
    # random update batch: 3 deletes + 3 inserts, one commit
    live = list(edges)
    deletes = [live[i] for i in rng.choice(len(live), 3, replace=False)]
    inserts = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
                float(1 + 7 * rng.random())) for _ in range(3)]
    for u, v in deletes:
        sess.delete_edge(u, v)
        edges.pop((u, v), None)
    for u, v, x in inserts:
        sess.add_edge(u, v, x)
        edges[(u, v)] = x
    info = sess.commit()
    (strategy, st), = info.repairs.values()
    print(f"update batch {batch_id}: strategy={strategy} "
          f"repair rounds={int(st.rounds)} actions={int(st.actions)} "
          f"({float(st.actions)/len(edges):.3f} per edge)")

# verify against a from-scratch oracle on the final graph
s2 = np.array([e[0] for e in edges])
d2 = np.array([e[1] for e in edges])
w2 = np.array(list(edges.values()))
ref, _ = event_sssp(build_adjacency(s2, d2, w2, n), n, 0)
got = sess.query("sssp", source=0).values[:n]
a = np.where(np.isinf(got), 1e30, got)
b = np.where(np.isinf(np.array(ref)), 1e30, np.array(ref))
assert np.allclose(a, b, atol=1e-4)
print("incremental fixed point == full recompute  [OK]")
