"""Dynamic graph processing — the paper's headline capability.

Streams edge insertions/deletions into a live SSSP fixed point; each batch
of updates is repaired by re-diffusion from the affected frontier (no
global recompute), using the seven graph primitives of §VI.

    PYTHONPATH=src python examples/dynamic_graph.py
"""

import numpy as np

from repro.core import build
from repro.core.diffuse import diffuse
from repro.core.dynamic import NameServer, incremental_sssp
from repro.core.event import build_adjacency, event_sssp
from repro.core.generators import make_graph_family
from repro.core.programs import sssp_program

rng = np.random.default_rng(0)
src, dst, w, n = make_graph_family("small_world", 800, seed=1)
part = build(src, dst, n, w, n_cells=8, strategy="locality",
             edge_slack=0.3, node_slack=0.05)
ns = NameServer(part)

vstate, st0 = diffuse(part, sssp_program(0))
print(f"initial diffusion: rounds={int(st0.rounds)} "
      f"actions={int(st0.actions)}")

edges = {(int(s), int(d)): float(x) for s, d, x in zip(src, dst, w)}
for batch_id in range(5):
    # random update batch: 3 deletes + 3 inserts
    live = list(edges)
    deletes = [live[i] for i in rng.choice(len(live), 3, replace=False)]
    inserts = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
                float(1 + 7 * rng.random())) for _ in range(3)]
    part, vstate, st = incremental_sssp(part, ns, vstate, 0,
                                        inserts=inserts, deletes=deletes)
    for e in deletes:
        edges.pop(e, None)
    for u, v, x in inserts:
        edges[(u, v)] = x
    print(f"update batch {batch_id}: repair rounds={int(st.rounds)} "
          f"actions={int(st.actions)} "
          f"({float(st.actions)/len(edges):.3f} per edge)")

# verify against a from-scratch oracle on the final graph
s2 = np.array([e[0] for e in edges])
d2 = np.array([e[1] for e in edges])
w2 = np.array(list(edges.values()))
ref, _ = event_sssp(build_adjacency(s2, d2, w2, n), n, 0)
got = np.asarray(part.to_global_layout(vstate["dist"]))[: part.n_real]
a = np.where(np.isinf(got), 1e30, got)
b = np.where(np.isinf(np.array(ref)), 1e30, np.array(ref))
assert np.allclose(a, b, atol=1e-4)
print("incremental fixed point == full recompute  [OK]")
