"""GatedGCN node classification on a synthetic cora-like graph, trained
with the same message-passing substrate the diffusion engine uses.

    PYTHONPATH=src python examples/gnn_node_classification.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generators import make_graph_family
from repro.models.gnn import gatedgcn
from repro.models.gnn.common import GraphBatch
from repro.optim import adamw

rng = np.random.default_rng(0)
n, n_classes, d_feat = 600, 4, 32
src, dst, w, n = make_graph_family("powerlaw_cluster", n, seed=0)

# planted communities: labels from graph blocks + noisy features
labels = (np.arange(n) * n_classes // n).astype(np.int32)
feats = (np.eye(n_classes)[labels] @ rng.normal(size=(n_classes, d_feat))
         + rng.normal(size=(n, d_feat)) * 2.0).astype(np.float32)
train_mask = rng.random(n) < 0.5

cfg = gatedgcn.GatedGCNConfig(n_layers=4, d_hidden=32, d_in=d_feat,
                              n_classes=n_classes)
batch = GraphBatch(
    senders=jnp.asarray(src), receivers=jnp.asarray(dst), n_nodes=n,
    nodes=jnp.asarray(feats), node_mask=jnp.asarray(train_mask),
    labels=jnp.asarray(labels),
)
params = gatedgcn.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw(lr=3e-3)
state = opt.init(params)


@jax.jit
def step(params, state, i):
    loss, g = jax.value_and_grad(gatedgcn.loss_fn)(params, batch, cfg)
    upd, state = opt.update(g, state, params, i)
    params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)
    return params, state, loss


for i in range(120):
    params, state, loss = step(params, state, jnp.int32(i))
    if i % 20 == 0:
        print(f"epoch {i:3d}  train loss {float(loss):.4f}")

logits = gatedgcn.apply(params, batch, cfg)
pred = np.asarray(jnp.argmax(logits, -1))
test = ~train_mask
acc = (pred[test] == labels[test]).mean()
print(f"test accuracy: {acc*100:.1f}%  (chance = {100/n_classes:.0f}%)")
assert acc > 0.5
