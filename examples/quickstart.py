"""Quickstart: the paper's diffusive SSSP in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import build, sssp
from repro.core.generators import make_graph_family

# 1. a weighted scale-free graph (one of the paper's five families)
src, dst, w, n = make_graph_family("scale_free", 2000, seed=0)

# 2. partition it over 8 compute cells with logical-locality placement
part = build(src, dst, n, w, n_cells=8, strategy="locality")

# 3. diffuse!  (hpx_diffuse equivalent: program = vertex_func + predicate,
#    terminator = built-in counting quiescence detection)
res = sssp(part, source=0)

print(f"reachable: {np.isfinite(res.values).sum()}/{n} vertices")
print(f"max distance: {np.nanmax(np.where(np.isfinite(res.values), res.values, np.nan)):.2f}")
s = res.stats
print(f"rounds={int(s.rounds)}  local_iters={int(s.local_iters)}  "
      f"actions={int(s.actions)} ({float(s.actions)/len(src):.2f} per edge)  "
      f"cross-cell operons={int(s.operons_sent)}")
