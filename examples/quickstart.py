"""Quickstart: the paper's diffusive SSSP in ~20 lines — plus the two
PR-3 superpowers: authoring your own diffusive program with @diffusive,
and serving many personalized queries as lanes of one sweep.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    DiffusionSession,
    DiffusiveProgram,
    Field,
    build,
    diffusive,
    sssp,
)
from repro.core.generators import make_graph_family

# 1. a weighted scale-free graph (one of the paper's five families)
src, dst, w, n = make_graph_family("scale_free", 2000, seed=0)

# 2. partition it over 8 compute cells with logical-locality placement
part = build(src, dst, n, w, n_cells=8, strategy="locality")

# 3. diffuse!  (hpx_diffuse equivalent: program = vertex_func + predicate,
#    terminator = built-in counting quiescence detection)
res = sssp(part, source=0)

print(f"reachable: {np.isfinite(res.values).sum()}/{n} vertices")
print(f"max distance: {np.nanmax(np.where(np.isfinite(res.values), res.values, np.nan)):.2f}")
s = res.stats
print(f"rounds={int(s.rounds)}  local_iters={int(s.local_iters)}  "
      f"actions={int(s.actions)} ({float(s.actions)/len(src):.2f} per edge)  "
      f"cross-cell operons={int(s.operons_sent)}")

# ---------------------------------------------------------------------------
# 4. author your own diffusive program (DESIGN.md §2.7): a declarative
#    state schema + a combine monoid + pure emit/receive over named state.
#    Max-reliability paths: edge weight in (0, 1] is a success probability,
#    the best path maximizes the product — a max-combine diffusion.
# ---------------------------------------------------------------------------


@diffusive("reliability", value_key="rel", monotone=True,
           lane_param="source")
def reliability(source: int) -> DiffusiveProgram:
    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox > vstate["rel"]) & node_ok
        return {"rel": jnp.where(better, inbox, vstate["rel"])}, better

    return DiffusiveProgram(
        monoid="max",
        msg_dtype=jnp.float32,
        state={"rel": Field(jnp.float32,
                            init=lambda v: jnp.where(v.gid == source,
                                                     1.0, 0.0),
                            on_dead=0.0)},
        init_active=lambda v: v.gid == source,
        emit=lambda s, weight, src_gid, dst_gid: s["rel"] * weight,
        receive=receive,
    )


probs = np.clip(w / w.max(), 0.05, 1.0)           # reuse weights as probs
sess2 = DiffusionSession.from_edges(src, dst, n, probs, n_cells=8)
rel = sess2.query("reliability", source=0)
print(f"\nreliability: {np.sum(rel.values > 0.01)} vertices reachable "
      f"with > 1% success (best {rel.values[1:n].max():.3f})")

# ---------------------------------------------------------------------------
# 5. multi-query lanes: B personalized queries through ONE edge sweep —
#    works for the custom program too, because lanes come from the spec.
# ---------------------------------------------------------------------------
batch = sess2.query(reliability(sources=[0, 17, 42, 99]))
print(f"lanes: {len(batch)} reliability queries in one diffusion "
      f"(rounds={int(batch[0].stats.rounds)})")

# ---------------------------------------------------------------------------
# 6. direction-optimizing sweeps (DESIGN.md §2.8): sweep="auto" pushes
#    only the active frontier's out-edge blocks while the frontier is
#    sparse and falls back to the dense pull sweep when it is not —
#    bitwise-identical results, work proportional to the frontier.
#    commit()-time repairs default to push automatically.
# ---------------------------------------------------------------------------
auto = sess2.query("reliability", source=7, sweep="auto")
st = auto.stats
print(f"sweep='auto': {int(st.push_iters)}/{int(st.local_iters)} "
      f"sub-iterations ran frontier-compacted "
      f"(per-round frontier sizes {np.asarray(st.frontier_log[:int(st.rounds)]).tolist()})")

# ---------------------------------------------------------------------------
# 7. streaming commits (DESIGN.md §2.9): mutations land as O(batch)
#    tombstone/delta patches on the device-resident edge streams — no
#    O(E log E) re-sort per commit — and the cached answers repair from
#    the update frontier.  Compare against the old eager-rebuild path.
# ---------------------------------------------------------------------------
import time

sess3 = DiffusionSession.from_edges(src, dst, n, w, n_cells=8,
                                    edge_slack=0.3,
                                    max_cache_entries=64)   # LRU-bounded
sess3.query("sssp", source=0)               # the fixed point to maintain

def commit_once(incremental: bool) -> float:
    batch = sess3.update()
    rng = np.random.default_rng(7)
    for _ in range(8):
        batch.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                       float(0.2 + rng.random()))
    t0 = time.perf_counter()
    sess3.part.sg, applied = batch.apply(sess3.part.sg,
                                         incremental=incremental)
    jnp.asarray(sess3.sg.csr_live).block_until_ready()
    return time.perf_counter() - t0

commit_once(True), commit_once(False)       # warm both compiled applies
t_eager = commit_once(False)
t_inc = commit_once(True)                   # leaves the deltas staged
print(f"\nstreaming commit (8-edge batch): incremental {t_inc*1e3:.2f} ms"
      f" vs eager rebuild {t_eager*1e3:.2f} ms "
      f"({t_eager / t_inc:.1f}x, staged deltas "
      f"{int(np.asarray(sess3.sg.delta_count).sum())})")
res = sess3.query("sssp", source=0, refresh=True)
print(f"query on the patched streams: "
      f"{np.isfinite(res.values[:n]).sum()}/{n} reachable")
