"""Two-tower retrieval: train with in-batch sampled softmax (logQ
corrected), then retrieve top-k from a candidate corpus with one matmul.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import RecsysPipeline
from repro.models import recsys
from repro.optim import adamw

cfg = recsys.TwoTowerConfig(embed_dim=32, tower_mlp=(64, 32),
                            n_user_fields=4, bag_len=6, user_vocab=5000,
                            item_vocab=5000, n_dense=8)
params = recsys.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw(lr=1e-3)
state = opt.init(params)
pipe = RecsysPipeline(batch=256, cfg=cfg)


@jax.jit
def step(params, state, i, batch):
    loss, g = jax.value_and_grad(recsys.loss_fn)(params, batch, cfg)
    upd, state = opt.update(g, state, params, i)
    params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)
    return params, state, loss


losses = []
for i, batch in zip(range(100), pipe):
    jb = jax.tree_util.tree_map(jnp.asarray, batch)
    params, state, loss = step(params, state, jnp.int32(i), jb)
    losses.append(float(loss))
    if i % 20 == 0:
        print(f"step {i:3d}  sampled-softmax loss {losses[-1]:.4f}")
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

# build the candidate index from the item tower and retrieve
rng = np.random.default_rng(0)
n_cand = 10_000
cand_ids = jnp.asarray(rng.integers(0, cfg.item_vocab, n_cand), jnp.int32)
cand_dense = jnp.asarray(rng.normal(size=(n_cand, cfg.n_dense)),
                         jnp.float32)
cand_emb = recsys.item_tower(params, cand_ids, cand_dense, cfg)

query = dict(
    user_ids=jnp.asarray(rng.integers(-1, cfg.user_vocab,
                                      (1, cfg.n_user_fields, cfg.bag_len)),
                         jnp.int32),
    user_dense=jnp.asarray(rng.normal(size=(1, cfg.n_dense)), jnp.float32),
    cand_emb=cand_emb,
)
scores, idx = recsys.retrieval_topk(params, query, cfg, k=10)
print("top-10 candidates:", np.asarray(idx))
print("scores:", np.round(np.asarray(scores), 3))
assert losses[-1] < losses[0]
