import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def sanitize():
    """The runtime sanitizer harness (repro.analysis.sanitize) as a
    fixture: ``with sanitize() as rep: ...`` runs the block under
    jax.transfer_guard('disallow') plus the hot-path jit cache-miss
    counter, raising RetraceError on clean exit if anything retraced."""
    from repro.analysis import sanitize as _sanitize

    return _sanitize
