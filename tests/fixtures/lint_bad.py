"""Deliberately-broken hot-path code for the lint self-test.

Every rule in :mod:`repro.analysis.lint` must fire at least once on
this file (tests/test_analysis.py asserts full rule coverage and that
the CLI exits nonzero on it), and the one ``# analysis: allow(...)``
marker below must suppress its finding.  Never imported at runtime —
linted as source only.
"""

import jax
import jax.numpy as jnp
import numpy as np


def diffuse(sg, prog, cells):
    state = prog.init(sg)
    for cell in cells:                       # host-loop: iterates cells
        state = edge_relax_cell(state, cell)
    if state.mask.any():                     # host-sync: bool() of .any()
        state = prog.finish(state)
    return np.asarray(state.values)          # host-sync: host materialize


def edge_relax_cell(state, cell):
    hops = int(cell.depth(state))            # host-sync: int() blocks
    keys = jnp.zeros(4, jnp.int64)           # int64: outside enable_x64
    probe = state.values.item()              # host-sync: .item()
    host = jax.device_get(state.values)      # host-sync: explicit pull
    return state.advance(hops, keys, probe, host)


def receive(vstate, inbox, has_msg, payload, node_ok):
    vstate["dist"] = jnp.minimum(vstate["dist"], inbox)   # mutation
    return vstate, has_msg


def apply_updates(sg, ops):
    del ops
    return int(sg.count())  # analysis: allow(host-sync): fixture's allowlist self-check
