"""Multi-query lanes: B queries through one edge sweep, bitwise-equal to
B single-source queries, cached and repaired per lane (DESIGN.md §2.7)."""

import numpy as np
import pytest

from repro.core import DiffusionSession
from repro.core.diffuse import diffuse
from repro.core.generators import make_graph_family
from repro.core.programs import make_laned, sssp_program


def _mask_inf(a):
    return np.where(np.isinf(a), 1e30, a)


def _eq(a, b):
    return np.array_equal(_mask_inf(np.asarray(a)), _mask_inf(np.asarray(b)))


SOURCES = [0, 7, 23, 41]

LANE_MATRIX = [("sssp", dict(track_parents=True)),
               ("bfs", {}),
               ("ppr", dict(eps=1e-5))]


@pytest.mark.parametrize("name,kw", LANE_MATRIX)
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_lanes_bitwise_equal_single_source(name, kw, backend):
    """Acceptance: each lane's fixed point is bitwise-equal to the
    corresponding single-source query for SSSP/BFS/PPR on both
    backends."""
    src, dst, w, n = make_graph_family("small_world", 150, seed=5)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    batch = sess.query(name, backend=backend, sources=SOURCES, **kw)
    assert len(batch) == len(SOURCES)
    fresh = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    for res, s in zip(batch, SOURCES):
        single = fresh.query(name, backend=backend, source=s, **kw)
        assert _eq(res.values, single.values), (name, s)
        for k, v in single.extra.items():
            if k == "live":
                continue
            assert _eq(res.extra[k], v), (name, s, k)


def test_lanes_spmd_engine_bitwise():
    src, dst, w, n = make_graph_family("erdos_renyi", 100, seed=4)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=1)
    batch = sess.query("sssp", engine="spmd", sources=[0, 9])
    for res, s in zip(batch, [0, 9]):
        single = sess.query("sssp", engine="sharded", source=s,
                            refresh=True)
        assert _eq(res.values, single.values), s


def test_lanes_delta_gate_per_lane_threshold():
    """A gated laned run buckets each lane independently, reproducing
    every gated single-source fixed point bitwise."""
    src, dst, w, n = make_graph_family("scale_free", 200, seed=15)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    batch = sess.query("sssp", sources=[0, 11], delta=2.0)
    fresh = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    for res, s in zip(batch, [0, 11]):
        single = fresh.query("sssp", source=s, delta=2.0)
        assert _eq(res.values, single.values), s


def test_lanes_unbalanced_convergence():
    """Lanes that converge rounds apart (near vs far source on a path
    graph) stay bitwise-stable while slower lanes finish — converged
    lanes are masked out of message generation."""
    n = 64
    src = np.arange(n - 1, dtype=np.int32)
    dst = src + 1
    w = np.ones(n - 1, np.float32)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       engine="sharded")
    near, far = sess.query("sssp", sources=[n - 2, 0])
    assert near.values[n - 1] == 1.0
    assert far.values[n - 1] == float(n - 1)
    fresh = DiffusionSession.from_edges(src, dst, n, w, n_cells=2)
    assert _eq(near.values, fresh.query("sssp", source=n - 2).values)
    assert _eq(far.values, fresh.query("sssp", source=0).values)


def test_lane_results_cached_per_source():
    """Lane fixed points split into ordinary single-source cache entries:
    a later single query is a pure cache hit, and commit() repairs each
    lane like an individually-issued query."""
    src, dst, w, n = make_graph_family("small_world", 120, seed=9)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                       edge_slack=0.4)
    sess.query("sssp", sources=[0, 5, 30])
    n_entries = len(sess._cache)
    assert n_entries == 3                      # one entry per lane
    sess.query("sssp", source=5)               # cache hit, no new entry
    assert len(sess._cache) == n_entries

    rng = np.random.default_rng(3)
    for _ in range(4):
        sess.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                      float(0.1 + rng.random()))
    sess.delete_edge(int(src[0]), int(dst[0]))
    info = sess.commit()
    assert len(info.repairs) == 3
    for s in (0, 5, 30):
        got = sess.query("sssp", source=s).values
        vstate, _ = diffuse(sess.sg, sssp_program(s))
        assert _eq(got, sess.to_global(vstate["dist"])), s


def test_make_laned_rejects_mixed_programs():
    from repro.core.programs import ppr_program

    with pytest.raises(ValueError):
        make_laned((sssp_program(0), ppr_program(1)))
    with pytest.raises(ValueError):
        make_laned(())


def test_lane_batch_speedup_over_sequential():
    """Acceptance: batching 32 PPR sources into lanes does >= 5x fewer
    global exchange rounds than 32 sequential queries, at wall-clock
    parity or better (sharded engine, CPU).

    This used to assert ``speedup_cold >= 5``, which held only because
    32 sequential sources paid 32 jit compiles.  The init-excluding
    program identity (DESIGN.md §2.11) makes those sources share one
    ``_run_rounds`` compilation, so the cold wall-clock ratio honestly
    collapsed to ~1x on CPU; the durable lane win is the engine-work
    one — one laned fixed point runs max-over-lanes rounds instead of
    the sum."""
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.bench_lanes import bench_lane_batch

    row = bench_lane_batch(n_nodes=400, batch=32, repeats=1)
    assert row["round_ratio"] >= 5.0, row
    # wall-clock guard: lanes must not make serving the batch slower
    # (generous margin — CI wall clocks are noisy)
    assert row["speedup_cold"] >= 0.5, row
