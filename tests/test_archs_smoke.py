"""Per-architecture smoke tests: reduced config, one real step on CPU,
asserting output shapes and no NaNs — for all 10 assigned architectures."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, shapes_for
from repro.launch.steps import build_cell


def _random_batch(specs, rng):
    def gen(sd):
        if sd.dtype == jnp.int32:
            return jnp.asarray(rng.integers(0, 2, size=sd.shape), jnp.int32)
        if sd.dtype == jnp.bool_:
            return jnp.ones(sd.shape, bool)
        return jnp.asarray(rng.normal(size=sd.shape) * 0.1, sd.dtype)
    return jax.tree_util.tree_map(gen, specs)


def _int_fields_fixed(batch, cell, rng):
    """Make int fields semantically valid (token ids, edges, labels...)."""
    import dataclasses

    if cell.family == "lm":
        cfg = cell.config
        out = dict(batch)
        for k in ("tokens", "labels", "token"):
            if k in out:
                out[k] = jnp.asarray(
                    rng.integers(0, cfg.vocab, out[k].shape), jnp.int32
                )
        return out
    if cell.family.startswith("gnn"):
        n = batch.n_nodes
        e = batch.senders.shape[0]
        kw = {}
        kw["senders"] = jnp.asarray(rng.integers(0, n, e), jnp.int32)
        kw["receivers"] = jnp.asarray(rng.integers(0, n, e), jnp.int32)
        if batch.species is not None:
            kw["species"] = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
        if batch.labels is not None and batch.labels.dtype == jnp.int32:
            ncls = getattr(cell.config, "n_classes",
                           getattr(cell.config, "d_out", 2))
            kw["labels"] = jnp.asarray(
                rng.integers(0, max(ncls, 2), batch.labels.shape), jnp.int32
            )
        if batch.graph_ids is not None:
            kw["graph_ids"] = jnp.zeros(n, jnp.int32)
        return dataclasses.replace(batch, **kw)
    # recsys
    out = dict(batch)
    cfg = cell.config
    if "user_ids" in out:
        out["user_ids"] = jnp.asarray(
            rng.integers(-1, cfg.user_vocab, out["user_ids"].shape),
            jnp.int32,
        )
    if "item_ids" in out:
        out["item_ids"] = jnp.asarray(
            rng.integers(0, cfg.item_vocab, out["item_ids"].shape),
            jnp.int32,
        )
    return out


def _first_shape(arch_id, mode):
    shapes = shapes_for(arch_id)
    for name, sh in shapes.items():
        if sh.mode == mode:
            return name
    if mode == "train" and ARCHS[arch_id].FAMILY == "gnn":
        return next(iter(shapes))      # every GNN shape is a training cell
    return None


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_arch_train_smoke(arch_id):
    shape = _first_shape(arch_id, "train")
    if shape is None:
        pytest.skip("no train shape")
    cell = build_cell(arch_id, shape, smoke=True)
    rng = np.random.default_rng(0)
    params = cell.init_params(jax.random.PRNGKey(0))
    opt_state = cell.init_opt(params)
    batch = _int_fields_fixed(_random_batch(cell.input_specs(), rng),
                              cell, rng)
    params, opt_state, metrics = jax.jit(cell.step)(
        params, opt_state, jnp.int32(0), batch
    )
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch_id}: loss={loss}"
    for leaf in jax.tree_util.tree_leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch_id


@pytest.mark.parametrize("arch_id", [
    "tinyllama-1.1b", "grok-1-314b", "command-r-plus-104b",
])
def test_lm_prefill_decode_smoke(arch_id):
    rng = np.random.default_rng(1)
    for mode in ("prefill", "decode"):
        shape = _first_shape(arch_id, mode)
        cell = build_cell(arch_id, shape, smoke=True)
        batch = _int_fields_fixed(_random_batch(cell.input_specs(), rng),
                                  cell, rng)
        out = jax.jit(cell.step)(cell.init_params(jax.random.PRNGKey(0)),
                                 batch)
        logits = out[0]
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_recsys_serve_and_retrieval_smoke():
    rng = np.random.default_rng(2)
    for shape in ("serve_p99", "retrieval_cand"):
        cell = build_cell("two-tower-retrieval", shape, smoke=True)
        batch = _int_fields_fixed(_random_batch(cell.input_specs(), rng),
                                  cell, rng)
        out = jax.jit(cell.step)(cell.init_params(jax.random.PRNGKey(0)),
                                 batch)
        for leaf in jax.tree_util.tree_leaves(out):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
