"""Model-level correctness: transformer serving equivalence, MoE
conservation, equivariance, chunked/SPMD path equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.sharding import sharding_context
from repro.launch.mesh import mesh_context
from repro.models.gnn.common import GraphBatch
from repro.models.gnn import equiformer_v2 as eqv2
from repro.models.gnn import gatedgcn, mace, meshgraphnet
from repro.models.moe import MoEConfig, init_moe, moe_ffn
from repro.models import recsys
from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    forward,
    init_params,
    loss_fn,
    prefill,
)


def _graph(rng, n=40, e=128, with_geometry=True):
    kw = dict(
        senders=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        receivers=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        n_nodes=n,
    )
    if with_geometry:
        kw["positions"] = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
        kw["species"] = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
    return kw


@pytest.mark.parametrize("moe", [False, True])
@pytest.mark.parametrize("parallel_block", [False, True])
def test_transformer_decode_matches_forward(moe, parallel_block):
    cfg = TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
        parallel_block=parallel_block,
        moe=MoEConfig(4, 2, 96) if moe else None,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 97)
    lg_pre, cache = prefill(params, toks, cfg, max_len=16)
    nxt = toks[:, -1:] * 0 + 5
    lg_dec, _ = decode_step(params, nxt, cache, 12, cfg)
    full, _ = forward(params, jnp.concatenate([toks, nxt], 1), cfg)
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0]), np.asarray(full[:, -1]), atol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(lg_pre[:, 0]), np.asarray(full[:, -2]), atol=2e-5
    )


def test_moe_token_conservation_and_impl_equivalence():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=4.0)
    p = init_moe(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y1, aux = moe_ffn(p, x, cfg)
    y2, _ = moe_ffn(p, x, dataclasses.replace(cfg, impl="ragged"))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    # routing conservation: fractions sum to 1
    assert np.isclose(float(aux["router_frac"].sum()), 1.0, atol=1e-5)
    assert np.isclose(float(aux["router_probs_mean"].sum()), 1.0, atol=1e-5)


def test_moe_capacity_drops_tokens_gracefully():
    # tiny capacity: output must stay finite and bounded
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=0.25)
    p = init_moe(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 16))
    y, _ = moe_ffn(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


@pytest.mark.parametrize("model,make_cfg", [
    (mace, lambda: mace.MACEConfig(n_layers=2, d_hidden=8, n_species=5)),
    (eqv2, lambda: eqv2.EquiformerV2Config(
        n_layers=2, d_hidden=16, l_max=3, n_heads=2, n_species=5, d_out=2)),
])
def test_rotation_invariance(model, make_cfg):
    rng = np.random.default_rng(0)
    cfg = make_cfg()
    kw = _graph(rng)
    Q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    b1 = GraphBatch(**kw, graph_ids=jnp.zeros(40, jnp.int32), n_graphs=1)
    kw2 = dict(kw)
    kw2["positions"] = jnp.asarray(np.asarray(kw["positions"]) @ Q.T)
    b2 = GraphBatch(**kw2, graph_ids=jnp.zeros(40, jnp.int32), n_graphs=1)
    p = model.init_params(jax.random.PRNGKey(0), cfg)
    o1, o2 = model.apply(p, b1, cfg), model.apply(p, b2, cfg)
    scale = max(1.0, float(jnp.max(jnp.abs(o1))))
    assert float(jnp.max(jnp.abs(o1 - o2))) / scale < 1e-3


def test_eqv2_chunked_and_spmd_paths_match():
    rng = np.random.default_rng(1)
    cfg = eqv2.EquiformerV2Config(n_layers=2, d_hidden=16, l_max=2,
                                  n_heads=2, n_species=5, d_out=3,
                                  channel_groups=4)
    kw = _graph(rng, n=32, e=96)
    b = GraphBatch(**kw, labels=jnp.asarray(rng.integers(0, 3, 32),
                                            jnp.int32))
    p = eqv2.init_params(jax.random.PRNGKey(0), cfg)
    o1 = eqv2.apply(p, b, cfg)
    o2 = eqv2.apply(p, b, dataclasses.replace(cfg, edge_chunks=4))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg_s = dataclasses.replace(cfg, edge_chunks=4, spmd_edges=True)
    rules = {"nodes": ("data",), "edges": ("data",), "channels": "model"}
    with mesh_context(mesh):
        with sharding_context(mesh, rules):
            o3 = jax.jit(lambda pp, bb: eqv2.apply(pp, bb, cfg_s))(p, b)
            g3 = jax.jit(
                lambda pp, bb: jax.grad(eqv2.loss_fn)(pp, bb, cfg_s)
            )(p, b)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=1e-5)
    g_ref = jax.grad(eqv2.loss_fn)(p, b, cfg)
    err = jax.tree_util.tree_reduce(
        lambda a, t: max(a, float(jnp.max(jnp.abs(t)))),
        jax.tree_util.tree_map(lambda a, b_: a - b_, g_ref, g3), 0.0,
    )
    assert err < 1e-4


def test_mace_spmd_path_matches():
    rng = np.random.default_rng(2)
    cfg = mace.MACEConfig(n_layers=2, d_hidden=8, n_species=5,
                          channel_groups=4)
    kw = _graph(rng, n=32, e=96)
    b = GraphBatch(**kw, graph_ids=jnp.zeros(32, jnp.int32), n_graphs=1,
                   labels=jnp.ones(1, jnp.float32))
    p = mace.init_params(jax.random.PRNGKey(0), cfg)
    e_ref = mace.apply(p, b, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg_s = dataclasses.replace(cfg, edge_chunks=4, spmd_edges=True)
    rules = {"nodes": ("data",), "edges": ("data",), "channels": "model"}
    with mesh_context(mesh):
        with sharding_context(mesh, rules):
            e_s = jax.jit(lambda pp, bb: mace.apply(pp, bb, cfg_s))(p, b)
    np.testing.assert_allclose(np.asarray(e_ref), np.asarray(e_s),
                               rtol=1e-4, atol=1e-4)


def test_scalar_gnns_train_step_decreases_loss():
    rng = np.random.default_rng(3)
    n, e = 48, 160
    for model, cfg, batch in [
        (gatedgcn,
         gatedgcn.GatedGCNConfig(n_layers=2, d_hidden=16, d_in=8,
                                 n_classes=3),
         GraphBatch(
             senders=jnp.asarray(rng.integers(0, n, e), jnp.int32),
             receivers=jnp.asarray(rng.integers(0, n, e), jnp.int32),
             n_nodes=n,
             nodes=jnp.asarray(rng.normal(size=(n, 8)), jnp.float32),
             labels=jnp.asarray(rng.integers(0, 3, n), jnp.int32))),
        (meshgraphnet,
         meshgraphnet.MeshGraphNetConfig(n_layers=2, d_hidden=16,
                                         d_node_in=8, d_edge_in=4, d_out=2),
         GraphBatch(
             senders=jnp.asarray(rng.integers(0, n, e), jnp.int32),
             receivers=jnp.asarray(rng.integers(0, n, e), jnp.int32),
             n_nodes=n,
             nodes=jnp.asarray(rng.normal(size=(n, 8)), jnp.float32),
             edges=jnp.asarray(rng.normal(size=(e, 4)), jnp.float32),
             labels=jnp.asarray(rng.normal(size=(n, 2)), jnp.float32))),
    ]:
        p = model.init_params(jax.random.PRNGKey(0), cfg)
        l0 = float(model.loss_fn(p, batch, cfg))
        for _ in range(15):
            g = jax.grad(model.loss_fn)(p, batch, cfg)
            p = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)
        l1 = float(model.loss_fn(p, batch, cfg))
        assert l1 < l0, (model.__name__, l0, l1)


def test_embedding_bag_matches_onehot_matmul():
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = jnp.asarray(rng.integers(-1, 50, size=(6, 5)), jnp.int32)
    out = recsys.embedding_bag(table, ids)
    onehot = jnp.where(
        (ids >= 0)[..., None],
        jax.nn.one_hot(jnp.maximum(ids, 0), 50), 0.0,
    )
    ref = jnp.einsum("blv,vd->bd", onehot, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # ragged variant agrees
    flat = ids.reshape(-1)
    bags = jnp.repeat(jnp.arange(6), 5)
    out2 = recsys.embedding_bag_ragged(table, flat, bags, 6)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out), atol=1e-5)


def test_two_tower_training_and_retrieval():
    cfg = recsys.TwoTowerConfig(embed_dim=16, tower_mlp=(32, 16),
                                n_user_fields=3, bag_len=4, user_vocab=300,
                                item_vocab=300, n_dense=5)
    p = recsys.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    batch = dict(
        user_ids=jnp.asarray(rng.integers(-1, 300, (16, 3, 4)), jnp.int32),
        user_dense=jnp.asarray(rng.normal(size=(16, 5)), jnp.float32),
        item_ids=jnp.asarray(rng.integers(0, 300, 16), jnp.int32),
        item_dense=jnp.asarray(rng.normal(size=(16, 5)), jnp.float32),
        item_logq=jnp.zeros(16, jnp.float32),
    )
    l0 = float(recsys.loss_fn(p, batch, cfg))
    for _ in range(20):
        g = jax.grad(recsys.loss_fn)(p, batch, cfg)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.1 * b, p, g)
    l1 = float(recsys.loss_fn(p, batch, cfg))
    assert l1 < l0
    cand = jnp.asarray(rng.normal(size=(500, 16)), jnp.float32)
    vals, idx = recsys.retrieval_topk(
        p, dict(user_ids=batch["user_ids"][:1],
                user_dense=batch["user_dense"][:1], cand_emb=cand),
        cfg, k=7,
    )
    assert vals.shape == (7,) and idx.shape == (7,)
    assert bool(jnp.all(vals[:-1] >= vals[1:]))


def test_int8_kv_cache_decode_accuracy():
    """int8-quantized KV decode: logits within 5% of full precision and
    identical argmax (the decode cells' bandwidth optimization)."""
    from repro.models.transformer import init_cache, kv_quantize

    cfg = TransformerConfig(n_layers=3, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab=97)
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
    _, cache = prefill(params, toks, cfg, max_len=24)
    qk, sk = kv_quantize(cache["k"])
    qv, sv = kv_quantize(cache["v"])
    qcache = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    nxt = toks[:, -1:] * 0 + 5
    lg_q, qc2 = decode_step(params, nxt, qcache, 16, cfgq)
    assert qc2["k"].dtype == jnp.int8
    full, _ = forward(params, jnp.concatenate([toks, nxt], 1), cfg)
    err = float(jnp.max(jnp.abs(lg_q[:, 0] - full[:, -1])))
    scale = float(jnp.max(jnp.abs(full[:, -1])))
    assert err / scale < 0.05
    assert bool((jnp.argmax(lg_q[:, 0], -1)
                 == jnp.argmax(full[:, -1], -1)).all())
    # init_cache produces the right structure
    c0 = init_cache(cfgq, 2, 24)
    assert set(c0) == {"k", "v", "k_scale", "v_scale"}
