"""Partition layer: global<->shard layout maps, partitioner cut quality,
and the blocked-CSR edge view the relaxation kernels consume."""

import numpy as np
import pytest

from repro.core import build
from repro.core.generators import make_graph_family
from repro.core.graph import DEFAULT_EDGE_BLOCK


@pytest.mark.parametrize("strategy", ["block", "hash", "locality"])
def test_shard_layout_round_trip(strategy, rng):
    src, dst, w, n = make_graph_family("small_world", 150, seed=3)
    part = build(src, dst, n, w, n_cells=4, strategy=strategy)
    vals = rng.normal(size=n).astype(np.float32)
    shard = part.to_shard_layout(vals, fill=np.nan)
    back = np.asarray(part.to_global_layout(shard))
    assert np.array_equal(back, vals)
    # fill lands only on slots owned by no vertex
    n_filled = np.isnan(np.asarray(shard)).sum()
    assert n_filled == part.sg.n_shards * part.sg.n_per_shard - n


def _cut_fraction(part) -> float:
    sg = part.sg
    ok = np.asarray(sg.edge_ok)
    own = np.arange(sg.n_shards)[:, None]
    remote = (np.asarray(sg.dst_shard) != own) & ok
    return remote.sum() / max(ok.sum(), 1)


@pytest.mark.parametrize("family", ["small_world", "scale_free",
                                    "powerlaw_cluster", "graph500"])
def test_locality_cut_no_worse_than_hash(family):
    """The paper's Strategy-2 claim, measured: topology-aware placement
    cuts no more edges than the adversarial hash baseline."""
    src, dst, w, n = make_graph_family(family, 300, seed=1)
    cuts = {
        s: _cut_fraction(build(src, dst, n, w, n_cells=8, strategy=s))
        for s in ("locality", "hash")
    }
    assert cuts["locality"] <= cuts["hash"], cuts


def test_csr_view_is_destination_sorted_permutation():
    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=5)
    part = build(src, dst, n, w, n_cells=4, edge_slack=0.3)
    sg = part.sg
    perm = np.asarray(sg.csr_perm)
    key = np.asarray(sg.csr_key)
    ep = sg.edges_per_shard
    assert key.shape[1] % DEFAULT_EDGE_BLOCK == 0
    assert key.shape[1] >= ep
    ok = np.asarray(sg.edge_ok)
    flat_dst = np.asarray(sg.dst_shard) * sg.n_per_shard + np.asarray(
        sg.dst_local)
    for s in range(sg.n_shards):
        live = key[s] >= 0
        # exactly the live edges carry a key, keys are ascending, and the
        # dead/padding tail is contiguous
        assert live.sum() == ok[s].sum()
        assert not live[live.argmin():].any() or live.all()
        lk = key[s][live]
        assert np.array_equal(lk, np.sort(lk))
        # perm covers exactly the live edge slots and carries their keys
        p = perm[s][live]
        assert np.array_equal(np.sort(p), np.flatnonzero(ok[s]))
        assert np.array_equal(lk, flat_dst[s][p])


def test_csr_view_tracks_updates():
    """Every topology-changing primitive refreshes both CSR views —
    destination-sorted pull and source-sorted push — together (batched
    and sequential paths)."""
    from repro.core import DiffusionSession
    from repro.core.dynamic import NameServer, edge_add, edge_delete

    src, dst, w, n = make_graph_family("erdos_renyi", 80, seed=7)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.5)
    sess.add_edge(0, 7, 2.0)
    sess.delete_edge(int(src[0]), int(dst[0]))
    sess.commit()
    rebuilt = sess.sg.with_csr()
    assert np.array_equal(np.asarray(sess.sg.csr_perm),
                          np.asarray(rebuilt.csr_perm))
    assert np.array_equal(np.asarray(sess.sg.push_perm),
                          np.asarray(rebuilt.push_perm))
    assert np.array_equal(np.asarray(sess.sg.push_pos),
                          np.asarray(rebuilt.push_pos))

    part = build(src, dst, n, w, n_cells=2, edge_slack=0.5)
    ns = NameServer(part)
    sg = edge_add(part.sg, ns, 0, 7, 2.0)
    sg = edge_delete(sg, ns, int(src[0]), int(dst[0]))
    # sequential primitives invalidate (lazy rebuild at the next diffusion)
    # instead of paying one sort per single-edge update — both views drop
    # together, a graph can never carry one stale view
    assert sg.csr_perm is None and sg.push_perm is None
    assert sg.push_src is None and sg.push_pos is None
    # ...and the rebuilt streams match the batched path's (same edge
    # multiset per cell => same sorted key stream, slot layout aside)
    assert np.array_equal(np.asarray(sg.with_csr().csr_key),
                          np.asarray(sess.sg.csr_key))
    assert np.array_equal(np.asarray(sg.with_csr().push_src),
                          np.asarray(sess.sg.push_src))


def test_sequential_primitives_invalidate_both_views():
    """Regression: edge_add / edge_delete / vertex_delete each lazily
    invalidate the pull AND push views consistently, and the lazy rebuild
    agrees with an eager with_csr() after every step."""
    from repro.core.dynamic import (NameServer, edge_add, edge_delete,
                                    vertex_delete)

    src, dst, w, n = make_graph_family("small_world", 90, seed=13)
    part = build(src, dst, n, w, n_cells=3, edge_slack=0.5,
                 node_slack=0.2)
    ns = NameServer(part)
    sg = part.sg
    steps = [
        lambda g: edge_add(g, ns, 1, 40, 0.7),
        lambda g: edge_delete(g, ns, int(src[2]), int(dst[2])),
        lambda g: vertex_delete(g, ns, 17),
    ]
    for step in steps:
        sg = step(sg)
        for f in ("csr_perm", "csr_key", "push_perm", "push_src",
                  "push_pos"):
            assert getattr(sg, f) is None, f
        with pytest.raises(ValueError):
            sg.csr_view()
        with pytest.raises(ValueError):
            sg.push_view()
        sg = sg.with_csr()     # persist before the next step


def test_push_view_is_source_sorted_permutation():
    """The push view is a per-cell permutation of the live edge slots
    sorted by source local index, with push_pos the exact inverse map
    into the destination-sorted stream."""
    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=5)
    part = build(src, dst, n, w, n_cells=4, edge_slack=0.3)
    sg = part.sg
    perm = np.asarray(sg.push_perm)
    psrc = np.asarray(sg.push_src)
    ppos = np.asarray(sg.push_pos)
    cperm = np.asarray(sg.csr_perm)
    ok = np.asarray(sg.edge_ok)
    assert psrc.shape[1] % DEFAULT_EDGE_BLOCK == 0
    assert psrc.shape == np.asarray(sg.csr_key).shape
    for s in range(sg.n_shards):
        live = psrc[s] >= 0
        # exactly the live edges, ascending by source, dead tail trailing
        assert live.sum() == ok[s].sum()
        assert not live[live.argmin():].any() or live.all()
        lk = psrc[s][live]
        assert np.array_equal(lk, np.sort(lk))
        p = perm[s][live]
        assert np.array_equal(np.sort(p), np.flatnonzero(ok[s]))
        assert np.array_equal(lk, np.asarray(sg.src_local)[s][p])
        # push_pos round-trips through the destination-sorted stream:
        # csr_perm[push_pos[i]] is the same edge slot as push_perm[i]
        assert np.array_equal(cperm[s][ppos[s][live]], p)


def test_lazy_csr_invalidation_rebuilds_before_query():
    """Regression (PR 2 lazy-invalidate path): sequential add_edge /
    delete_edge leave csr_perm=None, and a following peek()/query() must
    see the *rebuilt* CSR — bitwise-equal to a from-scratch partition of
    the same edge set, for a min and a sum program."""
    from repro.core import DiffusionSession, diffuse
    from repro.core.dynamic import edge_add, edge_delete
    from repro.core.programs import sssp_program

    src, dst, w, n = make_graph_family("small_world", 100, seed=11)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.5)
    # mutate through the sequential primitives (bypassing UpdateBatch's
    # eager with_csr), directly on the session's graph
    sg = sess.part.sg
    dels = [(int(src[i]), int(dst[i])) for i in (0, 3)]
    adds = [(1, 50, 0.25), (50, 97, 0.5)]
    for u, v in dels:
        sg = edge_delete(sg, sess.ns, u, v)
    for u, v, x in adds:
        sg = edge_add(sg, sess.ns, u, v, x)
    assert sg.csr_perm is None            # invalidated, not rebuilt
    sess.part.sg = sg

    # from-scratch reference partition over the same live edge set
    edges = {}
    for a, b, x in zip(src, dst, w):
        edges.setdefault((int(a), int(b)), []).append(float(x))
    for u, v in dels:
        edges[(u, v)].pop(0)
    for u, v, x in adds:
        edges.setdefault((u, v), []).append(x)
    flat = [(u, v, x) for (u, v), ws in edges.items() for x in ws]
    s2 = np.array([e[0] for e in flat], np.int32)
    d2 = np.array([e[1] for e in flat], np.int32)
    w2 = np.array([e[2] for e in flat], np.float32)
    ref = DiffusionSession.from_edges(s2, d2, n, w2, n_cells=2)

    # min-combine fixed points are order-free within a destination run =>
    # bitwise; sum depends on slot order inside runs => allclose
    got = sess.query("sssp", source=0).values[:n]
    want = ref.query("sssp", source=0).values[:n]
    both_inf = np.isinf(got) & np.isinf(want)
    assert np.array_equal(np.where(both_inf, 0, got),
                          np.where(both_inf, 0, want))
    got_r = sess.query("ppr", source=0, eps=1e-5).values[:n]
    want_r = ref.query("ppr", source=0, eps=1e-5).values[:n]
    assert np.allclose(got_r, want_r, atol=1e-6)
    pk = np.asarray(sess.peek(1, "sssp", source=0))
    assert np.isfinite(pk).sum() >= 1     # sees the inserted (1, 50) edge
    # the engine rebuilt in-trace; the persisted graph still lazily
    # invalidated until with_csr() is called explicitly
    vstate, _ = diffuse(sess.sg.with_csr(), sssp_program(0))
    assert np.array_equal(
        np.asarray(sess.vertex_state("sssp", source=0)["dist"]),
        np.asarray(vstate["dist"]))
