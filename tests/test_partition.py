"""Partition layer: global<->shard layout maps, partitioner cut quality,
and the blocked-CSR edge view the relaxation kernels consume."""

import numpy as np
import pytest

from repro.core import build
from repro.core.generators import make_graph_family
from repro.core.graph import DEFAULT_EDGE_BLOCK


@pytest.mark.parametrize("strategy", ["block", "hash", "locality"])
def test_shard_layout_round_trip(strategy, rng):
    src, dst, w, n = make_graph_family("small_world", 150, seed=3)
    part = build(src, dst, n, w, n_cells=4, strategy=strategy)
    vals = rng.normal(size=n).astype(np.float32)
    shard = part.to_shard_layout(vals, fill=np.nan)
    back = np.asarray(part.to_global_layout(shard))
    assert np.array_equal(back, vals)
    # fill lands only on slots owned by no vertex
    n_filled = np.isnan(np.asarray(shard)).sum()
    assert n_filled == part.sg.n_shards * part.sg.n_per_shard - n


def _cut_fraction(part) -> float:
    sg = part.sg
    ok = np.asarray(sg.edge_ok)
    own = np.arange(sg.n_shards)[:, None]
    remote = (np.asarray(sg.dst_shard) != own) & ok
    return remote.sum() / max(ok.sum(), 1)


@pytest.mark.parametrize("family", ["small_world", "scale_free",
                                    "powerlaw_cluster", "graph500"])
def test_locality_cut_no_worse_than_hash(family):
    """The paper's Strategy-2 claim, measured: topology-aware placement
    cuts no more edges than the adversarial hash baseline."""
    src, dst, w, n = make_graph_family(family, 300, seed=1)
    cuts = {
        s: _cut_fraction(build(src, dst, n, w, n_cells=8, strategy=s))
        for s in ("locality", "hash")
    }
    assert cuts["locality"] <= cuts["hash"], cuts


def test_csr_view_is_destination_sorted_permutation():
    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=5)
    part = build(src, dst, n, w, n_cells=4, edge_slack=0.3)
    sg = part.sg
    perm = np.asarray(sg.csr_perm)
    key = np.asarray(sg.csr_key)
    ep = sg.edges_per_shard
    assert key.shape[1] % DEFAULT_EDGE_BLOCK == 0
    assert key.shape[1] >= ep
    ok = np.asarray(sg.edge_ok)
    flat_dst = np.asarray(sg.dst_shard) * sg.n_per_shard + np.asarray(
        sg.dst_local)
    for s in range(sg.n_shards):
        live = key[s] >= 0
        # exactly the live edges carry a key, keys are ascending, and the
        # dead/padding tail is contiguous
        assert live.sum() == ok[s].sum()
        assert not live[live.argmin():].any() or live.all()
        lk = key[s][live]
        assert np.array_equal(lk, np.sort(lk))
        # perm covers exactly the live edge slots and carries their keys
        p = perm[s][live]
        assert np.array_equal(np.sort(p), np.flatnonzero(ok[s]))
        assert np.array_equal(lk, flat_dst[s][p])


def test_csr_view_tracks_updates():
    """Every topology-changing primitive keeps both CSR views current —
    destination-sorted pull and source-sorted push — by in-place
    tombstone/delta patching (batched and sequential paths), and a
    compacting ``with_csr()`` of either path agrees with the other
    (same edge multiset per cell => same sorted key stream)."""
    from repro.core import DiffusionSession
    from repro.core.dynamic import NameServer, edge_add, edge_delete

    src, dst, w, n = make_graph_family("erdos_renyi", 80, seed=7)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.5)
    sess.add_edge(0, 7, 2.0)
    sess.delete_edge(int(src[0]), int(dst[0]))
    sess.commit()
    # commit() staged the add and tombstoned the delete — O(batch), no
    # re-sort — and the views remained present throughout
    assert sess.sg.csr_perm is not None
    assert int(np.asarray(sess.sg.delta_count).sum()) == 1
    assert int(np.asarray(sess.sg.tomb_count).sum()) == 1

    part = build(src, dst, n, w, n_cells=2, edge_slack=0.5)
    ns = NameServer(part)
    sg = edge_add(part.sg, ns, 0, 7, 2.0)
    sg = edge_delete(sg, ns, int(src[0]), int(dst[0]))
    # the sequential primitives patch the same way (no invalidation, no
    # per-update sort): both views stay present together
    assert sg.csr_perm is not None and sg.push_perm is not None
    assert int(np.asarray(sg.delta_count).sum()) == 1
    # ...and both paths compact to identical sorted streams
    assert np.array_equal(np.asarray(sg.with_csr().csr_key),
                          np.asarray(sess.sg.with_csr().csr_key))
    assert np.array_equal(np.asarray(sg.with_csr().push_src),
                          np.asarray(sess.sg.with_csr().push_src))


def test_invalidate_csr_escape_hatch():
    """Regression: ``invalidate_csr`` still drops the pull AND push views
    (and every delta-maintenance field) consistently — the escape hatch
    for out-of-band mutation — and the lazy rebuild agrees with an eager
    with_csr() after every sequential step."""
    from repro.core.dynamic import (NameServer, edge_add, edge_delete,
                                    vertex_delete)

    src, dst, w, n = make_graph_family("small_world", 90, seed=13)
    part = build(src, dst, n, w, n_cells=3, edge_slack=0.5,
                 node_slack=0.2)
    ns = NameServer(part)
    sg = part.sg
    steps = [
        lambda g: edge_add(g, ns, 1, 40, 0.7),
        lambda g: edge_delete(g, ns, int(src[2]), int(dst[2])),
        lambda g: vertex_delete(g, ns, 17),
    ]
    for step in steps:
        sg = step(sg).invalidate_csr()
        for f in ("csr_perm", "csr_key", "csr_live", "csr_inv",
                  "push_perm", "push_src", "push_pos", "push_inv",
                  "delta_count", "tomb_count"):
            assert getattr(sg, f) is None, f
        with pytest.raises(ValueError):
            sg.csr_view()
        with pytest.raises(ValueError):
            sg.push_view()
        sg = sg.with_csr()     # persist before the next step


def test_push_view_is_source_sorted_permutation():
    """The push view is a per-cell permutation of the live edge slots
    sorted by source local index, with push_pos the exact inverse map
    into the destination-sorted stream."""
    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=5)
    part = build(src, dst, n, w, n_cells=4, edge_slack=0.3)
    sg = part.sg
    perm = np.asarray(sg.push_perm)
    psrc = np.asarray(sg.push_src)
    ppos = np.asarray(sg.push_pos)
    cperm = np.asarray(sg.csr_perm)
    ok = np.asarray(sg.edge_ok)
    assert psrc.shape[1] % DEFAULT_EDGE_BLOCK == 0
    assert psrc.shape == np.asarray(sg.csr_key).shape
    for s in range(sg.n_shards):
        live = psrc[s] >= 0
        # exactly the live edges, ascending by source, dead tail trailing
        assert live.sum() == ok[s].sum()
        assert not live[live.argmin():].any() or live.all()
        lk = psrc[s][live]
        assert np.array_equal(lk, np.sort(lk))
        p = perm[s][live]
        assert np.array_equal(np.sort(p), np.flatnonzero(ok[s]))
        assert np.array_equal(lk, np.asarray(sg.src_local)[s][p])
        # push_pos round-trips through the destination-sorted stream:
        # csr_perm[push_pos[i]] is the same edge slot as push_perm[i]
        assert np.array_equal(cperm[s][ppos[s][live]], p)


def test_lazy_csr_invalidation_rebuilds_before_query():
    """Regression (PR 2 lazy-invalidate path): an explicitly invalidated
    graph (csr_perm=None — the escape hatch) still serves peek()/query()
    through the in-trace rebuild — bitwise-equal to a from-scratch
    partition of the same edge set, for a min and a sum program."""
    from repro.core import DiffusionSession, diffuse
    from repro.core.dynamic import edge_add, edge_delete
    from repro.core.programs import sssp_program

    src, dst, w, n = make_graph_family("small_world", 100, seed=11)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.5)
    # mutate through the sequential primitives directly on the session's
    # graph, then drop the patched views through the escape hatch
    sg = sess.part.sg
    dels = [(int(src[i]), int(dst[i])) for i in (0, 3)]
    adds = [(1, 50, 0.25), (50, 97, 0.5)]
    for u, v in dels:
        sg = edge_delete(sg, sess.ns, u, v)
    for u, v, x in adds:
        sg = edge_add(sg, sess.ns, u, v, x)
    sg = sg.invalidate_csr()
    assert sg.csr_perm is None            # invalidated, not rebuilt
    sess.part.sg = sg

    # from-scratch reference partition over the same live edge set
    edges = {}
    for a, b, x in zip(src, dst, w):
        edges.setdefault((int(a), int(b)), []).append(float(x))
    for u, v in dels:
        edges[(u, v)].pop(0)
    for u, v, x in adds:
        edges.setdefault((u, v), []).append(x)
    flat = [(u, v, x) for (u, v), ws in edges.items() for x in ws]
    s2 = np.array([e[0] for e in flat], np.int32)
    d2 = np.array([e[1] for e in flat], np.int32)
    w2 = np.array([e[2] for e in flat], np.float32)
    ref = DiffusionSession.from_edges(s2, d2, n, w2, n_cells=2)

    # min-combine fixed points are order-free within a destination run =>
    # bitwise; sum depends on slot order inside runs => allclose
    got = sess.query("sssp", source=0).values[:n]
    want = ref.query("sssp", source=0).values[:n]
    both_inf = np.isinf(got) & np.isinf(want)
    assert np.array_equal(np.where(both_inf, 0, got),
                          np.where(both_inf, 0, want))
    got_r = sess.query("ppr", source=0, eps=1e-5).values[:n]
    want_r = ref.query("ppr", source=0, eps=1e-5).values[:n]
    assert np.allclose(got_r, want_r, atol=1e-6)
    pk = np.asarray(sess.peek(1, "sssp", source=0))
    assert np.isfinite(pk).sum() >= 1     # sees the inserted (1, 50) edge
    # the engine rebuilt in-trace; the persisted graph still lazily
    # invalidated until with_csr() is called explicitly
    vstate, _ = diffuse(sess.sg.with_csr(), sssp_program(0))
    assert np.array_equal(
        np.asarray(sess.vertex_state("sssp", source=0)["dist"]),
        np.asarray(vstate["dist"]))


# --------------------------------------------------------------------------
# delta-segment incremental CSR maintenance (DESIGN.md §2.9)
# --------------------------------------------------------------------------

def _delta_session(n=120, n_cells=3, seed=5):
    from repro.core import DiffusionSession

    src, dst, w, n = make_graph_family("erdos_renyi", n, seed=seed)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=n_cells,
                                       edge_slack=0.5, node_slack=0.2)
    return sess, (src, dst, w, n)


def test_delta_segment_invariants_after_mixed_batch():
    """CSR invariants for tombstoned and staged positions: staged delta
    entries carry the right slot/key/src in both views at matching
    positions, tombstones keep the structural key but drop the live
    mask / push validity, the slot inverses round-trip, and the counters
    track exactly."""
    sess, (src, dst, w, n) = _delta_session()
    sg0 = sess.sg
    es = sg0.sorted_width
    rng = np.random.default_rng(3)
    adds = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
             float(0.3 + rng.random())) for _ in range(7)]
    dels = [(int(src[i]), int(dst[i])) for i in (0, 4, 9)]
    for u, v, x in adds:
        sess.add_edge(u, v, x)
    for u, v in dels:
        sess.delete_edge(u, v)
    sess.commit()
    sg = sess.sg

    key = np.asarray(sg.csr_key)
    live = np.asarray(sg.csr_live)
    perm = np.asarray(sg.csr_perm)
    inv = np.asarray(sg.csr_inv)
    psrc = np.asarray(sg.push_src)
    pperm = np.asarray(sg.push_perm)
    ppos = np.asarray(sg.push_pos)
    pinv = np.asarray(sg.push_inv)
    ok = np.asarray(sg.edge_ok)
    dc = np.asarray(sg.delta_count)
    tc = np.asarray(sg.tomb_count)
    flat_dst = (np.asarray(sg.dst_shard) * sg.n_per_shard
                + np.asarray(sg.dst_local))

    assert int(dc.sum()) == len(adds)
    assert int(tc.sum()) == len(dels)
    for s in range(sg.n_shards):
        # structural key stays sorted over the whole sorted region
        sk = key[s, :es][key[s, :es] >= 0]
        assert np.array_equal(sk, np.sort(sk))
        # live positions (sorted survivors + staged deltas) are exactly
        # the live edges, and carry their current destination keys
        lp = np.flatnonzero(live[s])
        assert lp.size == ok[s].sum()
        assert np.array_equal(np.sort(perm[s, lp]), np.flatnonzero(ok[s]))
        assert np.array_equal(key[s, lp], flat_dst[s][perm[s, lp]])
        # staged region: first delta_count[s] positions after the sorted
        # region are live, the rest of the delta capacity is free
        dl = live[s, es:]
        assert dl[: dc[s]].all() and not dl[dc[s]:].any()
        # push view mirrors: staged edges sit at the *same* positions
        # with src filled; tombstones read -1
        assert np.array_equal(pperm[s, es:es + dc[s]],
                              perm[s, es:es + dc[s]])
        assert np.array_equal(
            psrc[s, es:es + dc[s]],
            np.asarray(sg.src_local)[s][perm[s, es:es + dc[s]]])
        assert np.array_equal(ppos[s, es:es + dc[s]],
                              np.arange(es, es + dc[s]))
        live_push = psrc[s] >= 0
        assert live_push.sum() == ok[s].sum()
        assert np.array_equal(np.sort(pperm[s, live_push]),
                              np.flatnonzero(ok[s]))
        # slot inverses round-trip for every live edge
        slots = np.flatnonzero(ok[s])
        assert np.array_equal(perm[s, inv[s, slots]], slots)
        assert np.array_equal(pperm[s, pinv[s, slots]], slots)
    # tombstoned dense positions: structural key kept, live dropped
    tomb = (key >= 0) & ~live
    tomb[:, es:] = False
    assert int(tomb.sum()) == len(dels)


def test_compaction_on_delta_overflow():
    """A batch that would overflow a cell's delta segment falls back to
    the eager compacting rebuild: counters reset and the streams equal a
    from-scratch with_csr()."""
    from repro.core import DiffusionSession

    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=5)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=3,
                                       edge_slack=3.0)   # slots >> delta
    cap = sess.sg.delta_width
    rng = np.random.default_rng(11)
    for _ in range(cap + 1):          # all adds land in one cell
        sess.add_edge(3, int(rng.integers(0, n)), 0.5)
    sess.commit()
    sg = sess.sg
    assert int(np.asarray(sg.delta_count).sum()) == 0
    assert int(np.asarray(sg.tomb_count).sum()) == 0
    rebuilt = sg.with_csr()
    assert np.array_equal(np.asarray(sg.csr_perm),
                          np.asarray(rebuilt.csr_perm))
    assert np.array_equal(np.asarray(sg.push_perm),
                          np.asarray(rebuilt.push_perm))


def test_apply_is_fully_device_resident():
    """Acceptance: the steady-state apply is one compiled program with
    zero device->host transfers (the old path pulled the whole edge_ok
    stream to the host every batch)."""
    import jax

    from repro.core.dynamic import NameServer
    from repro.core.updates import UpdateBatch, apply_updates

    src, dst, w, n = make_graph_family("scale_free", 150, seed=2)
    part = build(src, dst, n, w, n_cells=2, edge_slack=0.5,
                 node_slack=0.2)
    ns = NameServer(part)
    ub = UpdateBatch(ns)
    for i in range(6):
        ub.add_edge(i, (i * 11 + 5) % n, 0.5)
    ub.delete_edge(int(src[0]), int(dst[0]))
    gid = ub.add_vertex()
    ub.add_edge(gid, 1, 1.0)
    ops, _ = ub._pack_ops(part.sg)
    with jax.transfer_guard("disallow"):
        sg2, del_ok, add_ok = apply_updates(part.sg, ops, stage=True)
        jax.block_until_ready(sg2.csr_live)
    # and the padded op arrays ride a power-of-two ladder, so a stream
    # of similar batches reuses one compiled apply
    assert ops["ea_su"].shape[0] == 8          # 7 adds -> 8
    assert ops["ed_su"].shape[0] == 1


_WARM_QUERY_MATRIX = [
    (name, kwargs, backend)
    for name, kwargs in [("sssp", {"source": 0}), ("bfs", {"source": 2}),
                         ("cc", {}), ("ppr", {"source": 1}),
                         ("pagerank", {})]
    for backend in ("xla", "pallas")
]


@pytest.mark.parametrize("name,kwargs,backend", _WARM_QUERY_MATRIX)
def test_warm_query_matrix_transfer_and_retrace_free(name, kwargs, backend,
                                                     sanitize):
    """Acceptance (ISSUE #8): every builtin, on the sharded engine and
    both relaxation backends, re-answers a warm query under the full
    sanitizer — no guarded transfers, no hot-path retraces — and
    bitwise-identically to the cold run."""
    from repro.core import DiffusionSession

    src, dst, w, n = make_graph_family("scale_free", 120, seed=21)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2)
    cold = sess.query(name, backend=backend, **kwargs)
    with sanitize() as rep:
        warm = sess.query(name, backend=backend, refresh=True, **kwargs)
    assert rep.total_retraces() == 0
    assert np.array_equal(np.asarray(cold.values), np.asarray(warm.values))


def test_incremental_apply_can_be_forced_or_disabled():
    """apply(incremental=False) forces the eager rebuild (benchmark
    baseline); incremental=True raises when the graph cannot stage."""
    from repro.core.dynamic import NameServer
    from repro.core.updates import UpdateBatch

    src, dst, w, n = make_graph_family("erdos_renyi", 80, seed=1)
    part = build(src, dst, n, w, n_cells=2, edge_slack=0.5)
    ns = NameServer(part)
    ub = UpdateBatch(ns)
    ub.add_edge(0, 7, 2.0)
    sg2, _ = ub.apply(part.sg, incremental=False)
    assert int(np.asarray(sg2.delta_count).sum()) == 0   # rebuilt eagerly
    ub.add_edge(1, 9, 1.0)
    with pytest.raises(ValueError):
        ub.apply(part.sg.invalidate_csr(), incremental=True)


def test_incremental_views_equal_rebuild_random_batches():
    """Seeded twin of the hypothesis property test (test_properties.py —
    skipped where hypothesis is absent): two random mixed batches
    committed through the tombstone/delta path, then a representative
    program x backend x sweep matrix answers bitwise-identically on the
    incremental views and on a full with_csr() rebuild of the same
    graph."""
    from repro.core import DiffusionSession, diffuse
    from repro.core.programs import PROGRAMS

    src, dst, w, n = make_graph_family("scale_free", 90, seed=17)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=1.0, node_slack=0.5)
    rng = np.random.default_rng(23)
    for _ in range(2):                      # two accumulating batches
        for _ in range(5):
            sess.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                          float(0.2 + rng.random()))
        i = int(rng.integers(0, len(src)))
        sess.delete_edge(int(src[i]), int(dst[i]))
        g = sess.add_vertex()
        sess.add_edge(g, int(rng.integers(0, n)), 1.0)
        sess.delete_vertex(int(rng.integers(0, n)))
        sess.commit()
    assert int(np.asarray(sess.sg.delta_count).sum()) > 0   # really dirty
    assert int(np.asarray(sess.sg.tomb_count).sum()) > 0

    rebuilt = sess.sg.with_csr()
    matrix = [("sssp", dict(source=0)), ("cc", {}),
              ("widest", dict(source=0, track_parents=True)),
              ("ppr", dict(source=0, eps=1e-5)),
              ("reach", dict(sources=(0, 7)))]
    for backend, sweep in [("xla", "pull"), ("xla", "push"),
                           ("pallas", "auto")]:
        for name, kw in matrix:
            prog = PROGRAMS[name].factory(**kw)
            got, _ = diffuse(sess.sg, prog, backend=backend, sweep=sweep)
            want, _ = diffuse(rebuilt, prog, backend=backend, sweep=sweep)
            for k in got:
                a, b = np.asarray(got[k]), np.asarray(want[k])
                assert np.array_equal(np.isfinite(a), np.isfinite(b)), (
                    backend, sweep, name, k)
                fin = np.isfinite(a)
                assert np.array_equal(np.where(fin, a, 0),
                                      np.where(fin, b, 0)), (
                    backend, sweep, name, k)


def test_half_dead_vertex_placement():
    """Regression for the vectorized dead-slot scatter: with 50% of the
    vertex capacity dead (node_slack=1.0), every (cell, local) pair is
    still assigned exactly once, locals stay in range, and the layout
    round-trip is exact."""
    src, dst, w, n = make_graph_family("scale_free", 400, seed=21)
    part = build(src, dst, n, w, n_cells=4, node_slack=1.0,
                 edge_slack=0.2)
    sg = part.sg
    owner = np.asarray(part.owner)
    local = np.asarray(part.local)
    cap = owner.shape[0]
    assert cap >= 2 * n                       # really 50% dead
    assert owner.min() >= 0 and owner.max() < sg.n_shards
    assert local.min() >= 0 and local.max() < sg.n_per_shard
    # bijective into the shard layout: no two ids share a slot
    flat = owner.astype(np.int64) * sg.n_per_shard + local
    assert np.unique(flat).size == cap
    # live vertices keep node_ok; dead slots don't
    nok = np.asarray(sg.node_ok)
    assert nok[owner[:n], local[:n]].all()
    assert not nok[owner[n:], local[n:]].any()
    # round-trip through the layout is exact for every capacity slot
    vals = np.arange(cap, dtype=np.float32)
    back = np.asarray(part.to_global_layout(
        part.to_shard_layout(vals, fill=-1.0)))
    assert np.array_equal(back, vals)


def test_partition_views_equal_full_rebuild():
    """The views partition() builds host-side are bitwise-identical to
    what a from-scratch device rebuild (invalidate + with_csr) produces
    — the identity-permutation layout really is the stable argsort."""
    for fam, cells in (("scale_free", 4), ("graph500", 3)):
        src, dst, w, n = make_graph_family(fam, 600, seed=8)
        part = build(src, dst, n, w, n_cells=cells, edge_slack=0.3,
                     node_slack=0.1)
        sg = part.sg
        rb = sg.invalidate_csr().with_csr()
        for f in ("csr_perm", "csr_key", "csr_live", "csr_inv",
                  "push_perm", "push_src", "push_pos", "push_inv"):
            assert np.array_equal(np.asarray(getattr(sg, f)),
                                  np.asarray(getattr(rb, f))), (fam, f)


def test_merge_compaction_equals_full_rebuild_at_width():
    """Above MERGE_COMPACT_MIN_WIDTH the with_csr() dispatch takes the
    staged-delta merge path; after a dirty mix of deletes and staged
    adds it must reproduce the full stable-argsort rebuild bit for bit
    across all eight view arrays."""
    from repro.core.dynamic import NameServer, edge_add, edge_delete
    from repro.core.graph import MERGE_COMPACT_MIN_WIDTH

    src, dst, w, n = make_graph_family("scale_free", 2500, seed=17)
    part = build(src, dst, n, w, n_cells=2, edge_slack=0.3)
    sg = part.sg
    assert sg.sorted_width >= MERGE_COMPACT_MIN_WIDTH  # merge path armed
    ns = NameServer(part)
    rng = np.random.default_rng(0)
    for i in rng.choice(src.shape[0] // 2, 40, replace=False):
        sg = edge_delete(sg, ns, int(src[i]), int(dst[i]))
    for _ in range(30):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u != v:
            sg = edge_add(sg, ns, u, v, 0.5)
    assert int(np.asarray(sg.delta_count).sum()) > 0
    assert int(np.asarray(sg.tomb_count).sum()) > 0
    merged = sg.with_csr()
    full = sg.invalidate_csr().with_csr()
    for f in ("csr_perm", "csr_key", "csr_live", "csr_inv",
              "push_perm", "push_src", "push_pos", "push_inv"):
        assert np.array_equal(np.asarray(getattr(merged, f)),
                              np.asarray(getattr(full, f))), f
    # compacting a clean graph is a no-op (views already canonical)
    assert merged.with_csr() is merged


def test_skewed_capacity_stays_near_live_edges():
    """The degree-aware capacity model: even on the heavy-tailed
    families, the padded edge stream holds at most ~2x the live edge
    slots (the old max-cell-degree padding blew this up with shard
    count)."""
    for fam in ("scale_free", "graph500"):
        src, dst, w, n = make_graph_family(fam, 4000, seed=5)
        part = build(src, dst, n, w, n_cells=8)
        b = part.sg.layout_bytes()
        assert b["edge_stream"] <= 2 * b["live_edge_bytes"], (fam, b)
