"""Partition layer: global<->shard layout maps, partitioner cut quality,
and the blocked-CSR edge view the relaxation kernels consume."""

import numpy as np
import pytest

from repro.core import build
from repro.core.generators import make_graph_family
from repro.core.graph import DEFAULT_EDGE_BLOCK


@pytest.mark.parametrize("strategy", ["block", "hash", "locality"])
def test_shard_layout_round_trip(strategy, rng):
    src, dst, w, n = make_graph_family("small_world", 150, seed=3)
    part = build(src, dst, n, w, n_cells=4, strategy=strategy)
    vals = rng.normal(size=n).astype(np.float32)
    shard = part.to_shard_layout(vals, fill=np.nan)
    back = np.asarray(part.to_global_layout(shard))
    assert np.array_equal(back, vals)
    # fill lands only on slots owned by no vertex
    n_filled = np.isnan(np.asarray(shard)).sum()
    assert n_filled == part.sg.n_shards * part.sg.n_per_shard - n


def _cut_fraction(part) -> float:
    sg = part.sg
    ok = np.asarray(sg.edge_ok)
    own = np.arange(sg.n_shards)[:, None]
    remote = (np.asarray(sg.dst_shard) != own) & ok
    return remote.sum() / max(ok.sum(), 1)


@pytest.mark.parametrize("family", ["small_world", "scale_free",
                                    "powerlaw_cluster", "graph500"])
def test_locality_cut_no_worse_than_hash(family):
    """The paper's Strategy-2 claim, measured: topology-aware placement
    cuts no more edges than the adversarial hash baseline."""
    src, dst, w, n = make_graph_family(family, 300, seed=1)
    cuts = {
        s: _cut_fraction(build(src, dst, n, w, n_cells=8, strategy=s))
        for s in ("locality", "hash")
    }
    assert cuts["locality"] <= cuts["hash"], cuts


def test_csr_view_is_destination_sorted_permutation():
    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=5)
    part = build(src, dst, n, w, n_cells=4, edge_slack=0.3)
    sg = part.sg
    perm = np.asarray(sg.csr_perm)
    key = np.asarray(sg.csr_key)
    ep = sg.edges_per_shard
    assert key.shape[1] % DEFAULT_EDGE_BLOCK == 0
    assert key.shape[1] >= ep
    ok = np.asarray(sg.edge_ok)
    flat_dst = np.asarray(sg.dst_shard) * sg.n_per_shard + np.asarray(
        sg.dst_local)
    for s in range(sg.n_shards):
        live = key[s] >= 0
        # exactly the live edges carry a key, keys are ascending, and the
        # dead/padding tail is contiguous
        assert live.sum() == ok[s].sum()
        assert not live[live.argmin():].any() or live.all()
        lk = key[s][live]
        assert np.array_equal(lk, np.sort(lk))
        # perm covers exactly the live edge slots and carries their keys
        p = perm[s][live]
        assert np.array_equal(np.sort(p), np.flatnonzero(ok[s]))
        assert np.array_equal(lk, flat_dst[s][p])


def test_csr_view_tracks_updates():
    """Every topology-changing primitive refreshes the CSR view (batched
    and sequential paths)."""
    from repro.core import DiffusionSession
    from repro.core.dynamic import NameServer, edge_add, edge_delete

    src, dst, w, n = make_graph_family("erdos_renyi", 80, seed=7)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.5)
    sess.add_edge(0, 7, 2.0)
    sess.delete_edge(int(src[0]), int(dst[0]))
    sess.commit()
    assert np.array_equal(np.asarray(sess.sg.csr_perm),
                          np.asarray(sess.sg.with_csr().csr_perm))

    part = build(src, dst, n, w, n_cells=2, edge_slack=0.5)
    ns = NameServer(part)
    sg = edge_add(part.sg, ns, 0, 7, 2.0)
    sg = edge_delete(sg, ns, int(src[0]), int(dst[0]))
    # sequential primitives invalidate (lazy rebuild at the next diffusion)
    # instead of paying one sort per single-edge update
    assert sg.csr_perm is None
    # ...and the rebuilt stream matches the batched path's (same edge
    # multiset per cell => same sorted key stream, slot layout aside)
    assert np.array_equal(np.asarray(sg.with_csr().csr_key),
                          np.asarray(sess.sg.csr_key))


def test_lazy_csr_invalidation_rebuilds_before_query():
    """Regression (PR 2 lazy-invalidate path): sequential add_edge /
    delete_edge leave csr_perm=None, and a following peek()/query() must
    see the *rebuilt* CSR — bitwise-equal to a from-scratch partition of
    the same edge set, for a min and a sum program."""
    from repro.core import DiffusionSession, diffuse
    from repro.core.dynamic import edge_add, edge_delete
    from repro.core.programs import sssp_program

    src, dst, w, n = make_graph_family("small_world", 100, seed=11)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.5)
    # mutate through the sequential primitives (bypassing UpdateBatch's
    # eager with_csr), directly on the session's graph
    sg = sess.part.sg
    dels = [(int(src[i]), int(dst[i])) for i in (0, 3)]
    adds = [(1, 50, 0.25), (50, 97, 0.5)]
    for u, v in dels:
        sg = edge_delete(sg, sess.ns, u, v)
    for u, v, x in adds:
        sg = edge_add(sg, sess.ns, u, v, x)
    assert sg.csr_perm is None            # invalidated, not rebuilt
    sess.part.sg = sg

    # from-scratch reference partition over the same live edge set
    edges = {}
    for a, b, x in zip(src, dst, w):
        edges.setdefault((int(a), int(b)), []).append(float(x))
    for u, v in dels:
        edges[(u, v)].pop(0)
    for u, v, x in adds:
        edges.setdefault((u, v), []).append(x)
    flat = [(u, v, x) for (u, v), ws in edges.items() for x in ws]
    s2 = np.array([e[0] for e in flat], np.int32)
    d2 = np.array([e[1] for e in flat], np.int32)
    w2 = np.array([e[2] for e in flat], np.float32)
    ref = DiffusionSession.from_edges(s2, d2, n, w2, n_cells=2)

    # min-combine fixed points are order-free within a destination run =>
    # bitwise; sum depends on slot order inside runs => allclose
    got = sess.query("sssp", source=0).values[:n]
    want = ref.query("sssp", source=0).values[:n]
    both_inf = np.isinf(got) & np.isinf(want)
    assert np.array_equal(np.where(both_inf, 0, got),
                          np.where(both_inf, 0, want))
    got_r = sess.query("ppr", source=0, eps=1e-5).values[:n]
    want_r = ref.query("ppr", source=0, eps=1e-5).values[:n]
    assert np.allclose(got_r, want_r, atol=1e-6)
    pk = np.asarray(sess.peek(1, "sssp", source=0))
    assert np.isfinite(pk).sum() >= 1     # sees the inserted (1, 50) edge
    # the engine rebuilt in-trace; the persisted graph still lazily
    # invalidated until with_csr() is called explicitly
    vstate, _ = diffuse(sess.sg.with_csr(), sssp_program(0))
    assert np.array_equal(
        np.asarray(sess.vertex_state("sssp", source=0)["dist"]),
        np.asarray(vstate["dist"]))
