"""Fault-tolerance machinery: checkpoint/restore, elastic reshard, resume,
preemption, stragglers, heartbeats."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.optim import adamw
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    PreemptionGuard,
    StragglerMonitor,
    largest_mesh_shape,
)
from repro.runtime.trainer import train_loop


def _toy_setup():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    opt = adamw(lr=0.1)
    state = opt.init(params)

    def loss(p, batch):
        return jnp.mean((batch["x"] @ p["w"] + p["b"] - batch["y"]) ** 2)

    def step(params, opt_state, step_no, batch):
        l, g = jax.value_and_grad(loss)(params, batch)
        upd, opt_state = opt.update(g, opt_state, params, step_no)
        params = jax.tree_util.tree_map(lambda a, u: a + u, params, upd)
        return params, opt_state, {"loss": l, "grad_norm": l}

    def data():
        rng = np.random.default_rng(0)
        while True:
            x = rng.normal(size=(8, 4)).astype(np.float32)
            yield {"x": jnp.asarray(x), "y": jnp.asarray(x.sum(1,
                   keepdims=True) * np.ones((1, 4), np.float32))}

    return params, state, step, data()


def test_checkpoint_roundtrip_and_integrity(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10.0), "nested": {"b": jnp.ones((3, 3))}}
    ckpt.save(5, tree, wait=True)
    ckpt.save(7, tree, wait=True)
    ckpt.save(9, tree, wait=True)
    assert ckpt.all_steps() == [7, 9]          # retention pruned step 5
    restored, step = ckpt.restore(tree)
    assert step == 9
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(1, tree, wait=True)
    # corrupt a leaf on disk
    f = os.path.join(str(tmp_path), "step_1", "a.npy")
    arr = np.load(f)
    arr[0] = 999.0
    np.save(f, arr)
    with pytest.raises(IOError):
        ckpt.restore(tree)


def test_elastic_restore_onto_new_sharding(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(1, tree, wait=True)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None)
    )
    restored, _ = ckpt.restore(tree, shardings={"w": sh})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh


def test_train_loop_resumes_after_kill(tmp_path):
    params, state, step, data = _toy_setup()
    ck = str(tmp_path / "ck")
    # run 10 steps, checkpointing every 4
    p1, s1, last = train_loop(step, params, state, data, 10, ck,
                              ckpt_every=4)
    assert last == 9
    # "restart": resume from latest (step 9 saved at end)
    p2, s2, last2 = train_loop(step, params, state, data, 12, ck,
                               ckpt_every=4)
    assert last2 == 11   # resumed at 10, ran 10..11


def test_preemption_checkpoints_and_stops(tmp_path):
    params, state, step, data = _toy_setup()
    guard = PreemptionGuard()
    calls = []

    def on_metrics(s, m, dt):
        calls.append(s)
        if s == 3:
            guard.trigger()

    _, _, last = train_loop(step, params, state, data, 100,
                            str(tmp_path / "ck2"), ckpt_every=50,
                            guard=guard, on_metrics=on_metrics)
    assert last == 3
    ckpt = CheckpointManager(str(tmp_path / "ck2"))
    assert ckpt.latest_step() == 3


def test_straggler_monitor_flags_slow_steps():
    fired = []
    mon = StragglerMonitor(window=20, factor=2.0, patience=2,
                           on_straggle=lambda *a: fired.append(a))
    for i in range(20):
        mon.record(i, 0.1)
    assert not mon.record(20, 0.15)
    assert mon.record(21, 0.5)
    assert mon.record(22, 0.5)
    assert fired   # patience reached -> mitigation callback
    assert mon.flagged_steps == [21, 22]


def test_heartbeat_failure_detection():
    hb = HeartbeatMonitor(timeout_s=10.0)
    hb.beat("host0", t=100.0)
    hb.beat("host1", t=100.0)
    hb.beat("host0", t=105.0)
    assert hb.dead_nodes(now=112.0) == ["host1"]
    assert hb.alive_nodes(now=112.0) == ["host0"]


def test_largest_mesh_shape_elastic_downscale():
    assert largest_mesh_shape(512) == (32, 16)
    assert largest_mesh_shape(256) == (16, 16)
    assert largest_mesh_shape(248, 16) == (31, 8)   # lost 8 devices
    assert largest_mesh_shape(7, 16) == (7, 1)


# -- checkpoint fallback restore (DESIGN.md §2.13) --------------------------


def _save_steps(tmp_path, values=(1, 2, 3)):
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    tree = None
    for s in values:
        tree = {"a": jnp.arange(4.0) * s, "b": jnp.ones((2, 2)) * s}
        ckpt.save(s, tree, wait=True)
    return ckpt, tree


def _assert_restored_step(ckpt, tree, expected_step):
    with pytest.warns(UserWarning, match="damaged"):
        restored, step = ckpt.restore(tree)
    assert step == expected_step
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.arange(4.0) * expected_step)


def test_checkpoint_fallback_truncated_manifest(tmp_path):
    ckpt, tree = _save_steps(tmp_path)
    mf = os.path.join(str(tmp_path), "step_3", "manifest.json")
    with open(mf, "rb+") as f:
        f.truncate(os.path.getsize(mf) // 2)
    _assert_restored_step(ckpt, tree, 2)


def test_checkpoint_fallback_missing_leaf(tmp_path):
    ckpt, tree = _save_steps(tmp_path)
    os.remove(os.path.join(str(tmp_path), "step_3", "a.npy"))
    _assert_restored_step(ckpt, tree, 2)


def test_checkpoint_fallback_digest_mismatch(tmp_path):
    ckpt, tree = _save_steps(tmp_path)
    leaf = os.path.join(str(tmp_path), "step_3", "b.npy")
    raw = bytearray(open(leaf, "rb").read())
    raw[-1] ^= 0xFF
    open(leaf, "wb").write(bytes(raw))
    _assert_restored_step(ckpt, tree, 2)


def test_checkpoint_fallback_walks_past_two_damaged_steps(tmp_path):
    ckpt, tree = _save_steps(tmp_path)
    for s in (2, 3):
        os.remove(os.path.join(str(tmp_path), f"step_{s}", "a.npy"))
    _assert_restored_step(ckpt, tree, 1)


def test_checkpoint_explicit_step_never_falls_back(tmp_path):
    ckpt, tree = _save_steps(tmp_path)
    os.remove(os.path.join(str(tmp_path), "step_3", "a.npy"))
    with pytest.raises(IOError):
        ckpt.restore(tree, step=3)


# -- PreemptionGuard handler hygiene ----------------------------------------


def test_preemption_guard_restores_prior_handlers():
    import signal

    prior_term = signal.getsignal(signal.SIGTERM)
    prior_int = signal.getsignal(signal.SIGINT)
    with PreemptionGuard() as guard:
        assert signal.getsignal(signal.SIGTERM) is not prior_term
        assert not guard.should_stop
        guard.trigger()
        assert guard.should_stop
    assert signal.getsignal(signal.SIGTERM) is prior_term
    assert signal.getsignal(signal.SIGINT) is prior_int


def test_preemption_guard_uninstall_idempotent():
    guard = PreemptionGuard()
    guard.uninstall()            # never installed: no-op
    guard.install()
    guard.install()              # idempotent
    guard.uninstall()
    guard.uninstall()
