"""Distribution machinery: pipeline parallelism, compressed DP, logical
sharding rules, HLO analysis."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import logical_constraint, sharding_context

# The subprocess tests force the *host* platform (2 fake CPU devices), so
# pin the backend: on images that ship libtpu, an unset JAX_PLATFORMS
# makes the child probe for a TPU and sleep-retry until the timeout.
_SUBPROC_ENV = {
    "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
    "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
}


def test_pipeline_two_stages_matches_sequential():
    """GPipe over 2 host devices == sequential layer apply (subprocess so
    the device count doesn't leak into other tests)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import make_pipeline_fn, bubble_fraction

        mesh = jax.make_mesh((2,), ("pod",))
        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(size=(2, 8, 8)) * 0.5, jnp.float32)
        xs = jnp.asarray(rng.normal(size=(4, 3, 8)), jnp.float32)  # M=4 mb

        fn = make_pipeline_fn(mesh, stage_fn, n_stages=2, n_micro=4,
                              axis="pod")
        from repro.launch.mesh import mesh_context
        with mesh_context(mesh):
            ys = jax.jit(fn)(ws, xs)
        ref = jnp.stack([stage_fn(ws[1], stage_fn(ws[0], x)) for x in xs])
        assert np.allclose(np.asarray(ys), np.asarray(ref), atol=1e-5), (
            np.abs(np.asarray(ys) - np.asarray(ref)).max()
        )
        assert abs(bubble_fraction(4, 2) - 0.2) < 1e-9
        print("PIPELINE_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=_SUBPROC_ENV,
        cwd="/root/repo", timeout=600,
    )
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr


def test_compressed_psum_single_shard_roundtrip():
    """n_shards=1: compressed psum must reproduce the (quantized) mean and
    carry the residual in the error state."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.compressed_dp import (compressed_psum_mean,
                                              init_error_state)

        mesh = jax.make_mesh((2,), ("dp",))
        rng = np.random.default_rng(0)
        g_global = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)

        def body(g, e):
            m, e2 = compressed_psum_mean({"g": g[0]}, {"g": e[0]},
                                         "dp", 2)
            return m["g"][None], e2["g"][None]

        fn = shard_map(body, mesh=mesh, in_specs=(P("dp"), P("dp")),
                       out_specs=(P("dp"), P("dp")), check_rep=False)
        from repro.launch.mesh import mesh_context
        with mesh_context(mesh):
            mean, err = jax.jit(fn)(g_global, jnp.zeros_like(g_global))
        true_mean = np.asarray(g_global).mean(0)
        got = np.asarray(mean)
        # both shards agree and are close to the true mean (int8 quant)
        assert np.allclose(got[0], got[1], atol=1e-6)
        assert np.max(np.abs(got[0] - true_mean)) < 0.05
        # error feedback: residual + sent == contribution
        print("COMPRESSED_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=_SUBPROC_ENV,
        cwd="/root/repo", timeout=600,
    )
    assert "COMPRESSED_OK" in out.stdout, out.stdout + out.stderr


def test_logical_constraint_drops_indivisible_and_duplicate_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"batch": ("data",), "heads": "model", "seq": "model",
             "vocab": "model"}
    with sharding_context(mesh, rules):
        x = jnp.zeros((4, 6, 8))
        # heads (dim1) claims 'model'; seq (dim2... here named last) must
        # NOT claim it again
        y = logical_constraint(x, "batch", "heads", "seq")
        assert y.shape == x.shape
        # indivisible dim: silently unsharded, no error
        z = jnp.zeros((3, 5))
        logical_constraint(z, "batch", "heads")


def test_hlo_analysis_scan_awareness():
    from benchmarks.hlo_analysis import analyze_hlo

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    ws = jnp.zeros((7, 16, 16))
    x = jnp.zeros((4, 16))
    text = jax.jit(f).lower(ws, x).compile().as_text()
    a = analyze_hlo(text)
    # 7 iterations x (2 * 4*16*16) flops
    expect = 7 * 2 * 4 * 16 * 16
    assert abs(a["flops"] - expect) / expect < 0.01, a["flops"]
    assert a["bytes_est"] > 7 * (16 * 16 * 4)   # weight reads per step
