"""repro.analysis acceptance: the AST lint pass (rule coverage on the
bad fixture, clean src tree, CLI exit codes), the registration-time
program verifier (every builtin passes; broken specs fail with
distinct, named errors), and the runtime sanitizer (warm query/apply
run transfer- and retrace-free; forced retraces are caught)."""

import importlib
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]
FIXTURE = Path(__file__).parent / "fixtures" / "lint_bad.py"
LINT_TARGETS = [str(ROOT / "src" / "repro" / "core"),
                str(ROOT / "src" / "repro" / "kernels")]


# --------------------------------------------------------------------------
# lint pass (stdlib-only — no jax needed for these)
# --------------------------------------------------------------------------

def test_lint_fixture_trips_every_rule():
    from repro.analysis import lint_paths
    from repro.analysis.lint import RULES

    findings = lint_paths([FIXTURE])
    assert findings, "the bad fixture must produce findings"
    assert {f.rule for f in findings} == set(RULES), \
        "every lint rule must fire on the fixture"
    # the one allowlisted line (apply_updates' int()) stays suppressed
    allowed_line = next(i for i, text in enumerate(
        FIXTURE.read_text().splitlines(), start=1)
        if "analysis: allow" in text)
    assert all(f.line != allowed_line for f in findings)


def test_lint_findings_render_as_path_line_col():
    from repro.analysis import lint_paths

    f = lint_paths([FIXTURE])[0]
    rendered = f.render()
    assert rendered.startswith(f"{f.path}:{f.line}:{f.col}: {f.rule}:")


def test_lint_src_tree_is_clean():
    """Acceptance: the shipped engine carries no un-allowlisted host
    syncs, host loops, unguarded int64, or action-body mutation."""
    from repro.analysis import lint_paths

    findings = lint_paths(LINT_TARGETS)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_lint_cli_exit_codes():
    """The CI entry point: nonzero + findings on stdout for dirty input,
    zero for the real tree."""
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(FIXTURE)],
        capture_output=True, text=True, env=env)
    assert bad.returncode == 1
    assert "host-sync" in bad.stdout and "mutation" in bad.stdout
    good = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *LINT_TARGETS],
        capture_output=True, text=True, env=env)
    assert good.returncode == 0, good.stdout + good.stderr


def test_lint_importable_without_jax():
    """The lint layer must run in the CI lint job, which installs no
    accelerator stack: importing it may not import jax."""
    code = ("import sys; sys.modules['jax'] = None\n"
            "import repro.analysis.lint as L\n"
            "assert L.lint_paths([r'%s'])\n" % FIXTURE)
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr


# --------------------------------------------------------------------------
# registration-time verifier
# --------------------------------------------------------------------------

_BUILTIN_KWARGS = {
    "sssp": {"source": 0},
    "bfs": {"source": 0},
    "cc": {},
    "ppr": {"source": 0},
    "pagerank": {},
    "widest": {"source": 0},
    "reach": {"sources": [0, 3]},
}


def test_every_registered_builtin_passes_verification():
    """Acceptance: all shipped @diffusive programs (including widest and
    reach) lower cleanly through verify_program."""
    from repro.core.programs import PROGRAMS, VertexProgram

    checked = []
    for name, spec in PROGRAMS.items():
        if spec.factory is None or name not in _BUILTIN_KWARGS:
            continue
        prog = spec.factory(**_BUILTIN_KWARGS[name])
        assert isinstance(prog, VertexProgram)
        checked.append(name)
    assert set(checked) == set(_BUILTIN_KWARGS)


def _sssp_like(**overrides):
    """A minimal valid spec; each negative test breaks one component."""
    import jax.numpy as jnp

    from repro.core.programs import DiffusiveProgram, Field

    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["dist"]) & node_ok
        return {"dist": jnp.where(better, inbox, vstate["dist"])}, better

    base = dict(
        monoid="min",
        msg_dtype=jnp.float32,
        state={"dist": Field(jnp.float32, init=jnp.inf)},
        emit=lambda s, weight, src_gid, dst_gid: s["dist"] + weight,
        receive=receive,
    )
    base.update(overrides)
    return DiffusiveProgram(**base)


def test_verifier_rejects_wrong_emit_dtype():
    import jax.numpy as jnp

    from repro.analysis import ProgramVerificationError, verify_program

    spec = _sssp_like(
        emit=lambda s, weight, src_gid, dst_gid:
            (s["dist"] + weight).astype(jnp.int32))
    with pytest.raises(ProgramVerificationError, match="emit.*dtype"):
        verify_program(spec, name="bad-emit-dtype")


def test_verifier_rejects_schema_drift():
    import jax.numpy as jnp

    from repro.analysis import ProgramVerificationError, verify_program

    def receive(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["dist"]) & node_ok
        return {"distance": jnp.where(better, inbox, vstate["dist"])}, better

    with pytest.raises(ProgramVerificationError, match="keys drifted"):
        verify_program(_sssp_like(receive=receive), name="bad-schema")


def test_verifier_rejects_non_associative_combine():
    from repro.analysis import ProgramVerificationError, verify_program
    from repro.core.monoid import Monoid

    bad = Monoid("subtract", "min", op=lambda a, b: a - b)
    with pytest.raises(ProgramVerificationError,
                       match="not (associative|commutative)"):
        verify_program(_sssp_like(monoid=bad), name="bad-monoid")


def test_verifier_rejects_tracer_leaking_closure():
    from repro.analysis import ProgramVerificationError, verify_program

    stash = []

    def leaky_emit(s, weight, src_gid, dst_gid):
        stash.append(s["dist"])        # leaks the tracer out of the trace
        return s["dist"] + weight

    with pytest.raises(ProgramVerificationError, match="emit"):
        verify_program(_sssp_like(emit=leaky_emit), name="leaky")


def test_verifier_rejects_bad_receive_arity():
    from repro.analysis import ProgramVerificationError, verify_program

    def receive(vstate, inbox, has_msg, payload, node_ok):
        return vstate                  # forgot the activation mask

    with pytest.raises(ProgramVerificationError,
                       match=r"receive.*\(vstate, activated\)"):
        verify_program(_sssp_like(receive=receive), name="bad-arity")


def test_verifier_rejects_nonfinite_on_dead_in_int_field():
    import jax.numpy as jnp

    from repro.analysis import ProgramVerificationError, verify_program
    from repro.core.programs import Field

    state = {"dist": Field(jnp.float32, init=jnp.inf),
             "hops": Field(jnp.int32, init=0, on_dead=jnp.inf)}
    with pytest.raises(ProgramVerificationError, match="on_dead"):
        verify_program(_sssp_like(state=state), name="bad-on-dead")


def test_verifier_rejects_non_identity_empty_receive():
    """DESIGN.md §2.12: hub-replica mirrors stay coherent only if a
    receive with has_msg all-False is a bitwise no-op on state — a spec
    that rewrites state unconditionally must be rejected."""
    from repro.analysis import ProgramVerificationError, verify_program

    def receive(vstate, inbox, has_msg, payload, node_ok):
        # schema- and dtype-preserving, but every call decays the state
        # instead of gating the write on has_msg
        return {"dist": vstate["dist"] * 0.5}, has_msg

    with pytest.raises(ProgramVerificationError, match="empty inbox"):
        verify_program(_sssp_like(receive=receive), name="ungated-receive")


def test_verifier_errors_are_distinct():
    """Each broken spec names its own component — four distinct errors."""
    from repro.analysis import ProgramVerificationError, verify_program
    from repro.core.monoid import Monoid

    import jax.numpy as jnp

    def drifted(vstate, inbox, has_msg, payload, node_ok):
        better = has_msg & (inbox < vstate["dist"]) & node_ok
        return {"distance": jnp.where(better, inbox, vstate["dist"])}, better

    stash = []

    def leaky(s, weight, src_gid, dst_gid):
        stash.append(s["dist"])
        return s["dist"] + weight

    specs = [
        _sssp_like(emit=lambda s, w, sg, dg: (s["dist"] + w).astype(
            jnp.int32)),
        _sssp_like(receive=drifted),
        _sssp_like(monoid=Monoid("subtract", "min", op=lambda a, b: a - b)),
        _sssp_like(emit=leaky),
    ]
    messages = []
    for spec in specs:
        with pytest.raises(ProgramVerificationError) as exc:
            verify_program(spec, name="broken")
        messages.append(str(exc.value))
    assert len(set(messages)) == len(messages)


def test_verification_can_be_disabled(monkeypatch):
    from repro.analysis.verify import verification_enabled

    monkeypatch.setenv("REPRO_VERIFY", "0")
    assert not verification_enabled()
    monkeypatch.delenv("REPRO_VERIFY")
    assert verification_enabled()


# --------------------------------------------------------------------------
# runtime sanitizer
# --------------------------------------------------------------------------

def _session(n=128, m=1024, seed=0, n_cells=2, **kw):
    from repro.core.session import DiffusionSession

    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    w = rng.uniform(0.1, 2.0, m).astype(np.float32)
    return DiffusionSession.from_edges(src, dst, n, weight=w,
                                       n_cells=n_cells, **kw)


def test_warm_query_zero_retraces_across_sources(sanitize):
    """Acceptance (ISSUE #8): two queries differing only in source share
    one _run_rounds compilation — cache-miss delta exactly 0."""
    _run_rounds = importlib.import_module("repro.core.diffuse")._run_rounds
    sess = _session()
    sess.query("sssp", source=0)                  # warm the jit
    before = _run_rounds._cache_size()
    with sanitize() as rep:
        sess.query("sssp", source=1)
        sess.query("sssp", source=7)
    assert _run_rounds._cache_size() - before == 0
    assert rep.total_retraces() == 0


def test_warm_laned_query_zero_retraces_across_source_sets(sanitize):
    """Satellite (ISSUE #8): two query("sssp", sources=[...]) calls with
    different sources but identical lane shape hit the same jit cache
    entry — the laned program's init-excluding identity plus the eager
    init hoist keep the cache-miss delta at exactly 0."""
    _run_rounds = importlib.import_module("repro.core.diffuse")._run_rounds
    sess = _session(seed=1)
    sess.query("sssp", sources=[0, 1])            # warm the 2-lane entry
    before = _run_rounds._cache_size()
    with sanitize() as rep:
        sess.query("sssp", sources=[5, 9])
    assert _run_rounds._cache_size() - before == 0
    assert rep.total_retraces() == 0


def test_sanitize_catches_forced_retrace(sanitize):
    """A genuinely-cold static configuration inside a sanitize() block
    must raise RetraceError on exit."""
    from repro.analysis import RetraceError

    sess = _session(seed=3)
    sess.query("sssp", source=0, sweep="pull")
    with pytest.raises(RetraceError, match="_run_rounds"):
        with sanitize():
            sess.query("sssp", source=0, sweep="push", refresh=True)


def test_sanitize_blocks_host_roundtrip(sanitize):
    """On CPU the guard fires on the *re-upload* leg of a host
    round-trip (d2h from a CPU device is zero-copy and unguarded):
    compute on the host, feed the result back into device math."""
    import jax.numpy as jnp

    x = jnp.arange(8.0)
    with pytest.raises(Exception, match="[Tt]ransfer"):
        with sanitize(retraces=False):
            leaked = float(x.sum())     # d2h: free on CPU
            _ = x * leaked              # h2d re-upload: guard trips


def test_warm_apply_is_retrace_and_transfer_free(sanitize):
    """Same-ladder update batches reuse one compiled apply_updates."""
    from repro.core.dynamic import NameServer
    from repro.core.updates import UpdateBatch, apply_updates

    sess = _session(seed=5, edge_slack=1.0, node_slack=0.5)
    ns = NameServer(sess.part)

    def batch(lo):
        ub = UpdateBatch(ns)
        for i in range(lo, lo + 6):
            ub.add_edge(i % sess.n_ids, (i * 13 + 2) % sess.n_ids, 0.25)
        ops, _ = ub._pack_ops(sess.sg)
        return ops

    import jax

    sg1, _, _ = apply_updates(sess.sg, batch(0), stage=True)   # warm
    jax.block_until_ready(sg1.csr_live)
    with sanitize() as rep:
        sg2, _, _ = apply_updates(sg1, batch(40), stage=True)
        jax.block_until_ready(sg2.csr_live)
    assert rep.retraces()["apply_updates"] == 0


def test_sanitize_report_survives_clean_exit(sanitize):
    sess = _session(seed=9)
    sess.query("cc")
    with sanitize() as rep:
        sess.query("cc")
    assert rep.total_retraces() == 0
    assert set(rep.retraces()) == {"_run_rounds", "apply_updates"}
