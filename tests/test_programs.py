"""Diffusive Program API v2: declarative specs, the @diffusive extension
point, first-class monoids, and the two user-level proof programs
(widest-path / reachability-from-set) — DESIGN.md §2.7."""

import heapq

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DiffusionSession, build
from repro.core.diffuse import diffuse
from repro.core.generators import make_graph_family
from repro.core.monoid import MONOIDS, Monoid
from repro.core.programs import (
    PROGRAMS,
    DiffusiveProgram,
    Field,
    diffusive,
    reach_program,
    widest_program,
)


def _mask_inf(a):
    return np.where(np.isinf(a), np.where(a > 0, 1e30, -1e30), a)


# ---------------------------------------------------------------------------
# host references
# ---------------------------------------------------------------------------

def _widest_ref(src, dst, w, n, source):
    """Max-bottleneck widths by best-first search."""
    adj = [[] for _ in range(n)]
    for s, d, x in zip(src, dst, w):
        adj[int(s)].append((int(d), float(x)))
    width = np.full(n, -np.inf)
    width[source] = np.inf
    pq = [(-np.inf, source)]
    while pq:
        negw, v = heapq.heappop(pq)
        if -negw < width[v]:
            continue
        for u, x in adj[v]:
            cand = min(width[v], x)
            if cand > width[u]:
                width[u] = cand
                heapq.heappush(pq, (-cand, u))
    return width


def _reach_ref(src, dst, n, sources):
    adj = [[] for _ in range(n)]
    for s, d in zip(src, dst):
        adj[int(s)].append(int(d))
    seen = set(sources)
    stack = list(sources)
    while stack:
        v = stack.pop()
        for u in adj[v]:
            if u not in seen:
                seen.add(u)
                stack.append(u)
    out = np.zeros(n, np.int32)
    out[sorted(seen)] = 1
    return out


# ---------------------------------------------------------------------------
# the two user-level programs: correctness + engine matrix + backend matrix
# ---------------------------------------------------------------------------

def test_widest_path_matches_reference():
    src, dst, w, n = make_graph_family("scale_free", 200, seed=7)
    part = build(src, dst, n, w, n_cells=4)
    vstate, _ = diffuse(part, widest_program(0))
    got = part.to_global_layout(vstate["width"])[:n]
    ref = _widest_ref(src, dst, w, n, 0)
    assert np.array_equal(_mask_inf(np.asarray(got)), _mask_inf(ref))


def test_reach_matches_reference():
    src, dst, w, n = make_graph_family("erdos_renyi", 150, seed=3)
    sources = (0, 17, 42)
    part = build(src, dst, n, w, n_cells=4)
    vstate, _ = diffuse(part, reach_program(sources))
    got = np.asarray(part.to_global_layout(vstate["reached"]))[:n]
    assert np.array_equal(got, _reach_ref(src, dst, n, sources))


@pytest.mark.parametrize("name,kwargs", [
    ("widest", dict(source=0)),
    ("reach", dict(sources=(0, 9))),
])
def test_new_programs_engine_matrix(name, kwargs):
    """Acceptance: both new programs run unmodified on all three engines
    (sharded / spmd / the generic event oracle) with matching fixed
    points — selection monoids are order-free, so exactly."""
    src, dst, w, n = make_graph_family("small_world", 100, seed=6)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=1)
    ref = sess.query(name, engine="sharded", **kwargs).values[:n]
    spmd = sess.query(name, engine="spmd", **kwargs).values[:n]
    ev = sess.query(name, engine="event", **kwargs).values[:n]
    assert np.array_equal(_mask_inf(spmd), _mask_inf(ref))
    assert np.array_equal(_mask_inf(ev), _mask_inf(ref))


@pytest.mark.parametrize("name,kwargs", [
    ("widest", dict(source=0, track_parents=True)),
    ("reach", dict(sources=(0, 9))),
])
def test_new_programs_backend_matrix_bitwise(name, kwargs):
    """Acceptance: backend='pallas' reproduces backend='xla' bitwise for
    the user-level programs — the extension point reaches the kernels."""
    src, dst, w, n = make_graph_family("scale_free", 150, seed=9)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    rx = sess.query(name, backend="xla", **kwargs)
    rp = sess.query(name, backend="pallas", **kwargs)
    assert np.array_equal(_mask_inf(rx.values), _mask_inf(rp.values))
    for k, v in rx.extra.items():
        if k == "live":
            continue
        a, b = np.asarray(v), np.asarray(rp.extra[k])
        assert np.array_equal(_mask_inf(a), _mask_inf(b)), (name, k)


def test_widest_repair_after_commit_matches_from_scratch():
    """User programs ride the session cache + commit() repair unchanged."""
    src, dst, w, n = make_graph_family("small_world", 120, seed=4)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                       edge_slack=0.4)
    sess.query("widest", source=0)
    rng = np.random.default_rng(0)
    for _ in range(3):
        sess.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                      float(5 + rng.random()))
    sess.delete_edge(int(src[0]), int(dst[0]))
    sess.commit()
    got = sess.query("widest", source=0).values
    vstate, _ = diffuse(sess.sg, widest_program(0))
    assert np.array_equal(_mask_inf(got),
                          _mask_inf(sess.to_global(vstate["width"])))


# ---------------------------------------------------------------------------
# @diffusive extension point: a program defined *here*, outside the engine
# ---------------------------------------------------------------------------

def test_user_registered_program_end_to_end():
    """A custom spec registered in a test runs by name through query,
    lanes, peek, and commit-time repair — no engine/kernel edits."""

    @diffusive("hops2set", value_key="hops", monotone=True,
               lane_param="target")
    def hops2set(target: int):
        """Min hops to reach ``target`` — BFS on the reversed message
        direction is not needed: diffuse *from* the target."""
        return DiffusiveProgram(
            monoid="min",
            msg_dtype=jnp.float32,
            state={"hops": Field(jnp.float32,
                                 init=lambda v: jnp.where(v.gid == target,
                                                          0.0, jnp.inf),
                                 on_dead=jnp.inf)},
            init_active=lambda v: v.gid == target,
            emit=lambda s, w, sg, dg: s["hops"] + 1.0,
            receive=lambda vs, inbox, has, pay, ok: (
                {"hops": jnp.where(has & (inbox < vs["hops"]) & ok, inbox,
                                   vs["hops"])},
                has & (inbox < vs["hops"]) & ok),
        )

    try:
        src, dst, w, n = make_graph_family("small_world", 90, seed=8)
        sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=3,
                                           edge_slack=0.4)
        r = sess.query("hops2set", target=0)
        ref = sess.query("bfs", source=0)
        assert np.array_equal(_mask_inf(r.values), _mask_inf(ref.values))
        # bound-query object path + lanes
        lanes = sess.query(hops2set(targets=[0, 5, 11]))
        assert len(lanes) == 3
        single = sess.query(hops2set(target=11))
        assert np.array_equal(_mask_inf(lanes[2].values),
                              _mask_inf(single.values))
        # peek + repair
        assert np.isfinite(np.asarray(sess.peek(0, hops2set(target=0)))).any()
        sess.add_edge(3, 0, 1.0)
        sess.commit()
        got = sess.query("hops2set", target=0).values
        ref2 = sess.query("bfs", source=0, refresh=True).values
        assert np.array_equal(_mask_inf(got), _mask_inf(ref2))
    finally:
        PROGRAMS.pop("hops2set", None)


def test_string_and_object_lookup_resolve_identically():
    """Satellite: peek()/query() accept names, handles, and bound queries
    through one registry path — same cache entry either way."""
    from repro.core.programs import sssp

    src, dst, w, n = make_graph_family("erdos_renyi", 80, seed=2)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2)
    r1 = sess.query(sssp(source=3))
    n_entries = len(sess._cache)
    r2 = sess.query("sssp", source=3)          # must hit the same entry
    assert len(sess._cache) == n_entries
    assert np.array_equal(_mask_inf(r1.values), _mask_inf(r2.values))
    pk1 = np.asarray(sess.peek(3, "sssp", source=3))
    pk2 = np.asarray(sess.peek(3, sssp(source=3)))
    both_nan = np.isnan(pk1) & np.isnan(pk2)
    assert np.array_equal(pk1[~both_nan], pk2[~both_nan])
    with pytest.raises(KeyError):
        sess.query("nope")


def test_cache_key_accepts_list_kwargs():
    """Satellite: list-valued kwargs (sources) hash deterministically."""
    src, dst, w, n = make_graph_family("erdos_renyi", 60, seed=1)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2)
    k1 = sess._key("reach", "sharded", {"sources": [4, 2]})
    k2 = sess._key("reach", "sharded", {"sources": np.array([4, 2])})
    assert k1 == k2 and isinstance(hash(k1), int)
    # and a real query with a list kwarg caches + round-trips
    r1 = sess.query("reach", sources=[4, 2])
    r2 = sess.query("reach", sources=(4, 2))
    assert np.array_equal(r1.values, r2.values)


# ---------------------------------------------------------------------------
# monoid laws — every registered Monoid
# ---------------------------------------------------------------------------

def _kind_op(kind):
    return {"min": np.minimum, "max": np.maximum, "sum": np.add}[kind]


@pytest.mark.parametrize("name", sorted(MONOIDS))
def test_monoid_laws(name):
    """Associativity, commutativity, identity, and scatter-class
    consistency for every registered monoid (hypothesis sweeps wider
    value ranges when available)."""
    m = MONOIDS[name]
    rng = np.random.default_rng(hash(name) % 2**32)

    def check(a, b, c):
        a, b, c = (jnp.asarray(x, jnp.float32) for x in (a, b, c))
        ab_c = m.elem(m.elem(a, b), c)
        a_bc = m.elem(a, m.elem(b, c))
        assert np.allclose(np.asarray(ab_c), np.asarray(a_bc),
                           rtol=1e-5, atol=1e-6), "associativity"
        assert np.array_equal(np.asarray(m.elem(a, b)),
                              np.asarray(m.elem(b, a))), "commutativity"
        ident = m.identity(jnp.float32)
        assert np.array_equal(np.asarray(m.elem(a, jnp.full_like(a, ident))),
                              np.asarray(a)), "identity"
        # kind consistency: op must agree with its scatter class
        assert np.allclose(np.asarray(m.elem(a, b)),
                           _kind_op(m.kind)(np.asarray(a), np.asarray(b)),
                           rtol=1e-6), "kind-consistency"

    for _ in range(25):
        check(*(rng.normal(size=8) * 10 for _ in range(3)))

    try:    # property sweep over adversarial floats when hypothesis exists
        from hypothesis import given, settings, strategies as st
    except ImportError:
        return

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(-1e6, 1e6, width=32), min_size=3, max_size=3))
    def prop(vals):
        check(np.float32(vals[0]), np.float32(vals[1]), np.float32(vals[2]))

    prop()


def test_custom_op_monoid_through_engine():
    """A registered custom-op monoid (logical-or over {0,1} ints, a
    max-class monoid with its own identity) must survive the scan path's
    identity padding — laned and solo, both backends (regression: padding
    once used the scatter-class identity, which a custom op is not
    guaranteed to absorb)."""
    import jax.numpy as jnp

    from repro.core.monoid import register_monoid

    or01 = register_monoid(Monoid("or01", "max", op=jnp.logical_or,
                                  identity_of=lambda dt: 0))

    @diffusive("reach_or", value_key="reached", monotone=True,
               lane_param="source")
    def reach_or(source: int):
        def receive(vs, inbox, has, pay, ok):
            inbox = inbox.astype(jnp.int32)
            better = has & (inbox > vs["reached"]) & ok
            return ({"reached": jnp.where(better, inbox, vs["reached"])},
                    better)

        return DiffusiveProgram(
            monoid=or01, msg_dtype=jnp.int32,
            state={"reached": Field(jnp.int32,
                                    init=lambda v: (v.gid == source)
                                    .astype(jnp.int32), on_dead=0)},
            init_active=lambda v: v.gid == source,
            emit=lambda s, w, sg, dg: s["reached"],
            receive=receive)

    try:
        src, dst, w, n = make_graph_family("erdos_renyi", 100, seed=5)
        sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2)
        ref = _reach_ref(src, dst, n, (0,))
        for backend in ("xla", "pallas"):
            got = sess.query("reach_or", source=0, backend=backend,
                             refresh=True).values[:n]
            assert np.array_equal(got, ref), backend
        lanes = sess.query(reach_or(sources=[0, 7]))
        assert np.array_equal(lanes[0].values[:n], ref)
        assert np.array_equal(lanes[1].values[:n], _reach_ref(src, dst, n,
                                                              (7,)))
    finally:
        PROGRAMS.pop("reach_or", None)
        MONOIDS.pop("or01", None)


def test_monoid_registry_and_validation():
    with pytest.raises(ValueError):
        Monoid("bad", "prod")
    with pytest.raises(ValueError):
        Monoid("bad", "sum", payload="argbest")
    or_m = Monoid("or01", "max", op=jnp.logical_or,
                  identity_of=lambda dt: 0)
    a = jnp.asarray([0, 1, 0, 1], jnp.int32)
    b = jnp.asarray([0, 0, 1, 1], jnp.int32)
    assert np.array_equal(np.asarray(or_m.merge(a, b, b > -1)),
                          [0, 1, 1, 1])
