"""Durable diffusion sessions (DESIGN.md §2.13): write-ahead update
journal, snapshot/restore, chaos-harness kill/tear recovery, and the
convergence watchdog.

The central acceptance property: a session killed at *any* chaos point
and reopened with ``DiffusionSession.open`` is bitwise-equal to a
session that executed exactly the journaled prefix and never crashed —
graph arrays, name-server state, cache keys, and query results alike.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core import chaos
from repro.core.event import EVENT_ORACLE_MAX_N, event_diffuse
from repro.core.generators import make_graph_family
from repro.core.journal import JournalError, OpRecord, UpdateJournal
from repro.core.programs import cc_program
from repro.core.session import (
    ConvergenceError,
    ConvergenceWarning,
    DiffusionSession,
    ValidationError,
)
from repro.launch.serve import DurableSessionLoop

_SUBPROC_ENV = {
    "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
    "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
}


def _session(seed=5, family="small_world", n=120, n_cells=4):
    src, dst, w, n = make_graph_family(family, n, seed=seed)
    sess = DiffusionSession.from_edges(
        src, dst, n, w, n_cells=n_cells, edge_slack=0.5, node_slack=0.4)
    return sess, (src, dst, w, n)


def _sg_equal(a, b):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if va is None or vb is None:
            assert va is None and vb is None, f.name
            continue
        assert np.array_equal(np.asarray(va), np.asarray(vb),
                              equal_nan=True), f"graph field {f.name}"


def _ns_equal(a, b):
    sa, sb = a.state_dict(), b.state_dict()
    assert sorted(sa) == sorted(sb)
    for k in sa:
        assert np.array_equal(sa[k], sb[k]), f"ns field {k}"


def _results_equal(s1, s2, queries=(("sssp", {"source": 0}), ("cc", {}))):
    for name, kw in queries:
        a = np.asarray(s1.query(name, **kw).values)
        b = np.asarray(s2.query(name, **kw).values)
        assert np.array_equal(a, b, equal_nan=True), name


# ---------------------------------------------------------------------------
# journal frames
# ---------------------------------------------------------------------------


def _rec(seed=0, n=50):
    rng = np.random.default_rng(seed)
    return OpRecord.from_ops(
        vadds=[(n + i, i % 4, i) for i in range(3)],
        vdels=[int(rng.integers(0, n))],
        eadds=[(int(rng.integers(0, n)), int(rng.integers(0, n)),
                float(rng.uniform(0.1, 2.0))) for _ in range(5)],
        edels=[(int(rng.integers(0, n)), int(rng.integers(0, n)))],
        touch=[int(rng.integers(0, n))])


def test_journal_roundtrip(tmp_path):
    path = str(tmp_path / "j.bin")
    recs = [_rec(s) for s in range(4)]
    with UpdateJournal(path) as j:
        for i, r in enumerate(recs):
            assert j.append(r) == i
    j2 = UpdateJournal(path)
    got = list(j2.replay())
    assert [s for s, _ in got] == [0, 1, 2, 3]
    for (_, a), b in zip(got, recs):
        for f in OpRecord._fields:
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    # seq resumes, never reused
    assert j2.append(_rec(9)) == 4
    j2.close()


def test_journal_replay_from_seq(tmp_path):
    j = UpdateJournal(str(tmp_path / "j.bin"))
    for s in range(5):
        j.append(_rec(s))
    assert [s for s, _ in j.replay(from_seq=3)] == [3, 4]
    j.close()


def test_journal_torn_tail_truncates(tmp_path):
    path = str(tmp_path / "j.bin")
    j = UpdateJournal(path)
    for s in range(3):
        j.append(_rec(s))
    j.close()
    size = os.path.getsize(path)
    chaos.tear_file(path, size - 7)            # torn mid-final-frame
    j2 = UpdateJournal(path)
    assert [s for s, _ in j2.replay()] == [0, 1]
    assert j2.next_seq == 2
    # the truncation is physical: a re-scan finds a clean file
    assert os.path.getsize(path) < size
    j2.close()


def test_journal_corrupt_frame_truncates_from_there(tmp_path):
    path = str(tmp_path / "j.bin")
    j = UpdateJournal(path)
    for s in range(3):
        j.append(_rec(s))
    j.close()
    frame = os.path.getsize(path) // 3
    chaos.corrupt_file(path, offset=frame + 40)   # inside frame 1
    j2 = UpdateJournal(path)
    assert [s for s, _ in j2.replay()] == [0]     # 1 and 2 dropped
    j2.close()


def test_journal_rollback_last_record(tmp_path):
    j = UpdateJournal(str(tmp_path / "j.bin"))
    j.append(_rec(0))
    seq = j.append(_rec(1))
    j.rollback(seq)
    assert [s for s, _ in j.replay()] == [0]
    assert j.append(_rec(2)) == 1                 # seq 1 never hit disk
    with pytest.raises(JournalError):
        j.rollback(0)                             # only the last record
    j.close()


def test_journal_fsync_policy_validation(tmp_path):
    with pytest.raises(ValueError, match="fsync"):
        UpdateJournal(str(tmp_path / "j.bin"), fsync="sometimes")


def test_journal_truncate_gcs_head(tmp_path):
    j = UpdateJournal(str(tmp_path / "j.bin"))
    for s in range(5):
        j.append(_rec(s))
    j.truncate(3)
    assert [s for s, _ in j.replay()] == [3, 4]
    assert j.next_seq == 5
    j.close()


# ---------------------------------------------------------------------------
# snapshot / restore bitwise equality
# ---------------------------------------------------------------------------


def _mutate(sess, n, seed=0):
    """One deterministic batch of all op kinds, committed."""
    rng = np.random.default_rng(seed)
    g = sess.add_vertex()
    sess.add_edge(int(rng.integers(0, n)), g, 0.25)
    sess.add_edge(g, int(rng.integers(0, n)), 0.5)
    src, dst, _ = sess.edge_list()
    sess.delete_edge(int(src[0]), int(dst[0]))
    sess.touch(int(rng.integers(0, n)))
    return sess.commit()


def test_save_open_bitwise(tmp_path):
    sess, (_, _, _, n) = _session()
    sess.query("sssp", source=0)
    sess.query("cc")
    sess.query("ppr", source=3)
    sess.query("triangles")
    sess.save(str(tmp_path))
    _mutate(sess, n, 0)
    _mutate(sess, n, 1)

    recovered = DiffusionSession.open(str(tmp_path))
    _sg_equal(sess.sg, recovered.sg)
    _ns_equal(sess.ns, recovered.ns)
    assert set(map(repr, sess._cache)) == set(map(repr, recovered._cache))
    _results_equal(sess, recovered,
                   (("sssp", {"source": 0}), ("cc", {}),
                    ("ppr", {"source": 3})))
    assert (int(sess.query("triangles").values)
            == int(recovered.query("triangles").values))
    # settings travel with the snapshot
    assert recovered.engine == sess.engine
    assert recovered.on_budget == sess.on_budget
    assert recovered.max_rounds == sess.max_rounds


def test_open_with_empty_journal_tail(tmp_path):
    sess, _ = _session(seed=7)
    sess.query("sssp", source=0)
    sess.save(str(tmp_path))
    recovered = DiffusionSession.open(str(tmp_path))
    _sg_equal(sess.sg, recovered.sg)
    _results_equal(sess, recovered)


def test_save_requires_directory_once(tmp_path):
    sess, _ = _session()
    with pytest.raises(ValueError, match="directory"):
        sess.save()
    sess.save(str(tmp_path))
    sess.save()                                   # remembered
    with pytest.raises(ValueError, match="re-home"):
        sess.save(str(tmp_path / "elsewhere"))


def test_save_warns_on_pending_ops(tmp_path):
    sess, _ = _session()
    sess.add_edge(0, 1, 0.5)
    with pytest.warns(UserWarning, match="uncommitted"):
        sess.save(str(tmp_path))


def test_corrupt_snapshot_leaf_falls_back(tmp_path):
    sess, (_, _, _, n) = _session(seed=9)
    sess.query("sssp", source=0)
    sess.save(str(tmp_path))                      # step 0
    _mutate(sess, n, 0)
    step1 = sess.save(str(tmp_path))              # step 1
    _mutate(sess, n, 1)
    # damage the newest snapshot: digest catches it, open falls back to
    # step 0 and replays the *full* journal (truncate kept every record
    # the oldest retained snapshot needs)
    leaf = os.path.join(str(tmp_path), f"step_{step1}",
                        "graph__weight.npy")
    chaos.corrupt_file(leaf, offset=200)
    with pytest.warns(UserWarning, match="damaged"):
        recovered = DiffusionSession.open(str(tmp_path))
    _sg_equal(sess.sg, recovered.sg)
    _ns_equal(sess.ns, recovered.ns)
    _results_equal(sess, recovered)


# ---------------------------------------------------------------------------
# kill-and-recover at every chaos coordinate
# ---------------------------------------------------------------------------


def _ops_script(n):
    """The workload as a list of per-commit closures (for prefix replay)."""
    return [
        lambda s: (s.add_edge(1, 2, 0.1), s.commit()),
        lambda s: (s.add_vertex(), s.add_edge(0, n, 0.3), s.commit()),
        lambda s: (s.delete_edge(1, 2), s.touch(3), s.commit()),
        lambda s: (s.add_edge(4, 5, 0.7), s.commit()),
    ]


def _reference_prefix(k, n_commits_script, seed):
    """A never-crashed session that ran exactly k committed batches."""
    sess, (_, _, _, n) = _session(seed=seed)
    sess.query("sssp", source=0)
    for op in _ops_script(n)[:k]:
        op(sess)
    return sess


def test_kill_and_recover_every_coordinate(tmp_path):
    seed = 11
    sess, (_, _, _, n) = _session(seed=seed)
    ops = _ops_script(n)

    def workload(s):
        for i, op in enumerate(ops):
            op(s)
            if i == 1:
                s.save()        # exercises the checkpoint chaos points

    # dry run: enumerate every (point, hit) coordinate this workload hits
    d0 = str(tmp_path / "dry")
    sess.query("sssp", source=0)
    sess.save(d0)
    mon = chaos.ChaosMonkey(record_only=True)
    with chaos.harness(mon):
        workload(sess)
    coords = [(name, k) for name, hits in mon.counts.items()
              for k in range(hits)]
    assert {n_ for n_, _ in coords} >= {
        "journal.append", "commit.journal-appended", "commit.applied",
        "commit.repaired", "checkpoint.leaf-written",
        "checkpoint.pre-rename"}

    for idx, (name, k) in enumerate(coords):
        d = str(tmp_path / f"kill{idx}")
        s, _ = _session(seed=seed)
        s.query("sssp", source=0)
        s.save(d)
        # journal.append is the tear point (a torn frame write);
        # everything else is a kill point
        monkey = (chaos.ChaosMonkey(tear_at=(name, k, 9))
                  if name == "journal.append"
                  else chaos.ChaosMonkey(kill_at=(name, k)))
        with pytest.raises(chaos.ChaosKill):
            with chaos.harness(monkey):
                workload(s)
        assert monkey.fired == (name, k)

        recovered = DiffusionSession.open(d)
        durable = len(recovered._journal)       # commits that survived
        ref = _reference_prefix(durable, len(ops), seed)
        _sg_equal(ref.sg, recovered.sg)
        _ns_equal(ref.ns, recovered.ns)
        _results_equal(ref, recovered)


def test_kill_during_save_keeps_previous_snapshot(tmp_path):
    sess, (_, _, _, n) = _session(seed=13)
    sess.query("sssp", source=0)
    sess.save(str(tmp_path))
    _mutate(sess, n, 0)
    with pytest.raises(chaos.ChaosKill):
        with chaos.harness(chaos.ChaosMonkey(
                kill_at=("checkpoint.pre-rename", 0))):
            sess.save()
    # the atomic-rename protocol left the step-0 snapshot whole
    recovered = DiffusionSession.open(str(tmp_path))
    _sg_equal(sess.sg, recovered.sg)
    _results_equal(sess, recovered)


# ---------------------------------------------------------------------------
# convergence watchdog + validation
# ---------------------------------------------------------------------------


def test_converged_true_at_quiescence():
    sess, _ = _session()
    res = sess.query("sssp", source=0)
    assert bool(np.asarray(res.stats.converged))


def test_budget_exhaustion_warns_by_default():
    sess, _ = _session()
    sess.max_rounds = 1
    with pytest.warns(ConvergenceWarning, match="max_rounds"):
        res = sess.query("sssp", source=0)
    assert not bool(np.asarray(res.stats.converged))


def test_on_budget_raise():
    sess, _ = _session()
    sess.max_rounds = 1
    sess.on_budget = "raise"
    with pytest.raises(ConvergenceError, match="PARTIAL"):
        sess.query("sssp", source=0)


def test_on_budget_partial_is_silent():
    sess, _ = _session()
    sess.max_rounds = 1
    sess.on_budget = "partial"
    with warnings.catch_warnings():
        warnings.simplefilter("error", ConvergenceWarning)
        res = sess.query("sssp", source=0)
    assert not bool(np.asarray(res.stats.converged))


def test_on_budget_validated_at_init():
    part = _session()[0].part
    with pytest.raises(ValueError, match="on_budget"):
        DiffusionSession(part, on_budget="explode")


def test_commit_repair_honors_budget():
    sess, _ = _session()
    sess.query("sssp", source=0)
    sess.max_rounds = 1
    sess.on_budget = "raise"
    sess.add_edge(0, 1, 0.01)
    with pytest.raises(ConvergenceError, match="repair"):
        sess.commit()


def test_validate_catches_nan_poison():
    sess, _ = _session()
    sess.query("sssp", source=0)
    assert chaos.poison_vstate(sess)
    with pytest.raises(ValidationError, match="NaN"):
        sess.query("sssp", source=0, validate=True)
    # opt-out still serves the poisoned entry
    sess.query("sssp", source=0, validate=False)


def test_validate_catches_out_of_domain():
    sess, _ = _session()
    sess.query("sssp", source=0)
    assert chaos.poison_vstate(sess, value=-5.0)   # dist domain is [0, inf)
    with pytest.raises(ValidationError, match="below"):
        sess.query("sssp", source=0, validate=True)


def test_validate_session_default_and_clean_pass():
    sess, _ = _session()
    sess.validate = True
    sess.query("sssp", source=0)                   # clean state passes
    sess.query("cc")
    chaos.poison_vstate(sess)
    with pytest.raises(ValidationError):
        sess.query("sssp", source=0)


# ---------------------------------------------------------------------------
# event-oracle scope cap (satellite)
# ---------------------------------------------------------------------------


def test_event_oracle_caps_n():
    prog = cc_program()
    src = np.array([0, 1], np.int32)
    dst = np.array([1, 2], np.int32)
    w = np.ones(2, np.float32)
    with pytest.raises(ValueError, match=str(EVENT_ORACLE_MAX_N)):
        event_diffuse(prog, src, dst, w, EVENT_ORACLE_MAX_N + 1)


# ---------------------------------------------------------------------------
# durable serve loop (PreemptionGuard checkpoint-and-exit)
# ---------------------------------------------------------------------------


def test_durable_serve_loop_preemption(tmp_path):
    from repro.runtime.fault_tolerance import PreemptionGuard

    sess, (_, _, _, n) = _session(seed=17)
    sess.query("sssp", source=0)
    loop = DurableSessionLoop(sess, str(tmp_path), snapshot_every=2)
    guard = PreemptionGuard()      # caller-owned: no signal installation

    def batches():
        for i in range(10):
            if i == 5:
                guard.trigger()    # preemption lands mid-stream
            yield lambda s, i=i: s.add_edge(i % n, (i * 7 + 1) % n, 0.5)

    steps = loop.run(batches(), guard=guard)
    assert steps == 6 and loop.preempted
    # the exit snapshot + journal recover the exact preempted state
    recovered = DiffusionSession.open(str(tmp_path))
    _sg_equal(sess.sg, recovered.sg)
    _results_equal(sess, recovered)


def test_durable_serve_loop_runs_to_completion(tmp_path):
    sess, (_, _, _, n) = _session(seed=19)
    loop = DurableSessionLoop(sess, str(tmp_path), snapshot_every=3)
    steps = loop.run([
        (lambda s, i=i: s.add_edge(i % n, (i + 3) % n, 1.0))
        for i in range(7)
    ])
    assert steps == 7 and not loop.preempted
    recovered = DiffusionSession.open(str(tmp_path))
    _sg_equal(sess.sg, recovered.sg)


# ---------------------------------------------------------------------------
# spmd engine recovery (subprocess: needs one device per cell)
# ---------------------------------------------------------------------------


def test_spmd_recovery_bitwise_subprocess(tmp_path):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        from repro.core.generators import make_graph_family
        from repro.core.session import DiffusionSession

        d = {str(tmp_path)!r}
        src, dst, w, n = make_graph_family("small_world", 120, seed=5)
        sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                           edge_slack=0.5, node_slack=0.4,
                                           engine="spmd")
        sess.query("sssp", source=0)
        sess.query("cc")
        sess.save(d)
        sess.add_edge(1, 2, 0.1); sess.commit()
        sess.delete_edge(1, 2); sess.touch(3); sess.commit()

        rec = DiffusionSession.open(d)
        assert rec.engine == "spmd"
        for name, kw in (("sssp", dict(source=0)), ("cc", {{}})):
            a = np.asarray(sess.query(name, **kw).values)
            b = np.asarray(rec.query(name, **kw).values)
            assert np.array_equal(a, b, equal_nan=True), name
        print("SPMD_RECOVERY_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=_SUBPROC_ENV, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), timeout=900,
    )
    assert "SPMD_RECOVERY_OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# property test: random interleavings (hypothesis ships via
# requirements-dev.txt in CI; skipped when absent locally)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(st.lists(st.sampled_from(["eadd", "edel", "vadd", "touch",
                                     "commit", "save", "query"]),
                    min_size=3, max_size=14),
           st.integers(0, 2 ** 31 - 1))
    def test_random_interleaving_recovers_to_prefix(
            script, seed, tmp_path_factory):
        """Any interleaving of mutations/commits/saves/queries, killed
        at a seed-picked chaos coordinate, reopens to the same state as
        a session that ran exactly the durable (journaled) prefix."""
        tmp = tmp_path_factory.mktemp("prop")
        rng = np.random.default_rng(seed)

        def run_script(sess, n, upto=None):
            r = np.random.default_rng(seed)   # op randomness is shared
            commits = 0
            for op in script:
                if upto is not None and commits >= upto:
                    break                     # reference ran the prefix
                if op == "eadd":
                    sess.add_edge(int(r.integers(0, n)),
                                  int(r.integers(0, n)),
                                  float(r.uniform(0.1, 2.0)))
                elif op == "edel":
                    s_, d_, _ = sess.edge_list()
                    if len(s_):
                        i = int(r.integers(0, len(s_)))
                        sess.delete_edge(int(s_[i]), int(d_[i]))
                elif op == "vadd":
                    g = sess.add_vertex()
                    sess.add_edge(int(r.integers(0, n)), g, 1.0)
                elif op == "touch":
                    sess.touch(int(r.integers(0, n)))
                elif op == "commit":
                    sess.commit()
                    commits += 1
                elif op == "save":
                    # snapshots of a session with staged-but-uncommitted
                    # ops are legal but warn (pending ops are not
                    # durable); the property keeps saves at commit
                    # boundaries so the prefix is exactly the journal
                    if sess._pending is None or len(sess._pending) == 0:
                        if sess._dur_dir is not None:
                            sess.save()
                elif op == "query":
                    sess.query("sssp", source=0)
            return commits

        # dry run to enumerate this script's chaos coordinates
        s0, (_, _, _, n) = _session(seed=23)
        s0.query("sssp", source=0)
        s0.save(str(tmp / "dry"))
        mon = chaos.ChaosMonkey(record_only=True)
        with chaos.harness(mon):
            run_script(s0, n)
        coords = [(nm, k) for nm, hits in mon.counts.items()
                  for k in range(hits) if nm != "journal.append"]
        if not coords:
            return                            # script commits nothing
        name, k = coords[int(rng.integers(0, len(coords)))]

        s1, _ = _session(seed=23)
        s1.query("sssp", source=0)
        s1.save(str(tmp / "live"))
        try:
            with chaos.harness(chaos.ChaosMonkey(kill_at=(name, k))):
                run_script(s1, n)
        except chaos.ChaosKill:
            pass
        else:
            return                            # coordinate never reached

        recovered = DiffusionSession.open(str(tmp / "live"))
        durable = len(recovered._journal)
        ref, _ = _session(seed=23)
        ref.query("sssp", source=0)
        run_script(ref, n, upto=durable)
        _sg_equal(ref.sg, recovered.sg)
        _ns_equal(ref.ns, recovered.ns)
        _results_equal(ref, recovered)
