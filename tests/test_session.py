"""DiffusionSession: one message-driven API for static queries, batched
mutation, and incremental recomputation (DESIGN.md §2.4-2.5)."""

import numpy as np
import pytest

from repro.core import (
    DiffusionSession,
    NameServer,
    UpdateBatch,
    build,
)
from repro.core.diffuse import diffuse
from repro.core.dynamic import edge_add, edge_delete
from repro.core.event import build_adjacency, event_sssp
from repro.core.generators import make_graph_family
from repro.core.programs import cc_program, ppr_program, sssp_program


def _mask_inf(a):
    return np.where(np.isinf(a), 1e30, a)


def _random_updates(src, dst, n, rng, n_del=5, n_ins=5):
    edges = {(int(a), int(b)): float(x)
             for a, b, x in zip(src, dst, np.ones_like(src))}
    live = list(edges)
    dels = [live[i] for i in rng.choice(len(live), n_del, replace=False)]
    ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
            float(1 + 3 * rng.random())) for _ in range(n_ins)]
    return dels, ins


def _session(seed=5, family="small_world", n=150, n_cells=4):
    src, dst, w, n = make_graph_family(family, n, seed=seed)
    sess = DiffusionSession.from_edges(
        src, dst, n, w, n_cells=n_cells, edge_slack=0.4, node_slack=0.1
    )
    return sess, (src, dst, w, n)


# ---------------------------------------------------------------------------
# batched mutation == sequential primitives
# ---------------------------------------------------------------------------

def test_update_batch_apply_equals_sequential_loop():
    src, dst, w, n = make_graph_family("erdos_renyi", 100, seed=3)
    rng = np.random.default_rng(7)
    live = sorted({(int(a), int(b)) for a, b in zip(src, dst)})
    dels = [live[i] for i in rng.choice(len(live), 6, replace=False)]
    ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
            float(rng.random() * 4 + 1)) for _ in range(6)]

    part_seq = build(src, dst, n, w, n_cells=4, edge_slack=0.4,
                     node_slack=0.2)
    ns_seq = NameServer(part_seq)
    sg_seq = part_seq.sg
    for u, v in dels:
        sg_seq = edge_delete(sg_seq, ns_seq, u, v)
    for u, v, x in ins:
        sg_seq = edge_add(sg_seq, ns_seq, u, v, x)

    part_bat = build(src, dst, n, w, n_cells=4, edge_slack=0.4,
                     node_slack=0.2)
    batch = UpdateBatch(NameServer(part_bat))
    for u, v in dels:
        batch.delete_edge(u, v)
    for u, v, x in ins:
        batch.add_edge(u, v, x)
    sg_bat, applied = batch.apply(part_bat.sg)
    assert applied.n_ops == 12 and applied.has_deletes

    live_mask = np.asarray(sg_seq.edge_ok)
    assert np.array_equal(np.asarray(sg_bat.edge_ok), live_mask)
    for f in ("src_local", "dst_shard", "dst_local", "dst_gid", "weight"):
        a = np.asarray(getattr(sg_seq, f))[live_mask]
        b = np.asarray(getattr(sg_bat, f))[live_mask]
        assert np.array_equal(a, b), f
    for f in ("node_ok", "gid", "out_degree"):
        assert np.array_equal(np.asarray(getattr(sg_seq, f)),
                              np.asarray(getattr(sg_bat, f))), f


def test_update_batch_parallel_edge_multiplicity():
    sess, (src, dst, w, n) = _session(seed=9, n=80)
    u, v = 3, 11
    sess.add_edge(u, v, 2.0)
    sess.add_edge(u, v, 3.0)       # parallel duplicate
    sess.commit()
    sess.delete_edge(u, v)
    sess.delete_edge(u, v)         # one occurrence per parallel edge
    sess.commit()
    su, lu = sess.ns.resolve(u)
    sg = sess.sg
    m = ((np.asarray(sg.src_local[su]) == lu)
         & (np.asarray(sg.dst_gid[su]) == v)
         & np.asarray(sg.edge_ok[su]))
    assert m.sum() == 0


# ---------------------------------------------------------------------------
# commit() incremental repair == from-scratch recompute
# ---------------------------------------------------------------------------

def test_commit_round_trip_matches_from_scratch_bitwise():
    """Acceptance: build -> batched inserts+deletes -> commit() bit-equals
    a from-scratch diffuse for SSSP, CC, and PPR on a 4-cell graph."""
    sess, (src, dst, w, n) = _session(seed=5)
    queries = [("sssp", dict(source=0)), ("cc", {}),
               ("ppr", dict(source=0, eps=1e-6))]
    for name, kw in queries:
        sess.query(name, **kw)

    rng = np.random.default_rng(2)
    dels, ins = _random_updates(src, dst, n, rng)
    for u, v in dels:
        sess.delete_edge(u, v)
    for u, v, x in ins:
        sess.add_edge(u, v, x)
    info = sess.commit()
    strategies = {k[0]: v[0] for k, v in info.repairs.items()}
    assert strategies == {"sssp": "parents", "cc": "component",
                          "ppr": "restart"}

    progs = {"sssp": (sssp_program(0), "dist"),
             "cc": (cc_program(), "comp"),
             "ppr": (ppr_program(0, eps=1e-6), "rank")}
    for name, kw in queries:
        got = sess.query(name, **kw).values
        prog, vk = progs[name]
        vstate, _ = diffuse(sess.sg, prog)
        ref = sess.to_global(vstate[vk])
        assert np.array_equal(_mask_inf(got), _mask_inf(ref)), name


def test_commit_delete_induced_subtree_invalidation():
    """Deleting SSSP tree edges must invalidate + rebuild the downstream
    subtree (checked against the event-driven oracle)."""
    sess, (src, dst, w, n) = _session(seed=11, family="scale_free", n=200)
    res = sess.query("sssp", source=0)
    parent = res.extra["parent"][:n]
    # pick real tree edges (parent[v] -> v) so subtrees are invalidated
    tree = [(int(parent[v]), v) for v in range(1, n)
            if parent[v] >= 0 and parent[v] != v]
    rng = np.random.default_rng(4)
    dels = [tree[i] for i in rng.choice(len(tree), 4, replace=False)]
    edges = {(int(a), int(b)): float(x) for a, b, x in zip(src, dst, w)}
    for u, v in dels:
        if (u, v) in edges:
            sess.delete_edge(u, v)
            edges.pop((u, v))
    sess.commit()
    got = sess.query("sssp", source=0).values[:n]
    s2 = np.array([e[0] for e in edges], np.int32)
    d2 = np.array([e[1] for e in edges], np.int32)
    w2 = np.array(list(edges.values()), np.float32)
    ref, _ = event_sssp(build_adjacency(s2, d2, w2, n), n, 0)
    assert np.allclose(_mask_inf(got), _mask_inf(np.array(ref)), atol=1e-4)


def test_commit_insert_only_takes_warm_frontier_path():
    sess, (src, dst, w, n) = _session(seed=6)
    sess.query("sssp", source=0)
    sess.query("cc")
    rng = np.random.default_rng(3)
    for _ in range(4):
        sess.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                      float(0.1 + rng.random()))
    info = sess.commit()
    strategies = {k[0]: v[0] for k, v in info.repairs.items()}
    assert strategies == {"sssp": "frontier", "cc": "frontier"}
    for name, kw, prog, vk in (
        ("sssp", dict(source=0), sssp_program(0), "dist"),
        ("cc", {}, cc_program(), "comp"),
    ):
        got = sess.query(name, **kw).values
        vstate, _ = diffuse(sess.sg, prog)
        ref = sess.to_global(vstate[vk])
        assert np.array_equal(_mask_inf(got), _mask_inf(ref)), name


def test_cc_split_component_is_relabelled():
    # a path graph 0-1-2-3 (+ an isolated 2-cycle); cutting 1-2 splits the
    # component and the right half must get a fresh min label
    src = np.array([0, 1, 1, 2, 2, 3, 4, 5], np.int32)
    dst = np.array([1, 0, 2, 1, 3, 2, 5, 4], np.int32)
    sess = DiffusionSession.from_edges(src, dst, 6, None, n_cells=2,
                                       edge_slack=0.5)
    assert len(set(sess.query("cc").values[:6])) == 2
    sess.delete_edge(1, 2)
    sess.delete_edge(2, 1)
    sess.commit()
    comp = sess.query("cc").values[:6]
    assert len({comp[0], comp[2], comp[4]}) == 3
    assert comp[0] == comp[1] and comp[2] == comp[3] and comp[4] == comp[5]


def test_phantom_delete_does_not_race_real_delete_in_same_batch():
    """A non-matching delete must not scatter into the slot a real delete
    in the same batch is clearing (duplicate scatter indices with
    conflicting values are unordered in XLA)."""
    src = np.array([0, 1], np.int32)
    dst = np.array([1, 2], np.int32)
    sess = DiffusionSession.from_edges(src, dst, 3, None, n_cells=1)
    sess.delete_edge(0, 1)      # lives in slot 0 of the single cell
    sess.delete_edge(2, 0)      # phantom: would also resolve to slot 0
    info = sess.commit()
    assert info.applied.edge_deletes == ((0, 1),)
    eok = np.asarray(sess.sg.edge_ok)[0]
    dstg = np.asarray(sess.sg.dst_gid)[0]
    assert not ((dstg == 1) & eok).any()       # (0, 1) really deleted
    assert ((dstg == 2) & eok).sum() == 1      # (1, 2) untouched


def test_failed_apply_leaves_graph_and_nameserver_consistent():
    """Edge-capacity overflow aborts the whole batch: the graph is
    unchanged and the name server has not released the to-be-deleted
    vertex's slot (retry-safe)."""
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    sess = DiffusionSession.from_edges(src, dst, 3, None, n_cells=1,
                                       edge_slack=0.4, node_slack=0.5)
    victim = 2
    sess.delete_vertex(victim)
    # overflow the edge slots: the block-ladder capacity gives even this
    # tiny graph a full block, so derive the count from the layout (the
    # vertex delete frees at most ep-1 slots, so ep adds always overflow)
    for _ in range(int(sess.sg.edge_ok.shape[1])):
        sess.add_edge(0, 1, 1.0)
    with pytest.raises(RuntimeError):
        sess.commit()
    # graph untouched: victim still live, its slot still holds its gid
    s_, l_ = sess.ns.resolve(victim)
    assert bool(np.asarray(sess.sg.node_ok)[s_, l_])
    assert int(np.asarray(sess.sg.gid)[s_, l_]) == victim
    # name server did not free the slot: a new vertex must not collide
    g = sess.ns.allocate(s_)[0]
    assert sess.ns.resolve(g)[1] != l_


def test_phantom_delete_is_a_noop():
    """Deleting a nonexistent edge — including (source, source), which
    collides with the SSSP self-parent sentinel — must not perturb any
    cached fixed point."""
    sess, (src, dst, w, n) = _session(seed=14, family="erdos_renyi", n=80)
    before = sess.query("sssp", source=0).values.copy()
    comp_before = sess.query("cc").values.copy()
    sess.delete_edge(0, 0)
    sess.delete_edge(7, 7)
    info = sess.commit()
    assert not info.applied.edge_deletes       # nothing actually removed
    after = sess.query("sssp", source=0).values
    assert np.array_equal(_mask_inf(before), _mask_inf(after))
    assert np.array_equal(comp_before, sess.query("cc").values)


def test_vertex_add_delete_through_session():
    sess, (src, dst, w, n) = _session(seed=10, family="erdos_renyi", n=120)
    sess.query("sssp", source=0)
    gid = sess.add_vertex()
    sess.add_edge(0, gid, 2.5)
    sess.commit()
    got = sess.query("sssp", source=0).values
    assert np.isclose(got[gid], 2.5)
    pk = np.asarray(sess.peek(0))
    assert np.isfinite(pk).sum() > 0
    sess.delete_vertex(gid)
    sess.commit()
    res = sess.query("sssp", source=0)
    assert np.isinf(res.values[gid])
    # dead / free-capacity ids are flagged: live covers exactly the real
    # vertices (the new vertex was deleted again)
    live = res.extra["live"]
    n = len(sess.part.owner) and sess.part.n_real
    assert live[:n].all() and not live[gid]


# ---------------------------------------------------------------------------
# uniform engine + backend selection
# ---------------------------------------------------------------------------

PROGRAM_MATRIX = [("sssp", dict(source=0)), ("bfs", dict(source=0)),
                  ("cc", {}), ("ppr", dict(source=0)), ("pagerank", {})]


def test_backend_matrix_pallas_matches_xla_bitwise():
    """Acceptance: backend='pallas' (interpret mode on CPU) reproduces the
    backend='xla' fixed point bitwise for every registered diffusion
    program — values and every extra state field (incl. SSSP parents)."""
    sess, _ = _session(seed=8, family="small_world", n=120)
    for name, kw in PROGRAM_MATRIX:
        rx = sess.query(name, backend="xla", **kw)
        rp = sess.query(name, backend="pallas", **kw)
        assert np.array_equal(_mask_inf(rx.values), _mask_inf(rp.values)), name
        for k, v in rx.extra.items():
            if k == "live":
                continue
            a, b = np.asarray(v), np.asarray(rp.extra[k])
            assert np.array_equal(_mask_inf(a), _mask_inf(b)), (name, k)


def test_backend_matrix_spmd_engine():
    """The SPMD engine dispatches through the same relax backends."""
    src, dst, w, n = make_graph_family("erdos_renyi", 100, seed=4)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=1)
    rx = sess.query("sssp", engine="spmd", backend="xla", source=0)
    rp = sess.query("sssp", engine="spmd", backend="pallas", source=0)
    assert np.array_equal(_mask_inf(rx.values), _mask_inf(rp.values))


def test_backend_survives_commit_repair():
    """A pallas-backed cached query is repaired on the pallas path and
    still reproduces the from-scratch fixed point bitwise."""
    sess, (src, dst, w, n) = _session(seed=21, n=100)
    sess.query("sssp", backend="pallas", source=0)
    rng = np.random.default_rng(6)
    dels, ins = _random_updates(src, dst, n, rng, n_del=3, n_ins=3)
    for u, v in dels:
        sess.delete_edge(u, v)
    for u, v, x in ins:
        sess.add_edge(u, v, x)
    sess.commit()
    got = sess.query("sssp", backend="pallas", source=0).values
    vstate, _ = diffuse(sess.sg, sssp_program(0), backend="pallas")
    ref = sess.to_global(vstate["dist"])
    assert np.array_equal(_mask_inf(got), _mask_inf(ref))


def test_delta_gate_threads_through_resume_and_repair():
    """Satellite: diffuse_from honours the delta-stepping gate (fewer
    actions, same fixed point), and a delta-gated query's commit() repair
    still matches the from-scratch result."""
    from repro.core.diffuse import diffuse_from

    src, dst, w, n = make_graph_family("scale_free", 300, seed=15)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                       edge_slack=0.4)
    prog = sssp_program(0)
    vs0, active0 = prog.init(sess.sg)
    _, st_ungated = diffuse_from(sess.sg, prog, vs0, active0)
    vs_g, st_gated = diffuse_from(sess.sg, prog, vs0, active0, delta=2.0)
    ref, _ = diffuse(sess.sg, prog)
    assert np.array_equal(_mask_inf(np.asarray(vs_g["dist"])),
                          _mask_inf(np.asarray(ref["dist"])))
    assert int(st_gated.actions) < int(st_ungated.actions)

    sess.query("sssp", source=0, delta=2.0)
    rng = np.random.default_rng(7)
    for _ in range(4):
        sess.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                      float(0.5 + rng.random()))
    sess.commit()
    got = sess.query("sssp", source=0, delta=2.0).values
    vstate, _ = diffuse(sess.sg, prog)
    assert np.array_equal(_mask_inf(got),
                          _mask_inf(sess.to_global(vstate["dist"])))


def test_engine_matrix_same_fixed_point():
    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=9)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=1)
    ref = sess.query("sssp", engine="sharded", source=3).values[:n]
    ev = sess.query("sssp", engine="event", source=3).values[:n]
    spmd = sess.query("sssp", engine="spmd", source=3).values[:n]
    assert np.allclose(_mask_inf(ev), _mask_inf(ref), atol=1e-4)
    assert np.array_equal(_mask_inf(spmd), _mask_inf(ref))


def test_query_registry_and_errors():
    sess, _ = _session(seed=12, n=80)
    with pytest.raises(KeyError):
        sess.query("no-such-program")
    with pytest.raises(ValueError):
        sess.query("sssp", engine="warp", source=0)
    tri = sess.query("triangles")
    assert tri.extra["triangles"] >= 0
    # raw VertexProgram goes through the same door
    res = sess.query(sssp_program(0), value_key="dist")
    assert np.isfinite(res.values).any()
    with pytest.raises(ValueError):
        sess.peek(0, sssp_program(0))   # peek needs a registered program


def test_cc_runs_on_generic_event_oracle():
    """Programs without a handwritten event_fn fall back to the generic
    message-at-a-time oracle — every @diffusive program runs on all three
    engines."""
    sess, (src, dst, w, n) = _session(seed=12, n=80)
    ref = sess.query("cc").values[:n]
    ev = sess.query("cc", engine="event").values[:n]
    assert np.array_equal(ref, ev)


def test_batched_update_speedup_over_sequential_loop():
    """Acceptance: batched apply of 256 edge updates is >=5x faster than
    the per-edge primitive loop on CPU (measured ~9x uncontended; the
    ratio is contention-robust since both sides share the machine)."""
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.bench_actions import bench_updates

    u = bench_updates(n_updates=256, repeats=3)
    assert u["speedup"] >= 5.0, u


def test_query_cache_serves_repaired_state_without_recompute():
    sess, (src, dst, w, n) = _session(seed=13, n=100)
    r1 = sess.query("sssp", source=0)
    r2 = sess.query("sssp", source=0)      # cache hit: identical object state
    assert np.array_equal(_mask_inf(r1.values), _mask_inf(r2.values))
    sess.add_edge(0, 50, 0.01)
    sess.commit()
    r3 = sess.query("sssp", source=0)      # served from repaired cache
    assert r3.values[50] <= 0.01 + 1e-6


def test_triangles_cached_and_recounted_on_commit():
    """Satellite (PR 4): query('triangles') goes through the standard
    _Entry cache — repeat queries are cache hits, and commit() repairs
    the entry with a restart-style recount against the new topology."""
    from repro.core.session import PROGRAMS

    sess, (src, dst, w, n) = _session(seed=17, n=60)
    calls = []
    spec = PROGRAMS["triangles"]
    orig = spec.run_fn

    def counting(s, **kw):
        calls.append(1)
        return orig(s, **kw)

    PROGRAMS["triangles"] = spec._replace(run_fn=counting)
    try:
        t1 = sess.query("triangles")
        t2 = sess.query("triangles")           # cache hit
        assert len(calls) == 1
        assert t1.extra["triangles"] == t2.extra["triangles"]

        # a fresh triangle between previously unconnected vertices (both
        # directions: the bitset counter expects symmetrized edges)
        existing = {(int(a), int(b)) for a, b in zip(src, dst)}
        tri = None
        for a in range(n):
            for b in range(a + 1, n):
                for c in range(b + 1, n):
                    pairs = [(a, b), (b, c), (a, c)]
                    if all(p not in existing and p[::-1] not in existing
                           for p in pairs):
                        tri = pairs
                        break
                if tri:
                    break
            if tri:
                break
        assert tri is not None
        for u, v in tri:
            sess.add_edge(u, v, 1.0)
            sess.add_edge(v, u, 1.0)
        info = sess.commit()
        tags = [v[0] for k, v in info.repairs.items()
                if k[0] == "triangles"]
        assert tags == ["recount"]
        assert len(calls) == 2                  # recount ran at commit
        t3 = sess.query("triangles")
        assert len(calls) == 2                  # ...and query() is a hit
        # a Result-cached entry has no vertex state: peek/vertex_state
        # must refuse instead of crashing on vstate=None
        with pytest.raises(ValueError):
            sess.peek(0, "triangles")
        with pytest.raises(ValueError):
            sess.vertex_state("triangles")
        # the recount matches the exact host oracle on the *new* topology
        # (>= one new triangle; the fresh edges may close more wedges)
        from repro.core.triangles import triangle_count_exact

        es, ed, _ = sess.edge_list()
        assert t3.extra["triangles"] == triangle_count_exact(
            es, ed, sess.n_ids)
        assert t3.extra["triangles"] > t1.extra["triangles"]
    finally:
        PROGRAMS["triangles"] = spec


def test_session_cache_is_lru_bounded():
    """Satellite (PR 5): ``max_cache_entries`` bounds the query cache
    with LRU eviction — long-running streaming sessions (many sources x
    sweeps x backends) must not grow state without limit.  A cache hit
    refreshes recency; an evicted entry recomputes on its next query and
    is no longer repaired by commit()."""
    src, dst, w, n = make_graph_family("small_world", 100, seed=3)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.3,
                                       max_cache_entries=2)
    sess.query("sssp", source=0)
    sess.query("sssp", source=1)
    sess.query("sssp", source=0)          # hit: source=0 becomes recent
    sess.query("sssp", source=2)          # evicts source=1 (LRU)
    assert len(sess._cache) == 2
    cached_sources = {dict(k[2]).get("source") for k in sess._cache}
    assert cached_sources == {0, 2}

    # evicted entries are simply not repaired; surviving ones are
    sess.add_edge(0, 5, 0.1)
    info = sess.commit()
    repaired = {dict(k[2]).get("source") for k in info.repairs}
    assert repaired == {0, 2}

    # unbounded default unchanged
    free = DiffusionSession.from_edges(src, dst, n, w, n_cells=2)
    for s in range(5):
        free.query("sssp", source=s)
    assert len(free._cache) == 5
    with pytest.raises(ValueError):
        DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                    max_cache_entries=0)


def test_sum_programs_compact_once_and_persist():
    """Sum-combine queries on a dirty graph compact the streams once and
    the session persists the clean graph (the sort is paid per dirty
    epoch, not per query) — while min/max queries consume the dirty
    views directly."""
    src, dst, w, n = make_graph_family("erdos_renyi", 90, seed=6)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.5)
    sess.add_edge(0, 7, 2.0)
    sess.add_edge(7, 3, 1.0)
    sess.commit()
    assert int(np.asarray(sess.sg.delta_count).sum()) == 2
    sess.query("sssp", source=0)          # min: stays dirty
    assert int(np.asarray(sess.sg.delta_count).sum()) == 2
    r1 = sess.query("ppr", source=0, eps=1e-5)   # sum: compacts + persists
    assert int(np.asarray(sess.sg.delta_count).sum()) == 0
    ref, _ = diffuse(sess.sg.with_csr(), ppr_program(0, eps=1e-5))
    assert np.array_equal(r1.values, sess.to_global(ref["rank"]))
