"""End-to-end behaviour of the paper's system: diffusive computation."""

import numpy as np
import pytest

from repro.core import (
    bfs,
    build,
    connected_components,
    personalized_pagerank,
    sssp,
)
from repro.core.diffuse import _sg_as_dict, diffuse, make_spmd_diffuse
from repro.core.event import build_adjacency, event_sssp
from repro.core.generators import GENERATORS, make_graph_family
from repro.core.programs import sssp_program
from repro.core.dynamic import (
    NameServer,
    edge_add,
    incremental_sssp,
    peek,
    vertex_add,
    vertex_delete,
)

FAMILIES = list(GENERATORS)


def _dist_close(a, b, atol=1e-4):
    a = np.where(np.isinf(a), 1e30, a)
    b = np.where(np.isinf(b), 1e30, b)
    return np.allclose(a, b, atol=atol)


@pytest.mark.parametrize("family", FAMILIES)
def test_sssp_matches_event_oracle_all_families(family):
    src, dst, w, n = make_graph_family(family, 150, seed=2)
    dist_ev, ev = event_sssp(build_adjacency(src, dst, w, n), n, 0)
    part = build(src, dst, n, w, n_cells=4)
    res = sssp(part, 0)
    assert _dist_close(res.values, np.array(dist_ev))
    assert ev.ds_terminated and not ev.ds_was_premature


@pytest.mark.parametrize("strategy", ["block", "hash", "locality"])
def test_partition_strategies_same_fixed_point(strategy):
    src, dst, w, n = make_graph_family("scale_free", 200, seed=1)
    ref = sssp(build(src, dst, n, w, n_cells=1), 0).values
    got = sssp(build(src, dst, n, w, n_cells=8, strategy=strategy), 0).values
    assert _dist_close(got, ref)


def test_parent_tree_is_consistent():
    src, dst, w, n = make_graph_family("erdos_renyi", 150, seed=3)
    part = build(src, dst, n, w, n_cells=4)
    res = sssp(part, 0)
    dist, parent = res.values, res.extra["parent"]
    wmap = {}
    for s, d, x in zip(src, dst, w):
        key = (int(s), int(d))
        wmap[key] = min(wmap.get(key, np.inf), float(x))
    for v in range(n):
        if np.isfinite(dist[v]) and v != 0:
            p = int(parent[v])
            assert p >= 0
            assert np.isclose(dist[v], dist[p] + wmap[(p, v)], atol=1e-4)


def test_async_beats_or_matches_bsp_rounds():
    src, dst, w, n = make_graph_family("small_world", 300, seed=4)
    part = build(src, dst, n, w, n_cells=8)
    r_async = sssp(part, 0, max_local_iters=64)
    r_bsp = sssp(part, 0, max_local_iters=1)
    assert int(r_async.stats.rounds) <= int(r_bsp.stats.rounds)
    assert _dist_close(r_async.values, r_bsp.values)


def test_operons_sent_equals_delivered():
    src, dst, w, n = make_graph_family("graph500", 256, seed=5)
    res = sssp(build(src, dst, n, w, n_cells=4), 0)
    assert int(res.stats.operons_sent) == int(res.stats.operons_delivered)


def test_actions_normalized_at_least_one_edge_visit():
    src, dst, w, n = make_graph_family("erdos_renyi", 100, seed=6)
    res = sssp(build(src, dst, n, w, n_cells=2), 0)
    n_reachable_edges = sum(
        1 for s in src if np.isfinite(res.values[int(s)])
    )
    assert int(res.stats.actions) >= n_reachable_edges > 0


def test_bfs_and_cc_and_ppr():
    src, dst, w, n = make_graph_family("powerlaw_cluster", 150, seed=7)
    part = build(src, dst, n, w, n_cells=4)
    lv = bfs(part, 0).values
    dist_ev, _ = event_sssp(
        build_adjacency(src, dst, np.ones_like(w), n), n, 0
    )
    assert _dist_close(lv, np.array(dist_ev))
    cc = connected_components(part).values
    reach = np.isfinite(lv)
    assert len(set(cc[reach])) == 1
    ppr = personalized_pagerank(part, 0, eps=1e-6)
    assert 0.9 < ppr.values.sum() <= 1.0 + 1e-3


def test_ds_termination_fires_exactly_at_quiescence():
    src, dst, w, n = make_graph_family("small_world", 100, seed=8)
    for schedule in ("lifo", "fifo"):
        _, st = event_sssp(build_adjacency(src, dst, w, n), n, 0, schedule)
        assert st.ds_terminated
        assert not st.ds_was_premature
        assert st.acks == st.actions   # one ack per diffusion message


def test_spmd_engine_matches_logical_engine():
    import jax

    from repro.launch.mesh import mesh_context

    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=9)
    part = build(src, dst, n, w, n_cells=1)
    mesh = jax.make_mesh((1,), ("cells",))
    fn = make_spmd_diffuse(mesh, sssp_program(3), part.sg, axis_name="cells")
    with mesh_context(mesh):
        vs, st = fn(_sg_as_dict(part.sg))
    ref = sssp(part, 3)
    got = np.asarray(part.to_global_layout(vs["dist"]))[: part.n_real]
    assert _dist_close(got, ref.values)


def test_dynamic_graph_primitives_and_incremental_sssp():
    """Dynamic-graph round trip through the session API, with the legacy
    ``incremental_sssp`` wrapper checked for agreement along the way."""
    from repro.core import DiffusionSession

    src, dst, w, n = make_graph_family("erdos_renyi", 120, seed=10)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                       edge_slack=0.3, node_slack=0.1)
    sess.query("sssp", source=0)

    # legacy path on an identical twin (same partition, same updates)
    part = build(src, dst, n, w, n_cells=4, edge_slack=0.3, node_slack=0.1)
    ns = NameServer(part)
    vstate, _ = diffuse(part, sssp_program(0))

    rng = np.random.default_rng(1)
    live = np.stack([src, dst], 1)
    deletes = [tuple(map(int, live[i]))
               for i in rng.choice(len(src), 4, replace=False)]
    inserts = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
                float(1 + rng.random() * 5)) for _ in range(4)]

    for u, v in deletes:
        sess.delete_edge(u, v)
    for u, v, x in inserts:
        sess.add_edge(u, v, x)
    sess.commit()
    got = sess.query("sssp", source=0).values[:n]

    part, vstate, _ = incremental_sssp(part, ns, vstate, 0,
                                       inserts=inserts, deletes=deletes)
    legacy = np.asarray(part.to_global_layout(vstate["dist"]))[: part.n_real]
    assert _dist_close(got, legacy)

    edges = {}
    for s, d, x in zip(src, dst, w):
        edges[(int(s), int(d))] = float(x)
    for u, v in deletes:
        edges.pop((u, v), None)
    for u, v, x in inserts:
        edges[(u, v)] = x
    s2 = np.array([e[0] for e in edges])
    d2 = np.array([e[1] for e in edges])
    w2 = np.array(list(edges.values()))
    dist_ev, _ = event_sssp(build_adjacency(s2, d2, w2, n), n, 0)
    assert _dist_close(got, np.array(dist_ev))

    # vertex primitives through the session + raw-primitive parity
    gid = sess.add_vertex(shard=1)
    sess.add_edge(0, gid, 2.5)
    sess.commit()
    assert np.isfinite(sess.query("sssp", source=0).values[gid])
    pk = sess.peek(0, source=0)
    assert np.isfinite(np.asarray(pk)).sum() > 0

    sg, gid2 = vertex_add(part.sg, ns, shard=1)
    sg = edge_add(sg, ns, 0, gid2, 2.5)
    part.sg = sg
    vstate, _ = diffuse(part, sssp_program(0))
    s_, l_ = ns.resolve(gid2)
    assert np.isfinite(float(vstate["dist"][s_, l_]))
    pk = peek(part.sg, vstate["dist"], ns, 0)
    assert np.isfinite(np.asarray(pk)).sum() > 0
    part.sg = vertex_delete(part.sg, ns, gid2)
    vstate, _ = diffuse(part, sssp_program(0))
    assert np.isinf(float(vstate["dist"][s_, l_]))

    sess.delete_vertex(gid)
    sess.commit()
    assert np.isinf(sess.query("sssp", source=0).values[gid])


def test_global_pagerank_matches_power_iteration():
    from repro.core import pagerank

    src, dst, w, n = make_graph_family("scale_free", 200, seed=11)
    part = build(src, dst, n, w, n_cells=4)
    res = pagerank(part, alpha=0.15, eps=1e-8)
    # power iteration reference: p <- alpha*u + (1-alpha) W^T p
    deg = np.bincount(src, minlength=n).astype(np.float64)
    deg = np.maximum(deg, 1)
    p = np.full(n, 1.0 / n)
    u = np.full(n, 1.0 / n)
    for _ in range(200):
        spread = np.zeros(n)
        np.add.at(spread, dst, p[src] / deg[src])
        p = 0.15 * u + 0.85 * spread
    got = res.values / max(res.values.sum(), 1e-12)
    ref = p / p.sum()
    assert np.max(np.abs(got - ref)) < 5e-3, np.max(np.abs(got - ref))


def test_delta_stepping_gate_reduces_actions_to_near_ideal():
    """Beyond-paper: priority-gated diffusion (delta-stepping buckets)
    reaches the paper's ideal Actions Normalized ~= 1.0."""
    from repro.core.diffuse import diffuse as _diffuse
    from repro.core.programs import sssp_program as _sssp

    src, dst, w, n = make_graph_family("scale_free", 600, seed=12)
    part = build(src, dst, n, w, n_cells=4, strategy="locality")
    ref, _ = event_sssp(build_adjacency(src, dst, w, n), n, 0)

    vs0, st0 = _diffuse(part, _sssp(0))
    vs1, st1 = _diffuse(part, _sssp(0), delta=2.0)
    for vs in (vs0, vs1):
        got = np.asarray(part.to_global_layout(vs["dist"]))[: part.n_real]
        assert _dist_close(got, np.array(ref))
    assert int(st1.actions) < int(st0.actions)
    assert float(st1.actions) / len(src) < 1.25   # near-ideal work
