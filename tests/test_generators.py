"""Scale-invariant generator contracts (PR 7).

The family generators are the front of the scaled build pipeline, so their
invariants are asserted at two sizes each: what holds at n=500 must hold
unchanged at n=20000 — symmetry, no self-loops, no duplicates, int32
streams, heavy power-law tails where the family promises one, and bitwise
seed determinism.
"""

import numpy as np
import pytest

from repro.core.generators import (
    GENERATORS,
    graph500_rmat,
    make_graph_family,
    scale_free,
)

FAMILIES = ("erdos_renyi", "small_world", "scale_free", "powerlaw_cluster",
            "graph500")
SIZES = (500, 20000)


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("n_req", SIZES)
def test_generator_invariants(family, n_req):
    src, dst, w, n = make_graph_family(family, n_req, seed=11)
    assert src.dtype == np.int32 and dst.dtype == np.int32
    assert src.shape == dst.shape == w.shape
    assert w.dtype == np.float32
    assert src.size > 0
    assert 0 <= src.min() and src.max() < n
    assert 0 <= dst.min() and dst.max() < n
    # no self-loops
    assert not np.any(src == dst)
    # symmetric: (u, v) present iff (v, u) present — and deduplicated
    key = src.astype(np.int64) * n + dst
    assert np.unique(key).size == key.size
    rkey = dst.astype(np.int64) * n + src
    assert np.array_equal(np.sort(key), np.sort(rkey))


@pytest.mark.parametrize("family", FAMILIES)
def test_seed_determinism_bitwise(family):
    """Same seed -> bitwise-identical edge stream, at both test sizes;
    different seed -> different stream."""
    for n_req in SIZES:
        a = make_graph_family(family, n_req, seed=5)
        b = make_graph_family(family, n_req, seed=5)
        for x, y in zip(a[:3], b[:3]):
            assert np.array_equal(x, y)
        c = make_graph_family(family, n_req, seed=6)
        assert not np.array_equal(a[0], c[0])


@pytest.mark.parametrize("family", ("scale_free", "powerlaw_cluster",
                                    "graph500"))
def test_power_law_tail(family):
    """Skewed families keep their heavy tail at scale: the max degree is
    far above the mean (an Erdős–Rényi graph of the same size sits near
    the mean), and the degree distribution is right-skewed."""
    src, dst, w, n = make_graph_family(family, 20000, seed=3)
    deg = np.bincount(src, minlength=n).astype(np.float64)
    live = deg[deg > 0]
    assert live.max() > 10 * live.mean()
    # right-skew: median well below mean
    assert np.median(live) < live.mean()


def test_scale_free_degree_exponent():
    """BA attachment at n=20000 produces a tail compatible with
    deg^-gamma, gamma in the 2..4 window (loose two-point slope check)."""
    src, _, _, n = make_graph_family("scale_free", 20000, seed=0)
    deg = np.bincount(src, minlength=n)
    hist = np.bincount(deg[deg > 0])
    # slope of log ccdf between degree 8 and 64
    ccdf = hist[::-1].cumsum()[::-1].astype(np.float64)
    ccdf /= ccdf[1]
    g = -(np.log(ccdf[64]) - np.log(ccdf[8])) / (np.log(64) - np.log(8)) + 1
    assert 1.5 < g < 4.5, g


def test_scale_free_matches_reference_loop():
    """The vectorized Batagelj–Brandes construction is a faithful BA
    process: every new vertex i contributes exactly m sources and the
    repeated-array resolution only yields earlier vertices."""
    src, dst = scale_free(600, m=4, seed=9)
    und = src < dst  # one direction of the symmetrized pair
    s, d = src[und], dst[und]
    lo = np.minimum(s, d)
    hi = np.maximum(s, d)
    # attachment never points forward: each undirected edge touches at
    # least one vertex below the other (trivially true) and new vertices
    # have bounded degree toward the future: vertex i>m has at most m
    # edges to vertices > i... checked via the directed construction:
    # every non-seed vertex appears as a BA source exactly <= m times
    # toward *earlier* vertices
    back = np.bincount(hi, minlength=600)
    assert back[5:].max() <= 2 * 4 + 4  # m new + dedup slack; loose cap
    assert lo.min() >= 0


def test_graph500_n_propagation():
    """make_graph_family('graph500', n=...) never returns a vertex-id
    space smaller than the request — scale rounds UP to the next power
    of two and the returned n is the actual id space."""
    for n_req in (1400, 2048, 5000):
        src, dst, w, n = make_graph_family("graph500", n_req, seed=2)
        assert n >= n_req
        assert n == 1 << int(np.log2(n))  # power of two
        assert src.max() < n and dst.max() < n
    # exact power of two stays put
    _, _, _, n = make_graph_family("graph500", 1024, seed=2)
    assert n == 1024


def test_graph500_rmat_scale_dtype():
    src, dst = graph500_rmat(10, seed=4)
    assert src.dtype == np.int32
    assert src.max() < (1 << 10)


def test_generators_registry_covers_families():
    for f in FAMILIES:
        assert f in GENERATORS
