"""Hub replicas ("rhizomes", DESIGN.md §2.12): split-policy invariants,
replica-map round-trips through compaction and the tombstone/delta path,
the replica-mode partition cut, and the merged-fixed-point parity contract
(replicas on == replicas off, bitwise for order-free monoids)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import build
from repro.core.generators import make_graph_family
from repro.core.graph import from_edges as graph_from_edges
from repro.core.partition import (
    CAPACITY_SKEW_THRESHOLD,
    _degree_aware_cut,
    partition,
)
from repro.core.rhizome import member_rank, replica_counts
from repro.core.session import DiffusionSession

_SUBPROC_ENV = {
    "PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
    "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
}


def _split_part(n=400, thr=12, n_cells=4, seed=3, **kw):
    src, dst, w, n = make_graph_family("scale_free", n, seed=seed)
    part = build(src, dst, n, w, n_cells=n_cells,
                 replica_threshold=thr, **kw)
    assert part.sg.replica_members is not None, "no hubs split"
    return part, src, dst, w, n


# ---------------------------------------------------------------------------
# split policy / conservation
# ---------------------------------------------------------------------------

def test_split_conserves_edges_and_degrees():
    """Sum of member-slot stored out-degrees == hub out-degree, and every
    retargeted in-edge still lands on a slot of its destination hub."""
    part, src, dst, w, n = _split_part()
    sg = part.sg
    rep = part.replica
    gid = np.asarray(sg.gid)
    eok = np.asarray(sg.edge_ok)
    src_gid = np.take_along_axis(gid, np.asarray(sg.src_local), axis=1)
    dst_gid = gid[np.asarray(sg.dst_shard), np.asarray(sg.dst_local)]
    out_deg = np.bincount(src, minlength=n)
    in_deg = np.bincount(dst, minlength=n)
    for g_idx, h in enumerate(np.asarray(rep.hub_gid)):
        ms = np.asarray(rep.members_s[g_idx])
        ml = np.asarray(rep.members_l[g_idx])
        valid = ms >= 0
        assert valid.sum() >= 2
        # member slots all carry the hub's gid and distinct cells
        assert (gid[ms[valid], ml[valid]] == h).all()
        assert len(set(ms[valid].tolist())) == valid.sum()
        # stored out-edges across members == the hub's live out-degree
        stored = 0
        for s, l in zip(ms[valid], ml[valid]):
            stored += int((eok[s] & (np.asarray(sg.src_local)[s] == l)
                           & (src_gid[s] == h)).sum())
        assert stored == out_deg[h], (h, stored, out_deg[h])
        # retargeted in-edges: every edge whose logical dst is the hub
        # points at one of its member slots
        hits = eok & (dst_gid == h)
        ds = np.asarray(sg.dst_shard)[hits]
        dl = np.asarray(sg.dst_local)[hits]
        slots = set(zip(ms[valid].tolist(), ml[valid].tolist()))
        assert set(zip(ds.tolist(), dl.tolist())) <= slots
        assert hits.sum() == in_deg[h], (h, int(hits.sum()), in_deg[h])


def test_member_rank_routing_is_deterministic_and_in_range():
    part, src, dst, w, n = _split_part()
    rep = part.replica
    group_of = np.asarray(rep.group_of)
    n_members = np.asarray(rep.n_members)
    sg = part.sg
    gid = np.asarray(sg.gid)
    eok = np.asarray(sg.edge_ok)
    dst_gid = gid[np.asarray(sg.dst_shard), np.asarray(sg.dst_local)]
    src_gid = np.take_along_axis(gid, np.asarray(sg.src_local), axis=1)
    # every live edge into a split hub sits on exactly the member slot
    # the rank hash names — the property commit() relies on to route
    # incremental adds to the same slot the build chose
    for s in range(sg.n_shards):
        for e in np.where(eok[s])[0]:
            h = int(dst_gid[s, e])
            if h >= group_of.shape[0] or group_of[h] < 0:
                continue
            g = int(group_of[h])
            m = member_rank(h, int(src_gid[s, e]), int(n_members[g]))
            assert (int(np.asarray(rep.members_s[g])[m])
                    == int(np.asarray(sg.dst_shard)[s, e]))
            assert (int(np.asarray(rep.members_l[g])[m])
                    == int(np.asarray(sg.dst_local)[s, e]))


def test_replica_counts_policy():
    deg = np.array([0, 5, 10, 11, 25, 1000])
    r = replica_counts(deg, threshold=10, n_shards=4)
    # ceil(deg/thr), clamped to [1, n_shards], never split at <= thr
    assert r.tolist() == [1, 1, 1, 2, 3, 4]


# ---------------------------------------------------------------------------
# replica maps survive compaction and the tombstone/delta path
# ---------------------------------------------------------------------------

def test_replica_maps_round_trip_with_csr_and_dirty_views():
    sess = _split_session()
    sg0 = sess.sg
    keep = {k: np.asarray(getattr(sg0, k)).copy()
            for k in ("replica_of", "replica_group", "replica_members")}
    rng = np.random.default_rng(7)
    src, dst, _ = sess.edge_list()
    hub = int(np.asarray(sess.part.replica.hub_gid)[0])
    for _ in range(4):
        sess.add_edge(int(rng.integers(0, sess.n_ids)), hub,
                      float(0.5 + rng.random()))
    i = int(np.where(src == hub)[0][0])
    sess.delete_edge(hub, int(dst[i]))
    sess.commit()
    assert int(np.asarray(sess.sg.delta_count).sum()) > 0
    assert int(np.asarray(sess.sg.tomb_count).sum()) > 0
    for sg in (sess.sg, sess.sg.with_csr()):
        for k, want in keep.items():
            assert np.array_equal(np.asarray(getattr(sg, k)), want), k


# ---------------------------------------------------------------------------
# the replica-mode cut and the off-mode boundary
# ---------------------------------------------------------------------------

def test_degree_aware_cut_boundary_at_skew_threshold():
    """Equal-vertex chunking is kept exactly *at* the capacity-skew
    threshold and abandoned just past it (strict inequality)."""
    # 8 vertices, 2 cells: chunk loads [7, 1] -> max == 1.75 x mean
    src = np.array([0, 0, 0, 0, 1, 1, 2, 4])
    dst = np.array([1, 2, 3, 4, 0, 5, 6, 0])
    n = 8
    assert CAPACITY_SKEW_THRESHOLD == 1.75
    part = build(src, dst, n, None, n_cells=2)
    counts = np.bincount(np.asarray(part.owner)[:n], minlength=2)
    assert counts.tolist() == [4, 4]        # eq chunking retained at ==
    # one more hub edge: loads [8, 1] -> 8 > 1.75 * 4.5 -> walk engages
    src2 = np.concatenate([src, [0]])
    dst2 = np.concatenate([dst, [7]])
    part2 = build(src2, dst2, n, None, n_cells=2)
    counts2 = np.bincount(np.asarray(part2.owner)[:n], minlength=2)
    assert counts2.tolist() != [4, 4]
    # and the walk itself: exact budget math on the same degree sequence
    deg = np.array([5, 2, 1, 0, 1, 0, 0, 0])
    cells = _degree_aware_cut(deg, 2)
    loads = np.bincount(cells, weights=deg, minlength=2)
    assert loads.max() <= 7                  # better than eq's 8


def test_replica_cut_balances_edges_and_vertex_counts():
    """The strided replica-mode cut: vertex counts exactly even (the
    exchange table costs S^2 * Np, ragged chunks are pure overhead) and
    per-cell edge load within ~15% of the mean on a skewed family."""
    src, dst, w, n = make_graph_family("scale_free", 4000, seed=5)
    S = 16
    part = partition(graph_from_edges(src, dst, n, w), S,
                     replica_threshold="auto")
    sg = part.sg
    loads = np.asarray(sg.edge_ok).sum(axis=1)
    live_counts = np.asarray(sg.node_ok).sum(axis=1)
    assert live_counts.max() - live_counts.min() <= 1 + int(
        np.asarray(sg.replica_members).shape[0])  # replicas add slots
    assert loads.max() <= 1.2 * loads.mean(), (loads.max(), loads.mean())


# ---------------------------------------------------------------------------
# merged fixed points: replicas on == replicas off
# ---------------------------------------------------------------------------

def _split_session(**kw):
    src, dst, w, n = make_graph_family("scale_free", 400, seed=3)
    kw.setdefault("edge_slack", 1.0)
    kw.setdefault("node_slack", 0.5)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                       replica_threshold=12, **kw)
    assert sess.sg.replica_members is not None
    return sess


def _vals(res):
    if isinstance(res, list):
        res = res[0]
    return np.asarray(res.values)


@pytest.mark.parametrize("backend,sweep", [("xla", "pull"), ("xla", "push"),
                                           ("pallas", "auto")])
def test_fixed_point_parity_on_vs_off(backend, sweep):
    src, dst, w, n = make_graph_family("scale_free", 400, seed=3)
    off = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                      edge_slack=1.0, node_slack=0.5)
    on = _split_session()
    matrix = [("sssp", dict(source=0)), ("bfs", dict(source=0)),
              ("cc", {}), ("widest", dict(source=0)),
              ("reach", dict(sources=[0]))]
    for name, kwargs in matrix:
        a = _vals(off.query(name, sweep=sweep, backend=backend, **kwargs))
        b = _vals(on.query(name, sweep=sweep, backend=backend, **kwargs))
        assert np.array_equal(a, b, equal_nan=True), (name, backend, sweep)
    # sum-combine programs: fixed-tree merge keeps the split fixed point
    # within float tolerance of the unsplit one (ppr truncates at eps, so
    # the tolerance is eps-shaped, not machine-shaped)
    a = _vals(off.query("pagerank", sweep=sweep, backend=backend))
    b = _vals(on.query("pagerank", sweep=sweep, backend=backend))
    assert np.allclose(a, b, rtol=1e-5, atol=1e-6)
    eps = 1e-6
    a = _vals(off.query("ppr", source=0, eps=eps, sweep=sweep,
                        backend=backend))
    b = _vals(on.query("ppr", source=0, eps=eps, sweep=sweep,
                       backend=backend))
    assert np.allclose(a, b, atol=3 * eps)


def test_sssp_parent_payload_consistent_on_split_graph():
    sess = _split_session()
    res = sess.query("sssp", source=0, track_parents=True)
    dist = np.asarray(res.values)
    parent = np.asarray(res.extra["parent"])
    w_of = {}
    src, dst, w = sess.edge_list()
    for u, v, ww in zip(src, dst, w):
        key = (int(u), int(v))
        w_of[key] = min(w_of.get(key, np.inf), float(ww))
    for v in range(sess.n_ids):
        p = int(parent[v])
        if p < 0 or p == v or not np.isfinite(dist[v]):
            continue    # unreached, or the source itself
        assert (p, v) in w_of
        assert np.isclose(dist[v], dist[p] + w_of[(p, v)], rtol=1e-6)


def test_lanes_bitwise_on_split_graph():
    sess = _split_session()
    lanes = sess.query("sssp", sources=[0, 5, 9])
    for i, s in enumerate([0, 5, 9]):
        solo = sess.query("sssp", source=s, refresh=True)
        assert np.array_equal(np.asarray(lanes[i].values),
                              np.asarray(solo.values), equal_nan=True)


# ---------------------------------------------------------------------------
# dynamics: incremental == rebuild on split graphs
# ---------------------------------------------------------------------------

def test_incremental_commit_equals_rebuild_on_split_graph():
    sess = _split_session()
    n_real = sess.part.n_real
    rng = np.random.default_rng(11)
    hub = int(np.asarray(sess.part.replica.hub_gid)[0])
    src0, dst0, _ = sess.edge_list()
    for _ in range(2):
        for _ in range(4):
            sess.add_edge(int(rng.integers(0, n_real)), hub, 0.7)
            sess.add_edge(hub, int(rng.integers(0, n_real)), 0.9)
        i = int(rng.integers(0, len(src0)))
        sess.delete_edge(int(src0[i]), int(dst0[i]))
        sess.delete_vertex(int(rng.integers(1, 200)))
        sess.commit()
    # incremental views == compacted rebuild of the same sharded graph
    from repro.core.diffuse import diffuse
    from repro.core.programs import PROGRAMS
    rebuilt = sess.sg.with_csr()
    for name, kw in [("sssp", dict(source=0)), ("cc", {})]:
        prog = PROGRAMS[name].factory(**kw)
        got, _ = diffuse(sess.sg, prog)
        want, _ = diffuse(rebuilt, prog)
        for k in got:
            a, b = np.asarray(got[k]), np.asarray(want[k])
            fin = np.isfinite(a)
            assert np.array_equal(fin, np.isfinite(b)), (name, k)
            assert np.array_equal(np.where(fin, a, 0),
                                  np.where(fin, b, 0)), (name, k)
    # and == a from-scratch session over the surviving edge list
    # (min-monoid fixed points are layout-independent)
    s2, d2, w2 = sess.edge_list()
    fresh = DiffusionSession.from_edges(s2, d2, sess.n_ids, w2, n_cells=4,
                                        replica_threshold=12)
    a = np.asarray(sess.query("sssp", source=0, refresh=True).values)
    b = np.asarray(fresh.query("sssp", source=0).values)[:sess.n_ids]
    assert np.array_equal(a, b, equal_nan=True)


def test_split_hub_delete_and_slot_quarantine():
    """Deleting a split hub kills every member slot, commit() repairs the
    cached fixed point, and non-primary member slots never re-enter the
    allocator's free lists."""
    sess = _split_session()
    ns = sess.ns
    hub = int(np.asarray(sess.part.replica.hub_gid)[0])
    members = ns.members_of(hub)
    assert members is not None and len(members) >= 2
    sess.query("sssp", source=0)
    sess.delete_vertex(hub)
    sess.commit()
    gid = np.asarray(sess.sg.gid)
    nok = np.asarray(sess.sg.node_ok)
    for s, l in members:
        assert not nok[s, l]
    # repaired cache == fresh fixed point on the mutated graph
    a = np.asarray(sess.query("sssp", source=0).values)
    b = np.asarray(sess.query("sssp", source=0, refresh=True).values)
    assert np.array_equal(a, b, equal_nan=True)
    # new vertices may reuse the primary slot but never a mirror slot
    non_primary = set(members[1:])
    for _ in range(len(members) + 2):
        g = sess.add_vertex()
        assert tuple(ns.resolve(g)) not in non_primary
    del gid


def test_peek_concatenates_member_rows():
    sess = _split_session()
    rep = sess.part.replica
    hub = int(np.asarray(rep.hub_gid)[0])
    n_m = int(np.asarray(rep.n_members)[int(np.asarray(
        rep.group_of)[hub])])
    plain = int(np.where(np.asarray(rep.group_of) < 0)[0][0])
    row_plain = sess.peek(plain, source=0)  # unsplit: one capacity row
    row_hub = sess.peek(hub, source=0)
    assert row_hub.shape[0] == n_m * row_plain.shape[0]


# ---------------------------------------------------------------------------
# SPMD engine (multi-device): replica merge rides the all-gather
# ---------------------------------------------------------------------------

def test_spmd_replica_merge_bitwise_subprocess():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        from repro.core.generators import make_graph_family
        from repro.core.session import DiffusionSession

        src, dst, w, n = make_graph_family("scale_free", 400, seed=3)
        on = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                         replica_threshold=12)
        off = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
        assert on.sg.replica_members is not None
        for name, kw in (("sssp", dict(source=0)), ("cc", {})):
            a = np.asarray(off.query(name, engine="spmd", **kw).values)
            b = np.asarray(on.query(name, engine="spmd", **kw).values)
            c = np.asarray(on.query(name, engine="sharded", refresh=True,
                                    **kw).values)
            assert np.array_equal(a, b, equal_nan=True), name
            assert np.array_equal(b, c, equal_nan=True), name
        print("SPMD_RHIZOME_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=_SUBPROC_ENV, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), timeout=900,
    )
    assert "SPMD_RHIZOME_OK" in out.stdout, out.stdout + out.stderr
