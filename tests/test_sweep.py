"""Direction-optimizing sweeps (DESIGN.md §2.8): the frontier-compacted
push sweep and the auto selector reproduce the dense pull sweep bitwise
for every registered program, on both kernel backends, both engines, and
laned runs — and the per-round direction/frontier introspection that
tunes the selector threshold is exposed through ``Result.stats``."""

import numpy as np
import pytest

from repro.core import DiffusionSession, build
from repro.core.diffuse import diffuse, diffuse_from
from repro.core.generators import make_graph_family
from repro.core.programs import PROGRAMS, sssp_program


def _mask_inf(a):
    return np.where(np.isinf(a), 1e30, a)


def _eq(a, b):
    return np.array_equal(_mask_inf(np.asarray(a)), _mask_inf(np.asarray(b)))


# every registered diffusive program (run_fn customs like triangles have
# no sweep), with query kwargs
PROGRAM_MATRIX = [
    ("sssp", dict(source=0)),
    ("bfs", dict(source=0)),
    ("cc", {}),
    ("ppr", dict(source=0, eps=1e-5)),
    ("pagerank", {}),
    ("widest", dict(source=0, track_parents=True)),
    ("reach", dict(sources=(0, 7))),
]


def test_matrix_covers_every_registered_diffusion_program():
    diffusive = {n for n, s in PROGRAMS.items()
                 if s.factory is not None and s.run_fn is None}
    assert diffusive <= {name for name, _ in PROGRAM_MATRIX}


@pytest.mark.parametrize("family,seed", [("small_world", 5),
                                         ("scale_free", 11)])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("name,kw", PROGRAM_MATRIX)
def test_push_equals_pull_bitwise_sharded(name, kw, backend, family, seed):
    """Acceptance: push == auto == dense pull, bitwise, for every
    registered program on both backends (values and every extra state
    field, incl. argbest payloads)."""
    src, dst, w, n = make_graph_family(family, 120, seed=seed)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    ref = sess.query(name, backend=backend, sweep="pull", **kw)
    for sweep in ("push", "auto"):
        got = sess.query(name, backend=backend, sweep=sweep, **kw)
        assert _eq(ref.values, got.values), (name, sweep)
        for k, v in ref.extra.items():
            if k != "live":
                assert _eq(v, got.extra[k]), (name, sweep, k)


@pytest.mark.parametrize("name,kw", [("sssp", dict(source=0)),
                                     ("ppr", dict(source=0, eps=1e-5))])
def test_push_equals_pull_bitwise_spmd(name, kw):
    """The SPMD engine's per-device direction selector reaches the same
    fixed point bitwise (min payload program + sum program)."""
    src, dst, w, n = make_graph_family("erdos_renyi", 100, seed=4)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=1)
    ref = sess.query(name, engine="spmd", sweep="pull", **kw)
    for sweep in ("push", "auto"):
        got = sess.query(name, engine="spmd", sweep=sweep, **kw)
        assert _eq(ref.values, got.values), (name, sweep)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("name,kw", [("sssp", {}), ("ppr", dict(eps=1e-5))])
def test_push_equals_pull_bitwise_laned(name, kw, backend):
    """Laned queries OR every lane's senders into one shared push
    compaction; each lane still reproduces its pull fixed point bitwise."""
    src, dst, w, n = make_graph_family("small_world", 130, seed=7)
    sources = [0, 9, 31]
    pull = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    push = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    rp = pull.query(name, backend=backend, sweep="pull", sources=sources,
                    **kw)
    rq = push.query(name, backend=backend, sweep="push", sources=sources,
                    **kw)
    for a, b, s in zip(rp, rq, sources):
        assert _eq(a.values, b.values), (name, s)
        for k, v in a.extra.items():
            if k != "live":
                assert _eq(v, b.extra[k]), (name, s, k)


def test_push_repair_default_matches_from_scratch():
    """commit() warm repairs default to the push sweep and still
    reproduce the from-scratch fixed point bitwise (insert-only monotone
    frontier repair — the sparse-frontier case push exists for)."""
    src, dst, w, n = make_graph_family("small_world", 150, seed=9)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                       edge_slack=0.4)
    sess.query("sssp", source=0)
    rng = np.random.default_rng(2)
    for _ in range(6):
        sess.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                      float(0.2 + rng.random()))
    info = sess.commit()
    (strategy, stats) = next(v for k, v in info.repairs.items()
                             if k[0] == "sssp")
    assert strategy == "frontier"
    # the warm repair actually ran compacted sweeps
    assert int(stats.push_iters) == int(stats.local_iters) > 0
    got = sess.query("sssp", source=0).values
    ref_vs, _ = diffuse(sess.sg, sssp_program(0))
    assert _eq(got, sess.to_global(ref_vs["dist"]))


def test_sweep_stats_expose_frontier_and_direction():
    """Satellite: Result.stats carries per-round frontier sizes and the
    chosen direction so the selector threshold is tunable from
    measurements."""
    src, dst, w, n = make_graph_family("small_world", 150, seed=5)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4)
    res = sess.query("sssp", source=0, sweep="auto")
    st = res.stats
    rounds = int(st.rounds)
    flog = np.asarray(st.frontier_log)
    dlog = np.asarray(st.dir_log)
    assert rounds > 0
    # every executed round logged a frontier size and a direction ...
    assert (flog[:rounds] >= 0).all()
    assert set(np.unique(dlog[:rounds])) <= {0, 1}
    # ... and the unexecuted tail stays -1
    assert (flog[rounds:] == -1).all() and (dlog[rounds:] == -1).all()
    # the logged peak agrees with the existing max_frontier introspection
    assert flog.max() <= int(st.max_frontier)
    # pure push / pure pull bracket the auto run's push share
    pull = sess.query("sssp", source=0, sweep="pull", refresh=True).stats
    push = sess.query("sssp", source=0, sweep="push", refresh=True).stats
    assert int(pull.push_iters) == 0
    assert int(push.push_iters) == int(push.local_iters)
    assert 0 <= int(st.push_iters) <= int(st.local_iters)


def test_push_repair_resumes_under_delta_gate():
    """A delta-gated query's push repair keeps the gate (same contract as
    the dense resume path) and matches the from-scratch fixed point."""
    src, dst, w, n = make_graph_family("scale_free", 200, seed=15)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=4,
                                       edge_slack=0.4)
    sess.query("sssp", source=0, delta=2.0)
    rng = np.random.default_rng(7)
    for _ in range(4):
        sess.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                      float(0.5 + rng.random()))
    sess.commit()
    got = sess.query("sssp", source=0, delta=2.0).values
    ref_vs, _ = diffuse(sess.sg, sssp_program(0))
    assert _eq(got, sess.to_global(ref_vs["dist"]))


def test_explicit_pull_query_keeps_pull_repair():
    """sweep='pull' queried explicitly opts its repairs out of the push
    default; the repair still matches from-scratch."""
    src, dst, w, n = make_graph_family("small_world", 120, seed=3)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=0.4)
    sess.query("sssp", source=0, sweep="pull")
    sess.add_edge(0, 50, 0.3)
    info = sess.commit()
    (_, stats) = next(v for k, v in info.repairs.items() if k[0] == "sssp")
    assert int(stats.push_iters) == 0
    got = sess.query("sssp", source=0).values
    ref_vs, _ = diffuse(sess.sg, sssp_program(0))
    assert _eq(got, sess.to_global(ref_vs["dist"]))


def test_sweep_validation_errors():
    src, dst, w, n = make_graph_family("small_world", 80, seed=1)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2)
    with pytest.raises(ValueError):
        sess.query("sssp", source=0, sweep="sideways")
    with pytest.raises(ValueError):
        sess.query("sssp", source=0, engine="event", sweep="push")
    with pytest.raises(ValueError):
        sess.query("triangles", sweep="push")   # run_fn: no sweep to pick
    with pytest.raises(ValueError):
        DiffusionSession(build(src, dst, n, w, n_cells=2), sweep="dense")


def test_push_sweep_from_tiny_frontier_does_less_edge_work():
    """The point of the whole PR, asserted at the stats level: resuming
    from a one-vertex frontier, the push sweep's sending-edge actions
    match the dense sweep's exactly (same messages — that is the bitwise
    contract) while sweeping only the frontier's blocks per round."""
    src, dst, w, n = make_graph_family("scale_free", 300, seed=8)
    part = build(src, dst, n, w, n_cells=2)
    prog = sssp_program(0)
    vs, _ = diffuse(part, prog)            # converged state
    active = np.zeros((part.sg.n_shards, part.sg.n_per_shard), bool)
    s0, l0 = int(np.asarray(part.owner)[5]), int(np.asarray(part.local)[5])
    active[s0, l0] = True
    import jax.numpy as jnp
    re_pull = diffuse_from(part, prog, vs, jnp.asarray(active))
    re_push = diffuse_from(part, prog, vs, jnp.asarray(active),
                           sweep="push")
    assert _eq(re_pull[0]["dist"], re_push[0]["dist"])
    assert int(re_pull[1].actions) == int(re_push[1].actions)
    assert int(re_push[1].push_iters) == int(re_push[1].local_iters) > 0
