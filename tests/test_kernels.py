"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import attention, decode_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.xla_flash import mea_attention
from repro.kernels.segment_reduce.ops import segment_sum
from repro.kernels.sssp_relax.ops import relax
from repro.kernels.sssp_relax.ref import relax_ref


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 128, 64),    # MHA
    (2, 8, 2, 200, 64),    # GQA + padding path
    (1, 8, 1, 256, 32),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, hq, hkv, s, d, dtype, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    out = attention(q, k, v, causal=causal, backend="interpret")
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_softcap():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    out = attention(q, k, v, softcap=20.0, backend="interpret")
    ref = attention_ref(q, k, v, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_mea_attention_grads_match_oracle():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(2, 4, 96, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 96, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 96, 32)), jnp.float32)

    def loss(fn):
        return lambda q_, k_, v_: (fn(q_, k_, v_) ** 2).sum()

    g1 = jax.grad(loss(lambda a, b, c: mea_attention(a, b, c, True, 0.0, 32,
                                                     None)),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(lambda a, b, c: attention_ref(a, b, c, causal=True)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_decode_attention_matches_last_position():
    rng = np.random.default_rng(3)
    b, hq, hkv, s, d = 2, 8, 2, 64, 32
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    kc = jnp.pad(k, ((0, 0), (0, 0), (0, 16), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, 0), (0, 16), (0, 0)))
    out = decode_attention(q[:, :, -1:], kc, vc, cache_len=s)
    ref = attention_ref(q, k, v, causal=True)[:, :, -1:]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("e,f,n", [(64, 8, 13), (1000, 32, 77),
                                   (257, 1, 300), (128, 128, 5)])
def test_segment_sum_sweep(e, f, n):
    rng = np.random.default_rng(4)
    ids = rng.integers(0, n, e).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(e, f)), jnp.float32)
    out = segment_sum(vals, jnp.asarray(ids), n, backend="interpret")
    # unsorted wrapper sorts internally; compare against the raw jax oracle
    ref = jax.ops.segment_sum(vals, jnp.asarray(ids), num_segments=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_segment_sum_gradient():
    rng = np.random.default_rng(5)
    ids = np.sort(rng.integers(0, 10, 100)).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(100, 4)), jnp.float32)

    def f(backend):
        return lambda v: (
            segment_sum(v, jnp.asarray(ids), 10, backend=backend) ** 2
        ).sum()

    g1 = jax.grad(f("interpret"))(vals)
    g2 = jax.grad(f("xla"))(vals)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


@pytest.mark.parametrize("family,prog_name", [
    ("erdos_renyi", "sssp"), ("scale_free", "ppr"), ("small_world", "cc"),
])
def test_edge_relax_backends_bitwise_per_cell(family, prog_name):
    """edge_relax: the Pallas kernel (interpret) and the XLA reference
    return bitwise-identical (table, cnt, pay) for one cell's relaxation
    sweep — the invariant the engine's backend= switch rests on."""
    from repro.core.diffuse import _sg_as_dict
    from repro.core.generators import make_graph_family
    from repro.core.programs import cc_program, ppr_program, sssp_program
    from repro.core import build
    from repro.kernels.edge_relax import edge_relax

    progs = {"sssp": sssp_program(0), "ppr": ppr_program(0),
             "cc": cc_program()}
    prog = progs[prog_name]
    rng = np.random.default_rng(11)
    src, dst, w, n = make_graph_family(family, 150, seed=11)
    part = build(src, dst, n, w, n_cells=3, edge_slack=0.2)
    sg = part.sg
    sgd = _sg_as_dict(sg)
    vstate, active = prog.init(sg)
    # a partially-active frontier exercises the send masking
    senders = jnp.asarray(rng.random((sg.n_shards, sg.n_per_shard)) < 0.6)
    senders = senders & active if prog_name != "sssp" else active
    n_keys = sg.n_shards * sg.n_per_shard
    for s in range(sg.n_shards):
        args = (jax.tree_util.tree_map(lambda a: a[s], vstate), senders[s],
                sgd["gid"][s], sgd["csr_key"][s], sgd["csr_src"][s],
                sgd["csr_weight"][s], sgd["csr_dst_gid"][s])
        tx, cx, px = edge_relax(prog, *args, n_keys=n_keys,
                                block_e=sg.csr_block, backend="xla")
        tp, cp, pp = edge_relax(prog, *args, n_keys=n_keys,
                                block_e=sg.csr_block, backend="pallas",
                                interpret=True)
        assert np.array_equal(np.asarray(cx), np.asarray(cp))
        ax, ap = np.asarray(tx), np.asarray(tp)
        both_inf = ~np.isfinite(ax) & ~np.isfinite(ap)
        assert np.array_equal(np.where(both_inf, 0, ax),
                              np.where(both_inf, 0, ap))
        assert (px is None) == (pp is None)
        if px is not None:
            assert np.array_equal(np.asarray(px), np.asarray(pp))


def test_edge_relax_empty_frontier_is_identity():
    from repro.core.diffuse import _sg_as_dict
    from repro.core.generators import make_graph_family
    from repro.core.msg import identity_for
    from repro.core.programs import sssp_program
    from repro.core import build
    from repro.kernels.edge_relax import edge_relax

    prog = sssp_program(0)
    src, dst, w, n = make_graph_family("erdos_renyi", 60, seed=2)
    part = build(src, dst, n, w, n_cells=2)
    sg = part.sg
    sgd = _sg_as_dict(sg)
    vstate, _ = prog.init(sg)
    n_keys = sg.n_shards * sg.n_per_shard
    none = jnp.zeros(sg.n_per_shard, bool)
    for backend in ("xla", "pallas"):
        t, c, p = edge_relax(
            prog, jax.tree_util.tree_map(lambda a: a[0], vstate), none,
            sgd["gid"][0], sgd["csr_key"][0], sgd["csr_src"][0],
            sgd["csr_weight"][0], sgd["csr_dst_gid"][0],
            n_keys=n_keys, block_e=sg.csr_block, backend=backend,
            interpret=True)
        ident = float(identity_for(prog.combine, prog.msg_dtype))
        assert (np.asarray(t) == ident).all()
        assert (np.asarray(c) == 0).all()
        assert (np.asarray(p) == -1).all()


@pytest.mark.parametrize("np_,e", [(50, 200), (300, 900), (128, 512)])
def test_relax_sweep(np_, e):
    rng = np.random.default_rng(6)
    dist = jnp.asarray(
        np.where(rng.random(np_) < 0.4, rng.random(np_) * 10, np.inf),
        jnp.float32,
    )
    active = jnp.asarray(rng.random(np_) < 0.5)
    src = jnp.asarray(rng.integers(0, np_, e), jnp.int32)
    dstv = np.sort(rng.integers(0, np_, e)).astype(np.int32)
    # mask some edges dead
    dstv[rng.random(e) < 0.1] = -1
    w = jnp.asarray(rng.random(e) * 5, jnp.float32)
    out = relax(dist, active, w, src, jnp.asarray(dstv), np_,
                backend="interpret")
    ref = relax_ref(dist, w, src, jnp.asarray(dstv), active, np_)
    both_inf = np.isinf(np.asarray(out)) & np.isinf(np.asarray(ref))
    diff = np.where(both_inf, 0, np.asarray(out) - np.asarray(ref))
    assert np.max(np.abs(diff)) < 1e-5
