"""Property-based tests (hypothesis) on the system's invariants."""

import heapq

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import build, sssp
from repro.core.msg import segment_combine, segment_softmax
from repro.core.triangles import (
    cca_cost_model,
    triangle_count_bitset,
    triangle_count_exact,
)
from repro.optim.optimizers import compress_int8, decompress_int8


def _dijkstra(src, dst, w, n, source):
    adj = [[] for _ in range(n)]
    for s, d, x in zip(src, dst, w):
        adj[int(s)].append((int(d), float(x)))
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    pq = [(0.0, source)]
    while pq:
        d, v = heapq.heappop(pq)
        if d > dist[v]:
            continue
        for u, wt in adj[v]:
            nd = d + wt
            if nd < dist[u]:
                dist[u] = nd
                heapq.heappush(pq, (nd, u))
    return dist


graphs = st.integers(10, 60).flatmap(
    lambda n: st.tuples(
        st.just(n),
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1),
                      st.floats(0.1, 10.0)),
            min_size=1, max_size=4 * n,
        ),
    )
)


@settings(max_examples=25, deadline=None)
@given(graphs, st.integers(1, 4))
def test_sssp_matches_dijkstra(graph, n_cells):
    n, edges = graph
    edges = [(s, d, w) for s, d, w in edges if s != d]
    if not edges:
        return
    src = np.array([e[0] for e in edges], np.int32)
    dst = np.array([e[1] for e in edges], np.int32)
    w = np.array([e[2] for e in edges], np.float32)
    ref = _dijkstra(src, dst, w, n, 0)
    got = sssp(build(src, dst, n, w, n_cells=n_cells), 0,
               track_parents=False).values
    a = np.where(np.isinf(got), 1e30, got)
    b = np.where(np.isinf(ref), 1e30, ref)
    assert np.allclose(a, b, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(1, 200), st.integers(1, 20),
    st.sampled_from(["sum", "min", "max", "mean"]),
)
def test_segment_combine_matches_numpy(n_vals, n_seg, combine):
    rng = np.random.default_rng(n_vals * 31 + n_seg)
    vals = rng.normal(size=(n_vals,)).astype(np.float32)
    ids = rng.integers(0, n_seg, n_vals)
    got = np.asarray(segment_combine(
        jnp.asarray(vals), jnp.asarray(ids), n_seg, combine
    ))
    for s in range(n_seg):
        sel = vals[ids == s]
        if len(sel) == 0:
            continue
        expect = {"sum": sel.sum(), "min": sel.min(), "max": sel.max(),
                  "mean": sel.mean()}[combine]
        assert np.isclose(got[s], expect, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 150))
def test_segment_softmax_normalized(n_vals):
    rng = np.random.default_rng(n_vals)
    ids = np.sort(rng.integers(0, 8, n_vals))
    logits = jnp.asarray(rng.normal(size=(n_vals,)) * 5, jnp.float32)
    w = np.asarray(segment_softmax(logits, jnp.asarray(ids), 8))
    sums = np.zeros(8)
    np.add.at(sums, ids, w)
    present = np.unique(ids)
    assert np.allclose(sums[present], 1.0, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 2000))
def test_int8_compression_error_feedback_is_contraction(size):
    rng = np.random.default_rng(size)
    g = jnp.asarray(rng.normal(size=(size,)).astype(np.float32))
    err = jnp.zeros_like(g)
    # accumulated (decompressed - true) error stays bounded by one quantum
    total_true = np.zeros(size)
    total_sent = np.zeros(size)
    for _ in range(5):
        q, scale, err = compress_int8(g, err)
        total_sent += np.asarray(decompress_int8(q, scale))
        total_true += np.asarray(g)
    # error feedback: cumulative difference bounded by the current residual
    assert np.max(np.abs(total_true - total_sent)) <= float(
        np.max(np.abs(np.asarray(err)))
    ) + 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 40), st.integers(0, 300))
def test_triangle_count_bitset_matches_exact(n, seed):
    rng = np.random.default_rng(seed)
    m = rng.integers(0, n * 3)
    s = rng.integers(0, n, m)
    d = rng.integers(0, n, m)
    keep = s != d
    s, d = s[keep], d[keep]
    key = s.astype(np.int64) * n + d
    _, idx = np.unique(key, return_index=True)
    s, d = s[idx], d[idx]
    # symmetrize
    s2 = np.concatenate([s, d])
    d2 = np.concatenate([d, s])
    key = s2.astype(np.int64) * n + d2
    _, idx = np.unique(key, return_index=True)
    s2, d2 = s2[idx].astype(np.int32), d2[idx].astype(np.int32)
    if len(s2) == 0:
        return
    exact = triangle_count_exact(s2, d2, n)
    bitset = int(triangle_count_bitset(jnp.asarray(s2), jnp.asarray(d2), n))
    assert exact == bitset


def test_cca_cost_model_matches_paper_table():
    # Table III: Graph500 scale-24 row -> speedup ~10.7
    c = cca_cost_model(wedges=2.46e14, triangles=5.05e13)
    assert 9.0 < c.speedup < 11.5
    c = cca_cost_model(wedges=1.478e11, triangles=3.48e10)   # twitter
    assert 9.0 < c.speedup < 10.0
    c = cca_cost_model(wedges=1.226e13, triangles=9.65e12)   # wdc
    assert 3.0 < c.speedup < 4.0


# --------------------------------------------------------------------------
# delta-segment incremental CSR maintenance (DESIGN.md §2.9): random mixed
# op batches leave views that answer every query exactly like a rebuild
# --------------------------------------------------------------------------

_mixed_ops = st.lists(
    st.one_of(
        st.tuples(st.just("add_edge"), st.integers(0, 59),
                  st.integers(0, 59), st.floats(0.1, 5.0)),
        st.tuples(st.just("del_edge"), st.integers(0, 400)),
        st.tuples(st.just("add_vertex"), st.integers(0, 59)),
        st.tuples(st.just("del_vertex"), st.integers(0, 59)),
        st.tuples(st.just("touch"), st.integers(0, 59)),
    ),
    min_size=1, max_size=12,
)

# one (backend, sweep) pairing per sweep keeps the jit-compile budget
# sane while still crossing both kernel backends with all three sweeps
_INCR_MATRIX = [("xla", "pull"), ("xla", "push"), ("pallas", "auto")]


@settings(max_examples=8, deadline=None)
@given(_mixed_ops, _mixed_ops)
def test_incremental_views_equal_rebuild_at_query_level(ops1, ops2):
    """Two random mixed batches committed through the tombstone/delta
    path, then every registered diffusive program on every
    backend x sweep pairing answers bitwise-identically on the
    incremental views and on a full with_csr() rebuild of the same
    graph (sum programs compact on entry — that *is* their contract)."""
    from repro.core import DiffusionSession, diffuse
    from repro.core.generators import make_graph_family
    from repro.core.programs import PROGRAMS

    src, dst, w, n = make_graph_family("erdos_renyi", 60, seed=21)
    sess = DiffusionSession.from_edges(src, dst, n, w, n_cells=2,
                                       edge_slack=1.0, node_slack=0.5)
    edge_list = list(zip(src.tolist(), dst.tolist()))
    dead: set = set()

    def commit_batch(ops):
        for op in ops:
            kind = op[0]
            if kind == "add_edge":
                _, u, v, x = op
                if u not in dead and v not in dead:
                    sess.add_edge(u, v, x)
            elif kind == "del_edge":
                u, v = edge_list[op[1] % len(edge_list)]
                if u not in dead and v not in dead:
                    sess.delete_edge(u, v)      # phantom dels are no-ops
            elif kind == "add_vertex":
                g = sess.add_vertex()
                if op[1] not in dead:
                    sess.add_edge(g, op[1], 1.0)
            elif kind == "del_vertex":
                if op[1] not in dead:
                    dead.add(op[1])
                    sess.delete_vertex(op[1])
            else:
                if op[1] not in dead:
                    sess.touch(op[1])
        sess.commit()

    commit_batch(ops1)
    commit_batch(ops2)

    matrix = [("sssp", {"source": 0}), ("bfs", {"source": 0}), ("cc", {}),
              ("ppr", {"source": 0, "eps": 1e-5}), ("pagerank", {}),
              ("widest", {"source": 0, "track_parents": True}),
              ("reach", {"sources": (0, 7)})]
    rebuilt = sess.sg.with_csr()
    for backend, sweep in _INCR_MATRIX:
        for name, kw in matrix:
            spec = PROGRAMS[name]
            prog = spec.factory(**kw)
            got, _ = diffuse(sess.sg, prog, backend=backend, sweep=sweep)
            want, _ = diffuse(rebuilt, prog, backend=backend, sweep=sweep)
            for k in got:
                a, b = np.asarray(got[k]), np.asarray(want[k])
                fin = np.isfinite(a) & np.isfinite(b)
                assert np.array_equal(np.isfinite(a), np.isfinite(b)), (
                    backend, sweep, name, k)
                assert np.array_equal(np.where(fin, a, 0),
                                      np.where(fin, b, 0)), (
                    backend, sweep, name, k)
