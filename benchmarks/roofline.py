"""Roofline analysis from the dry-run's compiled artifacts.

TPU v5e model: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute    = FLOPs / (chips x peak)
    memory     = HBM bytes / (chips x bw)
    collective = collective bytes / (chips x link bw)

FLOPs/bytes come from ``cost_analysis`` corrected for XLA's count-scan-
body-once behaviour via benchmarks.hlo_analysis (loop trip counts from the
HLO text); collective bytes from the same scan-aware pass (ring factors:
all-reduce 2x).  MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for LM
training cells; analytic per-edge/node counts for GNN/recsys.
"""

from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9 * 4            # ~4 links usable per chip on a 2D torus
CHIPS = 256                  # single-pod roofline

ART_DIR = os.path.abspath(
    os.environ.get(
        "REPRO_ART_DIR",
        os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun"),
    )
)


def model_flops(arch_id: str, shape_name: str, mode: str) -> float | None:
    """Analytic useful-FLOPs for the cell (global, per step)."""
    from repro.configs.registry import get_module, shapes_for

    mod = get_module(arch_id)
    shape = shapes_for(arch_id)[shape_name]
    if mod.FAMILY == "lm":
        cfg = mod.make_config()
        n_act = cfg.active_param_count()
        if mode == "train":
            tokens = shape.global_batch * shape.seq_len
            return 6.0 * n_act * tokens
        if mode == "prefill":
            tokens = shape.global_batch * shape.seq_len
            return 2.0 * n_act * tokens
        # decode: one token per sequence
        return 2.0 * n_act * shape.global_batch
    if mod.FAMILY == "recsys":
        cfg = mod.make_config()
        d = cfg.embed_dim
        mlp = 0
        dims = (cfg.n_user_fields * d + cfg.n_dense,) + cfg.tower_mlp
        for a, b in zip(dims[:-1], dims[1:]):
            mlp += a * b
        dims = (d + cfg.n_dense,) + cfg.tower_mlp
        for a, b in zip(dims[:-1], dims[1:]):
            mlp += a * b
        per_row = 2 * mlp
        factor = 3.0 if mode == "train" else 1.0
        if shape_name == "retrieval_cand":
            return 2.0 * shape.n_candidates * d + per_row
        return factor * per_row * shape.batch
    # gnn: rough per-edge message + per-node update cost from the config
    cfg = mod.make_config() if arch_id != "gatedgcn" else mod.make_config(
        d_in=max(shape.d_feat, 1), n_classes=max(shape.n_classes, 2))
    e = shape.n_edges * (shape.batch_graphs if shape.mode == "batched" else 1)
    n = shape.n_nodes * (shape.batch_graphs if shape.mode == "batched" else 1)
    if shape.mode == "sampled":
        from repro.models.sampler import block_shapes
        n, e = block_shapes(shape.batch_nodes, shape.fanout)
    L = cfg.n_layers
    c = getattr(cfg, "d_hidden", 128)
    if arch_id == "gatedgcn":
        per_layer = 2 * (3 * e * c * c + 2 * n * c * c)
    elif arch_id == "meshgraphnet":
        per_layer = 2 * (e * (3 * c) * c * 2 + n * (2 * c) * c * 2)
    elif arch_id == "mace":
        paths = 15
        per_layer = 2 * e * paths * 9 * c + 2 * n * (paths + 6) * 9 * c * c
    else:  # equiformer-v2
        from repro.models.gnn.equivariant import n_sph
        ns = n_sph(cfg.l_max)
        so2 = 2 * e * (2 * 7 * c) * (7 * c) / max(cfg.channel_groups, 1)
        rot = 2 * e * ns * 13 * c
        per_layer = so2 + 2 * rot
    return 3.0 * L * per_layer     # fwd+bwd


def load_cells(mesh_tag="16x16"):
    out = {}
    if not os.path.isdir(ART_DIR):
        return out
    for fn in os.listdir(ART_DIR):
        if not fn.endswith(f"__{mesh_tag}.json"):
            continue
        with open(os.path.join(ART_DIR, fn)) as f:
            j = json.load(f)
        out[(j["arch"], j["shape"])] = j
    return out


def roofline_row(j: dict, mode_hint: str | None = None) -> dict:
    hlo = j.get("hlo", {})
    cost = j.get("cost", {})
    ratio = hlo.get("scan_correction_ratio", 1.0)
    flops_dev = hlo.get("flops_corrected") or cost.get("flops", 0.0)
    # memory term: prefer the loop-aware post-fusion traffic estimate;
    # fall back to ratio-scaled XLA bytes (upper bound) for old artifacts
    bytes_dev = hlo.get("bytes_est") or hlo.get(
        "bytes_accessed_corrected"
    ) or cost.get("bytes_accessed", 0.0)
    coll_dev = hlo.get("collective_bytes_corrected", 0.0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(j["arch"], j["shape"],
                     mode_hint or _infer_mode(j["shape"]))
    useful_ratio = (
        (mf / CHIPS) / flops_dev if (mf and flops_dev) else None
    )
    step_time = max(terms.values())
    mfu = ((mf / CHIPS) / step_time / PEAK_FLOPS
           if (mf and step_time > 0) else None)
    return {
        "arch": j["arch"], "shape": j["shape"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "model_flops": mf, "hlo_flops_dev": flops_dev,
        "useful_flops_ratio": useful_ratio,
        "roofline_mfu": mfu,
        "temp_gb": j.get("memory", {}).get("temp_bytes", 0) / 1e9,
        "scan_corr": ratio,
    }


def _infer_mode(shape_name: str) -> str:
    if "train" in shape_name:
        return "train"
    if "prefill" in shape_name:
        return "prefill"
    if "decode" in shape_name or "500k" in shape_name:
        return "decode"
    return "train"


def table(mesh_tag="16x16"):
    cells = load_cells(mesh_tag)
    rows = [roofline_row(j) for j in cells.values()]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def main():
    rows = table()
    hdr = (f"{'arch':26s} {'shape':15s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'bound':>10s} {'MFU':>6s} {'tempGB':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        mfu = f"{r['roofline_mfu']*100:5.1f}%" if r["roofline_mfu"] else "  n/a"
        print(f"{r['arch']:26s} {r['shape']:15s} "
              f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {r['bottleneck']:>10s} "
              f"{mfu:>6s} {r['temp_gb']:7.1f}")


if __name__ == "__main__":
    main()
