"""Scaling benchmarks (DESIGN.md §2.10, BENCH_pr9.json).

Three benches over the graph-ingest pipeline at memory-bound scale:

- ``speedup``: partition+CSR build on scale_free n=100k, the vectorized
  path vs a faithful copy of the pre-PR reference (per-shard Python fill
  loops, per-dead-vertex placement loop, global-max edge padding, device
  ``with_csr()`` re-sort).  Asserts the >= 5x acceptance bar.
- ``bytes``: device edge-stream footprint vs the live-edge floor on the
  skewed families.  Asserts edge_stream <= 2x live-edge bytes — the old
  ``ep = max(cell_edges)`` padding blew this up with shard count.
- ``scale``: graph500 RMAT s14/s16/s18 end to end — generate ->
  partition -> ``with_csr()`` -> one sharded-engine SSSP — recording
  generate/partition wall time, us per live edge for the query, layout
  bytes (:meth:`ShardedGraph.layout_bytes`), and peak RSS.

``--quick`` (CI smoke) runs s14 only; the asserts run in both modes.
"""

from __future__ import annotations

import gc
import resource
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build, sssp
from repro.core.generators import graph500_rmat, make_graph_family
from repro.core.graph import ShardedGraph, from_edges
from repro.core.partition import partition


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _reference_partition(graph, n_shards: int) -> ShardedGraph:
    """The pre-PR build path, kept verbatim as the speedup baseline:
    Python loops over shards and dead vertices, edge capacity padded to
    the *maximum* cell degree, and both CSR views rebuilt on device."""
    n = graph.n_nodes
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.weight)
    eok = np.asarray(graph.edge_ok)
    nok = np.asarray(graph.node_ok)
    live = np.where(nok)[0]
    n_live = live.shape[0]
    q = -(-n_live // n_shards)
    n_per = max(q, -(-n // n_shards))
    owner = np.zeros(n, np.int32)
    local = np.zeros(n, np.int32)
    r = np.arange(n_live)
    owner[live] = (r // q).astype(np.int32)
    local[live] = (r % q).astype(np.int32)
    taken = np.zeros((n_shards, n_per), bool)
    taken[owner[live], local[live]] = True
    free_pos = np.argwhere(~taken)
    for k, v in enumerate(np.where(~nok)[0]):
        owner[v], local[v] = free_pos[k % len(free_pos)]
    e_src, e_dst, e_w = src[eok], dst[eok], w[eok]
    e_owner = owner[e_src]
    order = np.argsort(e_owner, kind="stable")
    e_src, e_dst, e_w, e_owner = (
        e_src[order], e_dst[order], e_w[order], e_owner[order])
    counts = np.bincount(e_owner, minlength=n_shards)
    slack_total = int(eok.shape[0] - eok.sum())
    ep = max(1, int(counts.max()) + -(-slack_total // n_shards))
    S = n_shards
    src_local = np.zeros((S, ep), np.int32)
    dst_shard = np.zeros((S, ep), np.int32)
    dst_local = np.zeros((S, ep), np.int32)
    dst_gid = np.zeros((S, ep), np.int32)
    weight = np.zeros((S, ep), np.float32)
    edge_ok = np.zeros((S, ep), bool)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    for s in range(S):
        lo, hi = offsets[s], offsets[s + 1]
        k = hi - lo
        src_local[s, :k] = local[e_src[lo:hi]]
        dst_shard[s, :k] = owner[e_dst[lo:hi]]
        dst_local[s, :k] = local[e_dst[lo:hi]]
        dst_gid[s, :k] = e_dst[lo:hi]
        weight[s, :k] = e_w[lo:hi]
        edge_ok[s, :k] = True
    node_ok = np.zeros((S, n_per), bool)
    gid = np.zeros((S, n_per), np.int32)
    node_ok[owner, local] = nok[:n]
    gid[owner, local] = np.arange(n, dtype=np.int32)
    deg = np.zeros((S, n_per), np.int32)
    deg[owner, local] = np.bincount(e_src, minlength=n)[:n]
    sg = ShardedGraph(
        src_local=jnp.asarray(src_local), dst_shard=jnp.asarray(dst_shard),
        dst_local=jnp.asarray(dst_local), dst_gid=jnp.asarray(dst_gid),
        weight=jnp.asarray(weight), edge_ok=jnp.asarray(edge_ok),
        node_ok=jnp.asarray(node_ok), gid=jnp.asarray(gid),
        out_degree=jnp.asarray(deg), n_shards=S, n_per_shard=n_per,
        n_nodes=n,
    ).with_csr()
    jax.block_until_ready(sg.csr_key)
    return sg


def bench_build_speedup(n_nodes: int = 100_000, n_cells: int = 8,
                        reps: int = 3):
    """scale_free n=100k: vectorized partition+CSR vs the pre-PR path."""
    src, dst, w, n = make_graph_family("scale_free", n_nodes, seed=0)
    g = from_edges(src, dst, n, w, edge_slack=0.1)
    # warm both paths (compile caches, allocator), then time each path's
    # reps back to back — interleaving lets the reference's much larger
    # device buffers pollute the allocator under the other path's timings
    _reference_partition(g, n_cells)
    partition(g, n_cells)
    ref_s = new_s = float("inf")
    # the vectorized path is cheap enough that a few extra reps are free
    # — early reps still pay allocator/page-fault warmup, so min-of-N
    # needs a larger N to converge on the steady-state cost
    gc.collect()
    for _ in range(max(reps, 5)):
        t0 = time.perf_counter()
        part = partition(g, n_cells)
        jax.block_until_ready(part.sg.csr_key)
        new_s = min(new_s, time.perf_counter() - t0)
    gc.collect()
    for _ in range(reps):
        t0 = time.perf_counter()
        ref_sg = _reference_partition(g, n_cells)
        ref_s = min(ref_s, time.perf_counter() - t0)
    speedup = ref_s / new_s
    ref_slots = ref_sg.n_shards * ref_sg.edges_per_shard
    new_slots = part.sg.n_shards * part.sg.edges_per_shard
    assert speedup >= 5.0, (
        f"partition+CSR speedup {speedup:.2f}x < 5x "
        f"(ref {ref_s:.3f}s, new {new_s:.3f}s)")
    return dict(bench="speedup", family="scale_free", n=n, edges=src.size,
                ref_s=ref_s, new_s=new_s, speedup=speedup,
                ref_edge_slots=int(ref_slots), new_edge_slots=int(new_slots))


def bench_capacity_bytes(n_nodes: int = 30_000, n_cells: int = 8):
    """Skewed families: padded edge stream vs the live-edge floor."""
    rows = []
    for fam in ("scale_free", "graph500"):
        src, dst, w, n = make_graph_family(fam, n_nodes, seed=1)
        part = build(src, dst, n, w, n_cells=n_cells)
        b = part.sg.layout_bytes()
        ratio = b["edge_stream"] / max(1, b["live_edge_bytes"])
        assert ratio <= 2.0, (fam, ratio, b)
        rows.append(dict(bench="bytes", family=fam, n=n,
                         live_edges=b["live_edges"],
                         edge_stream_mb=b["edge_stream"] / 2**20,
                         live_edge_mb=b["live_edge_bytes"] / 2**20,
                         total_mb=b["total"] / 2**20, ratio=ratio))
    return rows


def bench_rmat_scale(scales=(14, 16, 18), n_cells: int = 8,
                     budget_s: float = 120.0):
    """graph500 RMAT end to end: generate -> partition -> with_csr ->
    one sharded SSSP; us/live-edge and layout bytes per scale."""
    rows = []
    for s in scales:
        t0 = time.perf_counter()
        src, dst = graph500_rmat(s, seed=0)
        n = 1 << s
        rng = np.random.default_rng(1)
        w = (1.0 + 7.0 * rng.random(src.shape[0])).astype(np.float32)
        gen_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        part = build(src, dst, n, w, n_cells=n_cells)
        sg = part.sg.with_csr()       # clean no-op: views already built
        jax.block_until_ready(sg.csr_key)
        part_s = time.perf_counter() - t0
        live = int(np.asarray(sg.edge_ok).sum())
        t0 = time.perf_counter()
        res = sssp(part, source=0)
        jax.block_until_ready(res.values)
        query_s = time.perf_counter() - t0
        total_s = gen_s + part_s + query_s
        b = sg.layout_bytes()
        rows.append(dict(
            bench="scale", scale=s, n=n, edges=int(src.size),
            live_edges=live, gen_s=gen_s, part_s=part_s, query_s=query_s,
            total_s=total_s, us_per_edge=query_s * 1e6 / max(1, live),
            layout_mb=b["total"] / 2**20, rss_mb=_rss_mb(),
            within_budget=total_s <= budget_s,
        ))
    return rows


def run(quick: bool = False):
    rows = [bench_build_speedup(reps=2 if quick else 3)]
    rows += bench_capacity_bytes(n_nodes=10_000 if quick else 30_000)
    rows += bench_rmat_scale(scales=(14,) if quick else (14, 16, 18))
    return rows


def main():
    rows = run()
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    main()
