"""§V.E recommendations, measured: how scheduling depth (the engine's
``max_local_iters`` — HPX's predicate-aware scheduling) and partition
locality change dynamic work (Actions Normalized) and rounds."""

from __future__ import annotations

from repro.core import build, sssp
from repro.core.generators import make_graph_family


def run(n_nodes: int = 1500, seed: int = 0):
    rows = []
    src, dst, w, n = make_graph_family("scale_free", n_nodes, seed=seed)
    e = len(src)
    for strategy in ("hash", "block", "locality"):
        for mli in (1, 4, 16, 64):
            part = build(src, dst, n, w, n_cells=8, strategy=strategy)
            res = sssp(part, 0, max_local_iters=mli)
            st = res.stats
            rows.append(dict(
                strategy=strategy, max_local_iters=mli, delta=None,
                actions_norm=float(st.actions) / e,
                rounds=int(st.rounds),
                operons=int(st.operons_sent),
                remote_frac=float(st.remote_actions)
                / max(float(st.actions), 1),
            ))
    # beyond-paper: delta-stepping priority gate (near-ideal actions)
    from repro.core.diffuse import diffuse as _diffuse
    from repro.core.programs import sssp_program as _sssp
    part = build(src, dst, n, w, n_cells=8, strategy="locality")
    for delta in (1.0, 2.0, 4.0):
        _, st = _diffuse(part, _sssp(0), delta=delta)
        rows.append(dict(
            strategy="locality", max_local_iters=64, delta=delta,
            actions_norm=float(st.actions) / e,
            rounds=int(st.rounds),
            operons=int(st.operons_sent),
            remote_frac=float(st.remote_actions)
            / max(float(st.actions), 1),
        ))
    return rows


def main():
    rows = run()
    print(f"{'strategy':10s} {'mli':>4s} {'act/E':>8s} {'rounds':>6s} "
          f"{'operons':>8s} {'remote%':>8s}")
    for r in rows:
        print(f"{r['strategy']:10s} {r['max_local_iters']:4d} "
              f"{r['actions_norm']:8.2f} {r['rounds']:6d} "
              f"{r['operons']:8d} {r['remote_frac']*100:7.1f}%")
    return rows


if __name__ == "__main__":
    main()
