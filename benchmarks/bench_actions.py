"""§V.E recommendations, measured: how scheduling depth (the engine's
``max_local_iters`` — HPX's predicate-aware scheduling) and partition
locality change dynamic work (Actions Normalized) and rounds.  Plus two
microbenchmarks: batched UpdateBatch apply vs the per-edge primitive loop
(DESIGN.md §2.4), and the xla-vs-pallas edge-relaxation sweep over the
blocked-CSR stream (DESIGN.md §2.6)."""

from __future__ import annotations

import time

from repro.core import NameServer, UpdateBatch, build, sssp
from repro.core.dynamic import edge_add, edge_delete
from repro.core.generators import make_graph_family


def run(n_nodes: int = 1500, seed: int = 0):
    rows = []
    src, dst, w, n = make_graph_family("scale_free", n_nodes, seed=seed)
    e = len(src)
    for strategy in ("hash", "block", "locality"):
        for mli in (1, 4, 16, 64):
            part = build(src, dst, n, w, n_cells=8, strategy=strategy)
            res = sssp(part, 0, max_local_iters=mli)
            st = res.stats
            rows.append(dict(
                strategy=strategy, max_local_iters=mli, delta=None,
                actions_norm=float(st.actions) / e,
                rounds=int(st.rounds),
                operons=int(st.operons_sent),
                remote_frac=float(st.remote_actions)
                / max(float(st.actions), 1),
            ))
    # beyond-paper: delta-stepping priority gate (near-ideal actions)
    from repro.core.diffuse import diffuse as _diffuse
    from repro.core.programs import sssp_program as _sssp
    part = build(src, dst, n, w, n_cells=8, strategy="locality")
    for delta in (1.0, 2.0, 4.0):
        _, st = _diffuse(part, _sssp(0), delta=delta)
        rows.append(dict(
            strategy="locality", max_local_iters=64, delta=delta,
            actions_norm=float(st.actions) / e,
            rounds=int(st.rounds),
            operons=int(st.operons_sent),
            remote_frac=float(st.remote_actions)
            / max(float(st.actions), 1),
        ))
    return rows


def bench_updates(n_nodes: int = 1500, n_updates: int = 256, seed: int = 0,
                  repeats: int = 3):
    """Batched vs sequential graph mutation: ``n_updates`` edge updates
    (half inserts, half deletes) applied as one UpdateBatch vs a per-edge
    primitive loop.  Returns the timing row (seconds, best of repeats)."""
    import numpy as np

    src, dst, w, n = make_graph_family("scale_free", n_nodes, seed=seed)
    rng = np.random.default_rng(seed)
    live = sorted({(int(a), int(b)) for a, b in zip(src, dst)})
    k = n_updates // 2
    deletes = [live[i] for i in rng.choice(len(live), k, replace=False)]
    inserts = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
                float(1 + rng.random())) for _ in range(k)]

    def fresh():
        part = build(src, dst, n, w, n_cells=8, edge_slack=0.5,
                     node_slack=0.1)
        return part, NameServer(part)

    def run_sequential():
        part, ns = fresh()
        sg = part.sg
        for u, v in deletes:
            sg = edge_delete(sg, ns, u, v)
        for u, v, x in inserts:
            sg = edge_add(sg, ns, u, v, x)
        sg.edge_ok.block_until_ready()
        return sg

    def run_batched():
        part, ns = fresh()
        batch = UpdateBatch(ns)
        for u, v in deletes:
            batch.delete_edge(u, v)
        for u, v, x in inserts:
            batch.add_edge(u, v, x)
        sg, _ = batch.apply(part.sg)
        sg.edge_ok.block_until_ready()
        return sg

    def best_of(fn):
        fn()                               # warm the jit/dispatch caches
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_seq = best_of(run_sequential)
    t_bat = best_of(run_batched)

    sg_a, sg_b = run_sequential(), run_batched()
    m = np.asarray(sg_a.edge_ok)
    assert np.array_equal(np.asarray(sg_b.edge_ok), m)
    assert np.array_equal(np.asarray(sg_a.weight)[m],
                          np.asarray(sg_b.weight)[m])

    return dict(n_updates=n_updates, sequential_s=t_seq, batched_s=t_bat,
                speedup=t_seq / t_bat)


def bench_edge_relax(edge_sizes=(1_000, 4_000, 16_000), n_cells: int = 4,
                     seed: int = 0, repeats: int = 5):
    """xla-vs-pallas edge sweep: one relaxation step (gather -> emit ->
    segment-combine over the destination-sorted blocked-CSR stream) per
    backend, across edge-stream sizes and both monoid classes — sssp (min:
    xla takes the flat segment path) and pagerank (sum: xla takes the
    blocked path, so the block_e flop overhead of bitwise parity is
    visible here).  The pallas numbers on CPU measure *interpret mode*
    (the CI path) — on TPU the same kernel compiles; the bench exists so
    the perf trajectory of both paths accumulates per PR.

    Returns one row per (prog, edges, backend): us_per_call + us/kedge.
    """
    import jax
    import numpy as np

    from repro.core.diffuse import _sg_as_dict
    from repro.core.programs import pagerank_program, sssp_program
    from repro.core.relax import make_relax

    progs = [("sssp", sssp_program(0)), ("pagerank", pagerank_program())]
    rows = []
    for e_target in edge_sizes:
        n = max(64, e_target // 8)
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, e_target).astype(np.int32)
        dst = rng.integers(0, n, e_target).astype(np.int32)
        w = (1 + rng.random(e_target)).astype(np.float32)
        part = build(src, dst, n, w, n_cells=n_cells)
        sg = part.sg
        sgd = _sg_as_dict(sg)
        for prog_name, prog in progs:
            vstate, active = prog.init(sg)
            for backend in ("xla", "pallas"):
                relax = make_relax(prog, sg.n_shards, sg.n_per_shard,
                                   sg.csr_block, backend)
                step = jax.jit(jax.vmap(relax))
                jax.block_until_ready(step(vstate, active, sgd))   # warm
                ts = []
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    jax.block_until_ready(step(vstate, active, sgd))
                    ts.append(time.perf_counter() - t0)
                sec = min(ts)
                rows.append(dict(
                    bench="edge_relax", prog=prog_name, backend=backend,
                    edges=int(e_target), n_cells=n_cells,
                    us_per_call=sec * 1e6,
                    us_per_kedge=sec * 1e9 / e_target,
                ))
    return rows


def main():
    rows = run()
    print(f"{'strategy':10s} {'mli':>4s} {'act/E':>8s} {'rounds':>6s} "
          f"{'operons':>8s} {'remote%':>8s}")
    for r in rows:
        print(f"{r['strategy']:10s} {r['max_local_iters']:4d} "
              f"{r['actions_norm']:8.2f} {r['rounds']:6d} "
              f"{r['operons']:8d} {r['remote_frac']*100:7.1f}%")
    u = bench_updates()
    print(f"\nupdate path ({u['n_updates']} edge updates): "
          f"sequential {u['sequential_s']*1e3:8.1f} ms   "
          f"batched {u['batched_s']*1e3:8.1f} ms   "
          f"speedup {u['speedup']:6.1f}x")
    rows.append(u)
    print(f"\n{'prog':>9s} {'edges':>8s} {'backend':>8s} "
          f"{'us/call':>10s} {'us/kedge':>9s}")
    for r in bench_edge_relax():
        print(f"{r['prog']:>9s} {r['edges']:8d} {r['backend']:>8s} "
              f"{r['us_per_call']:10.1f} {r['us_per_kedge']:9.2f}")
        rows.append(r)
    return rows


if __name__ == "__main__":
    main()
