"""Skew benchmarks — rhizome hub splitting (DESIGN.md §2.12, BENCH_pr9.json).

Two benches over the replica-vertex path:

- ``telemetry``: layout skew per family, replicas off vs on — max
  out-degree, split-hub count, per-cell edge capacity ``ep`` and the
  cell edge-load max/mean ratio.  Asserts that on the skewed families
  (scale_free, powerlaw_cluster) splitting reduces both ``ep`` and the
  load ratio, and that the uniform family (erdos_renyi) splits nothing
  and keeps ``ep`` within 5%.
- ``sweep``: end-to-end SSSP + PageRank wall time, replicas off vs on,
  warm min-of-reps with ``refresh=True`` so every rep runs the full
  diffusion.  Asserts value parity off-vs-on in both modes (SSSP
  bitwise, PageRank allclose); in full mode additionally asserts the
  >= 1.5x acceptance bar for both SSSP and PageRank on at least one
  skewed family/cell-count combination and no >5% regression on the
  uniform family.

``--quick`` (CI smoke) shrinks to n=20k / S=16 / 1 rep with an explicit
degree cutoff (the auto policy needs full-size hubs to trip); the
parity and telemetry asserts run in both modes, the speedup bar only at
full size.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.core.generators import make_graph_family
from repro.core.session import DiffusionSession

SKEWED = ("scale_free", "powerlaw_cluster")
UNIFORM = ("erdos_renyi",)


def _layout_row(family: str, n: int, n_cells: int, thr, seed: int = 0):
    src, dst, w, n = make_graph_family(family, n, seed=seed)
    row = dict(bench="telemetry", family=family, n=n, cells=n_cells,
               max_degree=int(np.bincount(src, minlength=n).max()))
    sessions = {}
    for tag, t in (("off", None), ("on", thr)):
        sess = DiffusionSession.from_edges(
            src, dst, n, w, n_cells=n_cells, replica_threshold=t)
        sg = sess.part.sg
        loads = np.asarray(sg.edge_ok).sum(axis=1)
        row[f"ep_{tag}"] = int(sg.edges_per_shard)
        row[f"load_ratio_{tag}"] = float(loads.max() / max(1.0, loads.mean()))
        if tag == "on":
            rep = sess.part.replica
            row["replica_groups"] = (0 if rep is None
                                     else int(rep.hub_gid.shape[0]))
            row["replica_slots"] = (0 if rep is None
                                    else int(rep.n_members.sum()))
            # flat graphs fall back to the unsplit layout (partition.py):
            # identical placement means any on-vs-off timing gap below is
            # measurement noise, not a cost of the replica machinery
            row["identical"] = bool(
                row["ep_on"] == row["ep_off"]
                and np.array_equal(np.asarray(sessions["off"].part.owner),
                                   np.asarray(sess.part.owner)))
        sessions[tag] = sess
    if family in SKEWED:
        # the skew-aware layout must shrink both the padded edge capacity
        # and the max/mean cell edge-load imbalance (whether the win
        # comes from strided dealing alone — small S, where per-cell
        # capacity dwarfs any degree — or from actual hub splits)
        assert row["ep_on"] < row["ep_off"], row
        assert row["load_ratio_on"] < row["load_ratio_off"], row
    else:
        # uniform degrees: nothing crosses the auto threshold and the
        # layout must not pay for the machinery it does not use — the
        # fallback keeps the placement bitwise-identical to off
        assert row["replica_groups"] == 0, row
        assert row["identical"], row
    return row, sessions


def _time_query(sess: DiffusionSession, prog: str, reps: int, **kw):
    res = sess.query(prog, refresh=True, **kw)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = sess.query(prog, refresh=True, **kw)
        jax.block_until_ready(res.values)
        best = min(best, time.perf_counter() - t0)
    return best, np.asarray(res.values)


def _sweep_rows(family: str, sessions: dict, n: int, n_cells: int,
                reps: int):
    rows = []
    n_real = sessions["off"].part.n_real
    for prog, kw in (("sssp", dict(source=0)), ("pagerank", {})):
        t_off, v_off = _time_query(sessions["off"], prog, reps, **kw)
        t_on, v_on = _time_query(sessions["on"], prog, reps, **kw)
        v_off, v_on = v_off[:n_real], v_on[:n_real]
        # the replica merge is a pure layout change: min-combine programs
        # are bitwise, pagerank's float32 sum-combine reassociates across
        # members and rounds (observed ~1e-6 abs drift at n=20k)
        if prog == "sssp":
            assert np.array_equal(v_off, v_on), (family, prog)
        else:
            assert np.allclose(v_off, v_on, rtol=1e-4, atol=1e-5), (
                family, prog, float(np.abs(v_off - v_on).max()))
        rows.append(dict(bench="sweep", family=family, n=n, cells=n_cells,
                         prog=prog, off_s=t_off, on_s=t_on,
                         speedup=t_off / t_on))
    return rows


def run(quick: bool = False):
    n = 20_000 if quick else 100_000
    # SSSP peaks at S=64 (fewer, fuller cells amortize per-round cost);
    # PageRank's longer sweeps only clear the bar at S=32 where the edge
    # term dominates the S^2 exchange buffers — record both at full size
    cells = (16,) if quick else (32, 64)
    # the "auto" policy keys off per-cell edge load and does not trip on
    # quick-size graphs (max degree ~400 at n=20k), so CI pins an
    # explicit cutoff that splits the few largest hubs
    thr = 200 if quick else "auto"
    reps = 1 if quick else 2
    rows = []
    sweep_rows = []
    for family in SKEWED + UNIFORM:
        for n_cells in cells:
            if family in UNIFORM and n_cells != cells[-1]:
                continue       # flat degrees: one cell count suffices
            row, sessions = _layout_row(family, n, n_cells, thr)
            rows.append(row)
            sweep_rows += _sweep_rows(family, sessions, n, n_cells, reps)
            del sessions
    # the replica machinery itself (not just the strided cut) must be
    # exercised somewhere in the matrix: auto trips at the larger cell
    # counts, the quick cutoff splits the n=20k hubs directly
    assert any(r["replica_groups"] > 0 for r in rows
               if r["family"] in SKEWED), rows
    rows += sweep_rows
    if not quick:
        for prog in ("sssp", "pagerank"):
            best = max(r["speedup"] for r in sweep_rows
                       if r["family"] in SKEWED and r["prog"] == prog)
            assert best >= 1.5, (
                f"skewed-family {prog} speedup {best:.2f}x < 1.5x bar")
        telem = {(r["family"], r["cells"]): r for r in rows
                 if r["bench"] == "telemetry"}
        for r in sweep_rows:
            if r["family"] in UNIFORM:
                # identical layouts make the timing comparison pure
                # noise; the assert only bites if the fallback broke
                assert (telem[(r["family"], r["cells"])]["identical"]
                        or r["speedup"] >= 0.95), (
                    f"uniform-family regression: {r}")
    return rows


def main():
    import sys
    rows = run(quick="--quick" in sys.argv)
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    main()
