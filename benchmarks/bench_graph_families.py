"""Paper Table II: degree-distribution + clustering-coefficient
characterization of the five experiment graph families."""

from __future__ import annotations

import numpy as np

from repro.core.generators import (
    clustering_coefficients,
    degree_distribution,
    make_graph_family,
)

FAMILIES = ["erdos_renyi", "small_world", "scale_free", "powerlaw_cluster",
            "graph500"]


def run(n_nodes: int = 1000, seed: int = 0):
    rows = []
    for fam in FAMILIES:
        src, dst, w, n = make_graph_family(fam, n_nodes, seed=seed)
        deg = degree_distribution(src, n)
        cc = clustering_coefficients(src, dst, n)
        rows.append(dict(
            family=fam, n=n, edges=len(src),
            deg_mean=float(deg.mean()), deg_max=int(deg.max()),
            deg_p99=float(np.percentile(deg, 99)),
            cc_mean=float(cc.mean()), cc_max=float(cc.max()),
        ))
    return rows


def main():
    rows = run()
    print(f"{'family':18s} {'n':>7s} {'edges':>8s} {'deg_mean':>9s} "
          f"{'deg_max':>8s} {'deg_p99':>8s} {'cc_mean':>8s}")
    for r in rows:
        print(f"{r['family']:18s} {r['n']:7d} {r['edges']:8d} "
              f"{r['deg_mean']:9.2f} {r['deg_max']:8d} {r['deg_p99']:8.1f} "
              f"{r['cc_mean']:8.4f}")
    return rows


if __name__ == "__main__":
    main()
