"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call for the timed
benches; derived = the paper-comparable metric).
"""

from __future__ import annotations

import sys
import time


def _csv(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    quick = "--quick" in sys.argv

    # Figures 1-5: SSSP scaling (time + actions normalized per family)
    from benchmarks import bench_sssp_scaling
    t0 = time.perf_counter()
    rows = bench_sssp_scaling.run(n_nodes=600 if quick else 1500,
                                  quick=quick)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _csv(
            f"sssp/{r['family']}/{r['engine']}/c{r['cells']}",
            r["seconds"] * 1e6,
            f"actions_norm={r['actions_norm']:.2f};rounds={r['rounds']}",
        )

    # Table II: graph family characterization
    from benchmarks import bench_graph_families
    t0 = time.perf_counter()
    rows = bench_graph_families.run(n_nodes=400 if quick else 1000)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _csv(f"families/{r['family']}", us,
             f"deg_mean={r['deg_mean']:.2f};cc={r['cc_mean']:.4f}")

    # Table III / Figs 8-10: triangle counting + CCA hops model
    from benchmarks import bench_triangle
    t0 = time.perf_counter()
    rows = bench_triangle.run(n_nodes=400 if quick else 1200)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _csv(f"triangle/{r['dataset']}", us,
             f"speedup={r['speedup']:.2f}")

    # §V.E: scheduling-depth + locality ablation (Actions Normalized)
    from benchmarks import bench_actions
    t0 = time.perf_counter()
    rows = bench_actions.run(n_nodes=600 if quick else 1500)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        tag = (f"actions/{r['strategy']}/mli{r['max_local_iters']}"
               + (f"/delta{r['delta']}" if r.get('delta') else ""))
        _csv(
            tag, us,
            f"actions_norm={r['actions_norm']:.2f};rounds={r['rounds']}",
        )

    # Roofline table from any dry-run artifacts present
    from benchmarks import roofline
    rows = roofline.table()
    for r in rows:
        mfu = r["roofline_mfu"]
        _csv(
            f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"bottleneck={r['bottleneck']};"
            f"mfu={mfu*100:.1f}%" if mfu else
            f"bottleneck={r['bottleneck']};mfu=n/a",
        )


if __name__ == "__main__":
    main()
