"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call for the timed
benches; derived = the paper-comparable metric) and writes the same
records, plus the kernel-backend tag, to ``BENCH_pr9.json`` at the repo
root so the perf trajectory accumulates machine-readably across PRs.
"""

from __future__ import annotations

import json
import os
import sys
import time

# runnable as `python benchmarks/run.py` (CI smoke) and `-m benchmarks.run`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

_RECORDS: list[dict] = []


_MODE = "full"


def _csv(name, us, derived, backend: str | None = None):
    # backend is only meaningful for benches that exercise the relax
    # kernels; everything else records null rather than asserting "xla"
    print(f"{name},{us:.1f},{derived}")
    _RECORDS.append(
        dict(name=name, us_per_call=round(us, 3), derived=derived,
             backend=backend, mode=_MODE)
    )


def main() -> None:
    global _MODE
    quick = "--quick" in sys.argv
    # quick (CI smoke) records are tagged so they are never mistaken for
    # the full-size trajectory numbers when the JSON is diffed across PRs
    _MODE = "quick" if quick else "full"

    # Figures 1-5: SSSP scaling (time + actions normalized per family)
    from benchmarks import bench_sssp_scaling
    t0 = time.perf_counter()
    rows = bench_sssp_scaling.run(n_nodes=600 if quick else 1500,
                                  quick=quick)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _csv(
            f"sssp/{r['family']}/{r['engine']}/c{r['cells']}",
            r["seconds"] * 1e6,
            f"actions_norm={r['actions_norm']:.2f};rounds={r['rounds']}",
        )

    # Table II: graph family characterization
    from benchmarks import bench_graph_families
    t0 = time.perf_counter()
    rows = bench_graph_families.run(n_nodes=400 if quick else 1000)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _csv(f"families/{r['family']}", us,
             f"deg_mean={r['deg_mean']:.2f};cc={r['cc_mean']:.4f}")

    # Table III / Figs 8-10: triangle counting + CCA hops model
    from benchmarks import bench_triangle
    t0 = time.perf_counter()
    rows = bench_triangle.run(n_nodes=400 if quick else 1200)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _csv(f"triangle/{r['dataset']}", us,
             f"speedup={r['speedup']:.2f}")

    # §V.E: scheduling-depth + locality ablation (Actions Normalized)
    from benchmarks import bench_actions
    t0 = time.perf_counter()
    rows = bench_actions.run(n_nodes=600 if quick else 1500)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        tag = (f"actions/{r['strategy']}/mli{r['max_local_iters']}"
               + (f"/delta{r['delta']}" if r.get('delta') else ""))
        _csv(
            tag, us,
            f"actions_norm={r['actions_norm']:.2f};rounds={r['rounds']}",
        )

    # DESIGN.md §2.6: xla-vs-pallas relaxation sweep over the CSR stream
    sizes = (1_000, 4_000) if quick else (1_000, 4_000, 16_000)
    for r in bench_actions.bench_edge_relax(edge_sizes=sizes):
        _csv(
            f"edge_relax/{r['prog']}/{r['backend']}/e{r['edges']}",
            r["us_per_call"],
            f"us_per_kedge={r['us_per_kedge']:.2f};cells={r['n_cells']}",
            backend=r["backend"],
        )

    # DESIGN.md §2.7: multi-query lanes — B PPR sources batched into one
    # diffusion vs B sequential single-source queries
    from benchmarks import bench_lanes
    for r in bench_lanes.run(quick=quick):
        _csv(
            f"lanes/{r['prog']}/b{r['batch']}",
            r["batched_cold_s"] * 1e6,
            f"speedup_cold={r['speedup_cold']:.2f};"
            f"speedup_warm={r['speedup_warm']:.2f}",
            backend="xla",
        )

    # DESIGN.md §2.8: direction-optimizing sweeps — commit()-repair cost,
    # per-round sweep cost vs frontier density, and delta-SSSP tails,
    # push vs pull vs the auto selector on the same graph
    from benchmarks import bench_frontier
    for r in bench_frontier.run(quick=quick):
        if r["bench"] == "density":
            _csv(
                f"frontier/density{r['density']:g}",
                r["push_us"],
                f"speedup_vs_pull={r['speedup_vs_pull']:.2f};"
                f"frontier={r['frontier']}",
                backend="xla",
            )
        else:
            _csv(
                f"frontier/{r['bench']}/{r['sweep']}",
                r["seconds"] * 1e6,
                f"speedup_vs_pull={r['speedup_vs_pull']:.2f}",
                backend="xla",
            )

    # DESIGN.md §2.9: O(batch) commits — incremental tombstone/delta apply
    # vs the eager with_csr rebuild, end-to-end update->repair->query, and
    # the reader-side cost of a staged delta segment
    from benchmarks import bench_commit
    for r in bench_commit.run(quick=quick):
        if r["bench"] == "apply":
            _csv(
                f"commit/apply/b{r['batch']}",
                r["inc_us"],
                f"speedup_vs_eager={r['speedup_vs_eager']:.2f};"
                f"eager_us={r['eager_us']:.0f}",
                backend="xla",
            )
        elif r["bench"] == "e2e":
            _csv(
                f"commit/e2e/u{r['n_updates']}",
                r["inc_s"] * 1e6,
                f"speedup_vs_eager={r['speedup_vs_eager']:.2f}",
                backend="xla",
            )
        else:
            _csv(
                f"commit/dirty_sweep/s{r['n_staged']}",
                r["dirty_s"] * 1e6,
                f"overhead={r['overhead']*100:.2f}%",
                backend="xla",
            )

    # DESIGN.md §2.10: scaled ingest — partition+CSR build speedup vs the
    # pre-PR path, skewed-family byte ratios, and graph500 RMAT
    # generate->partition->query end to end (both asserts live inside)
    from benchmarks import bench_scaling
    for r in bench_scaling.run(quick=quick):
        if r["bench"] == "speedup":
            _csv(
                f"scaling/build/{r['family']}/n{r['n']}",
                r["new_s"] * 1e6,
                f"speedup_vs_prepr={r['speedup']:.2f};"
                f"edge_slots={r['new_edge_slots']}",
            )
        elif r["bench"] == "bytes":
            _csv(
                f"scaling/bytes/{r['family']}",
                0.0,
                f"stream_vs_live={r['ratio']:.3f};"
                f"edge_stream_mb={r['edge_stream_mb']:.1f}",
            )
        else:
            _csv(
                f"scaling/rmat/s{r['scale']}",
                r["total_s"] * 1e6,
                f"us_per_edge={r['us_per_edge']:.3f};"
                f"part_s={r['part_s']:.2f};layout_mb={r['layout_mb']:.0f};"
                f"rss_mb={r['rss_mb']:.0f}",
                backend="xla",
            )

    # DESIGN.md §2.12: rhizome hub splitting — layout skew telemetry and
    # the end-to-end SSSP/PageRank sweep, replicas off vs on (parity,
    # ep-reduction, and full-size speedup asserts live inside)
    from benchmarks import bench_skew
    for r in bench_skew.run(quick=quick):
        if r["bench"] == "telemetry":
            _csv(
                f"skew/layout/{r['family']}/c{r['cells']}",
                0.0,
                f"ep_off={r['ep_off']};ep_on={r['ep_on']};"
                f"load_ratio_off={r['load_ratio_off']:.2f};"
                f"load_ratio_on={r['load_ratio_on']:.2f};"
                f"groups={r['replica_groups']}",
            )
        else:
            _csv(
                f"skew/{r['prog']}/{r['family']}/c{r['cells']}",
                r["on_s"] * 1e6,
                f"speedup_vs_off={r['speedup']:.2f};"
                f"off_s={r['off_s']:.2f}",
                backend="xla",
            )

    # DESIGN.md §2.13: durability — journal throughput per fsync policy,
    # snapshot latency, and open() recovery vs cold rebuild (the bitwise
    # recovery assert lives inside)
    from benchmarks import bench_recovery
    for r in bench_recovery.run(quick=quick):
        if r["bench"] == "journal":
            _csv(
                f"durability/journal/{r['fsync']}",
                r["seconds"] * 1e6 / r["records"],
                f"records_per_s={r['records_per_s']:.0f};"
                f"ops_per_s={r['ops_per_s']:.0f}",
            )
        elif r["bench"] == "snapshot":
            _csv(
                f"durability/snapshot/n{r['n']}",
                r["seconds"] * 1e6,
                f"mb={r['bytes']/1e6:.1f};mb_per_s={r['mb_per_s']:.0f}",
            )
        else:
            _csv(
                f"durability/recovery/k{r['journal_records']}",
                r["open_s"] * 1e6,
                f"speedup_vs_rebuild={r['speedup_vs_rebuild']:.2f};"
                f"cold_s={r['cold_rebuild_s']:.2f}",
            )

    # Roofline table from any dry-run artifacts present
    from benchmarks import roofline
    rows = roofline.table()
    for r in rows:
        mfu = r["roofline_mfu"]
        _csv(
            f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"bottleneck={r['bottleneck']};"
            f"mfu={mfu*100:.1f}%" if mfu else
            f"bottleneck={r['bottleneck']};mfu=n/a",
        )

    # quick (CI smoke) runs write a sibling file so they never clobber the
    # committed full-size trajectory records
    fname = "BENCH_pr9.quick.json" if quick else "BENCH_pr9.json"
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", fname)
    with open(os.path.abspath(out), "w") as f:
        json.dump(_RECORDS, f, indent=1)
    print(f"# wrote {len(_RECORDS)} records to {fname}", file=sys.stderr)


if __name__ == "__main__":
    main()
