"""Paper Figures 1-5: diffusive SSSP time-to-solution + Actions Normalized
vs compute-cell count, across the five graph families.

On this CPU container the cells are logical shards on one device, so
wall-clock measures engine overhead rather than real parallel speedup; the
scale-invariant metrics (rounds to quiescence, Actions Normalized, remote
operon fraction) are the paper-comparable outputs.  The event-driven engine
(one HPX-worker-equivalent) is run for the paper's LIFO-vs-FIFO scheduling
observation.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import build, sssp
from repro.core.event import build_adjacency, event_sssp
from repro.core.generators import make_graph_family

FAMILIES = ["erdos_renyi", "small_world", "scale_free", "powerlaw_cluster",
            "graph500"]
CELLS = [1, 2, 4, 8]


def run(n_nodes: int = 1500, seed: int = 0, quick: bool = False):
    rows = []
    fams = FAMILIES[:2] if quick else FAMILIES
    for fam in fams:
        src, dst, w, n = make_graph_family(fam, n_nodes, seed=seed)
        n_edges = len(src)
        # event engine (paper's HPX baseline behaviour) — on a smaller
        # graph: LIFO scheduling of weighted SSSP generates O(n^k) wasted
        # relaxations (the paper's own observation), so cap its size
        es, ed, ew, en = make_graph_family(fam, min(n_nodes, 400),
                                           seed=seed)
        for sched in ("lifo", "fifo"):
            t0 = time.perf_counter()
            _, st = event_sssp(build_adjacency(es, ed, ew, en), en, 0,
                               sched)
            dt = time.perf_counter() - t0
            rows.append(dict(
                family=fam, engine=f"event-{sched}", cells=1,
                seconds=dt, actions_norm=st.actions / len(es),
                rounds=0, remote_frac=0.0, acks=st.acks,
            ))
        for cells in CELLS:
            part = build(src, dst, n, w, n_cells=cells, strategy="locality")
            res = sssp(part, 0)        # compile + warm
            t0 = time.perf_counter()
            res = sssp(part, 0)
            dt = time.perf_counter() - t0
            st = res.stats
            rows.append(dict(
                family=fam, engine="diffusive", cells=cells,
                seconds=dt, actions_norm=float(st.actions) / n_edges,
                rounds=int(st.rounds),
                remote_frac=float(st.remote_actions)
                / max(float(st.actions), 1),
                acks=0,
            ))
    return rows


def main(quick=False):
    rows = run(quick=quick)
    print(f"{'family':18s} {'engine':12s} {'cells':>5s} {'ms':>9s} "
          f"{'act/E':>8s} {'rounds':>6s} {'remote%':>8s}")
    for r in rows:
        print(f"{r['family']:18s} {r['engine']:12s} {r['cells']:5d} "
              f"{r['seconds']*1e3:9.1f} {r['actions_norm']:8.2f} "
              f"{r['rounds']:6d} {r['remote_frac']*100:7.1f}%")
    return rows


if __name__ == "__main__":
    main()
