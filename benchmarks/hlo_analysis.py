"""Scan-aware analysis of compiled HLO text.

XLA's ``cost_analysis()`` counts a while-loop (lax.scan) body ONCE, so a
64-layer scanned transformer reports ~1/64th of its real FLOPs.  This module
re-derives compute and collective traffic from ``compiled.as_text()`` with
loop trip counts propagated through the call graph:

* computations are parsed into instruction lists;
* ``while`` instructions get a trip count extracted from the largest integer
  constant in their condition computation (jax lowers scan to a counted
  while; data-dependent loops — e.g. the diffusion engine — get trip=1 and
  are flagged ``dynamic_while``);
* dot FLOPs (2 * prod(result) * prod(contracting)) and collective bytes are
  accumulated recursively from ENTRY, weighting each called computation by
  its call-site multiplier.

The correction ratio (our flops / XLA's flops) is also applied to XLA's
``bytes accessed`` to estimate loop-corrected HBM traffic.
"""

from __future__ import annotations

import re
from typing import NamedTuple

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s+([a-z0-9\-]+)(.*)$"
)
_CALL_RE = re.compile(
    r"(to_apply|body|condition|calls|branch_computations)="
    r"(?:\{([^}]*)\}|%?([\w.\-]+))"
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class _Instr(NamedTuple):
    name: str
    type_str: str
    op: str
    rest: str


_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])")


def _parse_computations(text: str):
    comps: dict[str, list[_Instr]] = {}
    symtab: dict[str, dict[str, str]] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):
            m = _HEADER_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                symtab[cur] = {}
                # header parameters carry their types
                for pname, ptype in _PARAM_RE.findall(line):
                    symtab[cur][pname] = ptype
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        m = _INSTR_RE.match(line)
        if m and cur is not None:
            ins = _Instr(*m.groups())
            comps[cur].append(ins)
            symtab[cur][ins.name] = ins.type_str
    return comps, symtab, entry


def _paren_group(s: str) -> str | None:
    """Contents of the first balanced (...) group of ``s``."""
    s = s.strip()
    if not s.startswith("("):
        return None
    depth = 0
    for i, ch in enumerate(s):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return s[1:i]
    return None


def _split_args(arglist: str) -> list[str]:
    """Split an operand list on top-level commas (shapes contain commas)."""
    out, depth, cur = [], 0, []
    for ch in arglist:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def _operand_type(arg: str, syms: dict) -> str:
    """Type string of one operand: inline (newer HLO dumps annotate
    operands, e.g. ``dot(f32[4,16]{1,0} %a, ...)``) or via symbol table."""
    if _SHAPE_RE.search(arg):
        return arg
    name = arg.split()[-1].lstrip("%") if arg else ""
    return syms.get(name, "")


def _dot_flops(instr: _Instr, syms: dict) -> float:
    result = _shape_elems(instr.type_str)
    out = 1.0
    for d in result:
        out *= d
    group = _paren_group(instr.rest)
    contract = 1.0
    cdims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if group is not None and cdims_m:
        args = _split_args(group)
        lhs_dims = _shape_elems(_operand_type(args[0] if args else "", syms))
        for ci in cdims_m.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                contract *= lhs_dims[int(ci)]
    return 2.0 * out * contract


def _trip_count(cond_instrs) -> tuple[int, bool]:
    """Largest integer constant in the while condition; (1, True) if none
    (data-dependent loop)."""
    best = None
    for ins in cond_instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.rest)
            if m is None:
                m = re.search(r"\bconstant\((-?\d+)\)",
                              ins.op + ins.rest)
            if m:
                v = int(m.group(1))
                if best is None or v > best:
                    best = v
    if best is None or best <= 0:
        return 1, True
    return best, False


_BYTES_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "iota",
}


def _operand_bytes(ins: _Instr, syms: dict) -> int:
    group = _paren_group(ins.rest)
    if group is None:
        return 0
    total = 0
    for arg in _split_args(group):
        total += _shape_bytes(_operand_type(arg, syms))
    return total


def analyze_hlo(text: str) -> dict:
    comps, symtab, entry = _parse_computations(text)

    cache: dict[str, dict] = {}
    bcache: dict[str, float] = {}
    dynamic_whiles = []

    def total_bytes(name: str, stack=()) -> float:
        """Post-fusion HBM-traffic estimate: operand+result bytes of every
        top-level instruction (fusion internals excluded), while bodies
        multiplied by trip count."""
        if name in bcache:
            return bcache[name]
        if name in stack or name not in comps:
            return 0.0
        acc = 0.0
        syms = symtab[name]
        for ins in comps[name]:
            if ins.op == "while":
                calls = _CALL_RE.findall(ins.rest)
                trip = 1
                body = None
                for attr, grp, single in calls:
                    if attr == "condition" and single in comps:
                        trip, _ = _trip_count(comps[single])
                    if attr == "body":
                        body = single
                if body:
                    acc += total_bytes(body, stack + (name,)) * trip
                continue
            if ins.op in _BYTES_SKIP_OPS:
                continue
            if ins.op == "dynamic-update-slice":
                # in-place slice write: traffic = the update, not the stack
                group = _paren_group(ins.rest)
                upd = 0
                if group is not None:
                    ops = _split_args(group)
                    if len(ops) > 1:
                        upd = _shape_bytes(_operand_type(ops[1], syms))
                acc += 2 * upd
                continue
            if ins.op in ("dynamic-slice", "gather"):
                # traffic = the rows read, not the whole operand
                acc += 2 * _shape_bytes(ins.type_str)
                continue
            res = _shape_bytes(ins.type_str)
            opb = _operand_bytes(ins, syms)
            if ins.op == "fusion":
                # fusions that slice from big resident stacks (scanned
                # params) would otherwise count the whole stack per
                # iteration; cap operand traffic at 4x the result
                opb = min(opb, 4 * res)
            acc += res + opb
        bcache[name] = acc
        return acc

    def total(name: str, stack=()) -> dict:
        if name in cache:
            return cache[name]
        if name in stack or name not in comps:
            return {"flops": 0.0, "coll": {}, "dots": 0}
        acc = {"flops": 0.0,
               "coll": {k: {"count": 0.0, "bytes": 0.0}
                        for k in _COLLECTIVES},
               "dots": 0}
        for ins in comps[name]:
            if ins.op == "dot":
                acc["flops"] += _dot_flops(ins, symtab[name])
                acc["dots"] += 1
            for k in _COLLECTIVES:
                if ins.op == k or ins.op.startswith(k + "-"):
                    mult = 2.0 if k == "all-reduce" else 1.0
                    acc["coll"][k]["count"] += 1
                    acc["coll"][k]["bytes"] += _shape_bytes(
                        ins.type_str
                    ) * mult
            # recurse into called computations
            calls = _CALL_RE.findall(ins.rest)
            trip = 1
            if ins.op == "while":
                cond = next((c for t, grp, c in calls if t == "condition"),
                            None)
                if cond and cond in comps:
                    trip, dynamic = _trip_count(comps[cond])
                    if dynamic:
                        dynamic_whiles.append(ins.name)
            for attr, group, single in calls:
                names = (
                    [s.strip().lstrip("%") for s in group.split(",")]
                    if group else [single]
                )
                for cn in names:
                    if not cn or cn not in comps:
                        continue
                    sub = total(cn, stack + (name,))
                    f = trip if attr == "body" else 1
                    acc["flops"] += sub["flops"] * f
                    acc["dots"] += sub["dots"] * f
                    for k in _COLLECTIVES:
                        acc["coll"][k]["count"] += sub["coll"].get(
                            k, {}).get("count", 0) * f
                        acc["coll"][k]["bytes"] += sub["coll"].get(
                            k, {}).get("bytes", 0) * f
        cache[name] = acc
        return acc

    if entry is None:
        return {"flops": 0.0, "collectives": {}, "dynamic_whiles": 0,
                "bytes_est": 0.0}
    t = total(entry)
    return {
        "flops": t["flops"],
        "dots": t["dots"],
        "collectives": {
            k: {"count": v["count"], "bytes": v["bytes"]}
            for k, v in t["coll"].items()
        },
        "collective_bytes": sum(v["bytes"] for v in t["coll"].values()),
        "dynamic_whiles": len(dynamic_whiles),
        # loop-aware post-fusion HBM traffic estimate (operand+result bytes
        # of top-level ops; fusion internals excluded)
        "bytes_est": total_bytes(entry),
    }
